"""Dev script: reduced-config prefill + decode step for every decodable arch."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch, reduced, supports
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.launch.steps import make_serve_step, make_prefill_step

SHAPE = ShapeConfig("smoke-dec", seq_len=32, global_batch=2, kind="decode")

fails = []
for name in ARCHS:
    cfg0 = get_arch(name)
    ok, why = supports(cfg0, SHAPE)
    if cfg0.family == "lstm_am":
        print(f"SKIP {name}: {why}")
        continue
    try:
        cfg = reduced(cfg0)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        cache = model.init_cache(2, 32, jnp.bfloat16)
        serve = jax.jit(make_serve_step(model, cfg))
        toks = jnp.array([[1], [2]], jnp.int32)
        for i in range(3):
            toks, logits, cache = serve(params, cache, toks)
        assert jnp.all(jnp.isfinite(logits)), "non-finite logits"
        # prefill
        if cfg.encoder is None:
            pre = jax.jit(make_prefill_step(model, cfg))
            out = pre(params, {"tokens": jnp.zeros((2, 32), jnp.int32)})
            assert jnp.all(jnp.isfinite(out))
        print(f"OK   {name:24s} next={toks.ravel().tolist()}")
    except Exception as e:
        fails.append(name)
        import traceback
        print(f"FAIL {name}: {type(e).__name__}: {e}")
        traceback.print_exc(limit=8)

print("FAILS:", fails)
sys.exit(1 if fails else 0)
