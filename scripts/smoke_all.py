"""Dev script: reduced-config forward + train step for every arch on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch, reduced
from repro.models import build_model
from repro.models.api import input_specs
from repro.configs.base import ShapeConfig
from repro.launch.steps import make_train_step, init_opt_state

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")


def concrete(spec_tree, key):
    def mk(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.zeros(s.shape, s.dtype)
        return jnp.ones(s.shape, s.dtype) * 0.01
    return jax.tree_util.tree_map(mk, spec_tree)


fails = []
for name in ARCHS:
    try:
        cfg = reduced(get_arch(name))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        batch = concrete(input_specs(cfg, SMOKE_SHAPE), None)
        step = jax.jit(make_train_step(model, cfg, loss_kind="ce"))
        opt = init_opt_state(params)
        params2, opt2, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        assert jnp.isfinite(loss), f"loss not finite: {loss}"
        print(f"OK   {name:24s} loss={loss:.4f}")
    except Exception as e:
        fails.append(name)
        import traceback
        print(f"FAIL {name}: {type(e).__name__}: {e}")
        traceback.print_exc(limit=6)

print("FAILS:", fails)
sys.exit(1 if fails else 0)
