"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--suite S]``.

Suites:
  kernels  — Pallas kernel accounting + interpret-mode sanity timings
  roofline — §Roofline table from experiments/dryrun artifacts
  tables   — paper Tables 1/2/3/4 + Fig 1 reproductions (synthetic corpus)
  all      — everything above (default: kernels+roofline; tables behind
             --with-tables since the SSL pipeline takes ~10 min)
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="default",
                    choices=["default", "kernels", "roofline", "tables",
                             "all"])
    ap.add_argument("--out", default="experiments/benchmarks")
    ap.add_argument("--scale", default="tiny")
    args = ap.parse_args(argv)

    t0 = time.time()
    ran = []
    if args.suite in ("default", "kernels", "all"):
        from benchmarks import kernels_bench
        out = kernels_bench.run(args.out)
        print("== kernels ==")
        for k, v in out.items():
            print(f"  {k}: {json.dumps(v)}")
        ran.append("kernels")

    if args.suite in ("default", "roofline", "all"):
        from benchmarks import roofline
        try:
            rows, table = roofline.run(out_dir=args.out)
            print("== roofline (single-pod) ==")
            print(table)
            ran.append("roofline")
        except Exception as e:
            print(f"roofline skipped (run launch/dryrun first): {e}")

    if args.suite in ("tables", "all"):
        from benchmarks import tables
        out = tables.run(args.out, scale=args.scale)
        print("== paper tables ==")
        print(json.dumps(out, indent=1, default=float))
        ran.append("tables")

    print(f"\nbenchmarks done ({', '.join(ran)}) in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
