"""Kernel micro-benchmarks: interpret-mode correctness timings + the
roofline-relevant tile accounting (VMEM working set, arithmetic intensity).

Wall-clock on CPU interpret mode is NOT TPU perf; the value here is the
analytic table: bytes touched, FLOPs, and VMEM footprint per tile — the
numbers the BlockSpec choices are justified by (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _t(f, *a, n=3):
    f(*a)
    t0 = time.time()
    for _ in range(n):
        r = f(*a)
    jax.block_until_ready(r)
    return (time.time() - t0) / n


def run(out_dir: str = "experiments/benchmarks"):
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.default_rng(0)
    out = {}

    # ---- topk_logits: the paper's target-generation hot loop ----
    from repro.kernels import topk_logits, topk_logits_ref
    v, k, rows = 3183, 20, 256
    x = jnp.asarray(rng.normal(size=(rows, v)), jnp.float32)
    t_kern = _t(lambda a: topk_logits(a, k, interpret=True), x)
    t_ref = _t(lambda a: topk_logits_ref(a, k), x)
    out["topk_logits"] = {
        "shape": [rows, v], "k": k,
        "interpret_s": round(t_kern, 4), "ref_s": round(t_ref, 4),
        "bytes_in_per_row": v * 4, "bytes_out_per_row": k * 6,
        "compression_x": round(v * 4 / (k * 6), 1),
        "vmem_tile_bytes": 128 * 2048 * 4,
    }

    # ---- sparse_ce: fused lse+gather vs full-logit materialization ----
    from repro.kernels import sparse_ce_lse_gather, sparse_ce_lse_gather_ref
    t, d, v = 128, 512, 32768
    h = jnp.asarray(rng.normal(size=(t, d)), jnp.float32) * 0.1
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32) * 0.1
    idx = jnp.asarray(rng.integers(0, v, (t, 20)), jnp.int32)
    t_kern = _t(lambda *a: sparse_ce_lse_gather(*a, interpret=True),
                h, w, idx)
    t_ref = _t(sparse_ce_lse_gather_ref, h, w, idx)
    out["sparse_ce"] = {
        "shape": {"T": t, "D": d, "V": v},
        "interpret_s": round(t_kern, 4), "ref_s": round(t_ref, 4),
        "full_logit_bytes": t * v * 4,
        "fused_state_bytes": t * (2 + 20) * 4,
        "hbm_saving_x": round(v / 22, 1),
    }

    # ---- swa_attention: banded grid vs dense flash ----
    from repro.kernels import swa_attention, swa_attention_ref
    s, w_, hd = 1024, 256, 128
    q = jnp.asarray(rng.normal(size=(1, 2, s, hd)), jnp.float32) * 0.3
    kk = jnp.asarray(rng.normal(size=(1, 2, s, hd)), jnp.float32) * 0.3
    vv = jnp.asarray(rng.normal(size=(1, 2, s, hd)), jnp.float32)
    t_kern = _t(lambda *a: swa_attention(*a, interpret=True), q, kk, vv, w_)
    dense_flops = 4 * s * s * hd
    banded_flops = 4 * s * (w_ + 128) * hd
    out["swa_attention"] = {
        "S": s, "window": w_, "interpret_s": round(t_kern, 4),
        "dense_flops": dense_flops, "banded_flops": banded_flops,
        "flop_saving_x": round(dense_flops / banded_flops, 1),
        "long_500k_saving_x": round(524_288 / (4096 + 128), 1),
    }

    # ---- gtc_compress: fused pass vs 4-op unfused chain ----
    from repro.kernels import gtc_compress
    g = jnp.asarray(rng.normal(size=(1 << 20,)), jnp.float32) * 1e-3
    r = jnp.zeros((1 << 20,), jnp.float32)
    t_kern = _t(lambda *a: gtc_compress(*a, 1e-3, interpret=True), g, r)
    n = g.size
    out["gtc_compress"] = {
        "n": n, "interpret_s": round(t_kern, 4),
        "fused_hbm_bytes": 4 * n * 4,        # 2 reads + 2 writes
        "unfused_hbm_bytes": 10 * n * 4,     # acc/mask/send/resid round-trips
        "hbm_saving_x": 2.5,
    }

    with open(os.path.join(out_dir, "kernels.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out
