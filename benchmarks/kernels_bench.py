"""Kernel micro-benchmarks: interpret-mode correctness timings + the
roofline-relevant tile accounting (VMEM working set, arithmetic intensity).

Wall-clock on CPU interpret mode is NOT TPU perf; the value here is the
analytic table: bytes touched, FLOPs, and VMEM footprint per tile — the
numbers the BlockSpec choices are justified by (see EXPERIMENTS.md §Perf).
Every kernel section also carries an in-bench parity assert against its
oracle (the ``parity`` field is what tier2-kernels gates on) and, for
the decode-path kernels, ``pct_roofline`` = min(1, AI / machine balance)
— the fraction of HBM-bound peak the kernel's arithmetic intensity can
sustain on the reference part (TPU v5e).

  PYTHONPATH=src python benchmarks/kernels_bench.py

Writes experiments/benchmarks/kernels.json and mirrors it to the
repo-root BENCH_kernels.json (the tier2-kernels CI artifact).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.roofline import HBM_BW, PEAK_FLOPS
except ImportError:                       # run as a script from benchmarks/
    from roofline import HBM_BW, PEAK_FLOPS

MACHINE_BALANCE = PEAK_FLOPS / HBM_BW     # flops/byte at the roofline ridge


def _pct_roofline(flops: float, bytes_: float) -> float:
    """Fraction of peak a kernel of this arithmetic intensity can reach:
    memory-bound kernels sit at AI / machine-balance, compute-bound ones
    at the flat top."""
    return round(min(1.0, (flops / bytes_) / MACHINE_BALANCE), 4)


def _t(f, *a, n=3):
    f(*a)
    t0 = time.time()
    for _ in range(n):
        r = f(*a)
    jax.block_until_ready(r)
    return (time.time() - t0) / n


def run(out_dir: str = "experiments/benchmarks"):
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.default_rng(0)
    out = {}

    # ---- topk_logits: the paper's target-generation hot loop ----
    from repro.kernels import topk_logits, topk_logits_ref
    v, k, rows = 3183, 20, 256
    x = jnp.asarray(rng.normal(size=(rows, v)), jnp.float32)
    t_kern = _t(lambda a: topk_logits(a, k, interpret=True), x)
    t_ref = _t(lambda a: topk_logits_ref(a, k), x)
    out["topk_logits"] = {
        "shape": [rows, v], "k": k,
        "interpret_s": round(t_kern, 4), "ref_s": round(t_ref, 4),
        "bytes_in_per_row": v * 4, "bytes_out_per_row": k * 6,
        "compression_x": round(v * 4 / (k * 6), 1),
        "vmem_tile_bytes": 128 * 2048 * 4,
    }

    # ---- sparse_ce: fused lse+gather vs full-logit materialization ----
    from repro.kernels import sparse_ce_lse_gather, sparse_ce_lse_gather_ref
    t, d, v = 128, 512, 32768
    h = jnp.asarray(rng.normal(size=(t, d)), jnp.float32) * 0.1
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32) * 0.1
    idx = jnp.asarray(rng.integers(0, v, (t, 20)), jnp.int32)
    t_kern = _t(lambda *a: sparse_ce_lse_gather(*a, interpret=True),
                h, w, idx)
    t_ref = _t(sparse_ce_lse_gather_ref, h, w, idx)
    out["sparse_ce"] = {
        "shape": {"T": t, "D": d, "V": v},
        "interpret_s": round(t_kern, 4), "ref_s": round(t_ref, 4),
        "full_logit_bytes": t * v * 4,
        "fused_state_bytes": t * (2 + 20) * 4,
        "hbm_saving_x": round(v / 22, 1),
    }

    # ---- swa_attention: banded grid vs dense flash ----
    from repro.kernels import swa_attention, swa_attention_ref
    s, w_, hd = 1024, 256, 128
    q = jnp.asarray(rng.normal(size=(1, 2, s, hd)), jnp.float32) * 0.3
    kk = jnp.asarray(rng.normal(size=(1, 2, s, hd)), jnp.float32) * 0.3
    vv = jnp.asarray(rng.normal(size=(1, 2, s, hd)), jnp.float32)
    t_kern = _t(lambda *a: swa_attention(*a, interpret=True), q, kk, vv, w_)
    dense_flops = 4 * s * s * hd
    banded_flops = 4 * s * (w_ + 128) * hd
    out["swa_attention"] = {
        "S": s, "window": w_, "interpret_s": round(t_kern, 4),
        "dense_flops": dense_flops, "banded_flops": banded_flops,
        "flop_saving_x": round(dense_flops / banded_flops, 1),
        "long_500k_saving_x": round(524_288 / (4096 + 128), 1),
    }

    # ---- gtc_compress: fused pass vs 4-op unfused chain ----
    from repro.kernels import gtc_compress
    g = jnp.asarray(rng.normal(size=(1 << 20,)), jnp.float32) * 1e-3
    r = jnp.zeros((1 << 20,), jnp.float32)
    t_kern = _t(lambda *a: gtc_compress(*a, 1e-3, interpret=True), g, r)
    n = g.size
    out["gtc_compress"] = {
        "n": n, "interpret_s": round(t_kern, 4),
        "fused_hbm_bytes": 4 * n * 4,        # 2 reads + 2 writes
        "unfused_hbm_bytes": 10 * n * 4,     # acc/mask/send/resid round-trips
        "hbm_saving_x": 2.5,
    }

    # ---- decode_attention: fused RoPE + ring write + masked SDPA ----
    from repro.kernels import decode_attention, decode_attention_ref
    b, hq, hkv, s_, hd = 4, 4, 2, 64, 64
    g = hq // hkv
    q = jnp.asarray(rng.normal(size=(b, hq, 1, hd)), jnp.float32) * 0.3
    kn = jnp.asarray(rng.normal(size=(b, hkv, 1, hd)), jnp.float32) * 0.3
    vn = jnp.asarray(rng.normal(size=(b, hkv, 1, hd)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, hkv, s_, hd)), jnp.bfloat16)
    cv = jnp.asarray(rng.normal(size=(b, hkv, s_, hd)), jnp.bfloat16)
    pos = jnp.asarray(rng.integers(1, s_, (b,)), jnp.int32)
    kw = dict(rope_theta=10_000.0)
    o_k, nk_k, nv_k = decode_attention(q, kn, vn, ck, cv, pos,
                                       use_kernel=True, interpret=True,
                                       **kw)
    o_r, nk_r, nv_r = decode_attention_ref(q, kn, vn, ck, cv, pos, **kw)
    parity = (bool(jnp.allclose(o_k, o_r, atol=1e-5))
              and bool(jnp.array_equal(nk_k, nk_r))
              and bool(jnp.array_equal(nv_k, nv_r)))
    t_kern = _t(lambda *a: decode_attention(*a, use_kernel=True,
                                            interpret=True, **kw),
                q, kn, vn, ck, cv, pos)
    t_ref = _t(lambda *a: decode_attention_ref(*a, **kw),
               q, kn, vn, ck, cv, pos)
    kv_bytes = 2 * b * hkv * s_ * hd * 2                 # bf16 K+V caches
    flops = 4 * b * hq * s_ * hd                         # QK^T + PV
    fused_bytes = 2 * kv_bytes + (b * hq + 2 * b * hkv) * hd * 4 * 2
    # unfused XLA tail: row-update read+write of both caches, then the
    # attention re-reads them and materializes softmax scores twice
    unfused_bytes = 4 * kv_bytes + 2 * b * hq * s_ * 4 * 2 + fused_bytes
    out["decode_attention"] = {
        "shape": {"B": b, "Hq": hq, "Hkv": hkv, "S": s_, "hd": hd,
                  "groups": g},
        "interpret_s": round(t_kern, 4), "ref_s": round(t_ref, 4),
        "parity": parity,
        "flops": flops, "fused_hbm_bytes": fused_bytes,
        "unfused_hbm_bytes": unfused_bytes,
        "hbm_saving_x": round(unfused_bytes / fused_bytes, 2),
        "arith_intensity": round(flops / fused_bytes, 2),
        "pct_roofline": _pct_roofline(flops, fused_bytes),
        "vmem_tile_bytes": (s_ * 128 * 2 + 8 * 128) * 4 * 2,
    }

    # ---- topk_sample: fused top-k + truncated-nucleus Gumbel pick ----
    from repro.kernels import topk_sample, topk_sample_ref
    from repro.kernels.topk_sample import gumbel_rows
    rows, v, k_cap = 64, 4096, 32
    logits = jnp.asarray(rng.normal(size=(rows, v)), jnp.float32)
    temp = jnp.full((rows,), 0.8, jnp.float32)
    tk = jnp.full((rows,), 20, jnp.int32)
    tp = jnp.full((rows,), 0.95, jnp.float32)
    seeds = jnp.arange(rows, dtype=jnp.int32)
    pos_r = jnp.asarray(rng.integers(0, 63, (rows,)), jnp.int32)
    v_k, i_k, t_k = topk_sample(logits, temp, tk, tp, seeds, pos_r,
                                use_kernel=True, interpret=True)
    gum = gumbel_rows(seeds, pos_r, k_cap)
    v_r, i_r, t_r = topk_sample_ref(logits, temp, tk, tp, gum)
    parity = (bool(jnp.array_equal(v_k, v_r))
              and bool(jnp.array_equal(i_k, i_r))
              and bool(jnp.array_equal(t_k, t_r)))
    t_kern = _t(lambda *a: topk_sample(*a, use_kernel=True, interpret=True),
                logits, temp, tk, tp, seeds, pos_r)
    t_ref = _t(lambda l, s, p: topk_sample(l, temp, tk, tp, s, p,
                                           use_kernel=False),
               logits, seeds, pos_r)
    flops = rows * v * k_cap                  # k_cap max-extraction sweeps
    bytes_ = rows * v * 4 + rows * (k_cap * 8 + 4)
    out["topk_sample"] = {
        "shape": {"rows": rows, "V": v, "k_cap": k_cap},
        "interpret_s": round(t_kern, 4), "ref_s": round(t_ref, 4),
        "parity": parity,
        "flops": flops, "hbm_bytes": bytes_,
        "argsort_bytes": rows * v * (4 + 4 + 4) * 2,   # sorted vals+order
        "arith_intensity": round(flops / bytes_, 2),
        "pct_roofline": _pct_roofline(flops, bytes_),
    }

    # ---- sparse_ce distill route: chunked XLA loss vs kernel reroute ----
    from repro.core.distill import chunked_topk_distill_ce
    bt, st, d, v, kk2 = 2, 64, 512, 32768, 20
    h3 = jnp.asarray(rng.normal(size=(bt, st, d)), jnp.float32) * 0.1
    w2 = jnp.asarray(rng.normal(size=(d, v)), jnp.float32) * 0.1
    tv = jnp.asarray(rng.normal(size=(bt, st, kk2)), jnp.float32)
    ti = jnp.asarray(rng.integers(0, v, (bt, st, kk2)), jnp.int32)
    loss_x = chunked_topk_distill_ce(h3, w2, tv, ti, chunk=4096)
    loss_k = chunked_topk_distill_ce(h3, w2, tv, ti, use_kernel=True,
                                     interpret=True)
    parity = bool(jnp.allclose(loss_x, loss_k, atol=1e-5))
    t_kern = _t(lambda *a: chunked_topk_distill_ce(*a, use_kernel=True,
                                                   interpret=True),
                h3, w2, tv, ti)
    t_ref = _t(lambda *a: chunked_topk_distill_ce(*a, chunk=4096),
               h3, w2, tv, ti)
    t_ = bt * st
    flops = 2 * t_ * d * v
    fused_bytes = (t_ * d + d * v) * 4 + t_ * (kk2 * 8 + 4)
    out["sparse_ce_distill"] = {
        "shape": {"T": t_, "D": d, "V": v, "k": kk2},
        "interpret_s": round(t_kern, 4), "ref_s": round(t_ref, 4),
        "parity": parity,
        "loss_xla": float(loss_x), "loss_kernel": float(loss_k),
        "flops": flops, "fused_hbm_bytes": fused_bytes,
        "full_logit_bytes": fused_bytes + t_ * v * 4 * 2,
        "arith_intensity": round(flops / fused_bytes, 2),
        "pct_roofline": _pct_roofline(flops, fused_bytes),
    }

    with open(os.path.join(out_dir, "kernels.json"), "w") as f:
        json.dump(out, f, indent=1)
    # repo-root mirror: the tier2-kernels CI artifact
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_kernels.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    res = run()
    for name, rec in res.items():
        pr = rec.get("pct_roofline")
        tail = "" if pr is None else f"  {pr:.1%} of roofline"
        par = rec.get("parity")
        ptxt = "" if par is None else f"  parity={par}"
        print(f"{name:<18}{rec['interpret_s']:>9.4f}s interpret{ptxt}{tail}")
    bad = [n for n, r in res.items() if r.get("parity") is False]
    assert not bad, f"kernel parity failed: {bad}"
    print("wrote experiments/benchmarks/kernels.json + BENCH_kernels.json")
