"""Serving load generator: naive per-utterance loop vs the batched engine.

The paper's target-generation system is throughput-bound batch inference
(§3.2.2); this records the speedup of the engine's bucketed batching over
the naive utterance-at-a-time loop as a *number*, not a claim:

  PYTHONPATH=src python benchmarks/serve_bench.py
  PYTHONPATH=src python benchmarks/serve_bench.py --n-utts 128 --policy latency

Both paths run the same bidirectional teacher over the same synthetic
corpus and emit the same top-k logits.  The naive baseline is honest: one
XLA program (every utterance padded to the corpus max bucket), batch 1 —
its weakness is wasted padding frames and no cross-utterance batching,
which is exactly what the engine fixes.  Reported:

  frames/sec   — valid (unpadded) frames per wall-clock second
  p50/p95 ms   — per-utterance completion latency
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lstm_am_7khr import TEACHER
from repro.core.logit_store import topk_compress
from repro.data import FeatureConfig, SynthConfig
from repro.data.loader import CorpusLoader
from repro.models import build_model
from repro.serve import LATENCY, THROUGHPUT, StreamingEngine, bucket_length


def make_corpus(n_utts: int, n_mels: int = 16, seed: int = 0):
    loader = CorpusLoader(synth=SynthConfig(n_speakers=16, n_senones=49,
                                            mean_utt_sec=1.5, seed=seed),
                          feat=FeatureConfig(n_mels=n_mels))
    loader.estimate_mvn(8)
    return [f.astype(np.float32)
            for f, _, _ in loader.featurized(0, n_utts)]


def make_naive_fwd(model, k):
    """Built once and reused across warmup + measurement so both hit the
    same jit cache (a fresh closure per call would re-trace)."""

    @jax.jit
    def fwd(p, feats, lens):
        h, _ = model.apply(p, feats, lens=lens)
        return topk_compress(model.unembed(p, h), k)

    return fwd


def naive_loop(fwd, params, utts, max_bucket):
    """Per-utterance inference, one compile: pad every utterance to the
    corpus-wide bucket, batch 1."""
    # latency = completion since drain start (all requests "arrive" at
    # t0), the same semantics engine_run reports — columns stay comparable
    lat = []
    t0 = time.time()
    for u in utts:
        pad = np.zeros((1, max_bucket, u.shape[1]), np.float32)
        pad[0, :u.shape[0]] = u
        vals, idx = fwd(params, jnp.asarray(pad),
                        jnp.asarray([u.shape[0]], np.int32))
        jax.block_until_ready(idx)
        lat.append((time.time() - t0) * 1e3)
    return time.time() - t0, lat


def engine_run(cfg, params, utts, k, policy, *, warm: bool = True):
    eng = StreamingEngine(cfg, params, k=k, policy=policy)
    if warm:                    # compile every bucket shape once
        for u in utts:
            eng.submit(u)
        eng.run()
    rids = [eng.submit(u) for u in utts]
    t0 = time.time()
    done_at = {}

    def on_batch(fb):
        t = time.time()
        for r in fb.requests:
            done_at[r.rid] = t

    eng.run(on_batch=on_batch)
    wall = time.time() - t0
    lat = [(done_at[rid] - t0) * 1e3 for rid in rids if rid in done_at]
    return wall, lat


def pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-utts", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--policy", default="throughput",
                    choices=["throughput", "latency"])
    ap.add_argument("--out", default="experiments/benchmarks")
    args = ap.parse_args(argv)

    from repro.configs.base import LayerSpec, Segment
    utts = make_corpus(args.n_utts)
    feat_dim = utts[0].shape[1]
    cfg = TEACHER.replace(
        lstm_hidden=args.hidden, feat_dim=feat_dim, n_senones=49,
        vocab_size=49,
        segments=(Segment((LayerSpec(mixer="bilstm", ffn="none"),),
                          repeat=args.layers),))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = THROUGHPUT if args.policy == "throughput" else LATENCY

    frames = sum(u.shape[0] for u in utts)
    max_bucket = bucket_length(max(u.shape[0] for u in utts),
                               policy.bucket_multiple)
    print(f"corpus: {args.n_utts} utts, {frames} frames, "
          f"lens {min(u.shape[0] for u in utts)}.."
          f"{max(u.shape[0] for u in utts)} (bucket {max_bucket}); "
          f"teacher {args.layers}x{args.hidden} biLSTM, k={args.k}")

    # warm the naive path's single compile out of the measurement (same
    # fwd object as the measured run); the engine warms its bucket
    # shapes inside engine_run (serving steady state: cold-compile is a
    # one-time per-shape constant)
    naive_fwd = make_naive_fwd(model, args.k)
    naive_loop(naive_fwd, params, utts[:1], max_bucket)

    t_naive, lat_naive = naive_loop(naive_fwd, params, utts, max_bucket)
    t_eng, lat_eng = engine_run(cfg, params, utts, args.k, policy)

    fps_naive = frames / t_naive
    fps_eng = frames / t_eng
    rows = [
        ("naive loop (B=1)", t_naive, fps_naive, pct(lat_naive, 50),
         pct(lat_naive, 95)),
        (f"engine ({policy.name}, B={policy.max_batch})", t_eng, fps_eng,
         pct(lat_eng, 50), pct(lat_eng, 95)),
    ]
    print(f"{'path':<28}{'wall s':>8}{'frames/s':>10}{'p50 ms':>9}"
          f"{'p95 ms':>9}")
    for name, wall, fps, p50, p95 in rows:
        print(f"{name:<28}{wall:>8.2f}{fps:>10.0f}{p50:>9.1f}{p95:>9.1f}")
    speedup = fps_eng / fps_naive
    print(f"speedup: {speedup:.2f}x frames/sec")

    os.makedirs(args.out, exist_ok=True)
    rec = {"n_utts": args.n_utts, "frames": frames, "policy": policy.name,
           "fps_naive": fps_naive, "fps_engine": fps_eng,
           "speedup": speedup,
           "p50_ms": {"naive": pct(lat_naive, 50), "engine": pct(lat_eng, 50)},
           "p95_ms": {"naive": pct(lat_naive, 95), "engine": pct(lat_eng, 95)}}
    path = os.path.join(args.out, "serve_bench.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {path}")
    return rec


if __name__ == "__main__":
    main()
