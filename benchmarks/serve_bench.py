"""Serving load generator: AM batch inference, token-LM decode, and
slot-based streaming.

Measured sections, one JSON record (written to ``--out`` and mirrored
to repo-root ``BENCH_serve.json`` for the CI gates):

**AM** — naive per-utterance loop vs the batched engine.  The paper's
target-generation system is throughput-bound batch inference (§3.2.2);
this records the speedup of the engine's bucketed batching over the
naive utterance-at-a-time loop as a *number*, not a claim.  Both paths
run the same bidirectional teacher over the same synthetic corpus and
emit the same top-k logits; the naive baseline is honest (one XLA
program, batch 1).  Also reports ``padding_efficiency`` over exactly
the FormedBatches the engine ran (dead tail rows included).

**Decode** — the round-batched engine (equal-prompt-length generation
rounds, per-step host syncs) vs the continuous batcher (per-row cache
positions, mid-flight admit/retire, one host sync per window) on a
ragged-prompt workload.  Asserts continuous >= ``--assert-speedup`` x
round (the tier2-serve CI gate) and that both engines' outputs are
token-identical to sequential (one-request-at-a-time) decoding.

**Paged** — the block-table paged KV cache vs contiguous slots: token
parity on the ragged workload, peak KV bytes (pages actually in flight
vs the fixed ``slots x max_seq`` layout — asserted strictly below),
prefix-cache hit rate on a shared-prefix workload, and a prompt longer
than the contiguous ``max_seq`` served through the page pool.

**Stream** — the slot-based ``StreamServer`` (SLO tiers, one host sync
per window) vs the lockstep ``feed`` loop (one sync per chunk) on a
ragged attach/detach workload: long firehose streams saturating every
slot with short interactive streams arriving on top.  Gates bitwise
emission parity, >= ``--assert-stream`` x frames/s, and interactive-p99
< firehose-p50 under overload.

  PYTHONPATH=src python benchmarks/serve_bench.py
  PYTHONPATH=src python benchmarks/serve_bench.py --n-utts 128 --policy latency

Reported:

  frames/sec   — valid (unpadded) frames per wall-clock second (AM)
  tok/sec      — generated tokens per wall-clock second (decode)
  p50/p95 ms   — per-utterance completion latency
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lstm_am_7khr import TEACHER
from repro.core.logit_store import topk_compress
from repro.data import FeatureConfig, SynthConfig
from repro.data.loader import CorpusLoader
from repro.models import build_model
from repro.serve import (LATENCY, THROUGHPUT, StreamingEngine,
                         bucket_length, padding_efficiency)


def make_corpus(n_utts: int, n_mels: int = 16, seed: int = 0):
    loader = CorpusLoader(synth=SynthConfig(n_speakers=16, n_senones=49,
                                            mean_utt_sec=1.5, seed=seed),
                          feat=FeatureConfig(n_mels=n_mels))
    loader.estimate_mvn(8)
    return [f.astype(np.float32)
            for f, _, _ in loader.featurized(0, n_utts)]


def make_naive_fwd(model, k):
    """Built once and reused across warmup + measurement so both hit the
    same jit cache (a fresh closure per call would re-trace)."""

    @jax.jit
    def fwd(p, feats, lens):
        h, _ = model.apply(p, feats, lens=lens)
        return topk_compress(model.unembed(p, h), k)

    return fwd


def naive_loop(fwd, params, utts, max_bucket):
    """Per-utterance inference, one compile: pad every utterance to the
    corpus-wide bucket, batch 1."""
    # latency = completion since drain start (all requests "arrive" at
    # t0), the same semantics engine_run reports — columns stay comparable
    lat = []
    t0 = time.time()
    for u in utts:
        pad = np.zeros((1, max_bucket, u.shape[1]), np.float32)
        pad[0, :u.shape[0]] = u
        vals, idx = fwd(params, jnp.asarray(pad),
                        jnp.asarray([u.shape[0]], np.int32))
        jax.block_until_ready(idx)
        lat.append((time.time() - t0) * 1e3)
    return time.time() - t0, lat


def engine_run(cfg, params, utts, k, policy, *, warm: bool = True):
    eng = StreamingEngine(cfg, params, k=k, policy=policy)
    if warm:                    # compile every bucket shape once
        for u in utts:
            eng.submit(u)
        eng.run()
    rids = [eng.submit(u) for u in utts]
    t0 = time.time()
    done_at = {}
    batches = []

    def on_batch(fb):
        t = time.time()
        batches.append(fb)
        for r in fb.requests:
            done_at[r.rid] = t

    eng.run(on_batch=on_batch)
    wall = time.time() - t0
    lat = [(done_at[rid] - t0) * 1e3 for rid in rids if rid in done_at]
    # efficiency from the exact batches the engine ran: dead tail rows
    # count in padded_frames (FormedBatch accounting, pinned in tests)
    eff = padding_efficiency(batches)
    return wall, lat, eff


# --------------------------------------------------------------- decode

def make_decode_workload(vocab: int, n: int, *, ragged: bool, seed: int = 0):
    """(prompt, max_new) pairs.  Ragged draws mixed prompt lengths and
    budgets (the continuous batcher's home turf); lockstep uses one
    length and one budget (the round engine's best case)."""
    rng = np.random.default_rng(seed)
    if ragged:
        return [(rng.integers(1, vocab, int(rng.integers(3, 20))),
                 int(rng.integers(4, 24))) for _ in range(n)]
    return [(rng.integers(1, vocab, 8), 16) for _ in range(n)]


def decode_run(srv, workload, sampling=None):
    """Warm the server on a workload prefix (each engine's jit compiles
    once per server instance), reset its stats, then submit the whole
    workload and drain — steady-state wall/tokens/outputs.

    ``sampling``: optional request-index -> SamplingParams callable;
    None keeps every request greedy (and the kwarg off the submit call,
    which RoundTokenServer doesn't take)."""
    def sub(i, p, m):
        if sampling is None:
            return srv.submit(p, max_new=m)
        return srv.submit(p, max_new=m, sampling=sampling(i))
    for i, (p, m) in enumerate(workload[:2]):
        sub(i, p, m)
    srv.drain()
    for key in getattr(srv, "stats", {}):
        srv.stats[key] = 0
    rids = [sub(i, p, m) for i, (p, m) in enumerate(workload)]
    t0 = time.time()
    done = srv.drain()
    wall = time.time() - t0
    outs = [done[r].out for r in rids]
    return wall, sum(len(o) for o in outs), outs, getattr(srv, "stats", {})


def decode_bench(args) -> dict:
    from dataclasses import replace

    from repro.configs import get_arch, reduced
    from repro.serve import LATENCY, RoundTokenServer, TokenServer

    cfg = reduced(get_arch(args.decode_arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    pol = replace(LATENCY, max_batch=args.decode_slots,
                  sync_every=args.sync_every)
    max_seq = 64

    round_srv = RoundTokenServer(cfg, params, policy=pol, max_seq=max_seq)
    cont_srv = TokenServer(cfg, params, policy=pol, max_seq=max_seq)
    solo_srv = TokenServer(cfg, params, max_seq=max_seq,
                           policy=replace(pol, max_batch=1))

    # correctness gates first: lockstep parity + ragged vs sequential
    lock = make_decode_workload(cfg.vocab_size, args.decode_slots,
                                ragged=False, seed=1)
    _, _, out_r, _ = decode_run(round_srv, lock)
    _, _, out_c, _ = decode_run(cont_srv, lock)
    lockstep_equal = out_r == out_c

    work = make_decode_workload(cfg.vocab_size, args.decode_requests,
                                ragged=True, seed=2)
    wall_r, tok_r, out_r, _ = decode_run(round_srv, work)
    wall_c, tok_c, out_c, stats = decode_run(cont_srv, work)
    assert tok_r == tok_c, "engines emitted different token counts"
    seq_outs = []
    for p, m in work:                      # one server: one compile
        rid = solo_srv.submit(p, max_new=m)
        seq_outs.append(solo_srv.drain()[rid].out)
    parity = out_r == seq_outs and out_c == seq_outs

    tps_r, tps_c = tok_r / wall_r, tok_c / wall_c
    speedup = tps_c / tps_r
    occupancy = stats["active_slot_steps"] / max(stats["slot_steps"], 1)
    print(f"\ndecode: {args.decode_requests} ragged requests "
          f"(prompts 3..19, max_new 4..23), {args.decode_slots} slots, "
          f"sync window {args.sync_every}; {cfg.name}")
    rows = [("rounds (equal-length)", wall_r, tps_r),
            ("continuous batching", wall_c, tps_c)]
    print(f"{'path':<28}{'wall s':>8}{'tok/s':>10}")
    for name, wall, tps in rows:
        print(f"{name:<28}{wall:>8.2f}{tps:>10.1f}")
    print(f"decode speedup: {speedup:.2f}x tok/s "
          f"(lockstep-equal={lockstep_equal}, sequential-parity={parity}, "
          f"{stats['syncs']} syncs / {stats['steps']} steps, "
          f"occupancy {occupancy:.0%})")
    assert lockstep_equal, "continuous != rounds on a lockstep workload"
    assert parity, "engine outputs diverge from sequential decoding"
    if args.assert_speedup:
        assert speedup >= args.assert_speedup, (
            f"continuous batching {speedup:.2f}x < required "
            f"{args.assert_speedup}x over the round engine")
    return {"arch": cfg.name, "n_requests": args.decode_requests,
            "slots": args.decode_slots, "sync_every": args.sync_every,
            "tok_s_rounds": tps_r, "tok_s_continuous": tps_c,
            "speedup": speedup, "lockstep_equal": lockstep_equal,
            "sequential_parity": parity, "slot_occupancy": occupancy,
            "host_syncs": stats["syncs"], "decode_steps": stats["steps"]}


def fused_bench(args) -> dict:
    """Fused decode-kernel window (``TokenServer(decode_kernel=True)``:
    kernels/decode_attention + kernels/topk_sample inside the jitted
    sync window) vs the XLA window, same ragged continuous-batching
    workload as decode_bench.

    Gates: greedy tokens bitwise identical, and *window* tok/s under
    sampling (per-request temperature/top-k/top-p — the configuration
    where the full-vocab argsort sampler dominates the window) at least
    ``--assert-fused`` x the XLA window.  The window gate times the
    jitted sync window back-to-back on saturated device state: the
    whole-drain wall also includes per-pump host work (admission, slot
    mirrors, queue bookkeeping) that is byte-identical between the two
    servers and swamps the device window at smoke scale, so it is
    reported for context but not gated.

    The fused section bumps the smoke vocab (512) to 4096: the argsort
    sampler's cost is linear-log in vocab, so the 512-token smoke vocab
    makes it artificially free (sub-ms, smaller than one decode step)
    while real token-LM vocabs are 32k-152k.  4096 is the smallest
    size where the sampler visibly owns the window without making the
    XLA baseline take minutes on CPU."""
    from dataclasses import replace

    from repro.configs import get_arch, reduced
    from repro.serve import LATENCY, TokenServer
    from repro.serve.sampling import SamplingParams

    cfg = replace(reduced(get_arch(args.decode_arch)), vocab_size=4096)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    pol = replace(LATENCY, max_batch=args.decode_slots,
                  sync_every=args.sync_every)
    max_seq = 64
    work = make_decode_workload(cfg.vocab_size, args.decode_requests,
                                ragged=True, seed=2)

    def mk(**kw):
        return TokenServer(cfg, params, policy=pol, max_seq=max_seq, **kw)

    # --- gate 1: greedy bitwise parity on the ragged workload
    _, _, out_x, _ = decode_run(mk(), work)
    _, _, out_f, _ = decode_run(mk(decode_kernel=True), work)
    greedy_parity = out_x == out_f

    # --- gate 2: sampled-workload end-to-end drain (context, not gated)
    samp = lambda i: SamplingParams(temperature=0.8, top_k=20,
                                    top_p=0.95, seed=i)
    wall_x, tok_x, _, _ = decode_run(mk(), work, sampling=samp)
    wall_f, tok_f, _, _ = decode_run(mk(decode_kernel=True), work,
                                     sampling=samp)
    assert tok_x == tok_f, "fused window emitted a different token count"
    tps_x, tps_f = tok_x / wall_x, tok_f / wall_f

    # --- gate 3: jitted sampled-window throughput.  Saturate the slots
    # with sampled requests, let one pump() admit + compile the sample
    # window, then drive the window function back-to-back on device
    # state (tokens/positions advance inside the timing loop exactly as
    # they do under pump, minus the host bookkeeping both servers
    # share).
    def window_tps(**kw):
        srv = mk(**kw)
        rng = np.random.default_rng(3)
        for i in range(args.decode_slots):
            srv.submit(rng.integers(0, cfg.vocab_size,
                                    size=(8,)).astype(np.int32),
                       max_new=max_seq - 9, sampling=samp(i))
        srv.pump()
        win = srv._serve_sample
        samp_d = {"temperature": jnp.asarray(srv._temp),
                  "top_k": jnp.asarray(srv._topk),
                  "top_p": jnp.asarray(srv._topp),
                  "seed": jnp.asarray(srv._seed)}
        iters = 20

        def run():
            cache, tok = srv._cache, srv._tok
            t0 = time.perf_counter()
            for _ in range(iters):
                cache, tok, em = win(srv.params, cache, tok,
                                     srv._prompts_d, srv._plens_d, samp_d)
            jax.block_until_ready(em)
            return time.perf_counter() - t0

        run()                                              # warm
        wall = min(run() for _ in range(3))
        return args.decode_slots * args.sync_every * iters / wall

    wtps_x = window_tps()
    wtps_f = window_tps(decode_kernel=True)
    speedup = wtps_f / wtps_x

    print(f"\nfused decode kernels: sampled ragged workload "
          f"({args.decode_requests} requests, {args.decode_slots} slots, "
          f"window {args.sync_every}); {cfg.name} @ vocab "
          f"{cfg.vocab_size}")
    print(f"{'path':<28}{'drain tok/s':>12}{'window tok/s':>14}")
    print(f"{'XLA (argsort sampler)':<28}{tps_x:>12.1f}{wtps_x:>14.1f}")
    print(f"{'fused (decode_kernel)':<28}{tps_f:>12.1f}{wtps_f:>14.1f}")
    print(f"fused window speedup: {speedup:.2f}x tok/s "
          f"(greedy-parity={greedy_parity})")
    assert greedy_parity, "fused greedy tokens diverge from the XLA window"
    if args.assert_fused:
        assert speedup >= args.assert_fused, (
            f"fused window {speedup:.2f}x < required "
            f"{args.assert_fused}x over the XLA window")
    return {"vocab": cfg.vocab_size,
            "tok_s_xla": tps_x, "tok_s_fused": tps_f,
            "window_tok_s_xla": wtps_x, "window_tok_s_fused": wtps_f,
            "speedup": speedup, "greedy_parity": greedy_parity,
            "sampled": {"temperature": 0.8, "top_k": 20, "top_p": 0.95}}


def paged_bench(args) -> dict:
    """Paged KV cache vs contiguous slots: token parity on a ragged
    workload, memory-per-token accounting (peak pages x page bytes must
    beat slots x max_seq), prefix-cache hit rate on a shared-prefix
    workload, and the long-prompt capability the contiguous layout
    refuses outright."""
    from dataclasses import replace

    from repro.configs import get_arch, reduced
    from repro.models.paging import PagedCacheConfig, paged_token_bytes
    from repro.serve import LATENCY, TokenServer

    cfg = reduced(get_arch(args.decode_arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    pol = replace(LATENCY, max_batch=args.decode_slots,
                  sync_every=args.sync_every)
    max_seq = 64
    paging = PagedCacheConfig(page_size=args.page_size,
                              n_pages=args.pages,
                              max_ctx=max_seq)
    tok_bytes = paged_token_bytes(cfg, jnp.bfloat16)

    # --- parity + memory on the same ragged workload as decode_bench
    work = make_decode_workload(cfg.vocab_size, args.decode_requests,
                                ragged=True, seed=2)
    cont = TokenServer(cfg, params, policy=pol, max_seq=max_seq)
    page = TokenServer(cfg, params, policy=pol, paging=paging,
                       prefix_cache=False)
    wall_c, tok_c, out_c, _ = decode_run(cont, work)
    for key in page.alloc.stats:
        page.alloc.stats[key] = 0
    wall_p, tok_p, out_p, _ = decode_run(page, work)
    parity = out_c == out_p
    peak_pages = page.alloc.stats["peak_pages"]
    paged_bytes = peak_pages * paging.page_size * tok_bytes
    cont_bytes = args.decode_slots * max_seq * tok_bytes
    mem_ratio = paged_bytes / cont_bytes
    page.alloc.check()

    # --- prefix caching: N requests sharing one long prompt prefix
    rng = np.random.default_rng(7)
    pre = rng.integers(1, cfg.vocab_size, 2 * args.page_size)
    shared = [(np.concatenate([pre, rng.integers(
        1, cfg.vocab_size, int(rng.integers(1, 8)))]),
        int(rng.integers(4, 10))) for _ in range(12)]
    pref = TokenServer(cfg, params, policy=pol, paging=paging)
    decode_run(pref, shared)
    s = pref.paging_stats()
    sharable = s["hits"] + s["misses"]
    hit_rate = s["hits"] / max(sharable, 1)

    # --- long prompt: beyond the contiguous budget entirely
    big = PagedCacheConfig(page_size=args.page_size,
                           n_pages=args.pages, max_ctx=2 * max_seq)
    long_prompt = rng.integers(1, cfg.vocab_size, max_seq + 16)
    refused = False
    try:
        cont.submit(long_prompt, max_new=4)
    except ValueError:
        refused = True
    long_srv = TokenServer(cfg, params, policy=pol, paging=big)
    rid = long_srv.submit(long_prompt, max_new=4)
    long_out = long_srv.drain()[rid].out
    solo = TokenServer(cfg, params, max_seq=2 * max_seq,
                       policy=replace(pol, max_batch=1))
    srid = solo.submit(long_prompt, max_new=4)
    long_parity = long_out == solo.drain()[srid].out

    print(f"\npaged KV: page_size {paging.page_size}, {paging.n_pages} "
          f"pages vs {args.decode_slots} slots x {max_seq} contiguous; "
          f"{tok_bytes} B/token ({cfg.name})")
    print(f"{'layout':<28}{'peak KV bytes':>14}{'tok/s':>10}")
    print(f"{'contiguous slots':<28}{cont_bytes:>14,}"
          f"{tok_c / wall_c:>10.1f}")
    print(f"{'paged (peak in flight)':<28}{paged_bytes:>14,}"
          f"{tok_p / wall_p:>10.1f}")
    print(f"memory/token ratio: {mem_ratio:.2f}x  "
          f"(parity={parity}, peak {peak_pages} pages)")
    print(f"prefix cache: {s['hits']}/{sharable} sharable blocks hit "
          f"({hit_rate:.0%}); long prompt {len(long_prompt)} tokens: "
          f"contiguous refused={refused}, paged parity={long_parity}")
    assert parity, "paged != contiguous tokens on the ragged workload"
    assert long_parity and refused, "long-prompt demo failed"
    assert hit_rate > 0, "prefix cache never hit on a shared-prefix load"
    assert paged_bytes < cont_bytes, (
        f"paged peak {paged_bytes} B not below contiguous {cont_bytes} B")
    return {"page_size": paging.page_size, "n_pages": paging.n_pages,
            "token_bytes": tok_bytes, "peak_pages": peak_pages,
            "paged_peak_bytes": paged_bytes,
            "contiguous_bytes": cont_bytes, "memory_ratio": mem_ratio,
            "ragged_parity": parity, "tok_s_paged": tok_p / wall_p,
            "prefix_hits": s["hits"], "prefix_sharable": sharable,
            "prefix_hit_rate": hit_rate,
            "long_prompt_len": int(len(long_prompt)),
            "long_prompt_parity": long_parity}


def pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


# --------------------------------------------------------------- stream

def make_stream_workload(fd: int, n_fire: int, n_inter: int, seed: int = 11):
    """Firehose streams (long — offline target generation) + interactive
    streams (short — online recognition), Gaussian frames.  Returns
    (streams, tiers) in submission order: every firehose first, so the
    interactive arrivals land on a fully occupied server (the overload
    shape the SLO machinery exists for)."""
    rng = np.random.default_rng(seed)
    # long enough to span several 16-step firehose windows: parking has
    # something to interrupt
    fire = [(rng.normal(size=(int(rng.integers(500, 700)), fd)) * 0.1)
            .astype(np.float32) for _ in range(n_fire)]
    inter = [(rng.normal(size=(int(rng.integers(8, 25)), fd)) * 0.1)
             .astype(np.float32) for _ in range(n_inter)]
    return (fire + inter,
            ["firehose"] * n_fire + ["interactive"] * n_inter)


def lockstep_stream_run(cfg, params, streams, *, chunk, k, n_slots,
                        warm):
    """The pre-refactor baseline: the lockstep ``feed`` loop — FIFO
    admission into engine slots, one host sync per chunk for every
    active stream.  ``bucket_multiple=chunk`` so both paths compute
    exactly the same padded frames; the measured gap is sync cadence and
    admission, not padding.  ``warm`` streams run first on the same
    engine (same jit cache), outside the measurement."""
    from dataclasses import replace

    eng = StreamingEngine(cfg, params, k=k, n_slots=n_slots,
                          policy=replace(THROUGHPUT,
                                         bucket_multiple=chunk))

    def drive(work):
        pending = list(range(len(work)))
        active = {}                   # engine sid -> [stream idx, cursor]
        outs = [[] for _ in work]
        done_at = [0.0] * len(work)
        t0 = time.time()
        while pending or active:
            while pending and len(active) < n_slots:
                sid = eng.open_stream()
                active[sid] = [pending.pop(0), 0]
            chunks = {sid: work[i][c:c + chunk]
                      for sid, (i, c) in active.items()}
            res = eng.feed(chunks)    # host sync every chunk: the cost
            for sid in list(active):
                i, c = active[sid]
                outs[i].append(res[sid])
                c += chunks[sid].shape[0]
                active[sid][1] = c
                if c >= work[i].shape[0]:
                    done_at[i] = time.time()
                    eng.close_stream(sid)
                    del active[sid]
        return time.time() - t0, done_at, outs, t0

    drive(warm)
    wall, done_at, outs, t0 = drive(streams)
    lat = [(t - t0) * 1e3 for t in done_at]
    emis = [(np.concatenate([v for v, _ in o], axis=0),
             np.concatenate([ix for _, ix in o], axis=0)) for o in outs]
    return wall, lat, emis


def slot_stream_run(cfg, params, streams, tiers_of, *, chunk, k, n_slots,
                    warm, warm_tiers):
    """The slot-based path: StreamServer with SLO tiers, same arrival
    order (firehose saturates the server before interactive lands).
    ``warm`` streams compile both tier window lengths on the same
    server, outside the measurement."""
    from repro.serve import SLO_DEFAULT, StreamServer

    srv = StreamServer(cfg, params, n_slots=n_slots, chunk_frames=chunk,
                       k=k, tiers=SLO_DEFAULT)

    def drive(work, work_tiers):
        t0 = time.time()
        sub_at, done_at, sessions = {}, {}, {}

        def collect():
            for rid, s in srv.pump().items():
                done_at[rid] = time.time()
                sessions[rid] = s

        # firehose arrives first and saturates the server ...
        rids = [srv.submit(u, tier=t)
                for u, t in zip(work, work_tiers) if t == "firehose"]
        for rid in rids:
            sub_at[rid] = t0
        collect()
        # ... then interactive lands mid-flight: admission control must
        # park/shed firehose to serve it (latency from *its* arrival)
        t1 = time.time()
        late = [srv.submit(u, tier=t)
                for u, t in zip(work, work_tiers) if t != "firehose"]
        for rid in late:
            sub_at[rid] = t1
        rids += late
        while srv.queue.n_pending or srv.n_active:
            collect()
        wall = time.time() - t0
        lat = [(done_at[r] - sub_at[r]) * 1e3 for r in rids]
        return wall, lat, [sessions[r].emissions() for r in rids]

    drive(warm, warm_tiers)
    for key in srv.stats:
        srv.stats[key] = 0
    wall, lat, emis = drive(streams, tiers_of)
    return wall, lat, emis, srv


def stream_bench(args) -> dict:
    """Streaming-AM continuous batching (ISSUE 9): the slot-based
    StreamServer vs the lockstep feed loop on a ragged attach/detach
    workload — long firehose streams saturating every slot, short
    interactive streams arriving on top.

    Gates: emissions bitwise identical to the lockstep loop for every
    stream (parked/replayed firehose included), >= ``--assert-stream`` x
    frames/s, and interactive p99 completion below firehose p50 under
    overload (the SLO the tier machinery buys; the CI job re-checks both
    from the JSON artifact)."""
    from repro.configs.base import LayerSpec, Segment
    from repro.configs.lstm_am_7khr import CONFIG

    fd = 16
    cfg = CONFIG.replace(
        lstm_hidden=args.stream_hidden, feat_dim=fd, n_senones=49,
        vocab_size=49,
        segments=(Segment((LayerSpec(mixer="lstm", ffn="none"),),
                          repeat=args.layers),))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    chunk, k, n_slots = args.stream_chunk, args.k, args.stream_slots

    streams, tiers_of = make_stream_workload(
        fd, args.stream_firehose, args.stream_interactive)
    frames = sum(u.shape[0] for u in streams)

    # warm both paths' jit caches out of the measurement (lockstep: one
    # feed shape; slots: the firehose and interactive window lengths)
    warm, warm_t = make_stream_workload(fd, 1, 1, seed=99)

    # wall is ~0.2 s on this workload — a single run is at the mercy of
    # scheduler noise, so measure each path a few times (each run warms
    # its own fresh instance) and keep the best; emissions are
    # deterministic, identical across reps
    wall_l, lat_l, emis_l = min(
        (lockstep_stream_run(cfg, params, streams, chunk=chunk, k=k,
                             n_slots=n_slots, warm=warm)
         for _ in range(max(args.stream_reps, 1))),
        key=lambda r: r[0])
    wall_s, lat_s, emis_s, srv = min(
        (slot_stream_run(cfg, params, streams, tiers_of, chunk=chunk,
                         k=k, n_slots=n_slots, warm=warm,
                         warm_tiers=warm_t)
         for _ in range(max(args.stream_reps, 1))),
        key=lambda r: r[0])

    parity = all(
        np.array_equal(sv, lv) and np.array_equal(si, li)
        for (sv, si), (lv, li) in zip(emis_s, emis_l))

    fps_l, fps_s = frames / wall_l, frames / wall_s
    speedup = fps_s / fps_l

    def tier_pcts(lat):
        out = {}
        for name in ("interactive", "firehose"):
            xs = [l for l, t in zip(lat, tiers_of) if t == name]
            out[name] = {"p50_ms": pct(xs, 50), "p99_ms": pct(xs, 99)}
        return out

    tp_l, tp_s = tier_pcts(lat_l), tier_pcts(lat_s)
    slo_ok = tp_s["interactive"]["p99_ms"] < tp_s["firehose"]["p50_ms"]

    print(f"\nstream: {args.stream_firehose} firehose (500..699 fr) + "
          f"{args.stream_interactive} interactive (8..24 fr), "
          f"{n_slots} slots, chunk {chunk}; causal "
          f"{args.layers}x{args.stream_hidden} LSTM AM, k={k}, "
          f"best of {max(args.stream_reps, 1)}")
    print(f"{'path':<26}{'wall s':>8}{'frames/s':>10}"
          f"{'inter p50/p99 ms':>18}{'fire p50/p99 ms':>18}")
    for name, wall, fps, tp in (
            ("lockstep feed loop", wall_l, fps_l, tp_l),
            ("slot server (tiered)", wall_s, fps_s, tp_s)):
        print(f"{name:<26}{wall:>8.2f}{fps:>10.0f}"
              f"{tp['interactive']['p50_ms']:>9.1f}"
              f"/{tp['interactive']['p99_ms']:<8.1f}"
              f"{tp['firehose']['p50_ms']:>9.1f}"
              f"/{tp['firehose']['p99_ms']:<8.1f}")
    print(f"stream speedup: {speedup:.2f}x frames/s "
          f"(parity={parity}, slo_ok={slo_ok}, "
          f"{srv.stats['parked']} parks, {srv.stats['syncs']} syncs / "
          f"{srv.stats['steps']} steps, "
          f"utilization {srv.utilization():.0%})")
    assert parity, "slot-server emissions diverge from the lockstep loop"
    assert slo_ok, (
        f"interactive p99 {tp_s['interactive']['p99_ms']:.1f} ms not "
        f"below firehose p50 {tp_s['firehose']['p50_ms']:.1f} ms under "
        f"overload")
    if args.assert_stream:
        assert speedup >= args.assert_stream, (
            f"slot streaming {speedup:.2f}x < required "
            f"{args.assert_stream}x over the lockstep feed loop")
    return {"n_firehose": args.stream_firehose,
            "n_interactive": args.stream_interactive,
            "slots": n_slots, "chunk_frames": chunk,
            "hidden": args.stream_hidden,
            "reps": max(args.stream_reps, 1), "frames": frames,
            "fps_lockstep": fps_l, "fps_slots": fps_s, "speedup": speedup,
            "lockstep_parity": parity, "slo_ok": slo_ok,
            "lockstep": tp_l, "slots_tiered": tp_s,
            "parked": srv.stats["parked"], "syncs": srv.stats["syncs"],
            "utilization": srv.utilization()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-utts", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--policy", default="throughput",
                    choices=["throughput", "latency"])
    ap.add_argument("--out", default="experiments/benchmarks")
    ap.add_argument("--decode-arch", default="qwen2.5-3b")
    ap.add_argument("--decode-requests", type=int, default=24)
    ap.add_argument("--decode-slots", type=int, default=4)
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--assert-speedup", type=float, default=1.5,
                    help="fail unless continuous >= this x rounds tok/s "
                         "on the ragged workload (0 disables)")
    ap.add_argument("--assert-fused", type=float, default=1.3,
                    help="fail unless the fused decode-kernel window >= "
                         "this x the XLA window tok/s on the sampled "
                         "ragged workload (0 disables)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages", type=int, default=32,
                    help="paged-KV pool size for the paged section")
    ap.add_argument("--skip-decode", action="store_true")
    ap.add_argument("--stream-firehose", type=int, default=6)
    ap.add_argument("--stream-interactive", type=int, default=6)
    ap.add_argument("--stream-slots", type=int, default=4)
    ap.add_argument("--stream-chunk", type=int, default=4,
                    help="frames per stream chunk (40 ms at a 10 ms "
                         "hop): small chunks are the interactive regime "
                         "where per-chunk host syncs dominate the "
                         "lockstep loop")
    ap.add_argument("--stream-hidden", type=int, default=64)
    ap.add_argument("--stream-reps", type=int, default=3,
                    help="measured repetitions per path (best wall "
                         "kept): wall is ~0.2 s, single runs are noisy")
    ap.add_argument("--assert-stream", type=float, default=1.5,
                    help="fail unless the slot-based stream server >= "
                         "this x the lockstep feed loop frames/s on the "
                         "ragged attach/detach workload (0 disables)")
    ap.add_argument("--skip-stream", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs.base import LayerSpec, Segment
    utts = make_corpus(args.n_utts)
    feat_dim = utts[0].shape[1]
    cfg = TEACHER.replace(
        lstm_hidden=args.hidden, feat_dim=feat_dim, n_senones=49,
        vocab_size=49,
        segments=(Segment((LayerSpec(mixer="bilstm", ffn="none"),),
                          repeat=args.layers),))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = THROUGHPUT if args.policy == "throughput" else LATENCY

    frames = sum(u.shape[0] for u in utts)
    max_bucket = bucket_length(max(u.shape[0] for u in utts),
                               policy.bucket_multiple)
    print(f"corpus: {args.n_utts} utts, {frames} frames, "
          f"lens {min(u.shape[0] for u in utts)}.."
          f"{max(u.shape[0] for u in utts)} (bucket {max_bucket}); "
          f"teacher {args.layers}x{args.hidden} biLSTM, k={args.k}")

    # warm the naive path's single compile out of the measurement (same
    # fwd object as the measured run); the engine warms its bucket
    # shapes inside engine_run (serving steady state: cold-compile is a
    # one-time per-shape constant)
    naive_fwd = make_naive_fwd(model, args.k)
    naive_loop(naive_fwd, params, utts[:1], max_bucket)

    t_naive, lat_naive = naive_loop(naive_fwd, params, utts, max_bucket)
    t_eng, lat_eng, eff = engine_run(cfg, params, utts, args.k, policy)

    fps_naive = frames / t_naive
    fps_eng = frames / t_eng
    rows = [
        ("naive loop (B=1)", t_naive, fps_naive, pct(lat_naive, 50),
         pct(lat_naive, 95)),
        (f"engine ({policy.name}, B={policy.max_batch})", t_eng, fps_eng,
         pct(lat_eng, 50), pct(lat_eng, 95)),
    ]
    print(f"{'path':<28}{'wall s':>8}{'frames/s':>10}{'p50 ms':>9}"
          f"{'p95 ms':>9}")
    for name, wall, fps, p50, p95 in rows:
        print(f"{name:<28}{wall:>8.2f}{fps:>10.0f}{p50:>9.1f}{p95:>9.1f}")
    speedup = fps_eng / fps_naive
    print(f"speedup: {speedup:.2f}x frames/sec "
          f"(padding efficiency {eff:.0%})")

    rec = {"n_utts": args.n_utts, "frames": frames, "policy": policy.name,
           "fps_naive": fps_naive, "fps_engine": fps_eng,
           "speedup": speedup, "padding_efficiency": eff,
           "p50_ms": {"naive": pct(lat_naive, 50), "engine": pct(lat_eng, 50)},
           "p95_ms": {"naive": pct(lat_naive, 95), "engine": pct(lat_eng, 95)}}
    if not args.skip_decode:
        rec["decode"] = decode_bench(args)
        rec["fused"] = fused_bench(args)
        rec["paged"] = paged_bench(args)
    if not args.skip_stream:
        rec["stream"] = stream_bench(args)

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "serve_bench.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    # repo-root copy: the artifact the tier2-serve CI gates read
    with open("BENCH_serve.json", "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {path} and BENCH_serve.json")
    return rec


if __name__ == "__main__":
    main()
