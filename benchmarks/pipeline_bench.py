"""Data-plane throughput: the prefetching feed vs the synchronous feed,
and N-worker sharded target generation.

  PYTHONPATH=src python benchmarks/pipeline_bench.py
  PYTHONPATH=src python benchmarks/pipeline_bench.py --updates 40 --io-ms 20

**Feed benchmark** — the same distill workload (checksum-verified
LogitStore v2 shard reads joined with unlabeled batches, the student's
``distill_topk`` loss) driven through ``Trainer.fit`` twice: once
synchronously, once through ``PrefetchingSource``.  The run is made
*decode-bound* the way a real million-hour run is: every shard read
pays checksum verification plus ``--io-ms`` of simulated remote-storage
fetch latency (the petabyte-scale regime — shards stream from network
storage, not local disk; see arXiv:1904.10584).  The prefetching feed
overlaps that host-side decode with the jitted update, so steps/sec
should approach ``(t_decode + t_update) / max(t_decode, t_update)``
times the synchronous rate; the recorded claim (asserted here and in
the tier-2 CI job) is **>= 1.3x**.

**Generation benchmark** — ``generate_sharded`` at workers=1 vs
workers=2 on the same batch corpus (fresh store each): records
shards/sec and the ledger/manifest overhead of partitioning.  On one
CPU the workers are time-sliced, so this measures the *overhead* of the
claim protocol (near-zero), not a speedup — the scale-out claim is
structural (disjoint ranges, per-worker engines), and the e2e pipeline
exercises it at workers=2.

**Process-fleet benchmark** — the same generation as 1/2/4 *real OS
processes* (``processes=N``) racing the shared ledger through
``repro.runtime.workers``: records shards/sec including the spawn +
import + locked-claim overhead each process pays in deployment.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, Segment
from repro.configs.lstm_am_7khr import CONFIG
from repro.launch.steps import make_loss_fn
from repro.models import build_model
from repro.pipeline import generate_sharded
from repro.store import LogitStoreV2
from repro.train import ListSink, Local, TrainBatch, Trainer

V = 49          # senones
K = 10


def _corpus(n_batches, b, s, feat_dim, seed=0):
    rng = np.random.default_rng(seed)
    return [{"feats": rng.normal(size=(b, s, feat_dim)).astype(np.float32),
             "mask": np.ones((b, s), np.float32)} for _ in range(n_batches)]


def _fill_store(root, batches, seed=1):
    rng = np.random.default_rng(seed)
    store = LogitStoreV2(root, k=K, vocab=V)
    for j, bt in enumerate(batches):
        bsz, slen = bt["mask"].shape
        vals = rng.normal(size=(bsz, slen, K)).astype(np.float32)
        vals = vals - vals.max(-1, keepdims=True)
        idx = rng.integers(0, V, (bsz, slen, K)).astype(np.int32)
        store.append_shard(j, vals, idx)
    return store


def _feed_source(batches, store, lr, io_ms):
    """The decode-bound source: verified shard reads + simulated
    remote-store fetch latency, joined with the unlabeled batches."""
    def src():
        for bi, b in enumerate(batches):
            vals, idx = store.read_shard(bi, verify=True)
            if io_ms:
                time.sleep(io_ms / 1000.0)
            yield TrainBatch({"feats": b["feats"], "mask": b["mask"],
                              "topk_vals": np.asarray(vals),
                              "topk_idx": np.asarray(idx)},
                             lr, "distill_topk")
    return src


def calibrate_io_ms(args, model, cfg, batches, store):
    """Auto-balance the simulated fetch latency to the measured
    update+decode cost, so the run is decode-bound *by construction* on
    any hardware — the >=1.3x gate then measures the feed's overlap,
    not the CI box's model-vs-io speed ratio."""
    loss_fns = {"distill_topk": make_loss_fn(model, cfg, "distill_topk")}
    trainer = Trainer(Local(clip=0.0), loss_fns, metrics=ListSink())
    src = _feed_source(batches, store, args.lr, io_ms=0)
    state = trainer.init_state(model.init(jax.random.key(0)))
    state = trainer.fit(state, src(), resume=False, max_updates=2)  # warm
    jax.block_until_ready(state.params)
    n = min(8, len(batches))
    walls = []
    for _ in range(3):          # min-of-3: a GC pause or CPU spike in the
        t0 = time.time()        # calibration window must not inflate io
        state = trainer.fit(state, src(), resume=False, max_updates=n)
        jax.block_until_ready(state.params)
        walls.append(time.time() - t0)
    step_ms = min(walls) / n * 1000.0
    # match io to the step cost so the theoretical overlap win is ~2x on
    # any box; the floor only guards sleep-timer granularity and stays
    # low enough that even step_ms ~2ms keeps the >=1.3x gate reachable
    return round(max(3.0, step_ms), 1)


def bench_feed(args, model, cfg, batches, store):
    loss_fns = {"distill_topk": make_loss_fn(model, cfg, "distill_topk")}
    params = model.init(jax.random.key(0))
    records = []
    for label, depth in (("sync", 0), ("prefetch", args.depth)):
        trainer = Trainer(Local(clip=0.0), loss_fns, metrics=ListSink(),
                          prefetch=depth)
        src = _feed_source(batches, store, args.lr, args.io_ms)
        # warmup compiles + page caches
        state = trainer.init_state(params)
        state = trainer.fit(state, src(), resume=False, max_updates=2)
        jax.block_until_ready(state.params)

        # best-of-N: thread scheduling on a shared box is noisy; the
        # fastest repeat is the feed's achievable rate
        walls = []
        for _ in range(args.repeats):
            n_done = 0
            t0 = time.time()
            while n_done < args.updates:
                take = min(args.updates - n_done, len(batches))
                state = trainer.fit(state, src(), resume=False,
                                    max_updates=take)
                n_done += take
            jax.block_until_ready(state.params)
            walls.append(time.time() - t0)
        wall = min(walls)
        rec = {"feed": label, "depth": depth, "updates": args.updates,
               "io_ms": args.io_ms, "repeats": args.repeats,
               "steps_per_sec": round(args.updates / wall, 2),
               "wall_s": round(wall, 3),
               "wall_s_all": [round(w, 3) for w in walls]}
        print(f"  {label:9s} {rec['steps_per_sec']:7.2f} steps/s "
              f"(best of {args.repeats}: {rec['wall_s_all']}, "
              f"depth={depth})")
        records.append(rec)
    ratio = records[1]["steps_per_sec"] / max(records[0]["steps_per_sec"],
                                              1e-9)
    print(f"  prefetch/sync = {ratio:.2f}x")
    return records, round(ratio, 3)


def bench_generation(args, teacher_model, tcfg, batches, out_root):
    from repro.core.teacher import TeacherRunner
    tparams = teacher_model.init(jax.random.key(1))
    records = []
    for workers in (1, 2):
        root = os.path.join(out_root, f"_gen_w{workers}")
        store = LogitStoreV2(root, k=K, vocab=V)

        # engines built and warmed up front: each worker pays its own
        # forward compile in real deployments, but at tiny scale that
        # compile would swamp the per-shard signal being measured
        engines = {w: TeacherRunner(tcfg, tparams, k=K)
                   for w in range(workers)}
        for eng in engines.values():
            eng.forward_topk(batches[0])
        walls = []
        for _ in range(args.repeats):        # repeat = a new wave (the
            t0 = time.time()                 # supersede path, exercised)
            rep = generate_sharded(
                engines.__getitem__, batches, store, n_workers=workers,
                ledger_path=os.path.join(root, "ledger.json"))
            walls.append(time.time() - t0)
        wall = min(walls)
        store.verify()
        rec = {"workers": workers, "n_shards": rep["n_shards"],
               "final_wave": rep["wave"],
               "shards_per_sec": round(rep["n_shards"] / wall, 2),
               "wall_s": round(wall, 3),
               "wall_s_all": [round(w, 3) for w in walls]}
        print(f"  workers={workers}  {rec['shards_per_sec']:6.2f} shards/s "
              f"(best of {args.repeats}: {rec['wall_s_all']})")
        records.append(rec)
    return records


def bench_process_workers(args, batches, out_root):
    """The fleet at process granularity: ``generate_sharded(processes=N)``
    at N = 1/2/4 over the same corpus — N real OS processes racing the
    shared ledger, each paying its own spawn + import + engine build
    (the deployment cost model; the deterministic probe engine stands in
    for a teacher forward so the protocol cost dominates).  On one CPU
    this bounds the claim/spawn overhead rather than demonstrating
    speedup — the scale-out story is structural and the bitwise pin
    (tests/test_runtime.py) is the correctness claim."""
    spec = "repro.runtime.workers:linear_probe_engine"
    kw = {"k": K, "vocab": V, "seed": 0}
    records = []
    for procs_n in (1, 2, 4):
        root = os.path.join(out_root, f"_gen_p{procs_n}")
        store = LogitStoreV2(root, k=K, vocab=V)
        walls = []
        for _ in range(args.repeats):        # repeat = a new wave
            t0 = time.time()
            rep = generate_sharded(
                spec, batches, store, n_workers=max(procs_n, 2),
                engine_kwargs=kw, processes=procs_n,
                ledger_path=os.path.join(root, "ledger.json"),
                supervisor_opts={"timeout_s": 120.0})
            walls.append(time.time() - t0)
        wall = min(walls)
        store.verify()
        rec = {"processes": procs_n, "n_shards": rep["n_shards"],
               "restarts": rep["restarts"],
               "shards_per_sec": round(rep["n_shards"] / wall, 2),
               "wall_s": round(wall, 3),
               "wall_s_all": [round(w, 3) for w in walls]}
        print(f"  processes={procs_n}  {rec['shards_per_sec']:6.2f} "
              f"shards/s (best of {args.repeats}: {rec['wall_s_all']})")
        records.append(rec)
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=24)
    ap.add_argument("--batches", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=4,
                    help="feed timing repeats (best-of)")
    ap.add_argument("--depth", type=int, default=3,
                    help="prefetch queue depth")
    ap.add_argument("--io-ms", type=float, default=-1.0,
                    help="simulated remote-store fetch latency per shard "
                         "(-1: auto-calibrate to the measured update "
                         "cost, making the run decode-bound on any box)")
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--min-speedup", type=float, default=1.3)
    ap.add_argument("--out", default="experiments/benchmarks")
    args = ap.parse_args(argv)

    cfg = CONFIG.replace(
        lstm_hidden=args.hidden, n_senones=V, vocab_size=V, feat_dim=48,
        segments=(Segment((LayerSpec(mixer="lstm", ffn="none"),),
                          repeat=1),))
    tcfg = cfg.replace(
        name="teacher",
        segments=(Segment((LayerSpec(mixer="bilstm", ffn="none"),),
                          repeat=1),))
    model = build_model(cfg)
    batches = _corpus(args.batches, args.batch, args.seq, cfg.feat_dim)

    work = os.path.join(args.out, "_pipeline_bench")
    if os.path.isdir(work):                  # fresh run, fresh workspace
        import shutil
        shutil.rmtree(work)
    store = _fill_store(os.path.join(work, "store"), batches)

    if args.io_ms < 0:
        args.io_ms = calibrate_io_ms(args, model, cfg, batches, store)
        print(f"auto-calibrated io to {args.io_ms}ms "
              f"(~= measured update+decode cost)")
    print(f"feed: {args.updates} updates over {args.batches} shards of "
          f"{args.batch}x{args.seq}, io={args.io_ms}ms, "
          f"depth={args.depth}")
    feed_records, ratio = bench_feed(args, model, cfg, batches, store)
    print("generation: sharded target generation")
    gen_records = bench_generation(args, build_model(tcfg), tcfg,
                                   batches, work)
    print("generation: process-worker fleet (real OS processes)")
    proc_records = bench_process_workers(args, batches, work)

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "pipeline_bench.json")
    with open(path, "w") as f:
        json.dump({"config": vars(args),
                   "feed": feed_records,
                   "prefetch_speedup_x": ratio,
                   "generation": gen_records,
                   "generation_processes": proc_records}, f, indent=1)
    print(f"wrote {path}")
    assert ratio >= args.min_speedup, (
        f"prefetching feed {ratio}x < required {args.min_speedup}x on a "
        f"decode-bound run")


if __name__ == "__main__":
    main()
