"""GTC trainer: wire bytes/update and updates/s across worker counts.

  PYTHONPATH=src python benchmarks/gtc_bench.py
  PYTHONPATH=src python benchmarks/gtc_bench.py --updates 16 --hidden 128

The paper's 16-GPU sequence trainer ships threshold-compressed sends;
this records what the int8 pack buys as *numbers*:

  * **wire bytes/update** — what one worker ships into the all-reduce
    per update under each wire format (dense f32 send vs packed int8;
    int8 holds through the accumulation for <= 127 workers, so the
    claim asserted here is int8 >= 3x smaller than f32 at equal
    density — the sends are identical tensors, only the encoding
    differs; the observed ratio is 4x).
  * **updates/s** at workers ∈ {1, 2, 4} through the same Trainer.fit
    loop (GTC single-process at W=1, GTCShardMap above), with the lr
    swept every update — the compile count staying at 1 per strategy is
    asserted, as in train_bench.
  * **gtc_density** — fraction of elements actually nonzero on the
    wire (the sparsity Strom's threshold buys; diagnostic).

On one CPU the W>1 workers are time-sliced so updates/s *per update*
falls with W while frames/s stays comparable — the scale-out claim is
the wire format + the sharded exchange, exercised bitwise in
tests/test_distributed.py.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline
from repro.distributed import gtc as gtc_lib
from repro.launch.steps import make_loss_fn
from repro.models import build_model
from repro.train import (GTC, GTCShardMap, ListSink, TrainBatch, Trainer)


def bench_workers(workers, *, model, cfg, batches, updates, lrs, tau):
    gcfg = gtc_lib.GTCConfig(tau=tau, n_workers=workers)
    if workers == 1:
        strategy = GTC(gcfg, clip=0.0)
    else:
        mesh = jax.make_mesh((1,), ("data",))
        strategy = GTCShardMap(gcfg, mesh, clip=0.0)
    sink = ListSink()
    trainer = Trainer(strategy, {"ce": make_loss_fn(model, cfg, "ce")},
                      metrics=sink)
    need = strategy.microbatches

    def source(n_updates, lr_list):
        i = 0
        for u in range(n_updates):
            for _ in range(need):
                yield TrainBatch(batches[i % len(batches)],
                                 lr_list[u % len(lr_list)], "ce")
                i += 1

    params = model.init(jax.random.key(0))
    state = trainer.init_state(params)
    state = trainer.fit(state, source(1, [lrs[0]]), resume=False)  # warm
    jax.block_until_ready(state.params)

    t0 = time.time()
    state = trainer.fit(state, source(updates, lrs), resume=False)
    jax.block_until_ready(state.params)
    wall = time.time() - t0

    frames_per_micro = int(np.prod(batches[0]["mask"].shape))
    int8_bytes = gtc_lib.wire_bytes_per_update(params, gcfg)
    f32_bytes = gtc_lib.wire_bytes_per_update(
        params, gtc_lib.GTCConfig(tau=tau, n_workers=workers,
                                  quantize_int8=False))
    rec = {"workers": workers, "updates": updates,
           "microbatches_per_update": need,
           "steps_per_sec": round(updates / wall, 2),
           "frames_per_sec": round(updates * need * frames_per_micro
                                   / wall, 1),
           "wall_s": round(wall, 3),
           "wire_bytes_int8": int8_bytes,
           "wire_bytes_f32": f32_bytes,
           "wire_ratio_f32_over_int8": round(f32_bytes / int8_bytes, 2),
           "gtc_density": round(sink.last("gtc_density"), 4),
           "compiles": trainer.updates["ce"]._cache_size()}
    print(f"  W={workers}  {rec['steps_per_sec']:7.2f} updates/s "
          f"{rec['frames_per_sec']:9.1f} frames/s  wire "
          f"{int8_bytes}B (int8) vs {f32_bytes}B (f32) = "
          f"{rec['wire_ratio_f32_over_int8']}x, density "
          f"{rec['gtc_density']}, {rec['compiles']} compile(s)")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=12)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--tau", type=float, default=2e-4)
    ap.add_argument("--min-wire-ratio", type=float, default=3.0)
    ap.add_argument("--out", default="experiments/benchmarks")
    args = ap.parse_args(argv)

    pc = PipelineConfig(n_labeled=32, n_val=8,
                        lstm_hidden=args.hidden, n_layers=args.layers)
    pipe = SSLPipeline(pc, out_dir=os.path.join(args.out, "_gtc_bench"))
    cfg = pipe.student_cfg
    model = build_model(cfg)
    batches = pipe._batches(pipe.rng_labeled, chunked=True, seed=0)
    lrs = [5e-2 * (0.9 ** i) for i in range(args.updates)]
    print(f"{len(batches)} chunked batches of {pc.batch}x{pc.chunk_len}, "
          f"{args.updates} updates, tau={args.tau}")

    records = [bench_workers(w, model=model, cfg=cfg, batches=batches,
                             updates=args.updates, lrs=lrs, tau=args.tau)
               for w in (1, 2, 4)]
    for r in records:
        assert r["compiles"] == 1, r          # lr sweep must not re-jit
        assert r["wire_ratio_f32_over_int8"] >= args.min_wire_ratio, r

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "gtc_bench.json")
    with open(path, "w") as f:
        json.dump({"config": vars(args), "records": records}, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
