"""§Roofline: three-term roofline per (arch x shape) from dry-run artifacts.

Reads experiments/dryrun/*.json (written by launch/dryrun.py), computes

  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = wire_bytes_per_device / link_bw

using the cost-probe numbers when present (the scanned production artifact
undercounts loop bodies — see configs/base.py).  cost_analysis() of the
SPMD-partitioned module reports the *per-device* program, so no further
/chips.  Also reports MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def active_params(cfg) -> int:
    """Analytic per-token active parameter count (MoE-aware)."""
    d, v = cfg.d_model, cfg.vocab_size
    n = v * d * (1 if cfg.tie_embeddings else 2)
    hd = cfg.resolved_head_dim
    for seg in cfg.segments:
        for spec in seg.pattern:
            layer = 0
            if spec.mixer in ("attn", "swa"):
                if cfg.mla:
                    m = cfg.mla
                    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                    layer += d * m.q_lora_rank \
                        + m.q_lora_rank * cfg.n_heads * qk
                    layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    layer += m.kv_lora_rank * cfg.n_heads * \
                        (m.qk_nope_head_dim + m.v_head_dim)
                    layer += cfg.n_heads * m.v_head_dim * d
                else:
                    layer += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                        + cfg.n_heads * hd * d
            elif spec.mixer == "rglru":
                w = cfg.lru_width or d
                layer += 2 * d * w + 2 * w * w + w * d
            elif spec.mixer == "mlstm":
                inner = int(cfg.mlstm_proj_factor * d)
                layer += 2 * d * inner + 3 * inner * inner + inner * d
            elif spec.mixer == "slstm":
                layer += d * 4 * d + 4 * d * (d // cfg.n_heads) \
                    + 2 * d * int(cfg.slstm_proj_factor * d)
            if spec.ffn == "mlp":
                layer += 3 * d * cfg.d_ff
            elif spec.ffn == "moe":
                layer += d * cfg.n_experts                      # router
                per_expert = 3 * d * cfg.moe_d_ff
                layer += per_expert * cfg.moe_top_k             # routed
                layer += per_expert * cfg.n_shared_experts      # shared
            n += layer * seg.repeat
    if cfg.encoder is not None:
        enc_layer = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
            + cfg.n_heads * hd * d + 2 * d * cfg.d_ff
        n += enc_layer * cfg.encoder.n_layers
        # decoder cross attention
        n += cfg.n_layers * 4 * d * cfg.n_heads * hd
    if cfg.family == "lstm_am":
        n = 0
        d_in = cfg.feat_dim
        mult = 2 if "bilstm" in cfg.mixers() else 1
        for _ in range(cfg.n_layers):
            n += mult * (d_in * 4 * cfg.lstm_hidden
                         + cfg.lstm_hidden * 4 * cfg.lstm_hidden)
            d_in = mult * cfg.lstm_hidden
        n += d_in * cfg.n_senones
    return int(n)


def param_bytes(cfg, dtype_bytes: int = 2) -> int:
    return active_params_total(cfg) * dtype_bytes


def active_params_total(cfg) -> int:
    """Total stored params (all experts), for memory accounting."""
    na = active_params(cfg)
    for seg in cfg.segments:
        for spec in seg.pattern:
            if spec.ffn == "moe":
                per_expert = 3 * cfg.d_model * cfg.moe_d_ff
                na += per_expert * (cfg.n_experts - cfg.moe_top_k) \
                    * seg.repeat
    return na


def memory_traffic(cfg, shape, n_devices: int, record: dict) -> float:
    """Analytic per-device HBM traffic per step (bytes).

    cost_analysis() bytes are pre-fusion operand counts (order-of-magnitude
    overcounts), so the memory roofline term uses a standard analytic
    model instead:
      train:   3x params (bf16 read + grad write + opt update) +
               activation traffic ~ 8 bytes x L x tokens x d_model
               (fwd write + bwd read + recompute under remat)
      prefill: params read + 4 bytes x L x tokens x d_model
      decode:  params read + full KV/state cache read per token
    """
    pb = param_bytes(cfg)
    d = cfg.d_model
    L = max(cfg.n_layers, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        traffic = 3 * pb * 2 + 8.0 * L * tokens * d
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        traffic = pb + 4.0 * L * tokens * d
    else:
        cache = record["memory"]["argument_bytes"] / n_devices  # incl cache
        traffic = pb / n_devices + cache
        return traffic
    return traffic / n_devices


def model_flops(cfg, shape, n_devices: int) -> float:
    """6*N_active*D training / 2*N_active*D prefill / 2*N_active*B decode,
    GLOBAL; divide by devices for the per-device roofline."""
    na = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.encoder is not None:
            tokens = shape.global_batch * min(cfg.max_target_len,
                                              shape.seq_len)
        return 6.0 * na * tokens
    if shape.kind == "prefill":
        return 2.0 * na * shape.global_batch * shape.seq_len
    return 2.0 * na * shape.global_batch          # decode: one token


@dataclass
class Roofline:
    arch: str
    shape: str
    tag: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    corrected: bool
    peak_gb: float

    def suggestion(self) -> str:
        if self.dominant == "collective":
            return ("reduce resharding: fewer all-gathers via better "
                    "param/activation layout or collective overlap")
        if self.dominant == "memory":
            return ("raise arithmetic intensity: fuse/bigger tiles, bf16 "
                    "cache, avoid full-logit materialization")
        if self.useful_ratio < 0.4:
            return ("cut non-model FLOPs: masked attention blocks, MoE "
                    "capacity padding, remat recompute")
        return "near compute roofline: overlap collectives into the MXU"


def analyze(record: dict, cfg, shape) -> Roofline:
    n_dev = record["n_devices"]
    probe = record.get("probe") or {}
    corrected = "flops" in probe
    mf = model_flops(cfg, shape, n_dev)
    if corrected:
        flops = probe["flops"]
        wire = probe["wire_bytes_per_device"]
    else:
        # scanned production artifact: XLA counts loop bodies once, so raw
        # flops undercount by ~depth.  Best available per-device estimate:
        # max(analytic MODEL_FLOPS/chips, raw HLO) — analytic is a lower
        # bound on executed flops, raw catches non-model overheads when
        # the model is shallow.  wire: raw, flagged (collectives inside
        # scan bodies count once; probe rows are exact).
        flops = max(mf / n_dev, record["flops"])
        wire = record["wire_bytes_per_device"]
    # memory term: analytic traffic model for ALL rows — cost_analysis
    # bytes are pre-fusion operand counts, overcounted by orders of
    # magnitude (probe rows additionally materialize whole-seq attention)
    byts = memory_traffic(cfg, shape, n_dev, record)
    terms = {"compute": flops / PEAK_FLOPS,
             "memory": byts / HBM_BW,
             "collective": wire / ICI_BW}
    dom = max(terms, key=terms.get)
    return Roofline(
        arch=record["arch"], shape=record["shape"],
        tag=record.get("tag", ""),
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], dominant=dom,
        model_flops=mf, hlo_flops=flops * n_dev,
        useful_ratio=mf / max(flops * n_dev, 1.0),
        corrected=corrected,
        peak_gb=record["memory"]["peak_bytes_per_device"] / n_dev / 2**30)


def run(dryrun_dir: str = "experiments/dryrun",
        out_dir: str = "experiments/benchmarks", mesh: str = "pod"):
    from repro.configs import get_arch, get_shape
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        cfg = get_arch(rec["arch"])
        shape = get_shape(rec["shape"])
        rows.append(analyze(rec, cfg, shape))

    lines = ["| arch | shape | variant | compute s | memory s | "
             "collective s | dominant | useful | GB/chip | src |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r.arch, r.shape, r.tag)):
        lines.append(
            f"| {r.arch} | {r.shape} | {r.tag or 'base'} | "
            f"{r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | {r.dominant} | "
            f"{r.useful_ratio:.2f} | {r.peak_gb:.1f} | "
            f"{'probe' if r.corrected else 'analytic'} |")
    table = "\n".join(lines)
    with open(os.path.join(out_dir, f"roofline_{mesh}.md"), "w") as f:
        f.write(table + "\n")
    with open(os.path.join(out_dir, f"roofline_{mesh}.json"), "w") as f:
        json.dump([r.__dict__ | {"suggestion": r.suggestion()}
                   for r in rows], f, indent=1)
    return rows, table
