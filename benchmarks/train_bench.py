"""Trainer throughput: steps/sec (and frames/sec) for Local vs BMUFVmap.

The unified Trainer compiles one lr-as-argument update per (loss kind,
batch shape); this records what that buys as a *number*:

  PYTHONPATH=src python benchmarks/train_bench.py
  PYTHONPATH=src python benchmarks/train_bench.py --updates 16 --hidden 128

Both strategies run the same CE workload on the same synthetic corpus
through the same Trainer.fit() loop.  BMUF consumes tau*W microbatches
per update, so the fair comparison is *frames*/sec (each BMUF update
does tau*W local steps of work); steps/sec is reported as the raw
update cadence.  Also recorded: the wall-clock cost of sweeping the
learning rate across every update (re-jit would pay a compile per
distinct lr; the lr-as-argument step must not).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline
from repro.distributed.bmuf import BMUFConfig
from repro.launch.steps import make_loss_fn
from repro.models import build_model
from repro.train import BMUFVmap, ListSink, Local, TrainBatch, Trainer


def bench_strategy(strategy, label, *, model, cfg, batches, updates, lrs):
    trainer = Trainer(strategy, {"ce": make_loss_fn(model, cfg, "ce")},
                      metrics=ListSink())
    need = strategy.microbatches

    def source(n_updates, lr_list):
        i = 0
        for u in range(n_updates):
            for _ in range(need):
                yield TrainBatch(batches[i % len(batches)],
                                 lr_list[u % len(lr_list)], "ce")
                i += 1

    # warmup: one update compiles the executable
    state = trainer.init_state(model.init(jax.random.key(0)))
    state = trainer.fit(state, source(1, [lrs[0]]), resume=False)
    jax.block_until_ready(state.params)

    t0 = time.time()
    state = trainer.fit(state, source(updates, lrs), resume=False)
    jax.block_until_ready(state.params)
    wall = time.time() - t0

    frames_per_micro = int(np.prod(batches[0]["mask"].shape))
    frames = updates * need * frames_per_micro
    rec = {"strategy": label, "updates": updates,
           "microbatches_per_update": need,
           "distinct_lrs": len(set(lrs)),
           "steps_per_sec": round(updates / wall, 2),
           "frames_per_sec": round(frames / wall, 1),
           "wall_s": round(wall, 3),
           "compiles": trainer.updates["ce"]._cache_size()}
    print(f"  {label:10s} {rec['steps_per_sec']:8.2f} updates/s "
          f"{rec['frames_per_sec']:10.1f} frames/s "
          f"({need} microbatch(es)/update, "
          f"{rec['compiles']} compile(s) across {rec['distinct_lrs']} lrs)")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=12)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--block-steps", type=int, default=2)
    ap.add_argument("--out", default="experiments/benchmarks")
    args = ap.parse_args(argv)

    pc = PipelineConfig(n_labeled=32, n_val=8,
                        lstm_hidden=args.hidden, n_layers=args.layers)
    pipe = SSLPipeline(pc, out_dir=os.path.join(args.out, "_train_bench"))
    cfg = pipe.student_cfg
    model = build_model(cfg)
    batches = pipe._batches(pipe.rng_labeled, chunked=True, seed=0)
    # exponential LR sweep: every update sees a different lr — the
    # compile count staying at 1 is the tentpole's perf claim
    lrs = [5e-2 * (0.9 ** i) for i in range(args.updates)]
    print(f"{len(batches)} chunked batches of {pc.batch}x{pc.chunk_len}, "
          f"{args.updates} updates, {len(set(lrs))} distinct lrs")

    records = [
        bench_strategy(Local(), "local", model=model, cfg=cfg,
                       batches=batches, updates=args.updates, lrs=lrs),
        bench_strategy(
            BMUFVmap(BMUFConfig(n_workers=args.workers,
                                block_steps=args.block_steps)),
            "bmuf_vmap", model=model, cfg=cfg, batches=batches,
            updates=args.updates, lrs=lrs),
    ]
    for r in records:
        assert r["compiles"] == 1, r      # lr sweep must not re-compile
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "train_bench.json")
    with open(path, "w") as f:
        json.dump({"config": vars(args), "records": records}, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
