"""Elastic-wave benchmark: continuous generate -> train -> promote under
injected worker deaths, as numbers.

Runs the full wave driver (``SSLPipeline.run_waves``) at laptop scale:
baseline + teacher, then ``--waves`` generate/train/promote waves with
one BMUF lane killed after block 1 of every wave and revived two blocks
later.  Reports the costs the paper's million-hour operation cares
about — how many waves per hour the stack sustains, how many worker
deaths it absorbed, and what membership changes cost — next to the
health checks that make the numbers trustworthy (manifest
checksum-verified, generation ledger fully done).

Writes ``experiments/benchmarks/elastic.json`` and mirrors it to
repo-root ``BENCH_elastic.json`` for the tier2-elastic CI gates:

  waves >= 2, every wave's kill absorbed (final W back to full),
  manifest + ledger clean, resize overhead a small fraction of wall.

  PYTHONPATH=src python benchmarks/elastic_bench.py
  PYTHONPATH=src python benchmarks/elastic_bench.py --waves 3
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import time


def run(n_waves: int, out_dir: str, work_dir: str) -> dict:
    from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline

    # fresh work dir: wave numbering and ledger state start from zero
    shutil.rmtree(work_dir, ignore_errors=True)
    pc = dataclasses.replace(PipelineConfig.tiny(), bmuf_workers=4,
                             bmuf_block_steps=2, n_sub_epochs=4,
                             labeled_every=2, chunked_until=3)
    pipe = SSLPipeline(pc, out_dir=work_dir, student_trainer="bmuf")

    t0 = time.perf_counter()
    base = pipe.stage_baseline()
    pipe.stage_teacher()
    t_setup = time.perf_counter() - t0

    t0 = time.perf_counter()
    rep = pipe.run_waves(n_waves, kill_at=1, revive_after=2)
    t_waves = time.perf_counter() - t0

    final_w = [wv["student"]["final_workers"] for wv in rep["waves"]]
    rec = {
        "waves": rep["n_waves"],
        "bmuf_workers": pc.bmuf_workers,
        "wall_s": {"setup": round(t_setup, 2),
                   "waves": round(t_waves, 2)},
        "waves_per_hour": round(rep["n_waves"] / (t_waves / 3600.0), 2),
        "restarts_absorbed": rep["restarts_absorbed"],
        "resize_count": rep["resize_count"],
        "resize_overhead_s": rep["resize_seconds"],
        "resize_overhead_frac": round(rep["resize_seconds"]
                                      / max(t_waves, 1e-9), 4),
        "final_workers_per_wave": final_w,
        "all_kills_absorbed": all(w == pc.bmuf_workers for w in final_w),
        "manifest_clean": rep["manifest_clean"],
        "n_verified_shards": rep["n_verified"],
        "gc_removed": rep["gc_removed"],
        "ledger_clean": rep["ledger_clean"],
        "store_waves": [wv["wave"] for wv in rep["waves"]],
        "baseline_fer": base["val_fer"],
        "final_fer": rep["final_fer"],
        "rel_fer_reduction_pct": rep["rel_fer_reduction_pct"],
        "chaos": [wv["student"]["chaos"] for wv in rep["waves"]],
    }

    print(f"{'wave':<6}{'store':>6}{'FER':>8}{'resizes':>9}"
          f"{'final W':>9}")
    for i, wv in enumerate(rep["waves"]):
        s = wv["student"]
        print(f"{i:<6}{wv['wave']:>6}{s['val_fer']:>8.3f}"
              f"{s['resizes']['count']:>9}{s['final_workers']:>9}")
    print(f"{rec['waves_per_hour']} waves/hour, "
          f"{rec['restarts_absorbed']} deaths absorbed across "
          f"{rec['resize_count']} resizes "
          f"({rec['resize_overhead_s']}s, "
          f"{100 * rec['resize_overhead_frac']:.2f}% of wall)")
    print(f"manifest clean={rec['manifest_clean']} "
          f"({rec['n_verified_shards']} shards), "
          f"ledger done={rec['ledger_clean']}")

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "elastic.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    # repo-root copy: the artifact the tier2-elastic CI gates read
    with open("BENCH_elastic.json", "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {path} and BENCH_elastic.json")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=2)
    ap.add_argument("--out", default="experiments/benchmarks")
    ap.add_argument("--work-dir", default="experiments/elastic_bench")
    args = ap.parse_args()
    run(args.waves, args.out, args.work_dir)


if __name__ == "__main__":
    main()
