"""Paper-table reproductions on the synthetic corpus (laptop scale).

One benchmark per paper table/figure:
  table1  — scheduled learning x sMBR-teacher 2x2 grid (rel. FER reduction)
  table2  — sequence training of SSL students, GTC vs BMUF trainers
  fig1    — per-sub-epoch convergence of the scaled "1M-hour" schedule
  table34 — final model vs baseline across device/SNR conditions

All numbers are *relative* error reductions against the same baseline
recipe, mirroring how the paper reports WERR.  Absolute FERs on the
synthetic corpus are meaningless; the deliverable is that the orderings
and signs the paper reports emerge from the same design choices.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline
from repro.models import build_model
from repro.seqtrain.smbr import frame_error_rate


def _fer_by_condition(pipe, params):
    """FER split by device condition (paper Tables 3/4 structure)."""
    from repro.data.synthetic import synth_utterance
    from repro.data.features import featurize_utterance
    from repro.data.chunking import pad_batch
    model = build_model(pipe.student_cfg)
    by_dev = {}
    for uid in range(200_000, 200_000 + 48):
        u = synth_utterance(pipe.synth, uid)
        f, l, _ = featurize_utterance(u, pipe.feat, mvn=pipe.loader.mvn,
                                      lookahead=0)
        by_dev.setdefault(u.device, []).append((f, l, uid))
    out = {}
    for dev, pairs in sorted(by_dev.items()):
        b = pad_batch(pairs)
        h, _ = model.apply(params, jnp.asarray(b["feats"]))
        lg = model.unembed(params, h)
        out[dev] = float(frame_error_rate(lg, jnp.asarray(b["labels"]),
                                          jnp.asarray(b["mask"])))
    return out


def run(out_dir: str = "experiments/benchmarks", scale: str = "tiny"):
    os.makedirs(out_dir, exist_ok=True)
    results = {}
    pc = (PipelineConfig.tiny() if scale == "tiny"
          else PipelineConfig.small())

    # ---- shared pipeline: baseline + teacher + targets once ----
    pipe = SSLPipeline(pc, out_dir=os.path.join(out_dir, "pipe"),
                       student_trainer="gtc")
    t0 = time.time()
    base = pipe.stage_baseline()
    teach = pipe.stage_teacher()
    targ = pipe.stage_targets()
    results["setup"] = {"baseline": base, "teacher": teach,
                       "targets": targ, "sec": round(time.time() - t0, 1)}

    # ---- Table 1: SL x sMBR-teacher (scheduled-learning ablation) ----
    # "with SL" is the default student stage; "without SL" = no labeled
    # interleave (labeled_every > n_sub_epochs)
    t1 = {}
    student = pipe.stage_student()
    t1["with_SL"] = student["rel_fer_reduction_pct"]
    pipe_nosl = SSLPipeline(pc, out_dir=os.path.join(out_dir, "pipe"),
                            student_trainer="gtc")
    pipe_nosl.pc = pc
    nosl_sched = pc.__class__(**{**pc.__dict__,
                                 "labeled_every": pc.n_sub_epochs + 1})
    pipe_nosl.pc = nosl_sched
    t1["without_SL"] = pipe_nosl.stage_student()["rel_fer_reduction_pct"]
    results["table1"] = t1

    # ---- Table 2: sMBR of SSL students; GTC vs BMUF ----
    t2 = {}
    smbr_gtc = pipe.stage_smbr()
    t2["ssl_sl_smbr_gtc"] = smbr_gtc["rel_fer_reduction_pct"]
    pipe_b = SSLPipeline(pc, out_dir=os.path.join(out_dir, "pipe"),
                         student_trainer="bmuf")
    stu_b = pipe_b.stage_student()
    t2["ssl_student_bmuf"] = stu_b["rel_fer_reduction_pct"]
    smbr_b = pipe_b.stage_smbr()
    t2["ssl_sl_smbr_bmuf"] = smbr_b["rel_fer_reduction_pct"]
    t2["ssl_student_gtc"] = student["rel_fer_reduction_pct"]
    results["table2"] = t2

    # ---- Fig 1: convergence per sub-epoch (loss trace) ----
    results["fig1"] = {"note": "per-sub-epoch FER trace",
                       "student_steps": student["n_steps"],
                       "loss_first": student["loss_first"],
                       "loss_last": student["loss_last"]}

    # ---- Tables 3/4: final model vs baseline by condition ----
    model = build_model(pipe.student_cfg)
    base_params = pipe._load_or_none("baseline", pipe.student_cfg)
    final_params = pipe._load_or_none("smbr", pipe.student_cfg)
    fer_base = _fer_by_condition(pipe, base_params)
    fer_final = _fer_by_condition(pipe, final_params)
    results["table34"] = {
        dev: {"baseline_fer": fer_base[dev], "final_fer": fer_final[dev],
              "rel_reduction_pct": round(
                  100 * (fer_base[dev] - fer_final[dev])
                  / max(fer_base[dev], 1e-9), 2)}
        for dev in fer_base}

    with open(os.path.join(out_dir, "tables.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results
