"""StreamServer: streaming-AM sessions over the SlotServer core.

Acceptance pins (ISSUE 9):
  * slot-based emissions bitwise-identical to the lockstep
    ``StreamingEngine.feed`` loop, for both streaming families (LSTM AM
    per-frame posteriors, whisper one-position-per-chunk);
  * a stream that detaches, has its slot replaced by queued work, and
    reattaches emits bitwise what an uninterrupted solo run emits;
  * SLO tiers: interactive presence tightens the window, firehose
    sessions shed/park under interactive pressure and still finish
    correctly;
  * honest frame-level utilization (dead rows and padding counted).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import Segment
from repro.configs.lstm_am_7khr import CONFIG
from repro.models import build_model
from repro.serve import (FIREHOSE, INTERACTIVE, SLOTier, StreamServer,
                         StreamingEngine, TieredPolicy)

F, V, K = 6, 25, 5

AM = CONFIG.replace(
    lstm_hidden=16, feat_dim=F, n_senones=V, vocab_size=V,
    segments=(Segment((CONFIG.segments[0].pattern[0],), repeat=2),))
WHISPER = reduced(get_arch("whisper-medium"))


@pytest.fixture(scope="module")
def am():
    m = build_model(AM)
    return m.init(jax.random.key(0))


@pytest.fixture(scope="module")
def whisper():
    m = build_model(WHISPER)
    return m.init(jax.random.key(1))


def _utts(rng, lens, fd=F):
    return [(rng.normal(size=(t, fd)) * 0.1).astype(np.float32)
            for t in lens]


def _lockstep(cfg, params, utt, chunk, k=K):
    """The pre-refactor reference: one solo stream through the lockstep
    open_stream/feed loop at the same chunk boundaries."""
    eng = StreamingEngine(cfg, params, k=k, n_slots=2)
    sid = eng.open_stream()
    vals, idx = [], []
    for c0 in range(0, utt.shape[0], chunk):
        v, i = eng.feed({sid: utt[c0:c0 + chunk]})[sid]
        vals.append(v)
        idx.append(i)
    eng.close_stream(sid)
    return np.concatenate(vals, axis=0), np.concatenate(idx, axis=0)


# ----------------------------------------------------- lockstep parity

def test_stream_server_matches_lockstep_am(am):
    rng = np.random.default_rng(0)
    lens = [23, 7, 40, 16, 31]          # ragged: partial last chunks,
    utts = _utts(rng, lens)             # staggered retire/admit
    srv = StreamServer(AM, am, n_slots=3, chunk_frames=8, sync_every=2,
                       k=K)
    rids = [srv.submit(u) for u in utts]
    done = srv.drain()
    assert sorted(done) == sorted(rids)
    for rid, u in zip(rids, utts):
        sv, si = done[rid].emissions()
        assert sv.shape == (u.shape[0], K)          # per-frame emission
        lv, li = _lockstep(AM, am, u, 8)
        np.testing.assert_array_equal(si, li)
        np.testing.assert_array_equal(sv, lv)       # bitwise, not close
    assert srv.stats["useful_units"] == sum(lens)
    assert 0.0 < srv.utilization() <= 1.0


def test_stream_server_matches_lockstep_whisper(whisper):
    rng = np.random.default_rng(1)
    lens = [11, 4, 19]
    utts = _utts(rng, lens, WHISPER.d_model)
    srv = StreamServer(WHISPER, whisper, n_slots=2, chunk_frames=4,
                       sync_every=2, k=K)
    rids = [srv.submit(u) for u in utts]
    done = srv.drain()
    assert sorted(done) == sorted(rids)
    for rid, u, t in zip(rids, utts, lens):
        sv, si = done[rid].emissions()
        n_chunks = -(-t // 4)
        assert sv.shape == (n_chunks, K)        # one position per chunk
        lv, li = _lockstep(WHISPER, whisper, u, 4)
        np.testing.assert_array_equal(si, li)
        np.testing.assert_array_equal(sv, lv)


# -------------------------------------------------- detach / reattach

def test_detach_replace_reattach_bitwise(am):
    """ISSUE 9 satellite: a stream that detaches mid-flight, has its
    slot taken by queued work, then reattaches must emit bitwise what an
    uninterrupted solo run emits."""
    rng = np.random.default_rng(2)
    utt_a, utt_b = _utts(rng, [40, 12])

    solo = StreamServer(AM, am, n_slots=1, chunk_frames=8, sync_every=1,
                        k=K)
    ra = solo.submit(utt_a)
    ref_v, ref_i = solo.drain()[ra].emissions()

    srv = StreamServer(AM, am, n_slots=1, chunk_frames=8, sync_every=1,
                       k=K)
    ra = srv.submit(utt_a)
    srv.pump()                              # A consumes one chunk
    srv.pump()                              # ... and another
    srv.detach(ra)                          # state row -> host
    assert srv.n_active == 0
    rb = srv.submit(utt_b)                  # B takes A's (only) slot
    done = {}
    while rb not in done:
        done.update(srv.pump())
    bv, bi = done[rb].emissions()
    lv, li = _lockstep(AM, am, utt_b, 8)
    np.testing.assert_array_equal(bi, li)   # B unharmed by A's residue
    np.testing.assert_array_equal(bv, lv)
    srv.reattach(ra)                        # A's row restored bitwise
    done = srv.drain()
    av, ai = done[ra].emissions()
    np.testing.assert_array_equal(ai, ref_i)
    np.testing.assert_array_equal(av, ref_v)
    assert srv.stats["parked"] == 1


def test_detach_requires_attachment_and_drain_refuses_held(am):
    srv = StreamServer(AM, am, n_slots=1, chunk_frames=4, sync_every=1)
    rid = srv.submit(_utts(np.random.default_rng(3), [12])[0])
    with pytest.raises(KeyError):
        srv.detach(rid)                     # queued, not yet attached
    srv.pump()
    srv.detach(rid)
    with pytest.raises(RuntimeError, match="detached"):
        srv.drain()                         # held stream never finishes
    with pytest.raises(ValueError):
        srv.reattach(999)
    srv.reattach(rid)
    assert rid in srv.drain()


# --------------------------------------------------------- live streams

def test_live_append_close_matches_final_submit(am):
    rng = np.random.default_rng(4)
    (utt,) = _utts(rng, [24])
    ref = StreamServer(AM, am, n_slots=1, chunk_frames=8, sync_every=2)
    rr = ref.submit(utt)
    ref_v, ref_i = ref.drain()[rr].emissions()

    srv = StreamServer(AM, am, n_slots=1, chunk_frames=8, sync_every=2)
    rid = srv.submit(utt[:8], final=False)
    srv.pump()                              # consumes what's there...
    srv.pump()                              # ...then idles (dead row)
    srv.append(rid, utt[8:])
    srv.close(rid)
    with pytest.raises(ValueError):
        srv.append(rid, utt[:8])            # closed
    done = {}
    while rid not in done:
        done.update(srv.pump())
    v, i = done[rid].emissions()
    np.testing.assert_array_equal(i, ref_i)
    np.testing.assert_array_equal(v, ref_v)


def test_drain_refuses_open_streams(am):
    srv = StreamServer(AM, am, n_slots=1, chunk_frames=4, sync_every=1)
    srv.submit(_utts(np.random.default_rng(5), [8])[0], final=False)
    with pytest.raises(RuntimeError, match="open streams"):
        srv.drain()


# ----------------------------------------------------------- SLO tiers

def test_interactive_presence_tightens_window(am):
    rng = np.random.default_rng(6)
    fire, inter = _utts(rng, [64, 8])
    tiers = TieredPolicy((INTERACTIVE, FIREHOSE))
    srv = StreamServer(AM, am, n_slots=2, chunk_frames=4, sync_every=8,
                       tiers=tiers)
    srv.submit(fire, tier="firehose")
    srv.pump()
    assert srv.stats["steps"] == 16          # firehose-only: long window
    srv.submit(inter, tier="interactive")
    srv.pump()
    assert srv.stats["steps"] == 16 + 2      # interactive: 2-step window
    with pytest.raises(KeyError):
        srv.submit(inter, tier="bulk")       # unknown tier fails loudly


def test_firehose_parks_under_interactive_pressure(am):
    """Admission control: firehose streams occupying every slot are
    parked when interactive work queues, re-admitted after it clears,
    and their emissions are still bitwise correct."""
    rng = np.random.default_rng(7)
    fires = _utts(rng, [200, 200])      # outlast the 16-step window
    inters = _utts(rng, [8, 8])
    tiers = TieredPolicy((INTERACTIVE, FIREHOSE), shed_threshold=0.5)
    srv = StreamServer(AM, am, n_slots=2, chunk_frames=4, sync_every=2,
                       k=K, tiers=tiers)
    rf = [srv.submit(u, tier="firehose") for u in fires]
    srv.pump()                               # both firehose attached
    assert srv.occupancy()["firehose"] == 1.0
    ri = [srv.submit(u, tier="interactive") for u in inters]
    done2 = srv.pump()                       # rebalance parks firehose
    assert srv.stats["parked"] >= 1
    # the evicting interactive pair was admitted AND finished in that
    # single short window — the whole point of the tier machinery
    assert sorted(done2) == sorted(ri)
    done = srv.drain()
    done.update(done2)
    assert sorted(done) == sorted(rf + ri)
    for rid, u in zip(rf + ri, fires + inters):
        sv, si = done[rid].emissions()
        lv, li = _lockstep(AM, am, u, 4)
        np.testing.assert_array_equal(si, li)
        np.testing.assert_array_equal(sv, lv)
    # interactive finished strictly earlier than the parked firehose
    assert max(done[r].finished_sync for r in ri) < \
        max(done[r].finished_sync for r in rf)


def test_tier_max_batch_caps_occupancy(am):
    rng = np.random.default_rng(8)
    utts = _utts(rng, [40, 40, 40])     # outlast one 4-step window
    tiers = TieredPolicy((SLOTier("interactive", sync_every=2),
                          SLOTier("firehose", sync_every=4, max_batch=1,
                                  preemptible=True)))
    srv = StreamServer(AM, am, n_slots=3, chunk_frames=4, sync_every=4,
                       tiers=tiers)
    for u in utts:
        srv.submit(u, tier="firehose")
    srv.pump()
    assert srv._tier_counts().get("firehose", 0) == 1    # capped
    done = srv.drain()
    assert len(done) == 3                                # all served


# ------------------------------------------------------- honest stats

def test_frame_utilization_counts_padding_and_dead_rows(am):
    rng = np.random.default_rng(9)
    (utt,) = _utts(rng, [10])
    srv = StreamServer(AM, am, n_slots=4, chunk_frames=8, sync_every=2)
    rid = srv.submit(utt)
    done = srv.drain()
    assert rid in done
    # one window: 4 slots x 2 steps x 8 frames computed, 10 useful
    assert srv.stats["padded_units"] == 4 * 2 * 8
    assert srv.stats["useful_units"] == 10
    assert srv.utilization() == 10 / 64
    # the batch path's padding_efficiency reads slot stats too: one
    # honest number across surfaces (ISSUE 9 satellite)
    from repro.serve import padding_efficiency
    assert padding_efficiency(srv.stats) == srv.utilization()


def test_abort_recovers_streams(am):
    """A failed window must not strand its streams: they restart from
    frame 0 and still produce correct output."""
    rng = np.random.default_rng(10)
    (utt,) = _utts(rng, [16])
    srv = StreamServer(AM, am, n_slots=2, chunk_frames=4, sync_every=1,
                       k=K)
    rid = srv.submit(utt)
    srv.pump()
    orig = srv._run_window

    def boom(k):
        raise RuntimeError("injected")

    srv._run_window = boom
    with pytest.raises(RuntimeError, match="injected"):
        srv.pump()
    srv._run_window = orig
    assert srv.n_active == 0 and srv.queue.n_pending == 1
    v, i = srv.drain()[rid].emissions()
    lv, li = _lockstep(AM, am, utt, 4)
    np.testing.assert_array_equal(i, li)
    np.testing.assert_array_equal(v, lv)


def test_submit_validates(am):
    srv = StreamServer(AM, am, n_slots=1, chunk_frames=4, sync_every=1)
    with pytest.raises(ValueError):
        srv.submit(np.zeros((4, F + 1), np.float32))
    with pytest.raises(ValueError):
        srv.submit(np.zeros((0, F), np.float32))

    wsrv_cap = 8
    from repro.configs.lstm_am_7khr import TEACHER
    bidi = TEACHER.replace(
        lstm_hidden=16, feat_dim=F, n_senones=V, vocab_size=V,
        segments=(Segment((TEACHER.segments[0].pattern[0],), repeat=2),))
    with pytest.raises(ValueError, match="streaming"):
        StreamServer(bidi, None, n_slots=1)


def test_whisper_max_frames_capacity(whisper):
    srv = StreamServer(WHISPER, whisper, n_slots=1, chunk_frames=4,
                       sync_every=1, max_frames=8)
    with pytest.raises(ValueError, match="max_frames"):
        srv.submit(np.zeros((9, WHISPER.d_model), np.float32))
    rid = srv.submit(np.zeros((4, WHISPER.d_model), np.float32),
                     final=False)
    with pytest.raises(ValueError, match="max_frames"):
        srv.append(rid, np.zeros((5, WHISPER.d_model), np.float32))
