"""Unified inference engine: batched == sequential, streaming == full,
queue completeness, batcher invariants, top-k emitter parity.

The acceptance bar for the engine (ISSUE 1): top-k indices identical to
the per-utterance sequential path, values within fp tolerance, and no
request ever dropped or reordered incorrectly by the batcher.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import Segment
from repro.configs.lstm_am_7khr import CONFIG, TEACHER
from repro.core.logit_store import topk_compress
from repro.models import build_model
from repro.serve import (LATENCY, THROUGHPUT, BatchPolicy, RequestQueue,
                         StreamingEngine, form_batches, make_topk_emitter,
                         padding_efficiency)
from repro.serve.request import InferenceRequest

F, V, K = 6, 25, 5


def _tiny(base):
    return base.replace(
        lstm_hidden=16, feat_dim=F, n_senones=V, vocab_size=V,
        segments=(Segment((base.segments[0].pattern[0],), repeat=2),))


STUDENT = _tiny(CONFIG)
BIDI = _tiny(TEACHER)


@pytest.fixture(scope="module")
def student():
    m = build_model(STUDENT)
    return m, m.init(jax.random.key(0))


@pytest.fixture(scope="module")
def teacher():
    m = build_model(BIDI)
    return m, m.init(jax.random.key(1))


def _utts(rng, lens):
    return [rng.normal(size=(t, F)).astype(np.float32) for t in lens]


def _sequential_topk(model, params, utt, k=K):
    """The naive per-utterance reference path."""
    h, _ = model.apply(params, jnp.asarray(utt)[None])
    logits = model.unembed(params, h)
    vals, idx = topk_compress(logits, k)
    return (np.asarray(vals[0]).astype(np.float32), np.asarray(idx[0]),
            np.asarray(logits[0]))


# ------------------------------------------------------------- batcher

def test_batcher_covers_every_request_once():
    rng = np.random.default_rng(0)
    reqs = [InferenceRequest(i, f) for i, f in
            enumerate(_utts(rng, [3, 70, 18, 129, 64, 1, 40]))]
    for policy in (THROUGHPUT, LATENCY):
        batches = form_batches(reqs, policy)
        seen = [r.rid for b in batches for r in b.requests]
        assert sorted(seen) == list(range(len(reqs)))
        for b in batches:
            assert b.feats.shape[0] == policy.max_batch
            assert b.feats.shape[1] % policy.bucket_multiple == 0
            for i, r in enumerate(b.requests):
                assert b.lens[i] == r.length
                np.testing.assert_array_equal(b.feats[i, :r.length], r.feats)
            # dummy rows are zero-length
            assert (b.lens[b.n_real:] == 0).all()


def test_batcher_sorting_reduces_padding():
    """Length-sorted packing (throughput) wastes fewer padded frames than
    arrival-order packing on a bimodal corpus."""
    rng = np.random.default_rng(1)
    lens = [int(x) for pair in zip(rng.integers(5, 15, 40),
                                   rng.integers(200, 260, 40)) for x in pair]
    reqs = [InferenceRequest(i, np.zeros((t, F), np.float32))
            for i, t in enumerate(lens)]
    pol = BatchPolicy("t", max_batch=8, bucket_multiple=16,
                      sort_by_length=True)
    pol_fifo = BatchPolicy("l", max_batch=8, bucket_multiple=16,
                           sort_by_length=False)
    eff_sorted = padding_efficiency(form_batches(reqs, pol))
    eff_fifo = padding_efficiency(form_batches(reqs, pol_fifo))
    assert eff_sorted > eff_fifo


# ------------------------------------------------- batched == sequential

@pytest.mark.parametrize("fixture", ["student", "teacher"])
def test_batched_matches_sequential(fixture, request):
    """Engine (padded, bucketed, batched) == naive per-utterance loop:
    identical top-k indices, logits within 1e-5, stored values to bf16
    resolution.  The bidirectional teacher is the hard case — its
    backward pass must start at each row's true last frame."""
    model, params = request.getfixturevalue(fixture)
    cfg = STUDENT if fixture == "student" else BIDI
    rng = np.random.default_rng(2)
    # mixed lengths sharing one padded batch shape (both groups bucket to
    # 48): exercises the lens machinery, one XLA program for the engine
    lens = [11, 48, 23, 48]
    utts = _utts(rng, lens)
    eng = StreamingEngine(cfg, params, k=K,
                          policy=BatchPolicy("t", max_batch=3,
                                             bucket_multiple=16))
    rids = [eng.submit(u) for u in utts]
    res = eng.run()
    assert eng.queue.drained
    for rid, u in zip(rids, utts):
        vals_s, idx_s, logits_s = _sequential_topk(model, params, u)
        r = res[rid]
        np.testing.assert_array_equal(r.idx, idx_s)
        np.testing.assert_allclose(r.vals, vals_s, atol=1e-2)  # bf16 grid
        # raw fp parity on the engine's forward (the 1e-5 criterion)
        hb, _ = model.apply(params, jnp.asarray(u)[None],
                            lens=jnp.asarray([u.shape[0]]))
        np.testing.assert_allclose(np.asarray(model.unembed(params, hb)[0]),
                                   logits_s, atol=1e-5)


# ------------------------------------------------ streaming equivalence

def test_streaming_chunked_equals_full(student):
    """Chunked engine.feed over slots == one full-utterance forward:
    identical indices per frame, and identical final recurrent state."""
    model, params = student
    rng = np.random.default_rng(4)
    x0, x1 = _utts(rng, [50, 37])           # ragged: different chunk tails
    eng = StreamingEngine(STUDENT, params, k=K, policy=LATENCY, n_slots=3)
    s0, s1 = eng.open_stream(), eng.open_stream()
    got = {s0: [], s1: []}
    for lo in range(0, 50, 16):
        chunks = {}
        if lo < 50:
            chunks[s0] = x0[lo:lo + 16]
        if lo < 37:
            chunks[s1] = x1[lo:lo + 16]
        out = eng.feed(chunks)
        for sid in out:
            got[sid].append(out[sid])
    for sid, x in ((s0, x0), (s1, x1)):
        idx = np.concatenate([i for _, i in got[sid]])
        vals = np.concatenate([v for v, _ in got[sid]])
        vals_s, idx_s, _ = _sequential_topk(model, params, x)
        np.testing.assert_array_equal(idx, idx_s)
        np.testing.assert_allclose(vals, vals_s, atol=1e-2)
    eng.close_stream(s0)
    eng.close_stream(s1)
    with pytest.raises(ValueError):
        eng.close_stream(s0)            # double close
    with pytest.raises(ValueError):
        eng.feed({s0: x0[:4]})          # feeding a closed stream
    assert eng.open_stream() in (s0, s1)    # slots recycle cleanly


def test_stream_state_carry_equals_full(student):
    """model.stream_step chunk-carried state == full apply() final state,
    including a ragged (lens-masked) chunk boundary."""
    model, params = student
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 30, F)), jnp.float32)
    _, aux = model.apply(params, x)
    st = model.init_stream_state(2)
    h_parts = []
    for lo in (0, 10, 20):
        h, st = model.stream_step(params, st, x[:, lo:lo + 10])
        h_parts.append(h)
    full_h, _ = model.apply(params, x)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(h_parts, 1)),
                               np.asarray(full_h), atol=1e-5)
    for (h1, c1), (h2, c2) in zip(st, aux["state"]):
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                                   atol=1e-5)
    # ragged chunk: row 1 stops at frame 25 of 30
    st = model.init_stream_state(2)
    h, st = model.stream_step(params, st, x[:, :20])
    h, st = model.stream_step(params, st, x[:, 20:30],
                              lens=jnp.asarray([10, 5]))
    ref_h, ref_aux = model.apply(params, x[1:2, :25])
    np.testing.assert_allclose(np.asarray(st[0][0][1]),
                               np.asarray(ref_aux["state"][0][0][0]),
                               atol=1e-5)


def test_feed_rejects_zero_frame_chunk(student):
    """A (0, F) chunk would write lens[sid]=0 and waste a batched step —
    refused at the API boundary; an empty chunks dict (every stream
    closed / nothing to feed) is an explicit no-op that dispatches no
    forward."""
    _, params = student
    eng = StreamingEngine(STUDENT, params, k=K, policy=LATENCY, n_slots=2)
    sid = eng.open_stream()
    with pytest.raises(ValueError, match="zero-frame"):
        eng.feed({sid: np.zeros((0, F), np.float32)})
    # the rejected call left the stream usable
    out = eng.feed({sid: np.zeros((3, F), np.float32)})
    assert out[sid][0].shape == (3, K)
    # all-slots-closed edge: no step dispatched for an empty feed
    eng.close_stream(sid)
    calls = {"n": 0}
    real = eng._stream_fwd

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    eng._stream_fwd = counting
    assert eng.feed({}) == {}
    assert calls["n"] == 0
    with pytest.raises(ValueError):        # closed stream still refused
        eng.feed({sid: np.zeros((3, F), np.float32)})


def test_feed_pipelined_matches_sequential(student):
    """The double-buffered feed driver (feed_async staged ahead of the
    in-flight step) yields results identical to sequential feed()."""
    model, params = student
    rng = np.random.default_rng(12)
    x0, x1 = _utts(rng, [50, 37])

    def chunk_iter():
        for lo in range(0, 50, 16):
            chunks = {}
            if lo < 50:
                chunks[0] = x0[lo:lo + 16]
            if lo < 37:
                chunks[1] = x1[lo:lo + 16]
            yield chunks

    eng_seq = StreamingEngine(STUDENT, params, k=K, policy=LATENCY,
                              n_slots=3)
    eng_seq.open_stream(), eng_seq.open_stream()
    seq = [eng_seq.feed(c) for c in chunk_iter()]
    eng_pipe = StreamingEngine(STUDENT, params, k=K, policy=LATENCY,
                               n_slots=3)
    eng_pipe.open_stream(), eng_pipe.open_stream()
    pipe = list(eng_pipe.feed_pipelined(chunk_iter(), depth=2))
    assert len(seq) == len(pipe)
    for a, b in zip(seq, pipe):
        assert sorted(a) == sorted(b)
        for sid in a:
            np.testing.assert_array_equal(a[sid][1], b[sid][1])
            np.testing.assert_array_equal(a[sid][0], b[sid][0])
    # a StreamFeed result is idempotent (second call returns the cache)
    pend = eng_pipe.feed_async({0: x0[:8]})
    r1 = pend.result()
    assert r1 is pend.result()


def test_padding_efficiency_counts_dead_rows():
    """Tail batches with fewer real rows than policy.max_batch still pay
    for the dummy rows: padded_frames == max_batch * T_bucket regardless
    of n_real, and padding_efficiency reflects exactly that accounting
    (the same numbers benchmarks/serve_bench.py reports)."""
    rng = np.random.default_rng(13)
    # 6 requests, max_batch 4 -> one full batch + a 2-real-row tail
    reqs = [InferenceRequest(i, f) for i, f in
            enumerate(_utts(rng, [10, 12, 14, 16, 9, 11]))]
    pol = BatchPolicy("t", max_batch=4, bucket_multiple=16,
                      sort_by_length=True)
    batches = form_batches(reqs, pol)
    assert [b.n_real for b in batches] == [4, 2]
    for b in batches:
        t_bucket = b.feats.shape[1]
        assert b.padded_frames == pol.max_batch * t_bucket   # dead rows in
        assert b.frames == sum(r.length for r in b.requests)
        assert (b.lens[b.n_real:] == 0).all()
    eff = padding_efficiency(batches)
    useful = sum(r.length for r in reqs)
    total = sum(b.padded_frames for b in batches)
    assert eff == useful / total
    assert eff < 1.0                        # the tail's dead rows count


# ------------------------------------------------- queue completeness

def test_queue_ordering_and_completeness(student):
    model, params = student
    rng = np.random.default_rng(6)
    lens = list(rng.integers(1, 90, 17))
    utts = _utts(rng, lens)
    eng = StreamingEngine(STUDENT, params, k=K,
                          policy=BatchPolicy("t", max_batch=4,
                                             bucket_multiple=16))
    rids = [eng.submit(u, meta={"n": i}) for i, u in enumerate(utts)]
    assert eng.queue.n_pending == len(utts)
    res = eng.run()
    assert eng.queue.drained and eng.queue.n_pending == 0
    assert sorted(res) == sorted(rids)
    assert sorted(eng.queue.completion_order) == sorted(rids)
    for i, (rid, u) in enumerate(zip(rids, utts)):
        assert res[rid].vals.shape == (u.shape[0], K)
        assert res[rid].idx.shape == (u.shape[0], K)
        assert res[rid].meta == {"n": i}
    # a second wave reuses the engine; run() hands over exactly this
    # wave's results (earlier ones were evicted with the first run —
    # the ledger must not grow with engine uptime)
    more = [eng.submit(u) for u in _utts(rng, [12, 3])]
    res2 = eng.run()
    assert sorted(res2) == sorted(more)


def test_run_failure_restores_pending(student):
    """A forward failure mid-drain strands nothing: unfulfilled requests
    go back to pending and a retry completes them all."""
    _, params = student
    rng = np.random.default_rng(9)
    eng = StreamingEngine(STUDENT, params, k=K,
                          policy=BatchPolicy("t", max_batch=2,
                                             bucket_multiple=16))
    rids = [eng.submit(u) for u in _utts(rng, [8, 21, 13])]
    good_fwd = eng._fwd

    def boom(*_a, **_kw):
        raise RuntimeError("injected forward failure")

    eng._fwd = boom
    with pytest.raises(RuntimeError):
        eng.run()
    assert eng.queue.n_pending == len(rids) and not eng.queue.drained
    eng._fwd = good_fwd
    res = eng.run()
    assert sorted(res) == sorted(rids) and eng.queue.drained


# ----------------------------------------------------- property-based

_PROP = {}


def _prop_engine(max_batch):
    """Engines (and their jit caches) shared across property examples."""
    if "model" not in _PROP:
        _PROP["model"] = build_model(STUDENT)
        _PROP["params"] = _PROP["model"].init(jax.random.key(0))
        _PROP["seq"] = jax.jit(
            lambda p, u: _PROP["model"].logits(p, u))
    if max_batch not in _PROP:
        _PROP[max_batch] = StreamingEngine(
            STUDENT, _PROP["params"], k=3,
            policy=BatchPolicy("t", max_batch=max_batch,
                               bucket_multiple=16))
    return _PROP[max_batch]


@given(seed=st.integers(0, 1000), max_batch=st.integers(1, 3),
       n=st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_engine_property_random_lengths(seed, max_batch, n):
    """Any mix of lengths and batch sizes: complete, correctly shaped,
    and a random utterance's indices match the sequential path."""
    eng = _prop_engine(max_batch)
    model, params = _PROP["model"], _PROP["params"]
    rng = np.random.default_rng(seed)
    lens = [int(t) for t in rng.integers(1, 48, n)]
    utts = _utts(rng, lens)
    rids = [eng.submit(u) for u in utts]
    res = eng.run()
    assert eng.queue.drained
    assert all(rid in res for rid in rids)
    for rid, u in zip(rids, utts):
        assert res[rid].idx.shape == (u.shape[0], 3)
    # parity spot-check on one utterance, padded to its bucket so the
    # reference jit-cache is shared across examples
    j = int(rng.integers(n))
    u = utts[j]
    from repro.serve import bucket_length
    tb = bucket_length(u.shape[0], 16)
    up = np.zeros((1, tb, F), np.float32)
    up[0, :u.shape[0]] = u
    logits, _ = _PROP["seq"](params, jnp.asarray(up))
    _, idx_s = jax.lax.top_k(logits[0, :u.shape[0]], 3)
    np.testing.assert_array_equal(res[rids[j]].idx, np.asarray(idx_s))


def test_dict_forward_mask_aware(teacher):
    """The trainer's chunked batches carry a frame mask; the teacher's
    dict path must not let the biLSTM backward pass read the padding of
    partial chunks (targets == per-row truncated forward)."""
    model, params = teacher
    from repro.core.teacher import TeacherRunner
    runner = TeacherRunner(BIDI, params, k=K)
    rng = np.random.default_rng(11)
    feats = rng.normal(size=(2, 32, F)).astype(np.float32)
    mask = np.zeros((2, 32), np.float32)
    mask[0, :32] = 1.0
    mask[1, :18] = 1.0                       # partial chunk
    vals, idx = runner.generate({"feats": jnp.asarray(feats),
                                 "mask": jnp.asarray(mask)})
    _, idx_s, _ = _sequential_topk(model, params, feats[1, :18])
    np.testing.assert_array_equal(np.asarray(idx[1, :18]), idx_s)


# ------------------------------------------------------ firehose path

def test_firehose_corpus_to_store(teacher, tmp_path):
    """generate_corpus_to_store: generator corpus, waves, one shard per
    utterance in submission order, frame-exact; and the failure contract
    — a failed call retried in full rewrites shards idempotently."""
    from repro.core.logit_store import LogitStore
    from repro.core.teacher import TeacherRunner

    _, params = teacher
    runner = TeacherRunner(BIDI, params, k=K)
    rng = np.random.default_rng(10)
    lens = [9, 30, 14, 22, 5, 17, 11]
    utts = _utts(rng, lens)
    store = LogitStore(str(tmp_path / "s"), k=K, vocab=V)
    paths = runner.generate_corpus_to_store(store, iter(utts), wave=3)
    assert len(paths) == len(utts)
    for j, u in enumerate(utts):
        vals, idx = store.read_shard(j)
        assert idx.shape == (1, u.shape[0], K)
    # failure mid-run: inject a forward error, then retry the whole call
    good_fwd = runner.engine._fwd
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected")
        return good_fwd(*a, **kw)

    runner.engine._fwd = flaky
    with pytest.raises(RuntimeError):
        runner.generate_corpus_to_store(store, iter(utts), wave=3)
    runner.engine._fwd = good_fwd
    paths2 = runner.generate_corpus_to_store(store, iter(utts), wave=3)
    assert len(paths2) == len(utts)
    model = build_model(BIDI)
    for j, u in enumerate(utts):            # idempotent rewrite, no mixups
        vals, idx = store.read_shard(j)
        assert idx.shape == (1, u.shape[0], K)
    for j in (1, 4):                        # content spot-check vs sequential
        _, seq_idx, _ = _sequential_topk(model, params, utts[j])
        _, idx = store.read_shard(j)
        np.testing.assert_array_equal(np.asarray(idx[0]), seq_idx)


# ------------------------------------------------------ top-k emitter

def test_topk_kernel_emitter_matches_lax():
    """The Pallas-kernel emission path == the logit_store codec path."""
    rng = np.random.default_rng(7)
    logits = jnp.asarray(rng.normal(size=(3, 40, 100)), jnp.float32) * 3
    lax_emit = make_topk_emitter(7, "lax")
    ker_emit = make_topk_emitter(7, "kernel", interpret=True)
    v1, i1 = lax_emit(logits)
    v2, i2 = ker_emit(logits)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1, np.float32),
                               np.asarray(v2, np.float32), atol=1e-2)
    assert v2.dtype == jnp.bfloat16


def test_engine_kernel_topk_impl(student):
    """End-to-end engine run with topk_impl='kernel' (reuses
    kernels/topk_logits): indices match the default path."""
    _, params = student
    rng = np.random.default_rng(8)
    utts = _utts(rng, [9, 33])
    out = {}
    for impl in ("lax", "kernel"):
        eng = StreamingEngine(STUDENT, params, k=K, topk_impl=impl,
                              policy=BatchPolicy("t", max_batch=2,
                                                 bucket_multiple=16))
        rids = [eng.submit(u) for u in utts]
        out[impl] = (eng.run(), rids)
    res_l, rids_l = out["lax"]
    res_k, rids_k = out["kernel"]
    for rl, rk in zip(rids_l, rids_k):
        np.testing.assert_array_equal(res_l[rl].idx, res_k[rk].idx)


# ------------------------------------------------------- token server

LM_CFG = {}


def _lm():
    """Shared reduced token-LM config/params (compile caches reused)."""
    if not LM_CFG:
        from repro.configs import get_arch, reduced
        cfg = reduced(get_arch("qwen2.5-3b"))
        model = build_model(cfg)
        LM_CFG["cfg"] = cfg
        LM_CFG["params"] = model.init(jax.random.key(0))
    return LM_CFG["cfg"], LM_CFG["params"]


def _solo_decode(cfg, params, prompt, max_new, max_seq=64):
    """Reference: one request alone through a 1-slot continuous server."""
    from dataclasses import replace
    from repro.serve import TokenServer
    srv = TokenServer(cfg, params, max_seq=max_seq,
                      policy=replace(LATENCY, max_batch=1))
    rid = srv.submit(prompt, max_new=max_new)
    return srv.drain()[rid].out


@pytest.mark.parametrize("server", ["continuous", "rounds"])
def test_token_server_basics(server):
    """Both engines: mixed prompt lengths complete, outputs are
    deterministic, overflowing / empty requests are refused up front,
    and drain() evicts its completions."""
    from repro.serve import RoundTokenServer, TokenServer
    cls = TokenServer if server == "continuous" else RoundTokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, L) for L in (5, 5, 8, 5)]

    def run():
        srv = cls(cfg, params, max_seq=64)
        rids = [srv.submit(p, max_new=4) for p in prompts]
        return srv, rids, srv.drain()

    srv, rids, done = run()
    assert sorted(done) == sorted(rids)
    assert all(len(done[r].out) == 4 and done[r].done for r in rids)
    _, rids2, done2 = run()
    for a, b in zip(rids, rids2):
        assert done[a].out == done2[b].out
    with pytest.raises(ValueError):
        srv.submit(rng.integers(1, cfg.vocab_size, 62), max_new=4)
    with pytest.raises(ValueError):
        srv.submit(np.zeros((0,), np.int32))
    # drain() evicts: a second wave returns only its own requests
    extra = srv.submit(prompts[0], max_new=2)
    done3 = srv.drain()
    assert sorted(done3) == [extra]


@pytest.mark.parametrize("server", ["continuous", "rounds"])
def test_token_server_failure_restores(server):
    """A serve-step failure mid-flight strands nothing: requests return
    to pending with outputs reset, and a retry completes cleanly."""
    from repro.serve import RoundTokenServer, TokenServer
    cls = TokenServer if server == "continuous" else RoundTokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(2)
    srv = cls(cfg, params, max_seq=32)
    rids = [srv.submit(rng.integers(1, cfg.vocab_size, 5), max_new=3)
            for _ in range(2)]
    good = srv.serve

    def boom(*_a, **_kw):
        raise RuntimeError("injected serve failure")

    srv.serve = boom
    with pytest.raises(RuntimeError):
        srv.drain()
    assert srv.queue.n_pending == 2 and srv.queue.n_completed == 0
    assert srv.queue.n_in_flight == 0      # nothing stranded in flight
    srv.serve = good
    done = srv.drain()
    assert sorted(done) == sorted(rids)
    assert all(len(done[r].out) == 3 for r in rids)


def test_token_server_batched_equals_solo():
    """The headline decode fix: a batched slot must produce exactly the
    tokens each prompt gets when served alone (the seed's per-slot
    prefill corrupted concurrent slots' caches)."""
    from repro.serve import TokenServer

    cfg, params = _lm()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, 6) for _ in range(3)]

    srv = TokenServer(cfg, params, max_seq=32)
    rids = [srv.submit(p, max_new=4) for p in prompts]
    batched = srv.drain()
    for rid, p in zip(rids, prompts):
        assert batched[rid].out == _solo_decode(cfg, params, p, 4,
                                                max_seq=32)


# --------------------------------------------- continuous batching

def test_continuous_lockstep_matches_rounds():
    """Acceptance bar: on a lockstep workload (equal prompt lengths,
    equal max_new) the continuous engine is token-identical to the
    generation-round engine."""
    from repro.serve import RoundTokenServer, TokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, 7) for _ in range(4)]
    r_srv = RoundTokenServer(cfg, params, max_seq=64)
    r_ids = [r_srv.submit(p, max_new=5) for p in prompts]
    r_done = r_srv.drain()
    c_srv = TokenServer(cfg, params, max_seq=64)
    c_ids = [c_srv.submit(p, max_new=5) for p in prompts]
    c_done = c_srv.drain()
    for a, b in zip(r_ids, c_ids):
        assert r_done[a].out == c_done[b].out


def test_continuous_ragged_matches_solo():
    """Mixed prompt lengths AND mixed max_new — more requests than
    slots, so freed slots admit mid-flight — every request's tokens
    equal its solo decode."""
    from repro.serve import TokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(4)
    lens = [3, 9, 5, 12, 7, 4]
    max_new = [5, 2, 9, 4, 7, 3]
    prompts = [rng.integers(1, cfg.vocab_size, L) for L in lens]
    srv = TokenServer(cfg, params, max_seq=64)      # 4 slots (LATENCY)
    rids = [srv.submit(p, m) for p, m in zip(prompts, max_new)]
    done = srv.drain()
    assert sorted(done) == sorted(rids)
    assert srv.stats["admitted"] == len(rids)
    for rid, p, m in zip(rids, prompts, max_new):
        assert done[rid].out == _solo_decode(cfg, params, p, m)
    # retired slots are excluded from the cost accounting
    assert srv.stats["active_slot_steps"] < srv.stats["slot_steps"]


def test_continuous_sync_count_is_steps_over_k():
    """The per-step device→host sync regression: host syncs per drain
    must be O(steps / sync_every), not O(steps)."""
    from repro.serve import TokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(5)
    k = 4
    srv = TokenServer(cfg, params, max_seq=64, sync_every=k)
    # 4 equal requests, one admission wave: 5 prefill + 7 decode = 12
    # consumed tokens per row -> exactly ceil(12 / 4) = 3 windows
    rids = [srv.submit(rng.integers(1, cfg.vocab_size, 5), max_new=8)
            for _ in range(4)]
    done = srv.drain()
    assert sorted(done) == sorted(rids)
    assert srv.stats["steps"] == 12
    assert srv.stats["syncs"] == 3          # == steps / k, not steps
    assert srv.stats["syncs"] * k == srv.stats["steps"]


def test_continuous_early_retirement_and_admission():
    """max_new=[1, 64]: the short request's completion latency is one
    sync window, independent of the long request, and its freed slot
    admits queued work mid-flight."""
    from dataclasses import replace
    from repro.serve import TokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(6)
    srv = TokenServer(cfg, params, max_seq=80,
                      policy=replace(LATENCY, max_batch=2), sync_every=4)
    p_short = rng.integers(1, cfg.vocab_size, 3)
    p_long = rng.integers(1, cfg.vocab_size, 3)
    rid_s = srv.submit(p_short, max_new=1)
    rid_l = srv.submit(p_long, max_new=64)
    first = srv.pump()
    # short done after ONE window (3 prefill + its single token < 4
    # steps); the long row is still mid-flight
    assert rid_s in first and first[rid_s].out == _solo_decode(
        cfg, params, p_short, 1, max_seq=80)
    assert rid_l not in first and srv.n_active == 1
    assert first[rid_s].finished_sync == 1
    # the freed slot admits a queued request while the long one runs
    p_mid = rng.integers(1, cfg.vocab_size, 4)
    rid_m = srv.submit(p_mid, max_new=2)
    mid_done = {}
    for _ in range(3):
        mid_done.update(srv.pump())
    assert rid_m in mid_done and srv.n_active == 1      # long still going
    assert mid_done[rid_m].out == _solo_decode(cfg, params, p_mid, 2,
                                               max_seq=80)
    rest = srv.drain()
    assert rid_l in rest
    assert rest[rid_l].out == _solo_decode(cfg, params, p_long, 64,
                                           max_seq=80)
    assert rest[rid_l].finished_sync > first[rid_s].finished_sync


def test_continuous_admission_failure_restores():
    """A failure during slot admission (the jitted row reset) recovers
    like a window failure: nothing stranded, no stale slot state, and a
    retry produces the correct tokens."""
    from repro.serve import TokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab_size, 5) for _ in range(2)]
    srv = TokenServer(cfg, params, max_seq=32)
    rids = [srv.submit(p, max_new=3) for p in prompts]
    good = srv._reset

    def boom(*_a, **_kw):
        raise RuntimeError("injected reset failure")

    srv._reset = boom
    with pytest.raises(RuntimeError):
        srv.drain()
    assert srv.queue.n_pending == 2 and srv.queue.n_in_flight == 0
    assert srv.n_active == 0
    srv._reset = good
    done = srv.drain()
    assert sorted(done) == sorted(rids)
    for rid, p in zip(rids, prompts):
        assert done[rid].out == _solo_decode(cfg, params, p, 3, max_seq=32)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-2b"])
def test_continuous_recurrent_arch_matches_solo(arch):
    """Recurrent-mixer archs through the continuous batcher: their conv
    states come back in compute dtype, so the fused window's carry must
    be dtype-settled at init (regression for the lax.scan dtype
    mismatch); outputs equal solo decode."""
    from dataclasses import replace
    from repro.configs import get_arch, reduced
    from repro.serve import TokenServer
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(8)
    prompts = [rng.integers(1, cfg.vocab_size, L) for L in (3, 6, 4)]
    max_new = [4, 2, 5]
    pol = replace(LATENCY, max_batch=2)
    srv = TokenServer(cfg, params, max_seq=32, policy=pol)
    rids = [srv.submit(p, m) for p, m in zip(prompts, max_new)]
    done = srv.drain()
    solo = TokenServer(cfg, params, max_seq=32,
                       policy=replace(pol, max_batch=1))
    for rid, p, m in zip(rids, prompts, max_new):
        srid = solo.submit(p, max_new=m)
        assert done[rid].out == solo.drain()[srid].out


def test_continuous_eos_retirement():
    """A row retires at eos_id mid-window: output stops at (and
    includes) the EOS token, and the slot frees for new work."""
    from repro.serve import TokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab_size, 5)
    free_run = _solo_decode(cfg, params, prompt, 6)
    eos = free_run[0]                       # greedy decode will hit it
    srv = TokenServer(cfg, params, max_seq=64, eos_id=eos)
    rid = srv.submit(prompt, max_new=6)
    done = srv.drain()
    assert done[rid].out == [eos]
    assert srv.n_active == 0


def test_rounds_eos_matches_continuous():
    """RoundTokenServer honors eos_id (regression: it used to silently
    accept none) and stays token-for-token lockstep with the continuous
    engine on an equal-length EOS workload."""
    from repro.serve import RoundTokenServer, TokenServer
    cfg, params = _lm()
    rng = np.random.default_rng(8)
    prompts = [rng.integers(1, cfg.vocab_size, 6) for _ in range(3)]
    # pick an EOS each greedy run will actually emit mid-generation
    eos = _solo_decode(cfg, params, prompts[0], 8)[2]
    cont = TokenServer(cfg, params, max_seq=64, eos_id=eos)
    rounds = RoundTokenServer(cfg, params, max_seq=64, eos_id=eos)
    rc = [cont.submit(p, max_new=8) for p in prompts]
    rr = [rounds.submit(p, max_new=8) for p in prompts]
    out_c, out_r = cont.drain(), rounds.drain()
    for a, b in zip(rc, rr):
        assert out_c[a].out == out_r[b].out
        assert len(out_r[b].out) <= 8
        if eos in out_r[b].out:
            assert out_r[b].out[-1] == eos       # stops at, and
            assert out_r[b].out.count(eos) == 1  # includes, the EOS


def test_topk_emitter_auto_interpret():
    """interpret=None auto-detects the backend (regression: the kernel
    emitter used to hardcode interpret=True even on TPU); on CPU it must
    resolve to the interpreter and still match the lax path."""
    rng = np.random.default_rng(9)
    logits = jnp.asarray(rng.normal(size=(2, 10, 60)), jnp.float32) * 3
    auto = make_topk_emitter(5, "kernel")        # no interpret given
    ref = make_topk_emitter(5, "lax")
    v1, i1 = auto(logits)
    v2, i2 = ref(logits)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1, np.float32),
                               np.asarray(v2, np.float32), atol=1e-2)
