"""Unified inference engine: batched == sequential, streaming == full,
queue completeness, batcher invariants, top-k emitter parity.

The acceptance bar for the engine (ISSUE 1): top-k indices identical to
the per-utterance sequential path, values within fp tolerance, and no
request ever dropped or reordered incorrectly by the batcher.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import Segment
from repro.configs.lstm_am_7khr import CONFIG, TEACHER
from repro.core.logit_store import topk_compress
from repro.models import build_model
from repro.serve import (LATENCY, THROUGHPUT, BatchPolicy, RequestQueue,
                         StreamingEngine, form_batches, make_topk_emitter,
                         padding_efficiency)
from repro.serve.request import InferenceRequest

F, V, K = 6, 25, 5


def _tiny(base):
    return base.replace(
        lstm_hidden=16, feat_dim=F, n_senones=V, vocab_size=V,
        segments=(Segment((base.segments[0].pattern[0],), repeat=2),))


STUDENT = _tiny(CONFIG)
BIDI = _tiny(TEACHER)


@pytest.fixture(scope="module")
def student():
    m = build_model(STUDENT)
    return m, m.init(jax.random.key(0))


@pytest.fixture(scope="module")
def teacher():
    m = build_model(BIDI)
    return m, m.init(jax.random.key(1))


def _utts(rng, lens):
    return [rng.normal(size=(t, F)).astype(np.float32) for t in lens]


def _sequential_topk(model, params, utt, k=K):
    """The naive per-utterance reference path."""
    h, _ = model.apply(params, jnp.asarray(utt)[None])
    logits = model.unembed(params, h)
    vals, idx = topk_compress(logits, k)
    return (np.asarray(vals[0]).astype(np.float32), np.asarray(idx[0]),
            np.asarray(logits[0]))


# ------------------------------------------------------------- batcher

def test_batcher_covers_every_request_once():
    rng = np.random.default_rng(0)
    reqs = [InferenceRequest(i, f) for i, f in
            enumerate(_utts(rng, [3, 70, 18, 129, 64, 1, 40]))]
    for policy in (THROUGHPUT, LATENCY):
        batches = form_batches(reqs, policy)
        seen = [r.rid for b in batches for r in b.requests]
        assert sorted(seen) == list(range(len(reqs)))
        for b in batches:
            assert b.feats.shape[0] == policy.max_batch
            assert b.feats.shape[1] % policy.bucket_multiple == 0
            for i, r in enumerate(b.requests):
                assert b.lens[i] == r.length
                np.testing.assert_array_equal(b.feats[i, :r.length], r.feats)
            # dummy rows are zero-length
            assert (b.lens[b.n_real:] == 0).all()


def test_batcher_sorting_reduces_padding():
    """Length-sorted packing (throughput) wastes fewer padded frames than
    arrival-order packing on a bimodal corpus."""
    rng = np.random.default_rng(1)
    lens = [int(x) for pair in zip(rng.integers(5, 15, 40),
                                   rng.integers(200, 260, 40)) for x in pair]
    reqs = [InferenceRequest(i, np.zeros((t, F), np.float32))
            for i, t in enumerate(lens)]
    pol = BatchPolicy("t", max_batch=8, bucket_multiple=16,
                      sort_by_length=True)
    pol_fifo = BatchPolicy("l", max_batch=8, bucket_multiple=16,
                           sort_by_length=False)
    eff_sorted = padding_efficiency(form_batches(reqs, pol))
    eff_fifo = padding_efficiency(form_batches(reqs, pol_fifo))
    assert eff_sorted > eff_fifo


# ------------------------------------------------- batched == sequential

@pytest.mark.parametrize("fixture", ["student", "teacher"])
def test_batched_matches_sequential(fixture, request):
    """Engine (padded, bucketed, batched) == naive per-utterance loop:
    identical top-k indices, logits within 1e-5, stored values to bf16
    resolution.  The bidirectional teacher is the hard case — its
    backward pass must start at each row's true last frame."""
    model, params = request.getfixturevalue(fixture)
    cfg = STUDENT if fixture == "student" else BIDI
    rng = np.random.default_rng(2)
    # mixed lengths sharing one padded batch shape (both groups bucket to
    # 48): exercises the lens machinery, one XLA program for the engine
    lens = [11, 48, 23, 48]
    utts = _utts(rng, lens)
    eng = StreamingEngine(cfg, params, k=K,
                          policy=BatchPolicy("t", max_batch=3,
                                             bucket_multiple=16))
    rids = [eng.submit(u) for u in utts]
    res = eng.run()
    assert eng.queue.drained
    for rid, u in zip(rids, utts):
        vals_s, idx_s, logits_s = _sequential_topk(model, params, u)
        r = res[rid]
        np.testing.assert_array_equal(r.idx, idx_s)
        np.testing.assert_allclose(r.vals, vals_s, atol=1e-2)  # bf16 grid
        # raw fp parity on the engine's forward (the 1e-5 criterion)
        hb, _ = model.apply(params, jnp.asarray(u)[None],
                            lens=jnp.asarray([u.shape[0]]))
        np.testing.assert_allclose(np.asarray(model.unembed(params, hb)[0]),
                                   logits_s, atol=1e-5)


# ------------------------------------------------ streaming equivalence

def test_streaming_chunked_equals_full(student):
    """Chunked engine.feed over slots == one full-utterance forward:
    identical indices per frame, and identical final recurrent state."""
    model, params = student
    rng = np.random.default_rng(4)
    x0, x1 = _utts(rng, [50, 37])           # ragged: different chunk tails
    eng = StreamingEngine(STUDENT, params, k=K, policy=LATENCY, n_slots=3)
    s0, s1 = eng.open_stream(), eng.open_stream()
    got = {s0: [], s1: []}
    for lo in range(0, 50, 16):
        chunks = {}
        if lo < 50:
            chunks[s0] = x0[lo:lo + 16]
        if lo < 37:
            chunks[s1] = x1[lo:lo + 16]
        out = eng.feed(chunks)
        for sid in out:
            got[sid].append(out[sid])
    for sid, x in ((s0, x0), (s1, x1)):
        idx = np.concatenate([i for _, i in got[sid]])
        vals = np.concatenate([v for v, _ in got[sid]])
        vals_s, idx_s, _ = _sequential_topk(model, params, x)
        np.testing.assert_array_equal(idx, idx_s)
        np.testing.assert_allclose(vals, vals_s, atol=1e-2)
    eng.close_stream(s0)
    eng.close_stream(s1)
    with pytest.raises(ValueError):
        eng.close_stream(s0)            # double close
    with pytest.raises(ValueError):
        eng.feed({s0: x0[:4]})          # feeding a closed stream
    assert eng.open_stream() in (s0, s1)    # slots recycle cleanly


def test_stream_state_carry_equals_full(student):
    """model.stream_step chunk-carried state == full apply() final state,
    including a ragged (lens-masked) chunk boundary."""
    model, params = student
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 30, F)), jnp.float32)
    _, aux = model.apply(params, x)
    st = model.init_stream_state(2)
    h_parts = []
    for lo in (0, 10, 20):
        h, st = model.stream_step(params, st, x[:, lo:lo + 10])
        h_parts.append(h)
    full_h, _ = model.apply(params, x)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(h_parts, 1)),
                               np.asarray(full_h), atol=1e-5)
    for (h1, c1), (h2, c2) in zip(st, aux["state"]):
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                                   atol=1e-5)
    # ragged chunk: row 1 stops at frame 25 of 30
    st = model.init_stream_state(2)
    h, st = model.stream_step(params, st, x[:, :20])
    h, st = model.stream_step(params, st, x[:, 20:30],
                              lens=jnp.asarray([10, 5]))
    ref_h, ref_aux = model.apply(params, x[1:2, :25])
    np.testing.assert_allclose(np.asarray(st[0][0][1]),
                               np.asarray(ref_aux["state"][0][0][0]),
                               atol=1e-5)


# ------------------------------------------------- queue completeness

def test_queue_ordering_and_completeness(student):
    model, params = student
    rng = np.random.default_rng(6)
    lens = list(rng.integers(1, 90, 17))
    utts = _utts(rng, lens)
    eng = StreamingEngine(STUDENT, params, k=K,
                          policy=BatchPolicy("t", max_batch=4,
                                             bucket_multiple=16))
    rids = [eng.submit(u, meta={"n": i}) for i, u in enumerate(utts)]
    assert eng.queue.n_pending == len(utts)
    res = eng.run()
    assert eng.queue.drained and eng.queue.n_pending == 0
    assert sorted(res) == sorted(rids)
    assert sorted(eng.queue.completion_order) == sorted(rids)
    for i, (rid, u) in enumerate(zip(rids, utts)):
        assert res[rid].vals.shape == (u.shape[0], K)
        assert res[rid].idx.shape == (u.shape[0], K)
        assert res[rid].meta == {"n": i}
    # a second wave reuses the engine; run() hands over exactly this
    # wave's results (earlier ones were evicted with the first run —
    # the ledger must not grow with engine uptime)
    more = [eng.submit(u) for u in _utts(rng, [12, 3])]
    res2 = eng.run()
    assert sorted(res2) == sorted(more)


def test_run_failure_restores_pending(student):
    """A forward failure mid-drain strands nothing: unfulfilled requests
    go back to pending and a retry completes them all."""
    _, params = student
    rng = np.random.default_rng(9)
    eng = StreamingEngine(STUDENT, params, k=K,
                          policy=BatchPolicy("t", max_batch=2,
                                             bucket_multiple=16))
    rids = [eng.submit(u) for u in _utts(rng, [8, 21, 13])]
    good_fwd = eng._fwd

    def boom(*_a, **_kw):
        raise RuntimeError("injected forward failure")

    eng._fwd = boom
    with pytest.raises(RuntimeError):
        eng.run()
    assert eng.queue.n_pending == len(rids) and not eng.queue.drained
    eng._fwd = good_fwd
    res = eng.run()
    assert sorted(res) == sorted(rids) and eng.queue.drained


# ----------------------------------------------------- property-based

_PROP = {}


def _prop_engine(max_batch):
    """Engines (and their jit caches) shared across property examples."""
    if "model" not in _PROP:
        _PROP["model"] = build_model(STUDENT)
        _PROP["params"] = _PROP["model"].init(jax.random.key(0))
        _PROP["seq"] = jax.jit(
            lambda p, u: _PROP["model"].logits(p, u))
    if max_batch not in _PROP:
        _PROP[max_batch] = StreamingEngine(
            STUDENT, _PROP["params"], k=3,
            policy=BatchPolicy("t", max_batch=max_batch,
                               bucket_multiple=16))
    return _PROP[max_batch]


@given(seed=st.integers(0, 1000), max_batch=st.integers(1, 3),
       n=st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_engine_property_random_lengths(seed, max_batch, n):
    """Any mix of lengths and batch sizes: complete, correctly shaped,
    and a random utterance's indices match the sequential path."""
    eng = _prop_engine(max_batch)
    model, params = _PROP["model"], _PROP["params"]
    rng = np.random.default_rng(seed)
    lens = [int(t) for t in rng.integers(1, 48, n)]
    utts = _utts(rng, lens)
    rids = [eng.submit(u) for u in utts]
    res = eng.run()
    assert eng.queue.drained
    assert all(rid in res for rid in rids)
    for rid, u in zip(rids, utts):
        assert res[rid].idx.shape == (u.shape[0], 3)
    # parity spot-check on one utterance, padded to its bucket so the
    # reference jit-cache is shared across examples
    j = int(rng.integers(n))
    u = utts[j]
    from repro.serve import bucket_length
    tb = bucket_length(u.shape[0], 16)
    up = np.zeros((1, tb, F), np.float32)
    up[0, :u.shape[0]] = u
    logits, _ = _PROP["seq"](params, jnp.asarray(up))
    _, idx_s = jax.lax.top_k(logits[0, :u.shape[0]], 3)
    np.testing.assert_array_equal(res[rids[j]].idx, np.asarray(idx_s))


def test_dict_forward_mask_aware(teacher):
    """The trainer's chunked batches carry a frame mask; the teacher's
    dict path must not let the biLSTM backward pass read the padding of
    partial chunks (targets == per-row truncated forward)."""
    model, params = teacher
    from repro.core.teacher import TeacherRunner
    runner = TeacherRunner(BIDI, params, k=K)
    rng = np.random.default_rng(11)
    feats = rng.normal(size=(2, 32, F)).astype(np.float32)
    mask = np.zeros((2, 32), np.float32)
    mask[0, :32] = 1.0
    mask[1, :18] = 1.0                       # partial chunk
    vals, idx = runner.generate({"feats": jnp.asarray(feats),
                                 "mask": jnp.asarray(mask)})
    _, idx_s, _ = _sequential_topk(model, params, feats[1, :18])
    np.testing.assert_array_equal(np.asarray(idx[1, :18]), idx_s)


# ------------------------------------------------------ firehose path

def test_firehose_corpus_to_store(teacher, tmp_path):
    """generate_corpus_to_store: generator corpus, waves, one shard per
    utterance in submission order, frame-exact; and the failure contract
    — a failed call retried in full rewrites shards idempotently."""
    from repro.core.logit_store import LogitStore
    from repro.core.teacher import TeacherRunner

    _, params = teacher
    runner = TeacherRunner(BIDI, params, k=K)
    rng = np.random.default_rng(10)
    lens = [9, 30, 14, 22, 5, 17, 11]
    utts = _utts(rng, lens)
    store = LogitStore(str(tmp_path / "s"), k=K, vocab=V)
    paths = runner.generate_corpus_to_store(store, iter(utts), wave=3)
    assert len(paths) == len(utts)
    for j, u in enumerate(utts):
        vals, idx = store.read_shard(j)
        assert idx.shape == (1, u.shape[0], K)
    # failure mid-run: inject a forward error, then retry the whole call
    good_fwd = runner.engine._fwd
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected")
        return good_fwd(*a, **kw)

    runner.engine._fwd = flaky
    with pytest.raises(RuntimeError):
        runner.generate_corpus_to_store(store, iter(utts), wave=3)
    runner.engine._fwd = good_fwd
    paths2 = runner.generate_corpus_to_store(store, iter(utts), wave=3)
    assert len(paths2) == len(utts)
    model = build_model(BIDI)
    for j, u in enumerate(utts):            # idempotent rewrite, no mixups
        vals, idx = store.read_shard(j)
        assert idx.shape == (1, u.shape[0], K)
    for j in (1, 4):                        # content spot-check vs sequential
        _, seq_idx, _ = _sequential_topk(model, params, utts[j])
        _, idx = store.read_shard(j)
        np.testing.assert_array_equal(np.asarray(idx[0]), seq_idx)


# ------------------------------------------------------ top-k emitter

def test_topk_kernel_emitter_matches_lax():
    """The Pallas-kernel emission path == the logit_store codec path."""
    rng = np.random.default_rng(7)
    logits = jnp.asarray(rng.normal(size=(3, 40, 100)), jnp.float32) * 3
    lax_emit = make_topk_emitter(7, "lax")
    ker_emit = make_topk_emitter(7, "kernel", interpret=True)
    v1, i1 = lax_emit(logits)
    v2, i2 = ker_emit(logits)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1, np.float32),
                               np.asarray(v2, np.float32), atol=1e-2)
    assert v2.dtype == jnp.bfloat16


def test_engine_kernel_topk_impl(student):
    """End-to-end engine run with topk_impl='kernel' (reuses
    kernels/topk_logits): indices match the default path."""
    _, params = student
    rng = np.random.default_rng(8)
    utts = _utts(rng, [9, 33])
    out = {}
    for impl in ("lax", "kernel"):
        eng = StreamingEngine(STUDENT, params, k=K, topk_impl=impl,
                              policy=BatchPolicy("t", max_batch=2,
                                                 bucket_multiple=16))
        rids = [eng.submit(u) for u in utts]
        out[impl] = (eng.run(), rids)
    res_l, rids_l = out["lax"]
    res_k, rids_k = out["kernel"]
    for rl, rk in zip(rids_l, rids_k):
        np.testing.assert_array_equal(res_l[rl].idx, res_k[rk].idx)


# ------------------------------------------------------- token server

def test_token_server_rounds():
    """Generation rounds: mixed prompt lengths complete, equal-length
    prompts batch together, outputs are deterministic, and overflowing
    requests are refused up front (cache ring-buffer wrap protection)."""
    from repro.configs import get_arch, reduced
    from repro.serve import TokenServer

    cfg = reduced(get_arch("qwen2.5-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, L) for L in (5, 5, 8, 5)]

    def run():
        srv = TokenServer(cfg, params, max_seq=64)
        rids = [srv.submit(p, max_new=4) for p in prompts]
        return srv, rids, srv.drain()

    srv, rids, done = run()
    assert sorted(done) == sorted(rids)
    assert all(len(done[r].out) == 4 and done[r].done for r in rids)
    _, rids2, done2 = run()
    for a, b in zip(rids, rids2):
        assert done[a].out == done2[b].out
    with pytest.raises(ValueError):
        srv.submit(rng.integers(1, cfg.vocab_size, 62), max_new=4)
    with pytest.raises(ValueError):
        srv.submit(np.zeros((0,), np.int32))
    # drain() evicts: a second wave returns only its own requests
    extra = srv.submit(prompts[0], max_new=2)
    done3 = srv.drain()
    assert sorted(done3) == [extra]


def test_token_server_failure_restores_round():
    """A serve-step failure mid-round strands nothing: the round returns
    to pending with outputs reset, and a retry completes cleanly."""
    from repro.configs import get_arch, reduced
    from repro.serve import TokenServer

    cfg = reduced(get_arch("qwen2.5-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(2)
    srv = TokenServer(cfg, params, max_seq=32)
    rids = [srv.submit(rng.integers(1, cfg.vocab_size, 5), max_new=3)
            for _ in range(2)]
    good = srv.serve

    def boom(*_a, **_kw):
        raise RuntimeError("injected serve failure")

    srv.serve = boom
    with pytest.raises(RuntimeError):
        srv.drain()
    assert srv.queue.n_pending == 2 and srv.queue.n_completed == 0
    assert srv.queue.n_in_flight == 0      # nothing stranded in flight
    srv.serve = good
    done = srv.drain()
    assert sorted(done) == sorted(rids)
    assert all(len(done[r].out) == 3 for r in rids)


def test_token_server_batched_equals_solo():
    """The headline decode fix: a batched round must produce exactly the
    tokens each prompt gets when served alone (the seed's per-slot
    prefill corrupted concurrent slots' caches)."""
    from dataclasses import replace
    from repro.configs import get_arch, reduced
    from repro.serve import TokenServer

    cfg = reduced(get_arch("qwen2.5-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, 6) for _ in range(3)]

    srv = TokenServer(cfg, params, max_seq=32)      # one round of 3
    rids = [srv.submit(p, max_new=4) for p in prompts]
    batched = srv.drain()
    solo_srv = TokenServer(cfg, params, max_seq=32,
                           policy=replace(LATENCY, max_batch=1))
    for rid, p in zip(rids, prompts):
        srid = solo_srv.submit(p, max_new=4)
        solo = solo_srv.drain()
        assert batched[rid].out == solo[srid].out
