"""Elastic membership across the scheduled-learning stack (ISSUE 10):
BMUF/GTC surviving workers joining and leaving mid-run.

Pins, layer by layer:
  * restack_workers — the one re-partitioning primitive (shrink keeps /
    folds, grow broadcasts / zero-pads; fold is sum-preserving)
  * bmuf.active_mean_fn / block_sync(active=...) — dead lanes drop out
    of the block average; the masked W=4 run matches a fresh W=3 run to
    float32-ULP; dead lanes stay broadcast-warm for rejoin
  * Trainer.resize + fit(membership=...) — a lane killed mid-run via a
    scripted LaneCrashPlan produces bitwise the params of a fresh
    smaller-W trainer resuming the same cross-W checkpoint
  * GTCShardMap.resize — error-feedback residual conservation holds
    across a W=4 -> W=2 resize (fold scatter-adds dropped rows)
  * TrainerMembership / LaneCrashPlan — the roster + chaos machinery
  * WorkLedger.reclaim_stale claim-age signal + structured steal events
  * warmup_hold_decay — shape and the 1-compile pin
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.distributed import bmuf as B
from repro.distributed import gtc as G
from repro.optim import momentum_init, momentum_update, warmup_hold_decay
from repro.pipeline.generate import WorkLedger, shard_ranges
from repro.runtime import procs
from repro.runtime.cluster import worker_mesh
from repro.runtime.workers import LaneCrashPlan, TrainerMembership
from repro.train import (BMUFVmap, GTCShardMap, Local, TrainBatch, Trainer,
                         TrainState, restack_workers)
from repro.train.state import worker_dim

tmap = jax.tree_util.tree_map
D = 8


def quad_loss(params, batch):
    e = batch["x"] @ params["w"] - batch["y"]
    return jnp.mean(e ** 2), {"loss": jnp.mean(e ** 2)}


def quad_step():
    def step(params, opt_state, batch, lr):
        (_, m), g = jax.value_and_grad(quad_loss, has_aux=True)(params,
                                                                batch)
        params, opt_state = momentum_update(params, g, opt_state, lr=lr,
                                            beta=0.0, nesterov=False)
        return params, opt_state, m
    return step


def _problem(seed=0, n=64, d=D):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(d,))
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x @ w_true).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def lin_loss(params, batch):
    l = jnp.sum(params["w"] * batch["c"])
    return l, {"loss": l}


# ===================================================== restack_workers

def test_restack_shrink_fold_preserves_sum():
    """fold=True scatter-adds dropped rows round-robin onto survivors:
    the column sums (all the information carried on the W axis) are
    exactly preserved — the GTC residual-conservation primitive."""
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(5, 3)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(5,)), jnp.float32)}}
    out = restack_workers(tree, 2, fold=True)
    assert out["a"].shape == (2, 3) and out["b"]["c"].shape == (2,)
    for src, dst in ((tree["a"], out["a"]), (tree["b"]["c"],
                                             out["b"]["c"])):
        np.testing.assert_allclose(np.asarray(src).sum(0),
                                   np.asarray(dst).sum(0), rtol=1e-6)


def test_restack_shrink_nofold_keeps_head():
    x = jnp.arange(12.0).reshape(4, 3)
    out = restack_workers({"w": x}, 3)["w"]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x[:3]))


def test_restack_grow_broadcasts_lane0():
    """no-fold grow = BMUF semantics: a joiner warm-starts from lane 0
    (all lanes are identical right after a Nesterov restart anyway)."""
    x = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    out = restack_workers({"w": x}, 4)["w"]
    assert out.shape == (4, 2)
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(x[0]))
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(x[0]))


def test_restack_grow_fold_pads_zero():
    """fold grow = GTC semantics: a joiner starts with zero residual
    (sum-preserving in the grow direction too)."""
    x = jnp.asarray([[1.0, 2.0]])
    out = restack_workers({"w": x}, 3, fold=True)["w"]
    np.testing.assert_array_equal(np.asarray(out[1:]),
                                  np.zeros((2, 2), np.float32))


def test_restack_rejects_bad_w():
    with pytest.raises(ValueError):
        restack_workers({"w": jnp.zeros((2, 3))}, 0)


def test_worker_dim():
    assert worker_dim({"w": jnp.zeros((4, 3))}) == 4
    assert worker_dim({}) == 0


# ===================================================== masked block sync

def test_active_mean_fn_drops_dead_lanes():
    w = jnp.asarray([[1.0], [3.0], [5.0], [999.0]])
    got = B.active_mean_fn(jnp.asarray([1, 1, 1, 0]))(w)
    np.testing.assert_allclose(np.asarray(got), [3.0], rtol=1e-7)
    # all-dead degrades to zero contribution, not NaN
    got = B.active_mean_fn(jnp.zeros(4))(w)
    assert np.isfinite(np.asarray(got)).all()


def test_bmuf_masked_w4_matches_fresh_w3_ulp():
    """The acceptance pin: BMUF at W=4 with one lane masked dead (fed a
    junk duplicate batch — its local steps still run, its contribution
    is dropped at the sync) matches a fresh W=3 run over several blocks
    within float32-ULP.  Not bitwise: masked-sum/denom vs jnp.mean
    reassociate differently."""
    tau = 2
    step = quad_step()
    blk4 = jax.jit(B.make_bmuf_block_step(
        step, B.BMUFConfig(n_workers=4, block_steps=tau)))
    blk3 = jax.jit(B.make_bmuf_block_step(
        step, B.BMUFConfig(n_workers=3, block_steps=tau)))
    params = {"w": jnp.zeros((D,))}
    s4 = B.bmuf_init(params, B.BMUFConfig(n_workers=4, block_steps=tau))
    s3 = B.bmuf_init(params, B.BMUFConfig(n_workers=3, block_steps=tau))
    o4 = tmap(lambda x: jnp.broadcast_to(x, (4,) + x.shape).copy(),
              momentum_init(params))
    o3 = tmap(lambda x: jnp.broadcast_to(x, (3,) + x.shape).copy(),
              momentum_init(params))
    active = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    for blk in range(3):
        bs = [_problem(seed=10 * blk + i, n=16) for i in range(tau * 3)]
        b3 = tmap(lambda *xs: jnp.stack(xs).reshape(tau, 3, *xs[0].shape),
                  *bs)
        # lane 3 chews on junk (lane 0's batches) — masked out anyway
        b4 = tmap(lambda x: jnp.concatenate([x, x[:, :1]], axis=1), b3)
        s4, o4, _ = blk4(s4, o4, b4, 0.1, None, active)
        s3, o3, _ = blk3(s3, o3, b3, 0.1)
    np.testing.assert_allclose(np.asarray(s4["theta_g"]["w"]),
                               np.asarray(s3["theta_g"]["w"]),
                               rtol=0, atol=5e-7)


def test_bmuf_dead_lane_stays_warm():
    """The Nesterov restart broadcasts to ALL lanes, dead ones included:
    a rejoining worker resumes from current params by flipping its mask
    bit back on — no state transfer needed."""
    cfg = B.BMUFConfig(n_workers=4, block_steps=1)
    state = B.bmuf_init({"w": jnp.zeros((D,))}, cfg)
    rng = np.random.default_rng(1)
    state = dict(state, workers={"w": jnp.asarray(
        rng.normal(size=(4, D)), jnp.float32)})
    out = B.block_sync(state, cfg, active=jnp.asarray([1, 1, 0, 0]))
    w = np.asarray(out["workers"]["w"])
    for lane in range(1, 4):
        np.testing.assert_array_equal(w[lane], w[0])


def test_sharded_masked_sync_matches_vmap():
    """make_sharded_bmuf_block_step(active=...) — psum-of-masked-sums /
    psum-of-live-count — agrees with the vmap path's masked mean."""
    tau = 2
    step = quad_step()
    cfg = B.BMUFConfig(n_workers=4, block_steps=tau)
    blkv = jax.jit(B.make_bmuf_block_step(step, cfg))
    blks = jax.jit(B.make_sharded_bmuf_block_step(step, cfg,
                                                  worker_mesh(4)))
    params = {"w": jnp.zeros((D,))}
    opt = tmap(lambda x: jnp.broadcast_to(x, (4,) + x.shape).copy(),
               momentum_init(params))
    bs = [_problem(seed=i, n=16) for i in range(tau * 4)]
    bt = tmap(lambda *xs: jnp.stack(xs).reshape(tau, 4, *xs[0].shape), *bs)
    active = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    sv, _, _ = blkv(B.bmuf_init(params, cfg), opt, bt, 0.1, None, active)
    ss, _, _ = blks(B.bmuf_init(params, cfg), opt, bt, 0.1, None, active)
    tol = ({"rtol": 0, "atol": 0} if jax.device_count() == 1
           else {"atol": 1e-7})
    np.testing.assert_allclose(np.asarray(sv["theta_g"]["w"]),
                               np.asarray(ss["theta_g"]["w"]), **tol)


# ============================================ trainer-level elasticity

def _batches(n, seed0=100):
    return [_problem(seed=seed0 + i, n=16) for i in range(n)]


def _src(batches):
    return [TrainBatch(b, 0.05, "quad") for b in batches]


def test_trainer_elastic_kill_matches_cross_w_resume(tmp_path):
    """The end-to-end acceptance pin.  Run A: W=4 BMUF fit, checkpoint
    at step 2, then a LaneCrashPlan kills one lane (resize to W=3 at
    the block boundary) and training continues.  Run B: a *fresh* W=3
    trainer resumes the W=4 checkpoint (cross-W resume: the template is
    resized up to the saved W for the strict-shape load, then resized
    back down).  Both reach step 4 with bitwise-identical params — the
    roster path and the restart path agree exactly."""
    ck = str(tmp_path / "ck")
    batches = _batches(4 * 2 + 3 * 2)          # 2 updates @W4, 2 @W3
    params = {"w": jnp.zeros((D,))}

    # --- run A: elastic shrink mid-run
    trA = Trainer(BMUFVmap(B.BMUFConfig(n_workers=4, block_steps=1),
                           clip=0.0), {"quad": quad_loss},
                  checkpoint=CheckpointStore(ck), ckpt_every=2)
    sA = trA.fit(trA.init_state(params), _src(batches[:8]), resume=False)
    assert int(sA.step) == 2                   # W=4 checkpoint on disk
    trA.ckpt_every = 0                         # keep it the latest save

    m = TrainerMembership(str(tmp_path / "members.json"), timeout_s=30.0)
    for i in range(4):
        m.join(f"lane{i}")
    plan = LaneCrashPlan(m, kills={0: "lane3"})   # dies before block 3
    sA = trA.fit(sA, _src(batches[8:]), resume=False, membership=plan)
    assert int(sA.step) == 4
    assert trA.strategy.n_workers == 3
    assert trA.resize_stats["count"] == 1

    # --- run B: fresh W=3 trainer, cross-W resume of the W=4 save
    trB = Trainer(BMUFVmap(B.BMUFConfig(n_workers=3, block_steps=1),
                           clip=0.0), {"quad": quad_loss},
                  checkpoint=CheckpointStore(ck))
    sB = trB.fit(trB.init_state(params), _src(batches), resume=True)
    assert int(sB.step) == 4
    assert trB.resize_stats["count"] == 1      # the cross-W load resized
    np.testing.assert_array_equal(np.asarray(sA.params["w"]),
                                  np.asarray(sB.params["w"]))


def test_trainer_revive_grows_back(tmp_path):
    """Kill then revive: the trainer shrinks at one block boundary and
    grows back at a later one; the revived lane warm-starts from the
    broadcast params and the run completes at full W."""
    m = TrainerMembership(str(tmp_path / "members.json"), timeout_s=30.0)
    for i in range(4):
        m.join(f"lane{i}")
    plan = LaneCrashPlan(m, kills={1: "lane2"}, revives={3: "lane2"})
    tr = Trainer(BMUFVmap(B.BMUFConfig(n_workers=4, block_steps=1),
                          clip=0.0), {"quad": quad_loss})
    # enough batches for 5 updates at worst-case W (partial tail dropped)
    state = tr.fit(tr.init_state({"w": jnp.zeros((D,))}),
                   _src(_batches(24)), resume=False, membership=plan)
    assert tr.strategy.n_workers == 4          # grew back
    assert tr.resize_stats["count"] == 2
    assert [e["event"] for e in plan.log] == ["kill", "revive"]
    assert int(state.step) >= 4


def test_gtc_resize_conserves_residual():
    """GTC error feedback conserves information ACROSS A RESIZE: sum of
    everything shipped (W_t * averaged updates, W_t per round) plus the
    final residuals equals the sum of all gradients, with a W=4 -> W=2
    resize (fold scatter-adds the dropped workers' unshipped error onto
    survivors) in the middle."""
    tau = 2e-3
    rounds, d = 3, 16
    capture = lambda p, u, o, lr: (u, o)
    params = {"w": jnp.zeros((d,))}
    strat = GTCShardMap(G.GTCConfig(tau=tau, n_workers=4), worker_mesh(4),
                        clip=0.0)
    gstate = strat.init_state(params)
    rng = np.random.default_rng(3)
    total_g = np.zeros(d)
    total_sent = np.zeros(d)
    for w_phase in (4, 2):
        step = jax.jit(G.make_sharded_gtc_train_step(
            lin_loss, capture, strat.cfg, strat.mesh))
        for _ in range(rounds):
            cs = [{"c": jnp.asarray(rng.normal(size=(d,)) * tau,
                                    jnp.float32)} for _ in range(w_phase)]
            upd, _, gstate, _ = step(
                params, None, gstate,
                tmap(lambda *xs: jnp.stack(xs), *cs), 0.05)
            total_g += sum(np.asarray(c["c"], np.float64) for c in cs)
            total_sent += w_phase * np.asarray(upd["w"], np.float64)
        if w_phase == 4:                       # shrink between phases
            before = np.asarray(gstate["residual"]["w"],
                                np.float64).sum(0)
            ts = TrainState(params=params, opt_state=None,
                            strategy_state=gstate, step=jnp.asarray(0),
                            rng=jax.random.PRNGKey(0))
            ts = strat.resize(ts, 2)
            gstate = ts.strategy_state
            after = np.asarray(gstate["residual"]["w"], np.float64).sum(0)
            np.testing.assert_allclose(after, before, atol=1e-7)
            assert gstate["residual"]["w"].shape[0] == 2
    final_res = np.asarray(gstate["residual"]["w"], np.float64).sum(0)
    np.testing.assert_allclose(total_sent + final_res, total_g, atol=1e-5)


def test_gtc_cross_w_resume_preserves_residual_sum(tmp_path):
    """A GTC checkpoint saved at W=4 resumes into a W=2 trainer: the
    strict-shape load goes through the saved-W template, the resize
    folds residuals sum-preservingly, and training continues."""
    ck = str(tmp_path / "ck")
    batch = _problem(n=32)
    src = lambda n: [TrainBatch(batch, 0.05, "quad") for _ in range(n)]
    tr4 = Trainer(GTCShardMap(G.GTCConfig(tau=1e-3, n_workers=4),
                              worker_mesh(4), clip=0.0),
                  {"quad": quad_loss}, checkpoint=CheckpointStore(ck),
                  ckpt_every=2)
    s4 = tr4.fit(tr4.init_state({"w": jnp.zeros((D,))}), src(8),
                 resume=False)
    assert int(s4.step) == 2
    res_sum = np.asarray(s4.strategy_state["residual"]["w"],
                         np.float64).sum(0)

    tr2 = Trainer(GTCShardMap(G.GTCConfig(tau=1e-3, n_workers=2),
                              worker_mesh(2), clip=0.0),
                  {"quad": quad_loss}, checkpoint=CheckpointStore(ck))
    # pure replay (source == consumed prefix): the state right after
    # the cross-W load, before any new update
    s2 = tr2.fit(tr2.init_state({"w": jnp.zeros((D,))}), src(8),
                 resume=True)
    assert tr2.resize_stats["count"] == 1      # the cross-W load resized
    assert int(s2.step) == 2
    assert s2.strategy_state["residual"]["w"].shape[0] == 2
    np.testing.assert_array_equal(np.asarray(s4.params["w"]),
                                  np.asarray(s2.params["w"]))
    np.testing.assert_allclose(
        np.asarray(s2.strategy_state["residual"]["w"],
                   np.float64).sum(0), res_sum, atol=1e-7)
    # and training continues at the new W
    s2 = tr2.fit(s2, src(4), resume=False)
    assert int(s2.step) == 4


# =============================================== membership machinery

def test_membership_join_leave_kill(tmp_path):
    m = TrainerMembership(str(tmp_path / "members.json"), timeout_s=5.0)
    assert m.live() == [] and m.live_count() == 0   # trainer floors at 1
    m.join("a")
    m.join("b")
    assert m.live() == ["a", "b"]
    m.leave("b")
    assert m.live() == ["a"]
    m.kill("a")                                # backdated heartbeat
    assert m.live() == []
    m.join("a")                                # warm rejoin: same name
    assert m.live() == ["a"]
    roster = m.roster()
    assert roster["a"]["left"] is None and roster["b"]["left"] is not None


def test_lane_crash_plan_poll_indexing(tmp_path):
    """Polls are the chaos clock: poll 0 is fit()'s pre-loop check,
    poll N fires right after update N.  Kills/revives land at exact
    indices — chaos runs are replayable."""
    m = TrainerMembership(str(tmp_path / "members.json"), timeout_s=5.0)
    m.join("a")
    m.join("b")
    plan = LaneCrashPlan(m, kills={1: "b"}, revives={2: "b"})
    assert plan.live_count() == 2              # poll 0: nothing fires
    assert plan.live_count() == 1              # poll 1: kill b
    assert plan.live_count() == 2              # poll 2: revive b
    assert [(e["event"], e["poll"]) for e in plan.log] == [("kill", 1),
                                                           ("revive", 2)]


# =========================================== ledger: claim-age + events

def _open_shared(tmp_path, n=4):
    return WorkLedger.open(str(tmp_path / "ledger.json"),
                           shard_ranges(8, n))


def test_reclaim_stale_claim_age_zombie(tmp_path):
    """The zombie case: the heartbeat thread outlives a hung main loop,
    so the heartbeat stays fresh forever while the claim never
    completes.  ``claim_timeout_s`` ages the claim by its own
    timestamp, independent of the heartbeat."""
    led = _open_shared(tmp_path)
    procs.beat(led.heartbeat_dir, "z")
    claim = led.claim_shared("z")
    late = time.time() + 120
    procs.beat(led.heartbeat_dir, "z")         # heartbeat stays fresh
    # without the claim timeout the zombie holds its claim forever
    assert led.reclaim_stale(max_age_s=300.0, now=late) == []
    stolen = led.reclaim_stale(max_age_s=300.0, now=late,
                               claim_timeout_s=60.0)
    assert [(r.lo, r.hi) for r in stolen] == [(claim.lo, claim.hi)]
    modes = [e["mode"] for e in led.events if e["event"] == "steal"]
    assert modes == ["claim_age"]


def test_reclaim_events_structured(tmp_path):
    """Every steal is a structured event: who lost what, by which
    staleness signal, how old — the supervisor surfaces these up
    through stage_targets."""
    led = _open_shared(tmp_path)
    procs.beat(led.heartbeat_dir, "a")
    led.claim_shared("a")
    hb = procs.heartbeat_path(led.heartbeat_dir, "a")
    past = time.time() - 60
    os.utime(hb, (past, past))
    led.reclaim_stale(max_age_s=5.0)
    led.claim_shared("dead")
    led.reclaim_stale(max_age_s=0.0, owners=["dead"])
    evs = [e for e in led.events if e["event"] == "steal"]
    assert [e["mode"] for e in evs] == ["hb_age", "owner"]
    assert evs[0]["from"] == "a" and evs[0]["age_s"] > 5.0
    assert evs[1]["from"] == "dead" and evs[1]["age_s"] is None
    assert all({"lo", "hi", "t"} <= set(e) for e in evs)
    json.dumps(led.events)                     # wire-safe


# ================================================== warmup-hold-decay

def test_warmup_hold_decay_shape():
    s = warmup_hold_decay(0.1, warmup_steps=4, hold_steps=6, decay=0.5,
                          steps_per_epoch=2, floor=0.004)
    assert s(0) == pytest.approx(0.1 * 1 / 4)          # ramping
    assert s(3) == pytest.approx(0.1)                  # warm
    for step in range(4, 10):
        assert s(step) == pytest.approx(0.1)           # hold at peak
    assert s(12) == pytest.approx(0.05)                # decaying
    assert s(1000) == pytest.approx(0.004)             # floor clamp
    # monotone non-increasing after the warmup
    lrs = [s(i) for i in range(3, 40)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))


def test_warmup_hold_decay_single_compile():
    """The 1-compile pin extends to the new shape: lr stays a traced
    argument, so the whole warmup-hold-decay sweep reuses one
    executable."""
    batch = _problem()
    tr = Trainer(Local(clip=0.0), {"quad": quad_loss})
    sched = warmup_hold_decay(0.1, warmup_steps=2, hold_steps=3,
                              decay=0.8, steps_per_epoch=2)
    src = [TrainBatch(batch, sched, "quad") for _ in range(10)]
    state = tr.fit(tr.init_state({"w": jnp.zeros((D,))}), src,
                   resume=False)
    assert int(state.step) == 10
    assert tr.updates["quad"]._cache_size() == 1


# ======================================================= wave driver

@pytest.mark.slow
def test_elastic_waves_end_to_end(tmp_path):
    """Two full generate -> train -> promote waves with an injected
    kill+revive per wave: resizes absorbed, the student of wave 0
    regenerates wave 1's targets (store wave supersede), final manifest
    checksum-clean, ledger done."""
    import dataclasses

    from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline

    pc = dataclasses.replace(PipelineConfig.tiny(), bmuf_workers=4,
                             bmuf_block_steps=2, n_sub_epochs=4,
                             labeled_every=2, chunked_until=3)
    p = SSLPipeline(pc, out_dir=str(tmp_path / "waves"),
                    student_trainer="bmuf")
    p.stage_baseline()
    p.stage_teacher()
    rep = p.run_waves(2, kill_at=1, revive_after=2)
    assert rep["n_waves"] == 2
    assert rep["manifest_clean"] and rep["ledger_clean"]
    assert rep["restarts_absorbed"] == 2       # one kill per wave
    assert rep["resize_count"] == 4            # shrink+grow per wave
    assert [wv["wave"] for wv in rep["waves"]] == [0, 1]   # superseded
    for wv in rep["waves"]:
        assert wv["student"]["final_workers"] == 4
