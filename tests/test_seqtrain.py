"""Forward-backward / sMBR: exactness vs brute force + invariants."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.seqtrain import (build_denominator_graph, forward_backward,
                            smbr_loss)
from repro.seqtrain.fb import forward_log_norm, viterbi
from repro.seqtrain.graphs import uniform_graph
from repro.seqtrain.smbr import frame_error_rate


def _brute_logz(log_obs, g):
    t, s = log_obs.shape
    tot = -np.inf
    for path in itertools.product(range(s), repeat=t):
        lp = g.log_init[path[0]] + log_obs[0, path[0]]
        for i in range(1, t):
            lp += g.log_trans[path[i - 1], path[i]] + log_obs[i, path[i]]
        tot = np.logaddexp(tot, lp)
    return tot


def _brute_gamma(log_obs, g):
    t, s = log_obs.shape
    logz = _brute_logz(log_obs, g)
    gamma = np.zeros((t, s))
    for path in itertools.product(range(s), repeat=t):
        lp = g.log_init[path[0]] + log_obs[0, path[0]]
        for i in range(1, t):
            lp += g.log_trans[path[i - 1], path[i]] + log_obs[i, path[i]]
        w = np.exp(lp - logz)
        for i, si in enumerate(path):
            gamma[i, si] += w
    return gamma


@pytest.mark.slow
@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_fb_matches_bruteforce(seed):
    s_, t_ = 3, 5
    rng = np.random.default_rng(seed)
    g = uniform_graph(s_, self_loop=0.5)
    lo = rng.normal(size=(1, t_, s_)).astype(np.float32)
    gamma, logz = forward_backward(jnp.asarray(lo),
                                   jnp.asarray(g.log_trans),
                                   jnp.asarray(g.log_init))
    np.testing.assert_allclose(float(logz[0]), _brute_logz(lo[0], g),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(gamma[0]), _brute_gamma(lo[0], g),
                               atol=1e-4)


def test_gamma_normalized_and_masked():
    rng = np.random.default_rng(1)
    g = uniform_graph(5)
    lo = jnp.asarray(rng.normal(size=(2, 7, 5)), jnp.float32)
    mask = jnp.asarray([[1, 1, 1, 1, 1, 0, 0], [1, 1, 1, 0, 0, 0, 0]],
                       jnp.float32)
    gamma, _ = forward_backward(lo, jnp.asarray(g.log_trans),
                                jnp.asarray(g.log_init), mask)
    sums = np.asarray(gamma.sum(-1))
    np.testing.assert_allclose(sums[0, :5], 1.0, atol=1e-4)
    np.testing.assert_allclose(sums[0, 5:], 0.0, atol=1e-6)
    np.testing.assert_allclose(sums[1, 3:], 0.0, atol=1e-6)


def test_bigram_graph_stochastic():
    rng = np.random.default_rng(2)
    als = [rng.integers(0, 11, rng.integers(4, 30)) for _ in range(40)]
    g = build_denominator_graph(als, 11, self_loop=0.6)
    rows = np.exp(g.log_trans).sum(1)
    np.testing.assert_allclose(rows, 1.0, atol=1e-4)
    np.testing.assert_allclose(np.exp(g.log_init).sum(), 1.0, atol=1e-4)
    np.testing.assert_allclose(np.exp(g.log_prior).sum(), 1.0, atol=1e-4)
    assert np.allclose(np.diag(np.exp(g.log_trans)), 0.6, atol=1e-6)


@pytest.mark.slow
def test_smbr_bounds_and_grad_direction():
    """-1 <= loss <= 0; pushing logits toward the reference increases
    expected accuracy (loss decreases)."""
    rng = np.random.default_rng(3)
    s_, b_, t_ = 6, 2, 9
    g = uniform_graph(s_)
    labels = jnp.asarray(rng.integers(0, s_, (b_, t_)), jnp.int32)
    logits = jnp.asarray(rng.normal(size=(b_, t_, s_)), jnp.float32)
    loss, m = smbr_loss(logits, labels, g)
    assert -1.0 <= float(loss) <= 0.0
    onehot = jax.nn.one_hot(labels, s_) * 10.0
    loss_good, _ = smbr_loss(logits + onehot, labels, g)
    assert float(loss_good) < float(loss)
    gr = jax.grad(lambda lg: smbr_loss(lg, labels, g)[0])(logits)
    assert bool(jnp.all(jnp.isfinite(gr)))
    # gradient should on average push the reference senone logit UP
    ref_grad = jnp.take_along_axis(gr, labels[..., None], -1)
    assert float(ref_grad.mean()) < 0      # minimizing loss raises ref logit


def test_viterbi_matches_bruteforce():
    rng = np.random.default_rng(4)
    s_, t_ = 3, 5
    g = uniform_graph(s_, self_loop=0.4)
    lo = rng.normal(size=(1, t_, s_)).astype(np.float32)
    best, best_lp = None, -np.inf
    for path in itertools.product(range(s_), repeat=t_):
        lp = g.log_init[path[0]] + lo[0, 0, path[0]]
        for i in range(1, t_):
            lp += g.log_trans[path[i - 1], path[i]] + lo[0, i, path[i]]
        if lp > best_lp:
            best, best_lp = path, lp
    got = viterbi(jnp.asarray(lo), jnp.asarray(g.log_trans),
                  jnp.asarray(g.log_init))
    assert tuple(np.asarray(got[0])) == best


def test_frame_error_rate():
    logits = jnp.asarray([[[0.0, 5.0], [5.0, 0.0], [0.0, 5.0]]])
    labels = jnp.asarray([[1, 0, 0]])
    assert float(frame_error_rate(logits, labels)) == pytest.approx(1 / 3)
    mask = jnp.asarray([[1.0, 1.0, 0.0]])
    assert float(frame_error_rate(logits, labels, mask)) == 0.0
