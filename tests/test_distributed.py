"""BMUF + GTC: algebraic invariants and trainer equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.distributed import bmuf as B
from repro.distributed import gtc as G
from repro.optim import momentum_init, momentum_update
from repro.runtime.cluster import worker_mesh

tmap = jax.tree_util.tree_map


def quad_loss(params, batch):
    """Simple strongly-convex test problem."""
    w = params["w"]
    e = (batch["x"] @ w - batch["y"])
    return jnp.mean(e ** 2), {"loss": jnp.mean(e ** 2)}


def quad_step():
    """lr is a traced argument — the contract every strategy step uses."""
    def step(params, opt_state, batch, lr):
        (_, m), g = jax.value_and_grad(quad_loss, has_aux=True)(params,
                                                                batch)
        params, opt_state = momentum_update(params, g, opt_state, lr=lr,
                                            beta=0.0, nesterov=False)
        return params, opt_state, m
    return step


def _problem(seed=0, n=64, d=8):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(d,))
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x @ w_true).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


# ------------------------------------------------------------------- BMUF

def test_bmuf_single_worker_tau1_equals_sgd():
    """W=1, tau=1, eta=0, zeta=1 reduces exactly to plain SGD."""
    x, y = _problem()
    params = {"w": jnp.zeros((8,))}
    cfg = B.BMUFConfig(n_workers=1, block_steps=1, block_momentum=0.0,
                       block_lr=1.0, nesterov=False)
    state = B.bmuf_init(params, cfg)
    opt = jax.vmap(lambda _: momentum_init(params))(jnp.arange(1))
    block = jax.jit(B.make_bmuf_block_step(quad_step(), cfg))
    batches = {"x": x[None, None], "y": y[None, None]}
    state, opt, _ = block(state, opt, batches, 0.05)

    ref_params = {"w": jnp.zeros((8,))}
    ref_opt = momentum_init(ref_params)
    ref_params, ref_opt, _ = quad_step()(ref_params, ref_opt,
                                         {"x": x, "y": y}, 0.05)
    np.testing.assert_allclose(np.asarray(state["theta_g"]["w"]),
                               np.asarray(ref_params["w"]), rtol=1e-5,
                               atol=1e-7)


def test_bmuf_sync_math():
    """Block sync: theta' = theta + eta*delta + zeta*(mean(w) - theta)."""
    params = {"w": jnp.asarray([1.0, 2.0])}
    cfg = B.BMUFConfig(n_workers=2, block_momentum=0.5, block_lr=0.8,
                       nesterov=True)
    state = B.bmuf_init(params, cfg)
    state["delta"] = {"w": jnp.asarray([0.1, -0.1])}
    state["workers"] = {"w": jnp.asarray([[2.0, 2.0], [4.0, 0.0]])}
    out = B.block_sync(state, cfg)
    g = np.asarray([3.0 - 1.0, 1.0 - 2.0])
    delta = 0.5 * np.asarray([0.1, -0.1]) + 0.8 * g
    theta = np.asarray([1.0, 2.0]) + delta
    np.testing.assert_allclose(np.asarray(out["theta_g"]["w"]), theta,
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["delta"]["w"]), delta,
                               rtol=1e-6)
    # Nesterov restart: workers start from theta + eta*delta
    np.testing.assert_allclose(np.asarray(out["workers"]["w"][0]),
                               theta + 0.5 * delta, rtol=1e-6)


def test_bmuf_converges_on_quadratic():
    x, y = _problem(n=256)
    params = {"w": jnp.zeros((8,))}
    cfg = B.BMUFConfig(n_workers=4, block_steps=2, block_momentum=0.5,
                       block_lr=1.0)
    state = B.bmuf_init(params, cfg)
    opt = jax.vmap(lambda _: momentum_init(params))(jnp.arange(4))
    block = jax.jit(B.make_bmuf_block_step(quad_step(), cfg))
    rng = np.random.default_rng(1)
    start = float(quad_loss(state["theta_g"], {"x": x, "y": y})[0])
    for it in range(60):
        sel = rng.integers(0, 256, (2, 4, 32))
        batches = {"x": jnp.asarray(np.asarray(x)[sel]),
                   "y": jnp.asarray(np.asarray(y)[sel])}
        state, opt, ms = block(state, opt, batches, 0.05)
    final = float(quad_loss(state["theta_g"], {"x": x, "y": y})[0])
    assert final < 0.05 * start, (start, final)


def test_sharded_bmuf_matches_vmap_path():
    """shard_map BMUF on a 1-device CPU mesh == the vmap reference —
    bitwise on theta_g AND delta, held across >= 2 blocks (the second
    block exercises the carried block momentum and the Nesterov
    restart, not just the first sync).  When the worker mesh spans >1
    real device the cross-device psum reduction order differs from the
    single-device vmap mean, so equality relaxes to a float32-ULP
    tolerance."""
    x, y = _problem(n=64)
    params = {"w": jnp.zeros((8,))}
    cfg = B.BMUFConfig(n_workers=2, block_steps=2, block_momentum=0.5,
                       block_lr=1.0)
    rng = np.random.default_rng(7)

    state_v = B.bmuf_init(params, cfg)
    opt_v = jax.vmap(lambda _: momentum_init(params))(jnp.arange(2))
    block_v = jax.jit(B.make_bmuf_block_step(quad_step(), cfg))

    mesh = worker_mesh(2)
    state_s = B.bmuf_init(params, cfg)
    opt_s = jax.vmap(lambda _: momentum_init(params))(jnp.arange(2))
    block_s = B.make_sharded_bmuf_block_step(quad_step(), cfg, mesh,
                                             worker_axes=("data",))

    check = (np.testing.assert_array_equal if mesh.devices.size == 1
             else lambda a, b, err_msg: np.testing.assert_allclose(
                 a, b, atol=1e-7, rtol=0, err_msg=err_msg))
    for blk in range(3):
        sel = rng.integers(0, 64, (2, 2, 32))
        batches = {"x": jnp.asarray(np.asarray(x)[sel]),
                   "y": jnp.asarray(np.asarray(y)[sel])}
        state_v, opt_v, _ = block_v(state_v, opt_v, batches, 0.05)
        state_s, opt_s, _ = block_s(state_s, opt_s, batches, 0.05)
        check(np.asarray(state_s["theta_g"]["w"]),
              np.asarray(state_v["theta_g"]["w"]),
              err_msg=f"theta_g, block {blk}")
        check(np.asarray(state_s["delta"]["w"]),
              np.asarray(state_v["delta"]["w"]),
              err_msg=f"delta, block {blk}")


# -------------------------------------------------------------------- GTC

@given(seed=st.integers(0, 200), tau_exp=st.integers(-5, -1))
@settings(max_examples=30, deadline=None)
def test_gtc_conservation(seed, tau_exp):
    """send + residual' == residual + grad, always (error feedback)."""
    tau = 10.0 ** tau_exp
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(17, 5)), jnp.float32) * 0.01
    r = jnp.asarray(rng.normal(size=(17, 5)), jnp.float32) * 0.01
    s, nr = G.compress_leaf(g, r, tau)
    np.testing.assert_allclose(np.asarray(s + nr), np.asarray(g + r),
                               atol=1e-6)
    # ternary wire alphabet
    vals = np.unique(np.abs(np.asarray(s)).round(8))
    assert set(vals).issubset({0.0, np.float32(tau).item()}) or \
        np.allclose(vals[vals > 0], tau, rtol=1e-5)


def test_gtc_eventually_transmits():
    """A constant small gradient accumulates in the residual and is
    eventually sent — no information is lost, only delayed."""
    tau = 1.0
    g = jnp.full((4,), 0.3, jnp.float32)
    r = jnp.zeros((4,))
    sent = jnp.zeros((4,))
    for _ in range(10):
        s, r = G.compress_leaf(g, r, tau)
        sent = sent + s
    total = np.asarray(sent + r)
    np.testing.assert_allclose(total, 3.0, atol=1e-5)
    assert float(jnp.abs(sent).sum()) > 0


def test_gtc_int8_roundtrip():
    tau = 0.125
    s = jnp.asarray([-tau, 0.0, tau, 0.0], jnp.float32)
    packed = G.pack_int8(s, tau)
    assert packed.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(G.unpack_int8(packed, tau)),
                               np.asarray(s), atol=1e-7)


def test_pack_int8_overflow_guard():
    """Summing > 127 ternary messages at int8 width would wrap; pack
    refuses to build that wire unless the accumulation widens."""
    s = jnp.zeros((4,), jnp.float32)
    G.pack_int8(s, 1e-3, n_workers=127)               # fits
    with pytest.raises(ValueError, match="int32_accum"):
        G.pack_int8(s, 1e-3, n_workers=128)
    G.pack_int8(s, 1e-3, n_workers=128, int32_accum=True)  # widened: fine
    with pytest.raises(ValueError):
        G.wire_reduce({"w": s}, G.GTCConfig(tau=1e-3, n_workers=200))
    G.wire_reduce({"w": s}, G.GTCConfig(tau=1e-3, n_workers=200,
                                        int32_accum=True))


def test_unpack_int8_averages_summed_workers():
    """unpack_int8 honors n_workers_summed: a summed wire of W packed
    messages unpacks to the worker-averaged update."""
    tau = 0.5
    summed = jnp.asarray([2, -2, 1, 0], jnp.int8)     # sum of 2 messages
    out = G.unpack_int8(summed, tau, n_workers_summed=2)
    np.testing.assert_allclose(np.asarray(out),
                               [0.5, -0.5, 0.25, 0.0], atol=1e-7)


def test_wire_reduce_single_worker_is_identity_on_sends():
    """The degenerate wire (pack -> unpack, no axes) is bitwise-identity
    on ternary sends — what lets the single-process GTC strategy share
    the multi-worker arithmetic."""
    tau = 1e-3
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(33,)) * tau, jnp.float32)
    s, _ = G.compress_leaf(g, jnp.zeros((33,)), tau)
    out = G.wire_reduce({"w": s}, G.GTCConfig(tau=tau, n_workers=1))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(s))


def test_gtc_ring_converges_to_mean():
    """Repeated rounds on a constant gradient: cumulative applied update
    approaches rounds*mean(g) — 1-bit/threshold quantization delays but
    never loses information (error feedback)."""
    rng = np.random.default_rng(3)
    tau = 0.05
    # |g| < tau: the regime where the ±tau-per-round send keeps up with
    # the residual inflow (Strom picks tau above the typical grad scale)
    grads = [{"w": jnp.asarray(rng.normal(size=(6,)) * tau / 3,
                               jnp.float32)} for _ in range(4)]
    res = [{"w": jnp.zeros((6,))} for _ in range(4)]
    total = jnp.zeros((6,))
    rounds = 50
    for _ in range(rounds):
        avg, res = G.simulate_gtc_round(grads, res, tau)
        total = total + avg["w"]
    ref = rounds * np.mean([np.asarray(g["w"]) for g in grads], axis=0)
    # per-element residual is bounded by tau per worker
    np.testing.assert_allclose(np.asarray(total), ref, atol=4 * tau)


def test_gtc_strategy_matches_compress_tree():
    """The train.GTC strategy's update == the manual reference: grads
    compressed by gtc_lib.compress_tree against the carried residual,
    with the *sent* sparse tensor driving the optimizer."""
    from repro.train import GTC as GTCStrategy, Trainer
    x, y = _problem(n=32)
    params = {"w": jnp.zeros((8,))}
    tau = 1e-3
    strat = GTCStrategy(G.GTCConfig(tau=tau, n_workers=1), clip=0.0)
    tr = Trainer(strat, {"quad": quad_loss})
    state = tr.init_state(params)
    lr = 0.05

    ref_params = {"w": jnp.zeros((8,))}
    ref_opt = momentum_init(ref_params)
    ref_res = {"w": jnp.zeros((8,))}
    batch = {"x": x, "y": y}
    for _ in range(3):
        state, _ = tr.updates["quad"](state, batch,
                                      jnp.asarray(lr, jnp.float32))
        (_, _), g = jax.value_and_grad(quad_loss, has_aux=True)(
            ref_params, batch)
        send, ref_res = G.compress_tree(g, ref_res, tau)
        ref_params, ref_opt = momentum_update(ref_params, send, ref_opt,
                                              lr=lr)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(ref_params["w"]), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(state.strategy_state["residual"]["w"]),
        np.asarray(ref_res["w"]), rtol=1e-6)


# -------------------------------------------------- GTC sharded (tentpole)

def lin_loss(params, batch):
    """Linear probe: grad == batch["c"] bitwise (no float reassociation
    between eager references and the jitted step) — isolates the
    exchange arithmetic for the bitwise comparisons."""
    l = jnp.sum(params["w"] * batch["c"])
    return l, {"loss": l}


def _stack(dicts):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *dicts)


@pytest.mark.parametrize("n_workers,quantize",
                         [(2, True), (4, True), (2, False)])
def test_sharded_gtc_wire_matches_simulate_bitwise(n_workers, quantize):
    """The tentpole pin: make_sharded_gtc_train_step's applied update
    and per-worker residuals == simulate_gtc_round, BITWISE, for both
    the float and the packed-int8 wire (integer accumulation is exact,
    so the shard_map plumbing must add nothing)."""
    tau = 1e-3
    cfg = G.GTCConfig(tau=tau, n_workers=n_workers, quantize_int8=quantize)
    mesh = worker_mesh(n_workers)
    capture = lambda p, u, o, lr: (u, o)       # "params" := applied update
    step = jax.jit(G.make_sharded_gtc_train_step(lin_loss, capture, cfg,
                                                 mesh))
    params = {"w": jnp.zeros((9,))}
    state = {"residual": {"w": jnp.zeros((n_workers, 9))}}
    ref_res = [{"w": jnp.zeros((9,))} for _ in range(n_workers)]
    rng = np.random.default_rng(11)
    for it in range(4):
        cs = [{"c": jnp.asarray(rng.normal(size=(9,)) * tau, jnp.float32)}
              for _ in range(n_workers)]
        upd, _, state, ms = step(params, None, state, _stack(cs), 0.05)
        ref_upd, ref_res = G.simulate_gtc_round(
            [{"w": c["c"]} for c in cs], ref_res, tau,
            quantize_int8=quantize)
        np.testing.assert_array_equal(np.asarray(upd["w"]),
                                      np.asarray(ref_upd["w"]),
                                      err_msg=f"update, round {it}")
        for w in range(n_workers):
            np.testing.assert_array_equal(
                np.asarray(state["residual"]["w"][w]),
                np.asarray(ref_res[w]["w"]),
                err_msg=f"residual, worker {w}, round {it}")


def test_gtc_shardmap_w1_bitwise_equals_gtc_strategy():
    """GTCShardMap at n_workers=1 on a 1-device mesh == the
    single-process GTC strategy, bitwise on params AND residual — the
    BMUFVmap/BMUFShardMap validation story for the second trainer."""
    from repro.train import GTC as GTCStrategy, GTCShardMap, Trainer, \
        TrainBatch
    x, y = _problem(n=32)
    batch = {"x": x, "y": y}
    params = {"w": jnp.zeros((8,))}
    tau = 1e-3
    src = lambda: [TrainBatch(batch, 0.05, "quad") for _ in range(5)]

    tr1 = Trainer(GTCStrategy(G.GTCConfig(tau=tau, n_workers=1), clip=0.0),
                  {"quad": quad_loss})
    s1 = tr1.fit(tr1.init_state(params), src(), resume=False)

    mesh = worker_mesh(1)
    trs = Trainer(GTCShardMap(G.GTCConfig(tau=tau, n_workers=1), mesh,
                              clip=0.0), {"quad": quad_loss})
    ss = trs.fit(trs.init_state(params), src(), resume=False)
    assert int(s1.step) == int(ss.step) == 5
    np.testing.assert_array_equal(np.asarray(s1.params["w"]),
                                  np.asarray(ss.params["w"]))
    np.testing.assert_array_equal(
        np.asarray(s1.strategy_state["residual"]["w"]),
        np.asarray(ss.strategy_state["residual"]["w"][0]))


def test_sharded_gtc_residual_conservation():
    """Error feedback conserves information across workers and rounds:
    sum of everything shipped (W * the averaged updates) plus the final
    residuals equals the sum of all gradients — compression delays,
    never drops."""
    tau = 2e-3
    W, D, rounds = 4, 16, 6
    cfg = G.GTCConfig(tau=tau, n_workers=W)     # int8 wire, /4 is exact
    mesh = worker_mesh(W)
    capture = lambda p, u, o, lr: (u, o)
    step = jax.jit(G.make_sharded_gtc_train_step(lin_loss, capture, cfg,
                                                 mesh))
    params = {"w": jnp.zeros((D,))}
    state = {"residual": {"w": jnp.zeros((W, D))}}
    rng = np.random.default_rng(3)
    total_g = np.zeros(D)
    total_sent = np.zeros(D)
    for _ in range(rounds):
        cs = [{"c": jnp.asarray(rng.normal(size=(D,)) * tau, jnp.float32)}
              for _ in range(W)]
        upd, _, state, _ = step(params, None, state, _stack(cs), 0.05)
        total_g += sum(np.asarray(c["c"], np.float64) for c in cs)
        total_sent += W * np.asarray(upd["w"], np.float64)
    final_res = np.asarray(state["residual"]["w"], np.float64).sum(0)
    np.testing.assert_allclose(total_sent + final_res, total_g, atol=1e-5)


def test_gtc_strategy_kernel_path_matches_ref():
    """GTCConfig(use_kernel=True) routes compression through the Pallas
    kernel (interpret mode on CPU) and matches the ref path to float32
    round-off.  (The kernel itself is element-exact vs the ref oracle —
    test_kernels pins that; across a *full jitted update* the pallas_call
    boundary blocks the elementwise fusion XLA applies to the inline
    ref, so the carried residual can drift by ~1 ulp.)"""
    from repro.train import GTC as GTCStrategy, Trainer, TrainBatch
    x, y = _problem(n=32)
    batch = {"x": x, "y": y}
    params = {"w": jnp.zeros((8,))}
    src = lambda: [TrainBatch(batch, 0.05, "quad") for _ in range(3)]
    outs = []
    for use_kernel in (False, True):
        tr = Trainer(GTCStrategy(G.GTCConfig(tau=1e-3, n_workers=1,
                                             use_kernel=use_kernel),
                                 clip=0.0), {"quad": quad_loss})
        st = tr.fit(tr.init_state(params), src(), resume=False)
        outs.append(st)
    np.testing.assert_allclose(np.asarray(outs[0].params["w"]),
                               np.asarray(outs[1].params["w"]),
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(outs[0].strategy_state["residual"]["w"]),
        np.asarray(outs[1].strategy_state["residual"]["w"]),
        rtol=1e-5, atol=1e-6)


def test_adaptive_tau_density():
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    tau = G.adaptive_tau(g, 0.1)
    frac = float(jnp.mean(jnp.abs(g) > tau))
    assert 0.05 < frac < 0.15
