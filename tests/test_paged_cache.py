"""Paged KV cache: allocator invariants, paged == contiguous decode
parity, prefix caching, per-request sampling (ISSUE 6).

The acceptance bar: the paged TokenServer is token-identical to the
contiguous per-row path under greedy decoding, serves prompts longer
than an equal-budget contiguous cache allows, never leaks or aliases a
page (including across ``_abort``), and sampling with a fixed seed is
reproducible and independent of batch composition.
"""
from dataclasses import replace

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.models import build_model
from repro.models.paging import PagedCacheConfig, paged_token_bytes
from repro.serve import (LATENCY, BatchPolicy, PageAllocator, RoundTokenServer,
                         SamplingParams, TokenServer, block_hashes)

LM_CFG = {}


def _lm():
    """Shared reduced token-LM config/params (compile caches reused)."""
    if not LM_CFG:
        from repro.configs import get_arch, reduced
        cfg = reduced(get_arch("qwen2.5-3b"))
        model = build_model(cfg)
        LM_CFG["cfg"] = cfg
        LM_CFG["params"] = model.init(jax.random.key(0))
    return LM_CFG["cfg"], LM_CFG["params"]


PAGING = PagedCacheConfig(page_size=8, n_pages=32, max_ctx=64)
POL = BatchPolicy("t", max_batch=4, bucket_multiple=16,
                  sort_by_length=False, sync_every=4)


def _workload(rng, cfg, n=8):
    prompts = [rng.integers(1, cfg.vocab_size,
                            int(l)).astype(np.int32)
               for l in rng.integers(3, 14, n)]
    news = [int(x) for x in rng.integers(2, 12, n)]
    return prompts, news


# --------------------------------------------------------- allocator

@settings(max_examples=10, deadline=None)
@given(n_pages=st.integers(min_value=1, max_value=24),
       seed=st.integers(min_value=0, max_value=1000))
def test_allocator_never_aliases_live_pages(n_pages, seed):
    """Random alloc/release interleavings: live leases stay pairwise
    disjoint, and free + live + cached page counts are conserved."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(n_pages, 8, prefix_cache=False)
    leases = []
    for _ in range(50):
        if leases and rng.random() < 0.4:
            alloc.release(leases.pop(rng.integers(len(leases))))
        else:
            want = int(rng.integers(1, max(2, n_pages // 2 + 1)))
            if alloc.can_alloc(want):
                leases.append(alloc.alloc(want))
            else:
                with pytest.raises(RuntimeError):
                    alloc.alloc(want)
        flat = [p for lease in leases for p in lease]
        assert len(flat) == len(set(flat)), "page aliased across live rows"
        assert all(1 <= p <= n_pages for p in flat)
        alloc.check()
    for lease in leases:
        alloc.release(lease)
    alloc.check()
    assert alloc.free_pages() == n_pages and alloc.live_pages() == 0


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000))
def test_allocator_prefix_refcounts(seed):
    """A published block stays resident while any sharer holds it, parks
    in the reusable pool exactly when the last sharer releases, and is
    evicted only when the free list runs dry."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(8, 4)
    toks = rng.integers(1, 100, 9)
    hashes = block_hashes(toks, 4)       # 2 sharable blocks of the 9 toks
    assert len(hashes) == 2

    first = alloc.alloc(2)
    for page, h in zip(first, hashes):
        alloc.publish(page, h)
    assert alloc.peek_prefix(hashes) == 2
    sharers = [alloc.acquire_prefix(hashes) for _ in range(3)]
    alloc.release(first)
    for s in sharers[:-1]:
        alloc.release(s)
        assert alloc.peek_prefix(hashes) == 2      # still held by someone
        alloc.check()
    alloc.release(sharers[-1])
    alloc.check()
    # ref hit zero: pages are cached (reusable), not lost
    assert alloc.live_pages() == 0 and alloc.free_pages() == 8
    assert alloc.peek_prefix(hashes) == 2
    # exhausting the pool evicts the cached pages LRU-first
    all_pages = alloc.alloc(8)
    assert sorted(all_pages) == list(range(1, 9))
    assert alloc.peek_prefix(hashes) == 0
    assert alloc.stats["evictions"] == 2
    alloc.release(all_pages)
    alloc.check()


def test_block_hashes_exclude_final_prompt_position():
    """The block containing the last prompt token is never sharable (the
    retirement overshoot clamp may rewrite that position in place)."""
    toks = list(range(100, 117))          # 17 tokens, page_size 8
    assert len(block_hashes(toks, 8)) == 2        # 16 <= 17-1: both full
    assert len(block_hashes(toks[:16], 8)) == 1   # 16 > 16-1: 2nd excluded
    assert len(block_hashes(toks[:8], 8)) == 0
    # chained: equal first block, different second -> shared prefix of 1
    a = block_hashes(list(range(20)), 4)
    b = block_hashes(list(range(4)) + list(range(50, 66)), 4)
    assert a[0] == b[0] and a[1] != b[1]


# ------------------------------------------------- paged server parity

def test_paged_server_matches_contiguous_greedy():
    """The pin: block-table paging is invisible to greedy outputs."""
    cfg, params = _lm()
    rng = np.random.default_rng(3)
    prompts, news = _workload(rng, cfg)
    srv_c = TokenServer(cfg, params, policy=POL, max_seq=64)
    srv_p = TokenServer(cfg, params, policy=POL, paging=PAGING,
                        prefix_cache=False)
    rc = [srv_c.submit(p, n) for p, n in zip(prompts, news)]
    rp = [srv_p.submit(p, n) for p, n in zip(prompts, news)]
    out_c, out_p = srv_c.drain(), srv_p.drain()
    for a, b in zip(rc, rp):
        assert out_c[a].out == out_p[b].out
    # every page came back; conservation holds
    srv_p.alloc.check()
    assert srv_p.alloc.live_pages() == 0
    # memory high-water actually paged: peak pages stayed below the
    # contiguous equivalent (slots x max_seq worth of pages)
    peak = srv_p.alloc.stats["peak_pages"]
    assert 0 < peak < POL.max_batch * (64 // PAGING.page_size)


def test_paged_long_prompt_beyond_contiguous_budget():
    """A prompt longer than the contiguous max_seq serves fine when the
    page budget covers it — and matches a big-contiguous reference."""
    cfg, params = _lm()
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, cfg.vocab_size, 80).astype(np.int32)
    with pytest.raises(ValueError):
        TokenServer(cfg, params, policy=POL, max_seq=64).submit(prompt, 6)
    big = PagedCacheConfig(page_size=8, n_pages=32, max_ctx=128)
    srv = TokenServer(cfg, params, policy=POL, paging=big)
    rid = srv.submit(prompt, 6)
    out = srv.drain()[rid].out
    ref_srv = TokenServer(cfg, params, max_seq=128,
                          policy=replace(LATENCY, max_batch=1))
    rref = ref_srv.submit(prompt, 6)
    assert out == ref_srv.drain()[rref].out
    # but a request over the page budget is still refused up front
    with pytest.raises(ValueError):
        srv.submit(rng.integers(1, cfg.vocab_size, 300).astype(np.int32), 6)


def test_prefix_cache_hits_and_parity():
    """Requests sharing a prompt prefix reuse published pages (nonzero
    hit rate) and produce exactly the tokens of a prefix-cache-off run."""
    cfg, params = _lm()
    rng = np.random.default_rng(5)
    pre = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(
        1, cfg.vocab_size, int(t)).astype(np.int32)])
        for t in rng.integers(1, 8, 8)]
    on = TokenServer(cfg, params, policy=POL, paging=PAGING)
    off = TokenServer(cfg, params, policy=POL, paging=PAGING,
                      prefix_cache=False)
    r_on = [on.submit(p, 5) for p in prompts]
    r_off = [off.submit(p, 5) for p in prompts]
    out_on, out_off = on.drain(), off.drain()
    for a, b in zip(r_on, r_off):
        assert out_on[a].out == out_off[b].out
    s = on.paging_stats()
    assert s["hits"] > 0
    assert off.paging_stats()["hits"] == 0
    # fewer fresh pages were allocated thanks to sharing
    assert s["allocs"] < off.paging_stats()["allocs"]
    on.alloc.check()
    assert on.alloc.live_pages() == 0


def test_abort_leaks_no_pages():
    """A window that dies mid-flight must return every page: after the
    failure the allocator is at full capacity and the requeued requests
    complete with a healthy window."""
    cfg, params = _lm()
    rng = np.random.default_rng(6)
    prompts, news = _workload(rng, cfg, n=5)
    srv = TokenServer(cfg, params, policy=POL, paging=PAGING)
    rids = [srv.submit(p, n) for p, n in zip(prompts, news)]
    srv.drain()
    ref = TokenServer(cfg, params, policy=POL, paging=PAGING)
    ref_rids = [ref.submit(p, n) for p, n in zip(prompts, news)]
    ref.pump()                           # part-way: some rows mid-flight
    assert ref.alloc.live_pages() > 0
    good = ref.serve
    ref.serve = lambda *a, **k: (_ for _ in ()).throw(RuntimeError("die"))
    with pytest.raises(RuntimeError):
        ref.pump()
    ref.alloc.check()
    assert ref.alloc.live_pages() == 0
    assert ref.alloc.free_pages() == PAGING.n_pages
    assert all(b is None for b in ref._blocks)
    ref.serve = good
    out = ref.drain()
    base = TokenServer(cfg, params, policy=POL, paging=PAGING)
    base_rids = [base.submit(p, n) for p, n in zip(prompts, news)]
    base_out = base.drain()
    for a, b in zip(ref_rids, base_rids):
        assert out[a].out == base_out[b].out


def test_slot_position_invariant():
    """Host and device positions agree for every occupied slot at every
    sync (regression: empty slots' host positions used to drift)."""
    cfg, params = _lm()
    rng = np.random.default_rng(7)
    prompts, news = _workload(rng, cfg, n=6)
    srv = TokenServer(cfg, params, policy=POL, paging=PAGING)
    for p, n in zip(prompts, news):
        srv.submit(p, n)
    while srv.queue.n_pending or srv.n_active:
        srv.pump()
        host, dev = srv.slot_positions()
        for i, s in enumerate(srv._slots):
            if s is not None:
                assert host[i] == dev[i], (i, host, dev)


def test_admission_waits_for_pages():
    """FIFO no-skip: when the pool can't cover the next request it waits
    (requeued, not dropped) and completes once pages free up."""
    cfg, params = _lm()
    rng = np.random.default_rng(8)
    tight = PagedCacheConfig(page_size=8, n_pages=8, max_ctx=64)
    srv = TokenServer(cfg, params, policy=POL, paging=tight,
                      prefix_cache=False)
    # each needs ceil((20 + 13 - 1)/8) = 4 pages -> only 2 fit at once
    prompts = [rng.integers(1, cfg.vocab_size, 20).astype(np.int32)
               for _ in range(4)]
    rids = [srv.submit(p, 13) for p in prompts]
    srv.pump()
    assert srv.n_active == 2 and srv.queue.n_pending == 2
    out = srv.drain()
    assert sorted(out) == sorted(rids)
    assert all(len(out[r].out) == 13 for r in rids)
    srv.alloc.check()
    assert srv.alloc.live_pages() == 0


# ----------------------------------------------------------- sampling

def test_sampling_reproducible_and_composition_independent():
    """Fixed seed -> identical tokens across runs; a sampled request is
    also independent of its batch neighbours (solo == batched)."""
    cfg, params = _lm()
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, cfg.vocab_size, 6).astype(np.int32)
    others, news = _workload(rng, cfg, n=3)
    sp = SamplingParams(temperature=0.9, top_k=20, top_p=0.9, seed=42)

    def run(batched):
        srv = TokenServer(cfg, params, policy=POL, paging=PAGING)
        rid = srv.submit(prompt, 8, sampling=sp)
        if batched:
            for p, n in zip(others, news):
                srv.submit(p, n, sampling=SamplingParams(
                    temperature=1.3, seed=7))
        return srv.drain()[rid].out

    solo1, solo2, batched = run(False), run(False), run(True)
    assert solo1 == solo2 == batched
    # and a different seed actually changes something: near-infinite
    # temperature flattens even the untrained model's peaked logits
    srv = TokenServer(cfg, params, policy=POL, paging=PAGING)
    outs = set()
    for seed in range(6):
        rid = srv.submit(prompt, 8, sampling=SamplingParams(
            temperature=1000.0, seed=seed))
        outs.add(tuple(srv.drain()[rid].out))
    assert len(outs) > 1


def test_sampling_topk1_is_greedy_and_sync_cadence():
    """top_k=1 at any temperature degenerates to argmax — must equal the
    greedy window's tokens — and the sampled window keeps the one-sync-
    per-K contract."""
    cfg, params = _lm()
    rng = np.random.default_rng(10)
    prompt = rng.integers(1, cfg.vocab_size, 5).astype(np.int32)
    greedy = TokenServer(cfg, params, policy=POL, paging=PAGING)
    rid_g = greedy.submit(prompt, 8)
    out_g = greedy.drain()[rid_g].out
    samp = TokenServer(cfg, params, policy=POL, paging=PAGING)
    rid_s = samp.submit(prompt, 8, sampling=SamplingParams(
        temperature=0.7, top_k=1, seed=5))
    out_s = samp.drain()[rid_s].out
    assert out_g == out_s
    # 5 + 8 - 1 = 12 consumed steps at sync_every=4 -> exactly 3 syncs
    assert samp.stats["steps"] == 12 and samp.stats["syncs"] == 3


def test_mixed_greedy_and_sampled_window():
    """Greedy rows keep bitwise argmax even when sharing a window with
    sampled neighbours (temperature<=0 sentinel)."""
    cfg, params = _lm()
    rng = np.random.default_rng(11)
    gp = rng.integers(1, cfg.vocab_size, 7).astype(np.int32)
    sp = rng.integers(1, cfg.vocab_size, 4).astype(np.int32)
    solo = TokenServer(cfg, params, policy=POL, paging=PAGING)
    rid = solo.submit(gp, 6)
    ref = solo.drain()[rid].out
    mixed = TokenServer(cfg, params, policy=POL, paging=PAGING)
    rid_g = mixed.submit(gp, 6)
    mixed.submit(sp, 6, sampling=SamplingParams(temperature=1.5, seed=3))
    assert mixed.drain()[rid_g].out == ref


# ----------------------------------------------------- memory accounting

def test_paged_token_bytes_positive():
    cfg, _ = _lm()
    per_tok = paged_token_bytes(cfg, np.dtype(np.float32))
    assert per_tok > 0
    # a ragged in-flight set costs peak_pages * page_size tokens, the
    # contiguous layout always slots * max_seq — paging must cost less
    # on any workload that doesn't fill every slot to max_seq
    cfg2, params = _lm()
    srv = TokenServer(cfg2, params, policy=POL, paging=PAGING,
                      prefix_cache=False)
    rng = np.random.default_rng(12)
    prompts, news = _workload(rng, cfg2)
    for p, n in zip(prompts, news):
        srv.submit(p, n)
    srv.drain()
    paged_tokens = srv.alloc.stats["peak_pages"] * PAGING.page_size
    assert paged_tokens < POL.max_batch * 64
