"""Sharding policy: every param/cache leaf of every arch gets a legal spec
on the production meshes (divisibility-checked via AbstractMesh — no device
init needed; built through utils.compat so the ctor-signature churn across
jax releases stays out of the tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, get_arch, reduced
from repro.distributed import sharding as sh
from repro.models.api import abstract_params
from repro.utils.compat import abstract_mesh
from repro.utils.trees import map_with_path, tree_paths

POD = abstract_mesh((("data", 16), ("model", 16)))
MULTI = abstract_mesh((("pod", 2), ("data", 16), ("model", 16)))


def _check_specs(cfg, mesh):
    params = abstract_params(cfg)
    specs = sh.tree_param_specs(params, mesh)
    for (path, leaf), (_, spec) in zip(tree_paths(params),
                                       tree_paths(specs)):
        assert isinstance(spec, P), path
        shape = leaf.shape
        offset = len(shape) - len(spec)
        assert offset >= 0, (path, shape, spec)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            size = sh._axis_size(mesh, ax)
            assert shape[i] % size == 0, (path, shape, spec, ax)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [POD, MULTI], ids=["pod", "multipod"])
def test_param_specs_divisible(arch, mesh):
    _check_specs(get_arch(arch), mesh)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_degrade_on_tiny_mesh(arch):
    """Reduced configs on a 1-device mesh: everything degrades to
    replicated (or still-divisible) specs, never an error."""
    tiny = abstract_mesh((("data", 1), ("model", 1)))
    _check_specs(reduced(get_arch(arch)), tiny)


def test_big_matrices_are_2d_sharded():
    """The FSDP+TP policy must actually split the big matrices both ways
    on the pod mesh (this is what makes 671B fit)."""
    cfg = get_arch("deepseek-67b")
    params = abstract_params(cfg)
    specs = sh.tree_param_specs(params, POD)
    flat = dict(tree_paths(specs))
    # find an attention projection inside the scanned segment
    keys = [k for k in flat if k.endswith("mixer/wq")]
    assert keys
    spec = flat[keys[0]]
    used = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, tuple):
            used.update(entry)
        else:
            used.add(entry)
    assert "model" in used and "data" in used, spec


def test_moe_expert_dim_sharded():
    cfg = get_arch("qwen3-moe-30b-a3b")
    params = abstract_params(cfg)
    specs = dict(tree_paths(sh.tree_param_specs(params, POD)))
    k = [p for p in specs if p.endswith("ffn/w_gate")][0]
    spec = specs[k]
    # (lead, E, D, F): expert dim on model axis (expert parallelism)
    assert spec[1] == "model", spec


def test_batch_spec():
    assert sh.batch_spec(POD, 256) == P("data", None)
    assert sh.batch_spec(MULTI, 256) == P(("pod", "data"), None)
    # batch=1 (long_500k): degrades to replicated
    assert sh.batch_spec(MULTI, 1) == P(None, None)


def test_cache_specs_legal():
    cfg = get_arch("gemma3-27b")
    from repro.models import build_model
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 1024,
                                                    jnp.bfloat16))
    specs = sh.tree_cache_specs(cache, POD)
    for (path, leaf), (_, spec) in zip(tree_paths(cache),
                                       tree_paths(specs)):
        offset = len(leaf.shape) - len(spec)
        assert offset >= 0
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            assert leaf.shape[i] % sh._axis_size(POD, ax) == 0, (path, spec)


@pytest.mark.parametrize("mode", ["tp", "fsdp"])
def test_alternate_sharding_modes_legal(mode):
    """§Perf sharding variants: every leaf still divisibility-legal."""
    cfg = get_arch("gemma3-27b")
    params = abstract_params(cfg)
    specs = sh.tree_param_specs(params, POD, mode=mode)
    from repro.utils.trees import tree_paths
    for (path, leaf), (_, spec) in zip(tree_paths(params),
                                       tree_paths(specs)):
        offset = len(leaf.shape) - len(spec)
        assert offset >= 0, (path, leaf.shape, spec)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            assert leaf.shape[i] % sh._axis_size(POD, ax) == 0, (path, spec)
    if mode == "tp":
        # no data-axis entries anywhere
        for _, spec in tree_paths(specs):
            for ax in spec:
                axes = ax if isinstance(ax, tuple) else (ax,)
                assert "data" not in axes, spec
