"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the host's
real device count (1); only launch/dryrun.py forces 512 placeholders."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
