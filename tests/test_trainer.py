"""Unified Trainer API (ISSUE 2): compile-count pinning, strategy
equivalences through Trainer.fit, mid-stream resume, data sources,
metrics sinks, and TrainState checkpoint round-trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.distributed.bmuf import BMUFConfig
from repro.distributed.gtc import GTCConfig
from repro.optim import momentum_init, momentum_update
from repro.runtime.cluster import worker_mesh
from repro.train import (GTC, BMUFVmap, JsonlSink, ListSink, Local,
                         TrainBatch, Trainer, TrainState, chain,
                         epoch_source, make_sgd_step)

D = 8


def quad_loss(params, batch):
    e = batch["x"] @ params["w"] - batch["y"]
    return jnp.mean(e ** 2), {"loss": jnp.mean(e ** 2)}


def _problem(seed=0, n=64):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(D,))
    x = rng.normal(size=(n, D)).astype(np.float32)
    y = (x @ w_true).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def _params():
    return {"w": jnp.zeros((D,))}


def _source(batch, lrs, loss="quad"):
    return [TrainBatch(batch, lr, loss) for lr in lrs]


# ------------------------------------------------------- compile counts

def test_single_compile_across_lr_phases():
    """The tentpole perf fix: lr is traced, so an LR schedule sweeping
    many phases reuses ONE executable per (loss kind, batch shape) —
    the seed pipeline re-jitted its step on every phase change."""
    batch = _problem()
    tr = Trainer(Local(clip=0.0), {"quad": quad_loss})
    state = tr.init_state(_params())
    lrs = [0.1 * (0.85 ** i) for i in range(6)]     # 6 distinct lr phases
    state = tr.fit(state, _source(batch, lrs), resume=False)
    assert int(state.step) == 6
    assert tr.updates["quad"]._cache_size() == 1    # one compile, 6 lrs


def test_make_train_step_single_compile():
    """launch.steps.make_train_step: same property on the real AM step
    (the ssl_pipeline re-jit regression pin)."""
    from repro.configs.lstm_am_7khr import CONFIG
    from repro.configs.base import LayerSpec, Segment
    from repro.launch.steps import init_opt_state, make_train_step
    from repro.models import build_model

    cfg = CONFIG.replace(
        lstm_hidden=16, feat_dim=6, n_senones=11, vocab_size=11,
        segments=(Segment((LayerSpec(mixer="lstm", ffn="none"),),
                          repeat=1),))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    step = jax.jit(make_train_step(model, cfg, loss_kind="ce"))
    opt = init_opt_state(params)
    rng = np.random.default_rng(0)
    batch = {"feats": jnp.asarray(rng.normal(size=(2, 12, 6)),
                                  jnp.float32),
             "labels": jnp.asarray(rng.integers(0, 11, (2, 12))),
             "mask": jnp.ones((2, 12), jnp.float32)}
    for lr in (5e-2, 2e-2, 1e-2):
        params, opt, m = step(params, opt, batch, lr)
        assert jnp.isfinite(m["loss"])
    assert step._cache_size() == 1


# --------------------------------------------------- strategy via fit()

def test_local_fit_converges():
    batch = _problem(n=256)
    sink = ListSink()
    tr = Trainer(Local(clip=0.0), {"quad": quad_loss}, metrics=sink)
    state = tr.fit(tr.init_state(_params()),
                   _source(batch, [0.05] * 60), resume=False)
    assert sink.values("loss")[-1] < 0.05 * sink.values("loss")[0]
    assert int(state.step) == 60


def test_bmuf_fit_matches_manual_block_step():
    """BMUFVmap through Trainer.fit == driving bmuf_lib's block step by
    hand: same theta_g after the same microbatch stream."""
    from repro.distributed import bmuf as bmuf_lib
    cfg = BMUFConfig(n_workers=2, block_steps=2, block_momentum=0.5)
    rng = np.random.default_rng(3)
    full = _problem(n=64)
    micro = []
    for _ in range(8):                       # 2 full blocks of tau*W=4
        sel = rng.integers(0, 64, (16,))
        micro.append({"x": full["x"][sel], "y": full["y"][sel]})

    strat = BMUFVmap(cfg, clip=0.0)
    tr = Trainer(strat, {"quad": quad_loss})
    state = tr.fit(tr.init_state(_params()),
                   [TrainBatch(m, 0.05, "quad") for m in micro],
                   resume=False)
    assert int(state.step) == 2              # 8 microbatches / (tau*W)

    step = make_sgd_step(quad_loss, clip=0.0)
    block = jax.jit(bmuf_lib.make_bmuf_block_step(step, cfg))
    bstate = bmuf_lib.bmuf_init(_params(), cfg)
    opt = jax.vmap(lambda _: momentum_init(_params()))(jnp.arange(2))
    for blk in range(2):
        group = micro[blk * 4:(blk + 1) * 4]
        batches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs).reshape(2, 2, *xs[0].shape), *group)
        bstate, opt, _ = block(bstate, opt, batches, 0.05)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(bstate["theta_g"]["w"]),
                               rtol=1e-6)


def test_bmuf_partial_block_dropped_at_loss_boundary():
    """A block cannot straddle a loss-kind change: the partial group is
    dropped (BMUF semantics), and full blocks on either side still run."""
    cfg = BMUFConfig(n_workers=2, block_steps=1)
    batch = _problem(n=16)
    src = ([TrainBatch(batch, 0.05, "quad")] * 2      # 1 full block
           + [TrainBatch(batch, 0.05, "quad")]        # partial -> dropped
           + [TrainBatch(batch, 0.05, "other")] * 2)  # 1 full block
    tr = Trainer(BMUFVmap(cfg, clip=0.0),
                 {"quad": quad_loss, "other": quad_loss})
    state = tr.fit(tr.init_state(_params()), src, resume=False)
    assert int(state.step) == 2


# ---------------------------------------------------------- GTCShardMap

def test_gtc_shardmap_single_compile_across_lr_phases():
    """The new strategy keeps the Trainer's one-executable property:
    an lr sweep through the shard_map step compiles exactly once (the
    strategy's place() lays init state out on the mesh so even the
    first call hits the steady-state executable).

    Count actual XLA compilations via the jax_log_compiles log, not
    ``_cache_size()``: on a >1-device mesh the C++ fastpath can hold a
    second cache entry for the same single executable."""
    import logging

    from repro.train import GTCShardMap

    class _CompileCounter(logging.Handler):
        def __init__(self):
            super().__init__()
            self.compiles = []

        def emit(self, record):
            msg = record.getMessage()
            if "Finished XLA compilation" in msg:
                self.compiles.append(msg)

    batch = _problem()
    mesh = worker_mesh(2)
    tr = Trainer(GTCShardMap(GTCConfig(tau=1e-3, n_workers=2), mesh,
                             clip=0.0), {"quad": quad_loss})
    state = tr.init_state(_params())
    lrs = [0.1 * (0.85 ** i) for i in range(6)]
    # 2 microbatches per update: 12 source items -> 6 updates
    src = [TrainBatch(batch, lr, "quad") for lr in lrs for _ in range(2)]
    counter = _CompileCounter()
    logger = logging.getLogger("jax._src.dispatch")
    old_level = logger.level
    logger.addHandler(counter)
    logger.setLevel(logging.DEBUG)
    try:
        with jax.log_compiles():
            state = tr.fit(state, src, resume=False)
    finally:
        logger.removeHandler(counter)
        logger.setLevel(old_level)
    assert int(state.step) == 6
    updates = [m for m in counter.compiles if "jit(update)" in m]
    assert len(updates) == 1, counter.compiles


def test_gtc_shardmap_groups_microbatches_per_worker():
    """Each update consumes n_workers microbatches; a trailing partial
    group is dropped (same block semantics as BMUF)."""
    from repro.train import GTCShardMap
    batch = _problem(n=16)
    mesh = worker_mesh(2)
    tr = Trainer(GTCShardMap(GTCConfig(tau=1e-3, n_workers=2), mesh,
                             clip=0.0), {"quad": quad_loss})
    state = tr.fit(tr.init_state(_params()),
                   _source(batch, [0.05] * 5), resume=False)
    assert int(state.step) == 2              # 5 microbatches -> 2 updates


def test_gtc_shardmap_resume_preserves_worker_residuals(tmp_path):
    """The per-worker (W-stacked) error-feedback residuals round-trip
    through the checkpoint and the resumed run lands bitwise on the
    uninterrupted result."""
    from repro.train import GTCShardMap
    batch = _problem(n=32)
    mesh = worker_mesh(2)
    lrs = [0.05] * 12                        # 6 updates at W=2
    mk = lambda ck: Trainer(
        GTCShardMap(GTCConfig(tau=1e-3, n_workers=2), mesh, clip=0.0),
        {"quad": quad_loss},
        checkpoint=CheckpointStore(os.path.join(tmp_path, "state"))
        if ck else None, ckpt_every=2)
    ref = mk(False)
    ref_state = ref.fit(ref.init_state(_params()), _source(batch, lrs),
                        resume=False)
    t1 = mk(True)
    t1.fit(t1.init_state(_params()), _source(batch, lrs), max_updates=3)
    t2 = mk(True)
    state = t2.fit(t2.init_state(_params()), _source(batch, lrs))
    assert int(state.step) == 6
    assert state.strategy_state["residual"]["w"].shape == (2, D)
    np.testing.assert_array_equal(
        np.asarray(state.strategy_state["residual"]["w"]),
        np.asarray(ref_state.strategy_state["residual"]["w"]))
    np.testing.assert_array_equal(np.asarray(state.params["w"]),
                                  np.asarray(ref_state.params["w"]))


def test_gtc_shardmap_rng_distinct_per_worker():
    """Stochastic losses through GTCShardMap get per-(update, worker)
    folded keys — the sharded step's emitted per-worker noise equals
    normal(fold(fold(root, step), global_worker)) exactly, so every
    worker sees a distinct stream with the same folding scheme as the
    BMUF paths (global worker index, folded outside the shard_map)."""
    from repro.distributed import gtc as gtc_lib
    from repro.train import GTCShardMap

    def spy_loss(params, batch, rng):
        noise = jax.random.normal(rng, ())
        e = batch["x"] @ params["w"] - batch["y"] - noise
        return jnp.mean(e ** 2), {"loss": jnp.mean(e ** 2),
                                  "n0": noise}

    batch = _problem(n=16)
    mesh = worker_mesh(2)
    strat = GTCShardMap(GTCConfig(tau=1e-3, n_workers=2), mesh, clip=0.0)
    # drive the gtc_lib step directly: its metrics keep the (W,) worker
    # dim the strategy's update would average away
    step = jax.jit(gtc_lib.make_sharded_gtc_train_step(
        spy_loss, lambda p, u, o, lr: (p, o), strat.cfg, mesh))
    tr = Trainer(strat, {"noisy": spy_loss})
    state = tr.init_state(_params(), seed=0)
    root = jax.random.fold_in(state.rng, state.step)
    _, _, _, ms = step(state.params, state.opt_state,
                       state.strategy_state, strat.stack([batch] * 2),
                       jnp.float32(0.05), root)
    got = np.asarray(ms["n0"])
    expect = np.asarray([jax.random.normal(jax.random.fold_in(root, w), ())
                         for w in range(2)])
    assert got.shape == (2,)
    np.testing.assert_array_equal(got, expect)
    assert got[0] != got[1]                  # distinct per worker

    # ...and the strategy's update threads the same rng (its averaged
    # n0 metric is the mean of the per-worker noises)
    state2, metrics = tr.updates["noisy"](state, strat.stack([batch] * 2),
                                          jnp.float32(0.05))
    assert int(state2.step) == 1
    np.testing.assert_allclose(float(metrics["n0"]), expect.mean(),
                               rtol=1e-6)


# --------------------------------------------------------------- resume

def test_fit_resumes_from_periodic_checkpoint(tmp_path):
    """Kill-and-reinvoke: a run interrupted after the step-4 checkpoint
    resumes there (not from scratch) and lands bitwise on the
    uninterrupted result; finalize() retires the resume state."""
    batch = _problem(n=64)
    lrs = [0.05 * (0.9 ** i) for i in range(10)]

    # uninterrupted reference
    ref = Trainer(Local(clip=0.0), {"quad": quad_loss})
    ref_state = ref.fit(ref.init_state(_params()), _source(batch, lrs),
                        resume=False)

    store = CheckpointStore(os.path.join(tmp_path, "state"))
    t1 = Trainer(Local(clip=0.0), {"quad": quad_loss},
                 checkpoint=store, ckpt_every=2)
    t1.fit(t1.init_state(_params()), _source(batch, lrs), max_updates=5)
    assert store.latest() == 4               # ckpts at 2 and 4; kill at 5

    t2 = Trainer(Local(clip=0.0), {"quad": quad_loss},
                 checkpoint=store, ckpt_every=2)
    sink = ListSink()
    t2.metrics = sink
    state = t2.fit(t2.init_state(_params()), _source(batch, lrs))
    assert int(state.step) == 10
    # resumed run only executed steps 5..10, not 1..10
    assert len(sink) == 6
    np.testing.assert_array_equal(np.asarray(state.params["w"]),
                                  np.asarray(ref_state.params["w"]))
    t2.finalize(state)
    assert store.latest() is None            # completed: resume retired


def test_resume_preserves_strategy_state(tmp_path):
    """GTC's error-feedback residual survives the checkpoint boundary —
    resume must not silently zero strategy state."""
    batch = _problem(n=32)
    lrs = [0.05] * 6
    mk = lambda: Trainer(GTC(GTCConfig(tau=1e-3, n_workers=1), clip=0.0),
                         {"quad": quad_loss},
                         checkpoint=CheckpointStore(
                             os.path.join(tmp_path, "state")),
                         ckpt_every=2)
    ref = Trainer(GTC(GTCConfig(tau=1e-3, n_workers=1), clip=0.0),
                  {"quad": quad_loss})
    ref_state = ref.fit(ref.init_state(_params()), _source(batch, lrs),
                        resume=False)
    t1 = mk()
    t1.fit(t1.init_state(_params()), _source(batch, lrs), max_updates=3)
    t2 = mk()
    state = t2.fit(t2.init_state(_params()), _source(batch, lrs))
    np.testing.assert_array_equal(
        np.asarray(state.strategy_state["residual"]["w"]),
        np.asarray(ref_state.strategy_state["residual"]["w"]))
    np.testing.assert_array_equal(np.asarray(state.params["w"]),
                                  np.asarray(ref_state.params["w"]))


def test_trainstate_dict_roundtrip():
    tr = Trainer(Local(), {"quad": quad_loss})
    state = tr.init_state(_params(), seed=3)
    back = TrainState.from_dict(
        jax.tree_util.tree_map(np.asarray, state.to_dict()))
    assert int(back.step) == 0
    np.testing.assert_array_equal(np.asarray(back.params["w"]),
                                  np.asarray(state.params["w"]))
    # rng round-trips through raw key data
    a = jax.random.uniform(state.rng)
    b = jax.random.uniform(back.rng)
    assert float(a) == float(b)


# ------------------------------------------------- per-update RNG folding

def noisy_loss(params, batch, rng):
    """Stochastic loss: declares `rng` and gets a per-update folded key."""
    noise = jax.random.normal(rng, batch["y"].shape) * 0.01
    e = batch["x"] @ params["w"] - (batch["y"] + noise)
    return jnp.mean(e ** 2), {"loss": jnp.mean(e ** 2),
                              "n0": noise.reshape(-1)[0]}


def test_rng_folded_per_update():
    """The carried key folds with the step counter: every update sees a
    distinct stream (the seed bug: the key was carried but never split),
    and the sequence is a pure function of (seed, step) — two identical
    runs agree exactly."""
    batch = _problem(n=16)
    traces = []
    for _ in range(2):
        sink = ListSink()
        tr = Trainer(Local(clip=0.0), {"noisy": noisy_loss}, metrics=sink)
        tr.fit(tr.init_state(_params(), seed=7),
               _source(batch, [0.05] * 5, "noisy"), resume=False)
        traces.append(sink.values("n0"))
    assert len(set(traces[0])) == 5          # distinct stream per update
    assert traces[0] == traces[1]            # deterministic in the seed


def test_stochastic_loss_resume_is_bitwise(tmp_path):
    """Determinism under resume: a killed-and-reinvoked run of a
    stochastic (rng-consuming) loss lands bitwise on the uninterrupted
    result — the fold depends only on checkpointed state."""
    batch = _problem(n=32)
    lrs = [0.05] * 8

    ref = Trainer(Local(clip=0.0), {"noisy": noisy_loss})
    ref_state = ref.fit(ref.init_state(_params(), seed=3),
                        _source(batch, lrs, "noisy"), resume=False)

    store = CheckpointStore(os.path.join(tmp_path, "state"))
    t1 = Trainer(Local(clip=0.0), {"noisy": noisy_loss},
                 checkpoint=store, ckpt_every=2)
    t1.fit(t1.init_state(_params(), seed=3), _source(batch, lrs, "noisy"),
           max_updates=5)                     # "killed" after step 5
    t2 = Trainer(Local(clip=0.0), {"noisy": noisy_loss},
                 checkpoint=store, ckpt_every=2)
    state = t2.fit(t2.init_state(_params(), seed=3),
                   _source(batch, lrs, "noisy"))
    assert int(state.step) == 8
    np.testing.assert_array_equal(np.asarray(state.params["w"]),
                                  np.asarray(ref_state.params["w"]))


def test_bmuf_rng_distinct_per_worker_and_step():
    """Through BMUF, the block key folds per (worker, tau-step): all
    tau*W microbatches of a block see distinct noise."""
    from repro.distributed.bmuf import BMUFConfig

    def spy_loss(params, batch, rng):
        noise = jax.random.normal(rng, ())
        e = batch["x"] @ params["w"] - batch["y"] - noise
        return jnp.mean(e ** 2), {"loss": jnp.mean(e ** 2)}

    batch = _problem(n=16)
    strat = BMUFVmap(BMUFConfig(n_workers=2, block_steps=2), clip=0.0)
    update = jax.jit(strat.make_update(spy_loss))
    state = Trainer(strat, {"noisy": spy_loss}).init_state(_params(),
                                                           seed=0)
    state2, _ = update(state, strat.stack([batch] * 4),
                       jnp.float32(0.05))    # runs under jit with rng
    assert int(state2.step) == 1
    # the folding scheme: fold(fold(fold(root, step), worker), tau_idx)
    # gives 4 distinct streams for the block's 4 microbatches
    root = jax.random.fold_in(state.rng, state.step)
    keys = [jax.random.fold_in(jax.random.fold_in(root, w), t)
            for w in range(2) for t in range(2)]
    noises = {float(jax.random.normal(k, ())) for k in keys}
    assert len(noises) == 4


def test_bmuf_sharded_rng_matches_vmap_path():
    """Stochastic losses through BMUFShardMap == BMUFVmap bitwise on a
    1-device mesh: the per-worker keys are folded with *global* worker
    indices outside the shard_map (crossing as raw key data), so the
    two execution paths of the same math stay interchangeable.  On a
    >1-device mesh the cross-device psum reduction order shifts the
    block mean by float32 ULPs, so equality relaxes to that tolerance."""
    from repro.distributed.bmuf import BMUFConfig
    from repro.train import BMUFShardMap

    batch = _problem(n=32)
    src = lambda: _source(batch, [0.05] * 8, "noisy")
    cfg = BMUFConfig(n_workers=2, block_steps=2, block_momentum=0.5)

    tr_v = Trainer(BMUFVmap(cfg, clip=0.0), {"noisy": noisy_loss})
    st_v = tr_v.fit(tr_v.init_state(_params(), seed=5), src(),
                    resume=False)

    mesh = worker_mesh(2)
    tr_s = Trainer(BMUFShardMap(cfg, mesh, clip=0.0),
                   {"noisy": noisy_loss})
    st_s = tr_s.fit(tr_s.init_state(_params(), seed=5), src(),
                    resume=False)
    assert int(st_v.step) == int(st_s.step) == 2
    if mesh.devices.size == 1:
        np.testing.assert_array_equal(np.asarray(st_v.params["w"]),
                                      np.asarray(st_s.params["w"]))
    else:
        np.testing.assert_allclose(np.asarray(st_v.params["w"]),
                                   np.asarray(st_s.params["w"]),
                                   atol=1e-7, rtol=0)


# ------------------------------------------------- LR schedules as lr

def test_schedule_object_lr_single_compile():
    """An optim.schedules Schedule rides through the source as
    TrainBatch.lr, is evaluated at the update counter, and keeps the
    one-compile-per-loss-kind property."""
    from repro.optim import exponential_decay
    batch = _problem(n=16)
    sched = exponential_decay(0.1, 0.5, 2)   # lr halves every 2 updates
    tr = Trainer(Local(clip=0.0), {"quad": quad_loss})
    state = tr.init_state(_params())
    src = [TrainBatch(batch, sched, "quad") for _ in range(6)]
    state = tr.fit(state, src, resume=False)
    assert int(state.step) == 6
    assert tr.updates["quad"]._cache_size() == 1   # schedule != re-jit
    # schedule evaluated at the counter: steps 0,1 -> 0.1; 2,3 -> 0.05...
    assert sched(0) == pytest.approx(0.1)
    assert sched(2) == pytest.approx(0.05)
    assert sched(5) == pytest.approx(0.025)


def test_schedule_through_epoch_source_and_resume(tmp_path):
    """epoch_source passes Schedule objects through (no per-epoch
    evaluation), and a resumed run continues the schedule at the right
    step — bitwise vs uninterrupted."""
    from repro.optim import exponential_decay
    batch = _problem(n=32)
    mk_src = lambda: epoch_source(lambda ep: [batch] * 3, 2,
                                  exponential_decay(0.1, 0.7, 1), "quad")
    for tb in mk_src():
        assert callable(tb.lr)               # passed through, not a float

    ref = Trainer(Local(clip=0.0), {"quad": quad_loss})
    ref_state = ref.fit(ref.init_state(_params()), mk_src(), resume=False)

    store = CheckpointStore(os.path.join(tmp_path, "state"))
    t1 = Trainer(Local(clip=0.0), {"quad": quad_loss},
                 checkpoint=store, ckpt_every=2)
    t1.fit(t1.init_state(_params()), mk_src(), max_updates=3)
    t2 = Trainer(Local(clip=0.0), {"quad": quad_loss},
                 checkpoint=store, ckpt_every=2)
    state = t2.fit(t2.init_state(_params()), mk_src())
    np.testing.assert_array_equal(np.asarray(state.params["w"]),
                                  np.asarray(ref_state.params["w"]))


# ------------------------------------------------------ sources + sinks

def test_epoch_source_and_chain():
    batch = _problem(n=8)
    src = list(chain(
        epoch_source(lambda ep: [batch, batch], 2, lambda ep: 0.1 / (ep + 1),
                     "ce"),
        epoch_source(lambda ep: [batch], 1, 0.01, "ft")))
    assert len(src) == 5
    assert [tb.loss for tb in src] == ["ce"] * 4 + ["ft"]
    assert src[0].lr == pytest.approx(0.1) and src[2].lr == pytest.approx(0.05)
    assert src[-1].lr == pytest.approx(0.01)


def test_unknown_loss_kind_raises():
    tr = Trainer(Local(), {"quad": quad_loss})
    with pytest.raises(KeyError):
        tr.fit(tr.init_state(_params()),
               [TrainBatch(_problem(n=8), 0.1, "nope")], resume=False)


def test_jsonl_sink(tmp_path):
    import json
    path = os.path.join(tmp_path, "m", "metrics.jsonl")
    sink = JsonlSink(path)
    tr = Trainer(Local(clip=0.0), {"quad": quad_loss}, metrics=sink)
    tr.fit(tr.init_state(_params()), _source(_problem(n=8), [0.1] * 3),
           resume=False)
    rows = [json.loads(l) for l in open(path)]
    assert [r["step"] for r in rows] == [1, 2, 3]
    assert all(r["tag"] == "quad" and np.isfinite(r["loss"]) for r in rows)
