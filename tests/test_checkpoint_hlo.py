"""Checkpoint store + HLO collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, load_tree, save_tree
from repro.utils import hlo


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.zeros((), jnp.int32)}}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_tree(str(tmp_path / "x.npz"), t, meta={"step": 3})
    out = load_tree(str(tmp_path / "x.npz"), t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_store_retention(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        store.save(s, t)
    assert store.steps() == [3, 4]
    out, step = store.load(t)
    assert step == 4


def test_load_shape_mismatch(tmp_path):
    t = _tree()
    save_tree(str(tmp_path / "x.npz"), t)
    bad = dict(t, a=jnp.zeros((5, 5)))
    with pytest.raises(ValueError):
        load_tree(str(tmp_path / "x.npz"), bad)


# ----------------------------------------------------------------- HLO

SAMPLE = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%sum
  %rs = (f32[16,16]{1,0}, f32[4]{0}) reduce-scatter(%a, %b)
  %cp = f32[2,2]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(%p, %q)
"""


def test_collective_stats_parse():
    st = hlo.collective_stats(SAMPLE)
    assert st.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                "reduce-scatter": 1,
                                "collective-permute": 1}
    assert st.bytes_by_kind["all-gather"] == 8 * 128 * 2
    assert st.bytes_by_kind["all-reduce"] == 256 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 16 * 16 * 4 + 4 * 4
    assert st.total_count == 4


def test_shape_bytes_tuple():
    assert hlo.shape_bytes("(f32[2,2]{1,0}, bf16[4]{0})") == 16 + 8
    assert hlo.shape_bytes("pred[8]") == 8
    assert hlo.shape_bytes("f32[]") == 4


def test_wire_bytes_factors():
    st = hlo.CollectiveStats(bytes_by_kind={"all-reduce": 100},
                             count_by_kind={"all-reduce": 1})
    # 2(D-1)/D for D=4 -> 1.5x
    assert hlo.wire_bytes(st, 4) == pytest.approx(150.0)


def test_real_lowered_collectives():
    """End-to-end: a psum under shard_map shows up in the parse."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("w",))

    def f(x):
        return jax.lax.psum(x, "w")

    sf = shard_map(f, mesh=mesh, in_specs=P("w"), out_specs=P(),
                   check_rep=False)
    txt = jax.jit(sf).lower(jnp.ones((4, 8))).compile().as_text()
    st = hlo.collective_stats(txt)
    # 1-device psum may fold away; just assert the parser doesn't crash
    assert st.total_bytes >= 0
