"""Chunked+remat recurrent scans == flat scans (bitwise math, fewer saved
residuals) — the §Perf memory lever for xlstm train_4k."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import recurrent as R


def test_mlstm_chunked_equals_flat():
    rng = np.random.default_rng(0)
    b, h, s, hd = 2, 2, 128, 16
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    gates = jnp.asarray(rng.normal(size=(b, s, 2 * h)), jnp.float32)
    h_flat, (C1, n1, m1) = R.mlstm_scan(q, k, v, gates, chunk=s + 1)
    h_chunk, (C2, n2, m2) = R.mlstm_scan(q, k, v, gates, chunk=32)
    np.testing.assert_allclose(np.asarray(h_flat), np.asarray(h_chunk),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.slow
def test_mlstm_chunked_gradients_match():
    rng = np.random.default_rng(1)
    b, h, s, hd = 1, 2, 64, 8
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    gates = jnp.asarray(rng.normal(size=(b, s, 2 * h)), jnp.float32)

    def loss(qq, chunk):
        hs, _ = R.mlstm_scan(qq, k, v, gates, chunk=chunk)
        return jnp.sum(hs ** 2)

    g_flat = jax.grad(lambda qq: loss(qq, s + 1))(q)
    g_chunk = jax.grad(lambda qq: loss(qq, 16))(q)
    np.testing.assert_allclose(np.asarray(g_flat), np.asarray(g_chunk),
                               rtol=1e-4, atol=1e-5)


def test_rglru_assoc_scan_matches_sequential():
    """RG-LRU's associative scan == a step-by-step reference."""
    import jax
    from repro.configs import get_arch, reduced
    cfg = reduced(get_arch("recurrentgemma-2b"))
    key = jax.random.key(0)
    params = R.init_rglru_block(key, cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 24, cfg.lru_width or cfg.d_model)),
                    jnp.float32) * 0.3
    h_par, h_last = R.rglru_scan(params, x)
    a, bseq = R._rglru_coeffs(params, x)
    hs = []
    hprev = jnp.zeros_like(a[:, 0])
    for t in range(x.shape[1]):
        hprev = a[:, t] * hprev + bseq[:, t]
        hs.append(hprev)
    h_ref = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par, jnp.float32),
                               np.asarray(h_ref.astype(h_par.dtype),
                                          jnp.float32),
                               rtol=2e-3, atol=2e-3)
