"""Assigned-architecture configs: exact topology vs the assignment table."""
import pytest

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_arch, reduced, supports

# (layers, d_model, heads, kv, d_ff, vocab)
EXPECTED = {
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
    "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
    "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
    "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
}

FAMILY = {
    "recurrentgemma-2b": "hybrid", "gemma3-27b": "dense",
    "deepseek-67b": "dense", "h2o-danube-3-4b": "dense",
    "whisper-medium": "audio", "qwen3-moe-30b-a3b": "moe",
    "qwen2.5-3b": "dense", "chameleon-34b": "vlm",
    "deepseek-v3-671b": "moe", "xlstm-350m": "ssm",
}


@pytest.mark.parametrize("arch", list(EXPECTED))
def test_exact_topology(arch):
    cfg = get_arch(arch)
    layers, d, h, kv, dff, vocab = EXPECTED[arch]
    assert cfg.n_layers == layers
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.vocab_size == vocab
    if cfg.family == "moe":
        assert cfg.moe_d_ff == dff
    elif cfg.family != "ssm":
        assert cfg.d_ff == dff
    assert cfg.family == FAMILY[arch]
    assert cfg.source, "every config must cite its source"


def test_assignment_complete():
    assert len(ASSIGNED) == 10
    assert set(EXPECTED) == set(ASSIGNED)
    assert len({FAMILY[a] for a in ASSIGNED}) == 6   # 6 arch types


def test_moe_specs():
    q = get_arch("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.moe_top_k) == (128, 8)
    d = get_arch("deepseek-v3-671b")
    assert (d.n_experts, d.moe_top_k, d.n_shared_experts) == (256, 8, 1)
    assert d.mla is not None and d.mtp_depth == 1


@pytest.mark.parametrize("arch", list(EXPECTED))
def test_reduced_constraints(arch):
    r = reduced(get_arch(arch))
    assert r.n_layers <= 2 * max(len(s.pattern) for s in r.segments)
    assert r.d_model <= 512
    assert r.n_experts <= 4
    assert r.vocab_size <= 512


def test_supports_matrix():
    # long_500k: runs for subquadratic + swa-dominant; skips pure full attn
    runs = {a for a in ASSIGNED
            if supports(get_arch(a), SHAPES["long_500k"])[0]}
    assert runs == {"recurrentgemma-2b", "gemma3-27b", "h2o-danube-3-4b",
                    "xlstm-350m"}
    # +swa variant makes the dense archs lower
    assert supports(get_arch("deepseek-67b+swa"), SHAPES["long_500k"])[0]
    # everything runs train/prefill/decode_32k
    for a in ASSIGNED:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports(get_arch(a), SHAPES[s])[0], (a, s)


def test_swa_variant():
    v = get_arch("deepseek-67b+swa")
    assert all(m == "swa" for m in v.mixers())
    assert v.n_layers == 95
