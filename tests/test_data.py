"""Data pipeline: determinism, featurizer faithfulness, chunking, hashing."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.data import (CorpusLoader, FeatureConfig, SynthConfig,
                        chunk_utterances, featurize_utterance, pad_batch,
                        speaker_hash, synth_utterance)
from repro.data.features import (GlobalMVN, align_labels, causal_mean_norm,
                                 log_mel, mel_filterbank, stack_subsample)
from repro.data.synthetic import DEVICES


SC = SynthConfig(n_speakers=8, n_senones=49, mean_utt_sec=1.0)
FC = FeatureConfig(n_mels=16)


def test_synth_deterministic():
    u1 = synth_utterance(SC, 42)
    u2 = synth_utterance(SC, 42)
    np.testing.assert_array_equal(u1.audio, u2.audio)
    np.testing.assert_array_equal(u1.senones, u2.senones)
    assert u1.speaker == u2.speaker and u1.device == u2.device


def test_synth_structure():
    u = synth_utterance(SC, 7)
    assert u.device in DEVICES
    assert u.audio.dtype == np.float32
    assert np.abs(u.audio).max() <= 1.0
    assert len(u.audio) == len(u.senones) * 160      # 10ms @ 16k
    assert u.senones.min() >= 0 and u.senones.max() < SC.n_senones


def test_log_mel_shapes():
    u = synth_utterance(SC, 1)
    lm = log_mel(u.audio, FC)
    assert lm.shape[1] == FC.n_mels
    assert np.isfinite(lm).all()
    # ~one frame per 10ms
    assert abs(lm.shape[0] - len(u.senones)) <= 3


def test_mel_filterbank_partition():
    fb = mel_filterbank(16, 512, 16000, 60, 7600)
    assert fb.shape == (16, 257)
    assert (fb >= 0).all()
    assert (fb.sum(1) > 0).all()


def test_stack_subsample_offsets():
    x = np.arange(30, dtype=np.float32).reshape(10, 3)
    s0 = stack_subsample(x, FeatureConfig(n_mels=3), 0)
    s1 = stack_subsample(x, FeatureConfig(n_mels=3), 1)
    assert s0.shape == (3, 9)
    # offset shifts the stacking phase by one 10ms frame
    np.testing.assert_array_equal(s1[0, :3], x[1])


def test_causal_mean_carry():
    """Carrying the mean across utterances == one concatenated pass."""
    rng = np.random.default_rng(0)
    a = rng.normal(size=(20, 4)).astype(np.float32)
    b = rng.normal(size=(15, 4)).astype(np.float32)
    na, carry = causal_mean_norm(a, 0.95)
    nb, _ = causal_mean_norm(b, 0.95, carry)
    ncat, _ = causal_mean_norm(np.concatenate([a, b]), 0.95)
    np.testing.assert_allclose(np.concatenate([na, nb]), ncat, atol=1e-5)


def test_lookahead_label_shift():
    u = synth_utterance(SC, 3)
    f0, l0, _ = featurize_utterance(u, FC, lookahead=0)
    f3, l3, _ = featurize_utterance(u, FC, lookahead=3)
    assert f0.shape == f3.shape
    # label at output t with lookahead L == senone of stacked frame t-L
    np.testing.assert_array_equal(l3[3:], l0[:-3])


@given(t=st.integers(1, 120), chunk=st.sampled_from([16, 32, 64]))
@settings(max_examples=30, deadline=None)
def test_chunking_covers_everything(t, chunk):
    feats = np.arange(t * 2, dtype=np.float32).reshape(t, 2)
    labels = np.arange(t, dtype=np.int32)
    chunks = chunk_utterances([(feats, labels, 0)], chunk)
    # total valid frames == t, all chunks padded to chunk_len
    assert sum(c.valid for c in chunks) == t
    assert all(c.feats.shape == (chunk, 2) for c in chunks)
    rec = np.concatenate([c.labels[: c.valid] for c in
                          sorted(chunks, key=lambda c: c.chunk_index)])
    np.testing.assert_array_equal(rec, labels)


def test_pad_batch_mask():
    a = (np.ones((5, 3), np.float32), np.ones(5, np.int32), 0)
    b = (np.ones((9, 3), np.float32), np.ones(9, np.int32), 1)
    out = pad_batch([a, b])
    assert out["feats"].shape == (2, 9, 3)
    assert out["mask"].sum() == 14


def test_speaker_hash_stable_and_spread():
    h1 = [speaker_hash(s, 4) for s in range(100)]
    h2 = [speaker_hash(s, 4) for s in range(100)]
    assert h1 == h2
    counts = np.bincount(h1, minlength=4)
    assert counts.min() > 10        # roughly uniform


@pytest.mark.slow
def test_loader_partition_disjoint():
    """Workers see disjoint speaker sets; union covers all utterances'
    speakers."""
    l0 = CorpusLoader(synth=SC, feat=FC, worker=0, n_workers=2)
    l1 = CorpusLoader(synth=SC, feat=FC, worker=1, n_workers=2)
    u0 = l0._utts_for_range(0, 40)
    u1 = l1._utts_for_range(0, 40)
    s0 = {u.speaker for u in u0}
    s1 = {u.speaker for u in u1}
    assert s0.isdisjoint(s1)
    assert len(u0) + len(u1) == 40


def test_mvn_normalizes():
    rng = np.random.default_rng(1)
    feats = [rng.normal(5.0, 3.0, size=(50, 4)).astype(np.float32)
             for _ in range(8)]
    mvn = GlobalMVN.estimate(feats)
    out = mvn(feats[0])
    assert abs(out.mean()) < 1.0 and 0.3 < out.std() < 3.0
