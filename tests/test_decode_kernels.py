"""Fused decode kernels (decode_attention, topk_sample) vs their
oracles, and the fused serve/train integration surfaces.

Parity tiers, pinned explicitly:
  * kernel (interpret=True) vs ref — cache writes and top-k/sampled
    tokens are exact; attention outputs carry fp32 reassociation noise
    from the kernel's dot ordering, bounded at 1e-5.
  * ref twin vs the production XLA decode path — **bitwise** (the twin
    is built from the same primitives in the same order), which is what
    lets the off-TPU fused server keep greedy output token-identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_arch, reduced
from repro.core import distill
from repro.kernels import (decode_attention, decode_attention_ref,
                           topk_sample, topk_sample_ref)
from repro.kernels.topk_sample import gumbel_rows
from repro.models import attention as attn_mod
from repro.models import build_model


def _mk(shapes_seed, b=3, hq=4, hkv=2, s=16, hd=8, cache_dtype=jnp.bfloat16):
    rng = np.random.default_rng(shapes_seed)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, hd)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, hkv, 1, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, hkv, 1, hd)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, hkv, s, hd)), cache_dtype)
    cv = jnp.asarray(rng.normal(size=(b, hkv, s, hd)), cache_dtype)
    return q, kn, vn, ck, cv


# ------------------------------------------------------- decode_attention

@pytest.mark.parametrize("kwargs", [
    {},                                        # linear mask, no rope
    {"rope_theta": 1e4},                       # fused rotation
    {"window": 6},                             # SWA ring mask
    {"rope_theta": 1e4, "window": 6},
    {"softcap": 30.0},
    {"write": False},                          # paged-gather variant
])
def test_decode_attention_kernel_vs_ref(kwargs):
    q, kn, vn, ck, cv = _mk(0)
    pos = jnp.asarray([3, 15, 0], jnp.int32)   # ragged, incl. edge rows
    ro, rk, rv = decode_attention_ref(q, kn, vn, ck, cv, pos, **kwargs)
    ko, kk, kv = decode_attention(q, kn, vn, ck, cv, pos,
                                  use_kernel=True, interpret=True, **kwargs)
    np.testing.assert_allclose(np.asarray(ro), np.asarray(ko), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(kk))
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(kv))


def test_decode_attention_ring_wraparound():
    """SWA ring with pos far past the slot count: the kernel's iota mask
    must reproduce decode_slot_validity's modular position recovery."""
    q, kn, vn, ck, cv = _mk(1, s=8)
    pos = jnp.asarray([20, 37, 8], jnp.int32)  # all wrapped
    ro, rk, _ = decode_attention_ref(q, kn, vn, ck, cv, pos,
                                     window=5, rope_theta=1e4)
    ko, kk, _ = decode_attention(q, kn, vn, ck, cv, pos, window=5,
                                 rope_theta=1e4, use_kernel=True,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(ro), np.asarray(ko), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(kk))


def test_decode_attention_lockstep_rows():
    """Equal per-row positions == the scalar-pos lockstep schedule."""
    q, kn, vn, ck, cv = _mk(2)
    pos = jnp.full((3,), 7, jnp.int32)
    ro, _, _ = decode_attention_ref(q, kn, vn, ck, cv, pos, rope_theta=1e4)
    ko, _, _ = decode_attention(q, kn, vn, ck, cv, pos, rope_theta=1e4,
                                use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ro), np.asarray(ko), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), window=st.integers(0, 10))
def test_decode_attention_ragged_positions_property(seed, window):
    """Any ragged position vector (0 .. 4*S): kernel matches ref on the
    attention output and bitwise on the cache write."""
    s = 8
    q, kn, vn, ck, cv = _mk(seed, s=s)
    rng = np.random.default_rng(seed)
    hi = 4 * s if window else s   # linear layout never exceeds its slots
    pos = jnp.asarray(rng.integers(0, hi, size=(3,)), jnp.int32)
    kw = dict(window=window, rope_theta=1e4)
    ro, rk, rv = decode_attention_ref(q, kn, vn, ck, cv, pos, **kw)
    ko, kk, kv = decode_attention(q, kn, vn, ck, cv, pos,
                                  use_kernel=True, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(ro), np.asarray(ko), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(kk))
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(kv))


def test_attention_decode_fused_bitwise_off_tpu():
    """attention_decode(use_kernel=True) off-TPU routes to the ref twin
    and must be *bitwise* identical to the XLA path — output and cache."""
    cfg = reduced(get_arch("qwen2.5-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    spec = cfg.segments[0].pattern[0]
    ap = jax.tree_util.tree_map(lambda a: a[0], params["seg0"])["p0"]["mixer"]
    rng = np.random.default_rng(7)
    b, s = 4, 32
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)), jnp.float32)
    cache = {"k": jnp.asarray(rng.normal(size=(b, hkv, s, hd)),
                              jnp.bfloat16),
             "v": jnp.asarray(rng.normal(size=(b, hkv, s, hd)),
                              jnp.bfloat16)}
    pos = jnp.asarray([3, 7, 2, 9], jnp.int32)
    o0, c0 = attn_mod.attention_decode(ap, cfg, spec, x, cache, pos)
    o1, c1 = attn_mod.attention_decode(ap, cfg, spec, x, cache, pos,
                                       use_kernel=True)
    np.testing.assert_array_equal(np.asarray(o0), np.asarray(o1))
    np.testing.assert_array_equal(np.asarray(c0["k"]), np.asarray(c1["k"]))
    np.testing.assert_array_equal(np.asarray(c0["v"]), np.asarray(c1["v"]))


def test_decode_slot_validity_shapes():
    """Shared mask helper: scalar, per-row, and windowed-ring variants."""
    v = attn_mod.decode_slot_validity(jnp.int32(3), 8)
    np.testing.assert_array_equal(np.asarray(v),
                                  np.arange(8) <= 3)
    vb = attn_mod.decode_slot_validity(jnp.asarray([3, 5]), 8)
    assert vb.shape == (2, 8)
    # ring: slots=4, window=3, pos=6 -> slots hold positions 4,5,6,3;
    # window keeps 4,5,6
    vr = attn_mod.decode_slot_validity(jnp.asarray([6]), 4, window=3)
    np.testing.assert_array_equal(np.asarray(vr)[0],
                                  [True, True, True, False])


# ----------------------------------------------------------- topk_sample

def test_topk_sample_kernel_vs_ref_exact():
    rng = np.random.default_rng(0)
    b, v = 5, 300
    lg = jnp.asarray(rng.normal(size=(b, v)) * 3, jnp.float32)
    temp = jnp.asarray([0.8, 0.0, 1.3, 0.5, 1.0], jnp.float32)
    topk = jnp.asarray([20, 0, 5, 50, 1], jnp.int32)
    topp = jnp.asarray([0.95, 1.0, 0.5, 0.9, 1.0], jnp.float32)
    seeds = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    pos = jnp.asarray([0, 9, 3, 7, 2], jnp.int32)
    rv, ri, rt = topk_sample(lg, temp, topk, topp, seeds, pos,
                             use_kernel=False)
    kv, ki, kt = topk_sample(lg, temp, topk, topp, seeds, pos,
                             use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(kv))
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(kt))
    # vals/idx equal the stable full top-k
    tv, ti = jax.lax.top_k(lg, 32)
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(tv))
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ti))
    # the temperature<=0 row is the greedy sentinel
    assert int(rt[1]) == int(jnp.argmax(lg[1]))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_topk_sample_greedy_bitwise_argmax(use_kernel):
    rng = np.random.default_rng(1)
    lg = jnp.asarray(rng.normal(size=(7, 130)), jnp.float32)
    _, _, tok = topk_sample(lg, greedy=True, use_kernel=use_kernel,
                            interpret=True)
    np.testing.assert_array_equal(
        np.asarray(tok), np.asarray(jnp.argmax(lg, -1).astype(jnp.int32)))


def test_topk_sample_tiny_vocab():
    """V < k_cap clamps the candidate set without crashing."""
    rng = np.random.default_rng(2)
    lg = jnp.asarray(rng.normal(size=(3, 10)), jnp.float32)
    a = topk_sample(lg, greedy=True, use_kernel=False)
    k = topk_sample(lg, greedy=True, use_kernel=True, interpret=True)
    for x, y in zip(a, k):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_topk_sample_property_token_in_topk(seed):
    """Sampled token always lies in the top-k_eff candidate prefix, and
    kernel == ref exactly, over random knobs."""
    rng = np.random.default_rng(seed)
    b, v = 4, 200
    lg = jnp.asarray(rng.normal(size=(b, v)) * 2, jnp.float32)
    temp = jnp.asarray(rng.uniform(0.2, 1.5, b), jnp.float32)
    topk = jnp.asarray(rng.integers(1, 33, b), jnp.int32)
    topp = jnp.asarray(rng.uniform(0.3, 1.0, b), jnp.float32)
    seeds = jnp.asarray(rng.integers(0, 1000, b), jnp.int32)
    pos = jnp.asarray(rng.integers(0, 64, b), jnp.int32)
    rv, ri, rt = topk_sample(lg, temp, topk, topp, seeds, pos,
                             use_kernel=False)
    _, _, kt = topk_sample(lg, temp, topk, topp, seeds, pos,
                           use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(kt))
    for r in range(b):
        prefix = np.asarray(ri[r, :int(topk[r])])
        assert int(rt[r]) in prefix


def test_topk_sample_noise_composition_independent():
    """A row's noise depends only on its (seed, pos) — never on which
    other rows share the batch — so a request samples identically under
    any continuous-batching slot assignment (the same reproducibility
    contract as serve/sampling)."""
    g = gumbel_rows(jnp.asarray([3, 9], jnp.int32),
                    jnp.asarray([5, 11], jnp.int32), 32)
    solo = gumbel_rows(jnp.asarray([9], jnp.int32),
                       jnp.asarray([11], jnp.int32), 32)
    np.testing.assert_array_equal(np.asarray(g[1]), np.asarray(solo[0]))
    shuffled = gumbel_rows(jnp.asarray([7, 3], jnp.int32),
                           jnp.asarray([0, 5], jnp.int32), 32)
    np.testing.assert_array_equal(np.asarray(g[0]),
                                  np.asarray(shuffled[1]))


# ------------------------------------------------- sparse_ce distill path

def test_distill_kernel_loss_and_grad_parity():
    """chunked_topk_distill_ce(use_kernel=True) routes through the
    Pallas sparse_ce op; value and gradients (via its custom_vjp) must
    match the streamed-XLA oracle."""
    rng = np.random.default_rng(0)
    t, d, v, k = 24, 16, 260, 5
    h = jnp.asarray(rng.normal(size=(1, t, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, v)) * 0.1, jnp.float32)
    vals = jnp.asarray(rng.normal(size=(1, t, k)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, v, size=(1, t, k)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(1, t)), jnp.float32)
    for cap, mk in [(0.0, None), (30.0, mask)]:
        def xla(h, w):
            return distill.chunked_topk_distill_ce(
                h, w, vals, idx, chunk=64, softcap=cap, mask=mk)
        def ker(h, w):
            return distill.chunked_topk_distill_ce(
                h, w, vals, idx, chunk=64, softcap=cap, mask=mk,
                use_kernel=True, interpret=True)
        l0, (gh0, gw0) = jax.value_and_grad(xla, (0, 1))(h, w)
        l1, (gh1, gw1) = jax.value_and_grad(ker, (0, 1))(h, w)
        np.testing.assert_allclose(float(l0), float(l1), atol=1e-6)
        np.testing.assert_allclose(np.asarray(gh0), np.asarray(gh1),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(gw0), np.asarray(gw1),
                                   atol=1e-6)


# --------------------------------------------------- server integration

def test_token_server_fused_greedy_parity():
    """TokenServer(decode_kernel=True) emits bitwise-identical greedy
    tokens off-TPU (ragged prompts, continuous batching)."""
    from repro.serve.decode import TokenServer
    cfg = reduced(get_arch("qwen2.5-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(1, cfg.vocab_size,
                          size=(int(rng.integers(3, 12)),)).astype(np.int32),
             int(rng.integers(4, 9))) for _ in range(5)]

    def run(decode_kernel):
        srv = TokenServer(cfg, params, max_seq=64, sync_every=4,
                          decode_kernel=decode_kernel)
        for p, mn in reqs:
            srv.submit(p, max_new=mn)
        return {rid: list(r.out) for rid, r in srv.drain().items()}

    assert run(False) == run(True)


def test_token_server_fused_mixed_window_parity():
    """A fused server no longer rejects top_k beyond the kernel's
    candidate set: wide rows (top_k == 0 full-vocab, top_k > K_CAP)
    route through the argsort sampler inside the mixed window, bitwise
    what the non-kernel server draws for them, while cappable rows stay
    on the fused path — all in the same windows."""
    from repro.serve.decode import TokenServer
    from repro.serve.sampling import SamplingParams
    cfg = reduced(get_arch("qwen2.5-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(7)
    subs = []       # (prompt, max_new, sampling)
    for i, top_k in enumerate([0, 33, 64, 20, 8]):   # wide, wide, wide,
        prompt = rng.integers(                       # cappable, cappable
            1, cfg.vocab_size,
            size=(int(rng.integers(3, 10)),)).astype(np.int32)
        subs.append((prompt, int(rng.integers(4, 9)),
                     SamplingParams(temperature=1.0, top_k=top_k,
                                    top_p=0.95, seed=100 + i)))
    subs.append((np.asarray([1, 2, 3], np.int32), 4, None))   # greedy rides

    def run(decode_kernel):
        srv = TokenServer(cfg, params, max_seq=64, sync_every=4,
                          decode_kernel=decode_kernel)
        for p, mn, s in subs:
            srv.submit(p, max_new=mn, sampling=s)
        return {rid: list(r.out) for rid, r in srv.drain().items()}

    plain, fused = run(False), run(True)
    # wide and greedy rows: bitwise vs the argsort server (rows 0-2, 5);
    # cappable rows follow fused truncated-nucleus semantics, so only
    # shape is pinned for them
    for rid in (0, 1, 2, 5):
        assert plain[rid] == fused[rid]
    for rid in (3, 4):
        assert len(fused[rid]) == len(plain[rid])
