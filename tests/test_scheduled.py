"""Scheduled learning: the paper's two published schedules, structurally."""
import pytest

from repro.core import scheduled


def test_paper_100k_structure():
    cfg = scheduled.ScheduleConfig.paper_100k()
    phases = scheduled.phases(cfg)
    unl = [p for p in phases if p.kind == "unlabeled"]
    lab = [p for p in phases if p.kind == "labeled"]
    assert len(unl) == 4 and len(lab) == 4          # labeled after EVERY
    assert sum(p.hours for p in unl) == 100_000
    # chunked for sub-epochs 1-3, full-sequence on the 4th
    assert [p.chunked for p in unl] == [True, True, True, False]


def test_paper_1m_structure():
    cfg = scheduled.ScheduleConfig.paper_1m()
    phases = scheduled.phases(cfg)
    unl = [p for p in phases if p.kind == "unlabeled"]
    lab = [p for p in phases if p.kind == "labeled"]
    assert len(unl) == 18
    assert sum(p.hours for p in unl) == 990_000     # ~1M hours
    # labeled pass after every 5th sub-epoch (+ final)
    assert [p.sub_epoch for p in lab] == [5, 10, 15, 18]
    # chunked for 1-15, fine-tune (full seq) 16-18
    assert all(p.chunked for p in unl[:15])
    assert not any(p.chunked for p in unl[15:])


def test_lr_decay_and_boost():
    cfg = scheduled.ScheduleConfig(n_sub_epochs=6, labeled_every=2,
                                   lr0=1e-3, lr_decay=0.8,
                                   labeled_lr_boost=1.5)
    phases = scheduled.phases(cfg)
    unl = [p for p in phases if p.kind == "unlabeled"]
    # exponential decay over sub-epochs
    for i in range(1, len(unl)):
        assert unl[i].lr == pytest.approx(unl[i - 1].lr * 0.8)
    # "slightly higher learning rates on the labeled data"
    for p in phases:
        if p.kind == "labeled":
            se = next(u for u in unl if u.sub_epoch == p.sub_epoch)
            assert p.lr == pytest.approx(se.lr * 1.5)


def test_offsets_rotate():
    cfg = scheduled.ScheduleConfig(n_sub_epochs=9, labeled_every=1,
                                   n_feature_offsets=3)
    lab = [p for p in scheduled.phases(cfg) if p.kind == "labeled"]
    assert [p.feature_offset for p in lab] == [0, 1, 2, 0, 1, 2, 0, 1, 2]


def test_describe():
    txt = scheduled.describe(scheduled.ScheduleConfig.paper_100k())
    assert "sub-epoch" in txt and "full-seq" in txt
