"""Scheduled learning: the paper's two published schedules, structurally."""
import pytest

from repro.core import scheduled


def test_paper_100k_structure():
    cfg = scheduled.ScheduleConfig.paper_100k()
    phases = scheduled.phases(cfg)
    unl = [p for p in phases if p.kind == "unlabeled"]
    lab = [p for p in phases if p.kind == "labeled"]
    assert len(unl) == 4 and len(lab) == 4          # labeled after EVERY
    assert sum(p.hours for p in unl) == 100_000
    # chunked for sub-epochs 1-3, full-sequence on the 4th
    assert [p.chunked for p in unl] == [True, True, True, False]


def test_paper_1m_structure():
    cfg = scheduled.ScheduleConfig.paper_1m()
    phases = scheduled.phases(cfg)
    unl = [p for p in phases if p.kind == "unlabeled"]
    lab = [p for p in phases if p.kind == "labeled"]
    assert len(unl) == 18
    assert sum(p.hours for p in unl) == 990_000     # ~1M hours
    # labeled pass after every 5th sub-epoch (+ final)
    assert [p.sub_epoch for p in lab] == [5, 10, 15, 18]
    # chunked for 1-15, fine-tune (full seq) 16-18
    assert all(p.chunked for p in unl[:15])
    assert not any(p.chunked for p in unl[15:])


def test_lr_decay_and_boost():
    cfg = scheduled.ScheduleConfig(n_sub_epochs=6, labeled_every=2,
                                   lr0=1e-3, lr_decay=0.8,
                                   labeled_lr_boost=1.5)
    phases = scheduled.phases(cfg)
    unl = [p for p in phases if p.kind == "unlabeled"]
    # exponential decay over sub-epochs
    for i in range(1, len(unl)):
        assert unl[i].lr == pytest.approx(unl[i - 1].lr * 0.8)
    # "slightly higher learning rates on the labeled data"
    for p in phases:
        if p.kind == "labeled":
            se = next(u for u in unl if u.sub_epoch == p.sub_epoch)
            assert p.lr == pytest.approx(se.lr * 1.5)


def test_offsets_rotate():
    cfg = scheduled.ScheduleConfig(n_sub_epochs=9, labeled_every=1,
                                   n_feature_offsets=3)
    lab = [p for p in scheduled.phases(cfg) if p.kind == "labeled"]
    assert [p.feature_offset for p in lab] == [0, 1, 2, 0, 1, 2, 0, 1, 2]


def test_describe():
    txt = scheduled.describe(scheduled.ScheduleConfig.paper_100k())
    assert "sub-epoch" in txt and "full-seq" in txt


# ===================================== interleaving property tests

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

import dataclasses


@settings(max_examples=40, deadline=None)
@given(every=st.integers(1, 18), until=st.integers(0, 18))
def test_paper_1m_interleaving_invariants(every, until):
    """The paper-1M schedule keeps its structural contract under ANY
    labeled interleave period and chunked/full-sequence switch point —
    the wave driver re-derives schedules per wave, so these invariants
    must hold away from the published (5, 15) setting too."""
    cfg = dataclasses.replace(scheduled.ScheduleConfig.paper_1m(),
                              labeled_every=every, chunked_until=until)
    ph = scheduled.phases(cfg)
    n = cfg.n_sub_epochs
    unl = [p for p in ph if p.kind == "unlabeled"]
    lab = [p for p in ph if p.kind == "labeled"]

    # every sub-epoch appears exactly once, in order
    assert [p.sub_epoch for p in unl] == list(range(1, n + 1))
    # labeled passes: every `every`-th sub-epoch, plus always the final
    assert [p.sub_epoch for p in lab] == sorted(
        {se for se in range(1, n + 1) if se % every == 0} | {n})
    # a labeled pass immediately follows its own unlabeled sub-epoch
    for p in lab:
        i = ph.index(p)
        assert ph[i - 1].kind == "unlabeled"
        assert ph[i - 1].sub_epoch == p.sub_epoch
    # the chunked->full-sequence switch happens exactly once, at `until`
    for p in ph:
        assert p.chunked == (p.sub_epoch <= until)
    # lr: exponential decay per sub-epoch; labeled boosted off its own
    # sub-epoch's lr
    for p in unl:
        assert p.lr == pytest.approx(
            cfg.lr0 * cfg.lr_decay ** (p.sub_epoch - 1))
    for p in lab:
        assert p.lr == pytest.approx(
            cfg.lr0 * cfg.lr_decay ** (p.sub_epoch - 1)
            * cfg.labeled_lr_boost)
    # feature offsets rotate over labeled passes in order
    assert [p.feature_offset for p in lab] == [
        i % cfg.n_feature_offsets for i in range(len(lab))]
    # hours bookkeeping survives the re-interleave
    assert sum(p.hours for p in unl) == n * cfg.sub_epoch_hours
    assert all(p.hours == cfg.labeled_hours for p in lab)
