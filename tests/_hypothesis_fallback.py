"""Deterministic stand-in for the tiny hypothesis API subset the suite uses.

When the ``[test]`` extra (which declares ``hypothesis``) is installed, the
test modules import the real library and this file is inert.  When it is
not — e.g. a bare container with only jax/numpy/pytest — the modules fall
back to this shim so the property tests still *run* (with seeded,
deterministic draws) instead of erroring at collection or skipping
wholesale.

Supported surface: ``given(**kwargs)`` with keyword strategies,
``settings(max_examples=..., deadline=...)``, ``st.integers(lo, hi)``,
``st.sampled_from(seq)``.  Anything else raises immediately so a new
hypothesis feature can't silently no-op here.

The shim caps examples at FALLBACK_MAX_EXAMPLES: it is a smoke-level
stand-in; full-rigor randomized search comes from real hypothesis in CI.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

FALLBACK_MAX_EXAMPLES = 5


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


class _Namespace:
    integers = staticmethod(_integers)
    sampled_from = staticmethod(_sampled_from)


st = _Namespace()


def settings(max_examples: int = 20, deadline=None, **unknown):
    if unknown:
        raise NotImplementedError(
            f"fallback settings() does not support {sorted(unknown)}")

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*args, **strategies):
    if args or not strategies:
        raise NotImplementedError(
            "fallback given() supports keyword strategies only")
    for name, strat in strategies.items():
        if not isinstance(strat, _Strategy):
            raise NotImplementedError(
                f"fallback strategy for {name!r} not supported")

    def deco(fn):
        @functools.wraps(fn)
        def run(*fargs, **fkwargs):
            # read at call time so @settings works above or below @given
            # (above: the attribute lands on this wrapper, not fn)
            n = min(getattr(run, "_fallback_max_examples",
                            getattr(fn, "_fallback_max_examples", 20)),
                    FALLBACK_MAX_EXAMPLES)
            # stable per-test seed: independent of hash randomization
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                draws = {k: s.draw(rng) for k, s in strategies.items()}
                fn(*fargs, **draws, **fkwargs)

        # hide the drawn params from pytest's fixture resolution: the
        # wrapper itself takes no arguments
        run.__signature__ = inspect.Signature()
        del run.__wrapped__
        return run
    return deco
