"""Data-plane pipeline (ISSUE 3): sharded target generation over the
work ledger, and the async prefetching feed's ordering/determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pipeline import (PrefetchingSource, WorkLedger, generate_sharded,
                            shard_ranges)
from repro.store import LogitStoreV2
from repro.train import (ListSink, Local, TrainBatch, Trainer,
                         distill_shard_source)

K, V = 4, 30


# ----------------------------------------------------------- partitioning

def test_shard_ranges_partition():
    assert shard_ranges(8, 2) == [(0, 4), (4, 8)]
    assert shard_ranges(7, 3) == [(0, 3), (3, 5), (5, 7)]
    assert shard_ranges(2, 4) == [(0, 1), (1, 2)]     # empty ranges dropped
    ranges = shard_ranges(23, 5)
    covered = [i for lo, hi in ranges for i in range(lo, hi)]
    assert covered == list(range(23))                 # disjoint + complete


# ----------------------------------------------------------------- ledger

def test_ledger_claim_done_resume(tmp_path):
    path = os.path.join(tmp_path, "ledger.json")
    led = WorkLedger.open(path, [(0, 2), (2, 4), (4, 6)])
    a = led.claim("w0")
    b = led.claim("w1")
    assert (a.lo, a.hi) == (0, 2) and (b.lo, b.hi) == (2, 4)
    led.mark_done(a)
    # "kill" the run: b stays claimed on disk.  A fresh open demotes the
    # dead worker's claim to pending; done work stays done.
    led2 = WorkLedger.open(path, [(0, 2), (2, 4), (4, 6)])
    assert led2.n_done == 1 and not led2.all_done
    statuses = [r.status for r in led2.ranges]
    assert statuses == ["done", "pending", "pending"]
    c = led2.claim("w0")
    assert (c.lo, c.hi) == (2, 4)                     # re-claimed
    led2.mark_done(c)
    led2.mark_done(led2.claim("w0"))
    assert led2.all_done


def test_ledger_repartition_rejected(tmp_path):
    path = os.path.join(tmp_path, "ledger.json")
    WorkLedger.open(path, [(0, 2), (2, 4)])
    with pytest.raises(ValueError):
        WorkLedger.open(path, [(0, 4)])


# ------------------------------------------------------ sharded generation

class _FakeEngine:
    """Deterministic stand-in for a StreamingEngine: top-k of a fixed
    random projection of the batch — content depends only on the batch,
    never on which worker ran it."""

    def __init__(self, worker: int, calls: list):
        self.worker = worker
        self.calls = calls

    def forward_topk(self, batch):
        self.calls.append(self.worker)
        feats = np.asarray(batch["feats"], np.float32)
        rng = np.random.default_rng(0)
        w = rng.normal(size=(feats.shape[-1], V)).astype(np.float32)
        logits = feats @ w
        idx = np.argsort(-logits, axis=-1)[..., :K].astype(np.int32)
        vals = np.take_along_axis(logits, idx, axis=-1)
        vals = vals - vals[..., :1]
        return vals, idx


def _batches(n, b=2, s=5, f=8):
    rng = np.random.default_rng(3)
    return [{"feats": rng.normal(size=(b, s, f)).astype(np.float32),
             "mask": np.ones((b, s), np.float32)} for _ in range(n)]


def test_generate_sharded_two_workers_single_consumer(tmp_path):
    """workers=2 production, workers=1 consumption: the manifest is the
    contract — complete, checksummed, and bitwise equal to what a
    single worker would have produced."""
    batches = _batches(6)
    calls = []
    store2 = LogitStoreV2(str(tmp_path / "w2"), k=K, vocab=V)
    rep = generate_sharded(lambda w: _FakeEngine(w, calls), batches, store2,
                           n_workers=2)
    assert rep["n_shards"] == 6 and rep["n_workers"] == 2
    assert set(calls) == {0, 1}                       # both workers ran
    assert store2.verify() == 6                       # manifest-verified

    store1 = LogitStoreV2(str(tmp_path / "w1"), k=K, vocab=V)
    generate_sharded(lambda w: _FakeEngine(w, []), batches, store1,
                     n_workers=1)
    for j in range(6):                                # workers=1 reader
        v2, i2 = store2.read_shard(j, verify=True)
        v1, i1 = store1.read_shard(j)
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))


def test_generate_sharded_resumes_killed_range(tmp_path):
    """A worker dying mid-range leaves a claimed ledger entry; the next
    invocation re-claims exactly the unfinished ranges and the final
    store is complete."""
    batches = _batches(6)
    store = LogitStoreV2(str(tmp_path), k=K, vocab=V)
    ledger_path = os.path.join(tmp_path, "ledger.json")

    class _DyingEngine(_FakeEngine):
        def forward_topk(self, batch):
            if len(self.calls) == 3:
                raise RuntimeError("worker killed")
            return super().forward_topk(batch)

    calls = []
    with pytest.raises(RuntimeError):
        generate_sharded(lambda w: _DyingEngine(w, calls), batches, store,
                         n_workers=2, ledger_path=ledger_path)
    # the dying engine completes range (0,3) and dies entering (3,6):
    # genuinely partial progress, visible in both store and ledger
    assert 0 < len(store.shards()) < 6
    done_before = WorkLedger.open(ledger_path, shard_ranges(6, 2)).n_done
    assert done_before == 1

    calls2 = []
    rep = generate_sharded(lambda w: _FakeEngine(w, calls2), batches, store,
                           n_workers=2, ledger_path=ledger_path)
    assert rep["resumed"]
    assert store.verify() == 6
    assert store.shards() == list(range(6))
    # resumed pass only processed what the dead run left unfinished
    assert len(calls2) == 3


def test_generate_sharded_rerun_supersedes_wave(tmp_path):
    """A completed generation pass re-run (new teacher) supersedes the
    previous wave atomically rather than interleaving with it."""
    batches = _batches(4)
    store = LogitStoreV2(str(tmp_path), k=K, vocab=V)
    r0 = generate_sharded(lambda w: _FakeEngine(w, []), batches, store,
                          n_workers=2)
    r1 = generate_sharded(lambda w: _FakeEngine(w, []), batches, store,
                          n_workers=2)
    assert r0["wave"] == 0 and r1["wave"] == 1
    assert all(store.manifest.entry(j).wave == 1 for j in store.shards())
    store.verify()


def test_generate_sharded_completed_pass_repartitions(tmp_path):
    """A completed pass re-run with a different n_workers is a fresh
    wave with a fresh partition — only an *unfinished* ledger pins its
    ranges."""
    batches = _batches(6)
    store = LogitStoreV2(str(tmp_path), k=K, vocab=V)
    lp = os.path.join(tmp_path, "ledger.json")
    generate_sharded(lambda w: _FakeEngine(w, []), batches, store,
                     n_workers=2, ledger_path=lp)
    rep = generate_sharded(lambda w: _FakeEngine(w, []), batches, store,
                           n_workers=3, ledger_path=lp)
    assert rep["n_workers"] == 3 and rep["wave"] == 1
    assert store.verify() == 6


def test_generate_sharded_fresh_ledger_respects_live_wave(tmp_path):
    """A deleted ledger (or a new ledger_path) against a store already
    at a higher wave must start at next_wave(), not crash the first
    append with StaleWaveError."""
    batches = _batches(4)
    store = LogitStoreV2(str(tmp_path), k=K, vocab=V)
    lp = os.path.join(tmp_path, "ledger.json")
    generate_sharded(lambda w: _FakeEngine(w, []), batches, store,
                     n_workers=2, ledger_path=lp)
    generate_sharded(lambda w: _FakeEngine(w, []), batches, store,
                     n_workers=2, ledger_path=lp)   # store now at wave 1
    os.remove(lp)                                   # repartition hygiene
    rep = generate_sharded(lambda w: _FakeEngine(w, []), batches, store,
                           n_workers=1, ledger_path=lp)
    assert rep["wave"] == 2
    store.verify()


# ------------------------------------------------------- prefetching feed

def _quad(params, batch):
    e = batch["x"] @ params["w"] - batch["y"]
    return jnp.mean(e ** 2), {"loss": jnp.mean(e ** 2)}


def _quad_problem(n=32, d=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x @ rng.normal(size=(d,))).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def test_prefetch_preserves_order():
    src = [TrainBatch({"i": np.asarray([i])}, 0.1, "t") for i in range(20)]
    out = [int(np.asarray(tb.data["i"])[0])
           for tb in PrefetchingSource(src, depth=3)]
    assert out == list(range(20))


def test_prefetch_training_bitwise_equals_sync():
    """The acceptance pin: training through the prefetching feed is
    bitwise-identical to the synchronous feed — same loss trace, same
    final params."""
    batch = _quad_problem()
    src = lambda: [TrainBatch(batch, 0.05 * (0.9 ** i), "q")
                   for i in range(12)]
    sink_s, sink_p = ListSink(), ListSink()
    tr_s = Trainer(Local(clip=0.0), {"q": _quad}, metrics=sink_s)
    st_s = tr_s.fit(tr_s.init_state({"w": jnp.zeros((8,))}), src(),
                    resume=False)
    tr_p = Trainer(Local(clip=0.0), {"q": _quad}, metrics=sink_p,
                   prefetch=3)
    st_p = tr_p.fit(tr_p.init_state({"w": jnp.zeros((8,))}), src(),
                    resume=False)
    assert sink_s.values("loss") == sink_p.values("loss")
    np.testing.assert_array_equal(np.asarray(st_s.params["w"]),
                                  np.asarray(st_p.params["w"]))


def test_prefetch_distill_shard_source_bitwise(tmp_path):
    """End-to-end over the real store: distill shards fed sync vs
    prefetched (with checksum verify on the decode thread) produce the
    same training loss bitwise."""
    from repro.launch.steps import make_loss_fn
    from repro.models import build_model
    from repro.configs.lstm_am_7khr import CONFIG
    from repro.configs.base import LayerSpec, Segment

    cfg = CONFIG.replace(
        lstm_hidden=16, feat_dim=8, n_senones=V, vocab_size=V,
        segments=(Segment((LayerSpec(mixer="lstm", ffn="none"),),
                          repeat=1),))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    batches = [{"feats": rng.normal(size=(2, 6, 8)).astype(np.float32),
                "mask": np.ones((2, 6), np.float32)} for _ in range(4)]
    store = LogitStoreV2(str(tmp_path), k=K, vocab=V)
    for j in range(4):
        vals = rng.normal(size=(2, 6, K)).astype(np.float32)
        vals = vals - vals.max(-1, keepdims=True)
        idx = np.stack([rng.choice(V, K, replace=False)
                        for _ in range(12)]).reshape(2, 6, K)
        store.append_shard(j, vals, idx)

    loss_fns = {"distill_topk": make_loss_fn(model, cfg, "distill_topk")}
    outs = []
    for depth in (0, 2):
        sink = ListSink()
        tr = Trainer(Local(clip=0.0), loss_fns, metrics=sink,
                     prefetch=depth)
        st = tr.fit(tr.init_state(params),
                    distill_shard_source(batches, store, 0, 4, 0.05,
                                         verify=depth > 0),
                    resume=False)
        outs.append((sink.values("loss"), jax.device_get(st.params)))
    assert outs[0][0] == outs[1][0]
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        outs[0][1], outs[1][1])


def test_prefetch_exhausted_iterator_stays_exhausted():
    """next() on an exhausted prefetch iterator raises StopIteration
    again instead of parking forever on the drained queue."""
    it = iter(PrefetchingSource([TrainBatch({"i": np.zeros(1)}, 0.1, "t")],
                                depth=2))
    assert len(list(it)) == 1
    with pytest.raises(StopIteration):
        next(it)


def test_prefetch_propagates_producer_error():
    def bad():
        yield TrainBatch({"i": np.zeros(1)}, 0.1, "t")
        raise ValueError("decode failed")
    it = iter(PrefetchingSource(bad, depth=2))
    next(it)
    with pytest.raises(ValueError, match="decode failed"):
        next(it)


def test_distill_source_pin_wave_survives_mid_epoch_supersede(tmp_path):
    """The wave-consistency fix: a pinned source snapshots its shards'
    manifest entries at iteration start, so a teacher regeneration
    superseding shards *mid-sub-epoch* cannot switch the pass onto
    new-wave targets half way through — while an unpinned source
    silently mixes the two waves (the bug)."""
    batches = _batches(4)
    store = LogitStoreV2(str(tmp_path), k=K, vocab=V)
    old = {}
    rng = np.random.default_rng(5)
    for j in range(4):
        vals = rng.normal(size=(2, 5, K)).astype(np.float32)
        vals = vals - vals.max(-1, keepdims=True)
        idx = rng.integers(0, V, (2, 5, K)).astype(np.int32)
        store.append_shard(j, vals, idx)
        old[j] = idx

    def supersede_all():
        for j in range(4):
            vals = np.zeros((2, 5, K), np.float32)
            idx = np.full((2, 5, K), j % V, np.int32)   # distinctive
            store.append_shard(j, vals, idx, wave=1)

    # pinned: iterate two shards, regenerate everything, keep iterating
    # — every batch still carries wave-0 targets
    it = iter(distill_shard_source(batches, store, 0, 4, 0.1,
                                   pin_wave=True, verify=True))
    got = [next(it), next(it)]
    supersede_all()
    got += list(it)
    for j, tb in enumerate(got):
        np.testing.assert_array_equal(np.asarray(tb.data["topk_idx"]),
                                      old[j], err_msg=f"shard {j}")

    # unpinned (the old behavior): the same interleaving mixes waves
    it = iter(distill_shard_source(batches, store, 0, 4, 0.1))
    first = next(it)
    # a third wave lands mid-epoch
    for j in range(4):
        store.append_shard(j, np.zeros((2, 5, K), np.float32),
                           np.full((2, 5, K), (j + 7) % V, np.int32),
                           wave=2)
    rest = list(it)
    assert np.asarray(first.data["topk_idx"]).max() != \
        np.asarray(rest[0].data["topk_idx"]).max()


def test_prefetch_early_close_stops_producer():
    produced = []

    def src():
        for i in range(1000):
            produced.append(i)
            yield TrainBatch({"i": np.asarray([i])}, 0.1, "t")

    ps = PrefetchingSource(src, depth=2)
    it = iter(ps)
    for _ in range(3):
        next(it)
    ps.close()
    n = len(produced)
    assert n < 1000                       # producer stopped early
    import time
    time.sleep(0.1)
    assert len(produced) == n             # ...and stays stopped
