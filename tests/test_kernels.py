"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.kernels import (gtc_compress, gtc_compress_ref,
                           sparse_ce_lse_gather, sparse_ce_lse_gather_ref,
                           swa_attention, swa_attention_ref,
                           topk_distill_ce, topk_distill_ce_ref,
                           topk_logits, topk_logits_ref)


# ------------------------------------------------------------ topk_logits

@pytest.mark.parametrize("shape,k", [
    ((4, 3183), 20),           # the paper's senones, k=20
    ((2, 3, 500), 5),
    pytest.param((1, 262144), 20, marks=pytest.mark.slow),  # gemma3 vocab
    ((130, 777), 11),          # unaligned rows + vocab
    ((8, 128), 128),           # k == v_tile edge
])
@pytest.mark.parametrize("dtype", [
    jnp.float32, pytest.param(jnp.bfloat16, marks=pytest.mark.slow)])
def test_topk_sweep(shape, k, dtype):
    rng = np.random.default_rng(hash((shape, k)) % 2**31)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    v1, i1 = topk_logits(x, k, interpret=True)
    v2, i2 = topk_logits_ref(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.slow
@given(v=st.integers(100, 5000), k=st.integers(1, 20),
       seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_topk_property(v, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(3, v)), jnp.float32)
    vals, idx = topk_logits(x, k, interpret=True)
    # every returned value really is at its claimed index, sorted desc
    picked = np.take_along_axis(np.asarray(x), np.asarray(idx), -1)
    np.testing.assert_allclose(np.asarray(vals), picked, atol=1e-6)
    assert (np.diff(np.asarray(vals), axis=-1) <= 1e-6).all()


# -------------------------------------------------------------- sparse_ce

@pytest.mark.parametrize("t,d,v,k,cap", [
    (37, 64, 3183, 20, 0.0),
    pytest.param(130, 96, 500, 5, 30.0, marks=pytest.mark.slow),
    pytest.param(16, 128, 8192, 20, 0.0, marks=pytest.mark.slow),
    (5, 32, 150, 3, 0.0),
])
def test_sparse_ce_sweep(t, d, v, k, cap):
    rng = np.random.default_rng(t * 7 + k)
    h = jnp.asarray(rng.normal(size=(t, d)), jnp.float32) * 0.1
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32) * 0.1
    idx = jnp.asarray(np.stack([rng.choice(v, k, replace=False)
                                for _ in range(t)]), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(t, k)), jnp.float32)
    l1, g1 = sparse_ce_lse_gather(h, w, idx, softcap=cap, interpret=True)
    l2, g2 = sparse_ce_lse_gather_ref(h, w, idx, softcap=cap)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=2e-4)
    c1 = topk_distill_ce(h, w, vals, idx, softcap=cap, interpret=True)
    c2 = topk_distill_ce_ref(h, w, vals, idx, softcap=cap)
    np.testing.assert_allclose(float(c1), float(c2), rtol=1e-4)


def test_sparse_ce_bf16_inputs():
    rng = np.random.default_rng(9)
    h = jnp.asarray(rng.normal(size=(16, 32)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(32, 300)), jnp.bfloat16)
    idx = jnp.asarray(rng.integers(0, 300, (16, 4)), jnp.int32)
    l1, g1 = sparse_ce_lse_gather(h, w, idx, interpret=True)
    l2, g2 = sparse_ce_lse_gather_ref(h, w, idx)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-2,
                               atol=2e-2)


# ---------------------------------------------------------- swa_attention

@pytest.mark.parametrize("b,hq,hkv,s,hd,w", [
    pytest.param(2, 4, 2, 256, 64, 128, marks=pytest.mark.slow),
    pytest.param(1, 2, 1, 300, 80, 100,     # unaligned everything
                 marks=pytest.mark.slow),
    pytest.param(1, 1, 1, 512, 128, 512,    # window == seq
                 marks=pytest.mark.slow),
    (2, 2, 2, 64, 32, 16),         # tiny
    pytest.param(1, 2, 1, 1024, 128, 384,   # non-tile-multiple window
                 marks=pytest.mark.slow),
])
def test_swa_sweep(b, hq, hkv, s, hd, w):
    rng = np.random.default_rng(s + w)
    q = jnp.asarray(rng.normal(size=(b, hq, s, hd)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(b, hkv, s, hd)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(b, hkv, s, hd)), jnp.float32)
    o1 = swa_attention(q, k, v, w, interpret=True)
    o2 = swa_attention_ref(q, jnp.repeat(k, hq // hkv, 1),
                           jnp.repeat(v, hq // hkv, 1), w)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)


def test_swa_bf16():
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    o1 = swa_attention(q, k, v, 64, interpret=True)
    o2 = swa_attention_ref(q, k, v, 64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-2)


def test_swa_window_locality_property():
    """Tokens beyond the window must not influence the output."""
    rng = np.random.default_rng(12)
    s, w = 256, 64
    q = jnp.asarray(rng.normal(size=(1, 1, s, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, s, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, s, 32)), jnp.float32)
    o1 = swa_attention(q, k, v, w, interpret=True)
    # perturb k/v OUTSIDE the window of the last query
    k2 = k.at[:, :, : s - w].set(rng.normal(size=(1, 1, s - w, 32)))
    v2 = v.at[:, :, : s - w].set(rng.normal(size=(1, 1, s - w, 32)))
    o2 = swa_attention(q, k2, v2, w, interpret=True)
    np.testing.assert_allclose(np.asarray(o1[:, :, -1]),
                               np.asarray(o2[:, :, -1]), atol=1e-5)


# ----------------------------------------------------------- gtc_compress

@pytest.mark.parametrize("shape", [(33, 257), (8192,), (3, 5, 7),
                                   (1, 8193)])
@pytest.mark.parametrize("tau", [1e-4, 1e-2])
def test_gtc_kernel_sweep(shape, tau):
    rng = np.random.default_rng(int(np.prod(shape)))
    g = jnp.asarray(rng.normal(size=shape), jnp.float32) * 1e-2
    r = jnp.asarray(rng.normal(size=shape), jnp.float32) * 1e-2
    s1, r1 = gtc_compress(g, r, tau, interpret=True)
    s2, r2 = gtc_compress_ref(g, r, tau)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-7)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-7)


def test_gtc_kernel_bf16_grad():
    rng = np.random.default_rng(13)
    g = jnp.asarray(rng.normal(size=(64, 64)), jnp.bfloat16) * 0.01
    r = jnp.zeros((64, 64), jnp.float32)
    s1, r1 = gtc_compress(g, r, 1e-3, interpret=True)
    s2, r2 = gtc_compress_ref(g, r, 1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-6)
