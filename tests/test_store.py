"""LogitStore v2 (ISSUE 3 tentpole): manifest-backed sharded archive —
round-trips, v1 migration, checksum integrity, wave-supersede atomicity."""
import os

import numpy as np
import pytest

from repro.core.logit_store import LogitStore
from repro.store import (LogitStoreV2, Manifest, ShardCorruptionError,
                         StaleWaveError, StoreError, migrate_v1)


def _shard(seed=0, b=2, s=6, k=4, v=50):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(b, s, k)).astype(np.float32)
    # max-shifted like the codec: <= 0, bf16/f16-friendly
    vals = vals - vals.max(-1, keepdims=True)
    idx = rng.integers(0, v, (b, s, k)).astype(np.int32)
    return vals, idx


# ---------------------------------------------------------------- basics

def test_roundtrip_and_manifest_stats(tmp_path):
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    vals, idx = _shard(0)
    store.append_shard(0, vals, idx, utt_lens=[6, 6])
    v, i = store.read_shard(0)
    np.testing.assert_array_equal(np.asarray(i), idx)
    np.testing.assert_allclose(np.asarray(v, np.float32), vals, atol=1e-2)
    np.testing.assert_array_equal(store.read_lens(0), [6, 6])
    # stats come from the manifest — O(1) file reads, not a shard walk
    meta = store.stats()
    assert meta.n_frames == 12 and meta.k == 4 and meta.vocab == 50
    # reopening sees the same manifest
    again = LogitStoreV2(str(tmp_path))
    assert again.shards() == [0] and again.k == 4 and again.vocab == 50


def test_reads_are_memory_mapped(tmp_path):
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    vals, idx = _shard(1)
    store.append_shard(0, vals, idx)
    v, i = store.read_shard(0)
    assert isinstance(v, np.memmap) and isinstance(i, np.memmap)


def test_k_vocab_mismatch_rejected(tmp_path):
    LogitStoreV2(str(tmp_path), k=4, vocab=50).append_shard(0, *_shard(0))
    with pytest.raises(StoreError):
        LogitStoreV2(str(tmp_path), k=8, vocab=50)
    with pytest.raises(StoreError):
        LogitStoreV2(str(tmp_path), k=4, vocab=99)


# ------------------------------------------------------------- integrity

def test_checksum_rejects_corrupted_shard(tmp_path):
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    vals, idx = _shard(2)
    store.append_shard(0, vals, idx)
    store.verify()                              # intact: passes
    path = os.path.join(store.root, store.manifest.entry(0).files["vals"])
    with open(path, "r+b") as f:                # flip bytes past the header
        f.seek(os.path.getsize(path) - 4)
        f.write(b"\xff\xff\xff\xff")
    fresh = LogitStoreV2(str(tmp_path))
    with pytest.raises(ShardCorruptionError):
        fresh.read_shard(0, verify=True)
    with pytest.raises(ShardCorruptionError):
        fresh.verify()
    # unverified mmap read still works (opt-in integrity, by design)
    fresh.read_shard(0)


# ------------------------------------------------------- wave supersede

def test_wave_supersede_is_atomic(tmp_path):
    """A regenerated wave replaces a shard atomically: files staged
    without a manifest commit are invisible (killed writer), the commit
    swaps the entry in one rename, and stale files are *retired* —
    kept on disk for wave-pinned readers until the next gc()."""
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    v0, i0 = _shard(3)
    store.append_shard(0, v0, i0)
    old_files = dict(store.manifest.entry(0).files)

    # stage wave-1 files but "die" before the manifest commit; the
    # racing readers disable gc-on-open — a concurrent open while a
    # writer is mid-stage is outside the gc contract (it would sweep
    # the staged-but-uncommitted files as orphans)
    v1_, i1_ = _shard(4)
    staged = store._write_shard_files(0, v1_, i1_, wave=1)
    reader = LogitStoreV2(str(tmp_path), gc_on_open=False)
    got_v, got_i = reader.read_shard(0, verify=True)
    np.testing.assert_array_equal(np.asarray(got_i), i0)  # still wave 0
    assert reader.manifest.entry(0).wave == 0

    # commit: readers now see wave 1; wave-0 files survive as retired
    # (a pinned reader may still be on them) until gc reclaims them
    store._commit(staged)
    reader2 = LogitStoreV2(str(tmp_path), gc_on_open=False)
    got_v2, got_i2 = reader2.read_shard(0, verify=True)
    np.testing.assert_array_equal(np.asarray(got_i2), i1_)
    assert reader2.manifest.entry(0).wave == 1
    for rel in old_files.values():
        assert os.path.exists(os.path.join(str(tmp_path), rel))
    removed = store.gc()
    assert sorted(removed) == sorted(old_files.values())
    for rel in old_files.values():
        assert not os.path.exists(os.path.join(str(tmp_path), rel))
    # gc cleared the retired list durably
    assert LogitStoreV2(str(tmp_path)).manifest.retired == []


def test_stale_wave_rejected_and_same_wave_idempotent(tmp_path):
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    v, i = _shard(5)
    store.append_shard(0, v, i, wave=2)
    with pytest.raises(StaleWaveError):
        store.append_shard(0, v, i, wave=1)
    # same-wave rewrite (idempotent retry) is fine
    store.append_shard(0, v, i, wave=2)
    store.verify()
    assert store.next_wave() == 3


# ----------------------------------------------------------- v1 -> v2

def test_v1_migration_roundtrip(tmp_path):
    """A v1 archive opens as a v2 store in place: same shards, same
    contents, checksummed; a new wave then supersedes shard-by-shard
    into v2 format and the npz is retired."""
    root = str(tmp_path / "s")
    v1 = LogitStore(root, k=4, vocab=50)
    shards = {j: _shard(10 + j) for j in range(3)}
    for j, (v, i) in shards.items():
        v1.write_shard(j, v, i, utt_lens=[6, 6])

    store = migrate_v1(root)
    assert store.k == 4 and store.vocab == 50
    assert store.shards() == [0, 1, 2]
    assert store.verify() == 3
    for j, (v, i) in shards.items():
        got_v, got_i = store.read_shard(j)
        assert store.manifest.entry(j).format == "v1-npz"
        np.testing.assert_array_equal(np.asarray(got_i), i)
        np.testing.assert_allclose(np.asarray(got_v, np.float32), v,
                                   atol=1e-2)
    assert store.stats().n_frames == 36

    # a regeneration wave supersedes the migrated entries with v2 files
    v_new, i_new = _shard(99)
    store.append_shard(1, v_new, i_new, wave=1)
    entry = store.manifest.entry(1)
    assert entry.format == "v2" and entry.wave == 1
    # the npz is retired (still readable by a pinned consumer) until gc
    assert os.path.exists(os.path.join(root, "shard_00001.npz"))
    store.gc()
    assert not os.path.exists(os.path.join(root, "shard_00001.npz"))
    got_v, got_i = store.read_shard(1, verify=True)
    np.testing.assert_array_equal(np.asarray(got_i), i_new)
    # untouched v1 siblings still read and verify
    store.verify()


def test_manifest_atomic_write_survives_garbage_tmp(tmp_path):
    """A leftover .tmp from a killed writer never shadows the manifest."""
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    store.append_shard(0, *_shard(0))
    with open(Manifest.path_for(str(tmp_path)) + ".tmp", "w") as f:
        f.write("{not json")
    again = LogitStoreV2(str(tmp_path))
    assert again.shards() == [0]


# -------------------------------------------------------------------- gc

def test_gc_reclaims_writer_killed_mid_stage(tmp_path):
    """A writer killed between staging the shard .npy files and the
    manifest commit leaks unreferenced wave files; gc() on the next
    store open removes exactly those orphans and nothing live."""
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    store.append_shard(0, *_shard(0))
    live_files = dict(store.manifest.entry(0).files)

    # "kill" a wave-1 writer mid-stage: files on disk, no manifest entry
    staged = store._write_shard_files(1, *_shard(1), wave=1)
    for rel in staged.files.values():
        assert os.path.exists(os.path.join(str(tmp_path), rel))

    reopened = LogitStoreV2(str(tmp_path))       # gc_on_open sweeps
    for rel in staged.files.values():
        assert not os.path.exists(os.path.join(str(tmp_path), rel))
    for rel in live_files.values():
        assert os.path.exists(os.path.join(str(tmp_path), rel))
    reopened.verify()                            # live shard untouched


def test_gc_on_open_reclaims_retired_wave(tmp_path):
    """Files of a superseded wave survive the commit (pinned readers)
    but die at the next open's gc."""
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    store.append_shard(0, *_shard(0))
    wave0_files = dict(store.manifest.entry(0).files)
    store.append_shard(0, *_shard(1), wave=1)    # supersede -> retire
    assert len(store.manifest.retired) == 1
    for rel in wave0_files.values():
        assert os.path.exists(os.path.join(str(tmp_path), rel))

    again = LogitStoreV2(str(tmp_path))
    for rel in wave0_files.values():
        assert not os.path.exists(os.path.join(str(tmp_path), rel))
    assert again.manifest.retired == []
    again.verify()


def test_gc_idempotent_and_empty_on_clean_store(tmp_path):
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    store.append_shard(0, *_shard(0))
    assert store.gc() == []
    assert store.gc() == []


# ------------------------------------------------------- wave pinning

def test_read_entry_pins_superseded_wave(tmp_path):
    """A reader holding a pre-supersede entry keeps reading the old
    wave's bytes (deferred retirement), and its checksum still
    verifies; after gc() the pinned read fails loudly, not silently."""
    store = LogitStoreV2(str(tmp_path), k=4, vocab=50)
    v0, i0 = _shard(7)
    store.append_shard(0, v0, i0)
    pinned = store.manifest.entry(0)

    v1_, i1_ = _shard(8)
    store.append_shard(0, v1_, i1_, wave=1)      # concurrent regeneration
    got_v, got_i = store.read_entry(pinned, verify=True)
    np.testing.assert_array_equal(np.asarray(got_i), i0)   # old wave
    live_v, live_i = store.read_shard(0)
    np.testing.assert_array_equal(np.asarray(live_i), i1_)  # new wave

    store.gc()
    with pytest.raises(ShardCorruptionError):
        store.read_entry(pinned, verify=True)
