"""Distillation losses + top-k logit store: exactness and properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # [test] extra absent: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core import distill
from repro.core.logit_store import (LogitStore, full_bytes_per_frame,
                                    iter_reconstruct, reconstruct,
                                    storage_bytes_per_frame,
                                    topk_compress)


def test_chunked_ce_matches_full():
    rng = np.random.default_rng(0)
    b, s, d, v = 2, 5, 16, 333
    h = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32) * 0.3
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32) * 0.3
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    full_logits = (h @ w).astype(jnp.float32)
    ref = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(full_logits, -1), labels[..., None], -1))
    got = distill.chunked_ce(h, w, labels, chunk=64)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


@pytest.mark.parametrize("chunk", [32, 100, 512])
def test_chunked_topk_matches_full(chunk):
    rng = np.random.default_rng(1)
    b, s, d, v, k = 2, 4, 12, 200, 7
    h = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32) * 0.3
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32) * 0.3
    tv = jnp.asarray(rng.normal(size=(b, s, k)), jnp.float32)
    ti = jnp.asarray(
        np.stack([rng.choice(v, k, replace=False)
                  for _ in range(b * s)]).reshape(b, s, k), jnp.int32)
    full = (h @ w).astype(jnp.float32)
    ref = distill.topk_soft_ce(full, tv, ti)
    got = distill.chunked_topk_distill_ce(h, w, tv, ti, chunk=chunk)
    np.testing.assert_allclose(float(got), float(ref), rtol=2e-4)


def test_chunked_ce_mask():
    rng = np.random.default_rng(2)
    h = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 50)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 50, (1, 4)), jnp.int32)
    mask = jnp.asarray([[1, 1, 0, 0]], jnp.float32)
    # masked loss == loss on the unmasked prefix
    got = distill.chunked_ce(h, w, labels, chunk=16, mask=mask)
    ref = distill.chunked_ce(h[:, :2], w, labels[:, :2], chunk=16)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


@pytest.mark.slow
@given(v=st.integers(10, 400), k=st.integers(1, 9), seed=st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_topk_compress_properties(v, k, seed):
    """Property: stored top-k reconstructs the dominant mass exactly."""
    k = min(k, v)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(3, v)), jnp.float32) * 3
    vals, idx = topk_compress(logits, k)
    # indices are the true top-k
    ref_idx = np.argsort(-np.asarray(logits), axis=-1)[:, :k]
    ref_sorted = np.sort(ref_idx, axis=-1)
    got_sorted = np.sort(np.asarray(idx), axis=-1)
    assert (ref_sorted == got_sorted).all()
    # shift-invariance: max stored value is 0 (bf16 storage trick)
    assert np.allclose(np.asarray(vals).max(-1), 0.0, atol=1e-2)
    # reconstruction preserves softmax over the top-k support
    rec = reconstruct(vals, idx, v)
    p_ref = jax.nn.softmax(logits, -1)
    p_rec = jax.nn.softmax(rec, -1)
    topmass_ref = np.take_along_axis(np.asarray(p_ref), ref_idx, -1).sum(-1)
    # reconstructed distribution concentrates all mass on the stored ids
    got_mass = np.take_along_axis(np.asarray(p_rec),
                                  np.asarray(idx), -1).sum(-1)
    assert np.allclose(got_mass, 1.0, atol=1e-3)
    # and the relative mass among stored ids matches (renormalized)
    ref_top = np.take_along_axis(np.asarray(p_ref), np.asarray(idx), -1)
    ref_top /= ref_top.sum(-1, keepdims=True)
    got_top = np.take_along_axis(np.asarray(p_rec), np.asarray(idx), -1)
    np.testing.assert_allclose(got_top, ref_top, atol=5e-3)


def test_storage_gain_k20():
    """Paper: top-20 storage vs full 3,183-senone posteriors ~26x."""
    assert full_bytes_per_frame(3183) / storage_bytes_per_frame(20) > 10


def test_logit_store_roundtrip(tmp_path):
    store = LogitStore(str(tmp_path), k=4, vocab=100)
    rng = np.random.default_rng(0)
    vals = rng.normal(size=(6, 10, 4)).astype(np.float32)
    idx = rng.integers(0, 100, (6, 10, 4)).astype(np.int32)
    store.write_shard(0, vals, idx)
    v2, i2 = store.read_shard(0)
    assert v2.shape == (6, 10, 4) and i2.shape == (6, 10, 4)
    np.testing.assert_array_equal(np.asarray(i2), idx)
    np.testing.assert_allclose(np.asarray(v2, np.float32), vals, atol=1e-2)
    meta = store.stats()
    assert meta.n_frames == 60 and meta.k == 4


def _topk_case(n_rows, v, k, seed=0):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(n_rows, k)), jnp.float32)
    idx = jnp.asarray(
        np.stack([rng.choice(v, k, replace=False)
                  for _ in range(n_rows)]), jnp.int32)
    return vals, idx


def test_reconstruct_chunked_matches_unchunked():
    """row_chunk streaming == the one-shot scatter, bitwise, including
    the ragged tail (n_rows not a multiple of the chunk)."""
    v, k = 123, 5
    vals, idx = _topk_case(17, v, k)
    ref = reconstruct(vals, idx, v)
    for rc in (4, 5, 16, 17, 64):
        got = reconstruct(vals, idx, v, row_chunk=rc)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # leading batch dims reshape through identically
    vals3 = vals.reshape(1, 17, k)
    idx3 = idx.reshape(1, 17, k)
    got3 = reconstruct(vals3, idx3, v, row_chunk=4)
    np.testing.assert_array_equal(np.asarray(got3)[0], np.asarray(ref))


def test_reconstruct_chunked_bounds_scatter_working_set():
    """The large-vocab regression pin: inside the chunked path's scan
    body, no intermediate exceeds one (row_chunk, vocab) block — the
    full canvas only ever exists as the final output, never (as in the
    unchunked scatter) as a second working copy."""
    v, k, n, rc = 500, 4, 256, 16
    vals, idx = _topk_case(n, v, k, seed=3)

    jaxpr = jax.make_jaxpr(
        lambda va, ix: reconstruct(va, ix, v, row_chunk=rc))(vals, idx)

    def body_avals(jxp):
        out = []
        for eqn in jxp.eqns:
            for sub in jax.core.jaxprs_in_params(eqn.params) \
                    if hasattr(jax.core, "jaxprs_in_params") else []:
                out.extend(body_avals(sub))
            if eqn.primitive.name in ("scan", "while"):
                inner = eqn.params.get("jaxpr")
                if inner is not None:
                    ij = getattr(inner, "jaxpr", inner)
                    for e in ij.eqns:
                        out.extend(x.aval for x in e.outvars)
        return out

    inner_avals = body_avals(jaxpr)
    assert inner_avals, "chunked path must lower to a scan"
    cap = rc * v
    for aval in inner_avals:
        assert int(np.prod(aval.shape)) <= cap, (
            f"scan-body intermediate {aval.shape} exceeds one "
            f"(row_chunk={rc}, vocab={v}) block")


def test_iter_reconstruct_streams_blocks():
    """Host-side streaming reconstruction: block-bounded shapes, exact
    content."""
    v, k = 97, 4
    vals, idx = _topk_case(11, v, k, seed=5)
    ref = np.asarray(reconstruct(vals, idx, v))
    seen = np.zeros_like(ref)
    for lo, hi, block in iter_reconstruct(vals, idx, v, row_chunk=4):
        assert block.shape[0] <= 4 and block.shape[1] == v
        seen[lo:hi] = block
    np.testing.assert_allclose(seen, ref, atol=1e-5)


def test_soft_ce_self_is_entropy():
    """CE(t||t) == H(t): distilling a model into itself gives entropy."""
    rng = np.random.default_rng(5)
    lg = jnp.asarray(rng.normal(size=(4, 30)), jnp.float32)
    p = jax.nn.softmax(lg, -1)
    ent = -jnp.mean(jnp.sum(p * jnp.log(p + 1e-30), -1))
    got = distill.soft_ce(lg, lg)
    np.testing.assert_allclose(float(got), float(ent), rtol=1e-4)
