"""Per-architecture smoke tests: reduced variant, one forward/train step on
CPU, asserting output shapes + no NaNs (the assignment's required smoke)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.launch.steps import (init_opt_state, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import build_model
from repro.models.api import input_specs

SMOKE_TRAIN = ShapeConfig("smoke", seq_len=16, global_batch=2, kind="train")

# XLA-compile-heavy reduced configs: their train/distill smokes dominate
# tier-1 wall clock, so they ride in the slow lane (run with -m slow or a
# plain unfiltered pytest; CI's fast lane deselects them).  The cheap
# representatives of each family stay in the fast lane.
SLOW_COMPILE = {"recurrentgemma-2b", "deepseek-v3-671b", "whisper-medium",
                "gemma3-27b", "xlstm-350m", "qwen3-moe-30b-a3b",
                "chameleon-34b", "deepseek-67b", "h2o-danube-3-4b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_COMPILE
            else a for a in archs]


def concrete_batch(cfg, shape, *, topk=0, seed=0):
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape, topk=topk)

    def mk(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, cfg.vocab_size, s.shape),
                               s.dtype)
        return jnp.asarray(rng.normal(size=s.shape) * 0.1, s.dtype)
    return jax.tree_util.tree_map(
        mk, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.mark.parametrize("arch", _arch_params(ASSIGNED + ["lstm-am-7khr",
                                                          "lstm-am-teacher"]))
def test_train_step_smoke(arch):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = concrete_batch(cfg, SMOKE_TRAIN)
    step = jax.jit(make_train_step(model, cfg, loss_kind="ce"))
    opt = init_opt_state(params)
    params2, opt2, metrics = step(params, opt, batch, 1e-2)
    assert jnp.isfinite(metrics["loss"]), arch
    # params actually move
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", _arch_params(ASSIGNED))
def test_distill_step_smoke(arch):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = concrete_batch(cfg, SMOKE_TRAIN, topk=5)
    step = jax.jit(make_train_step(model, cfg, loss_kind="distill_topk"))
    opt = init_opt_state(params)
    _, _, metrics = step(params, opt, batch, 1e-2)
    assert jnp.isfinite(metrics["loss"]), arch


@pytest.mark.parametrize("arch", _arch_params(ASSIGNED))
def test_decode_smoke(arch):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 16, jnp.bfloat16)
    serve = jax.jit(make_serve_step(model, cfg))
    toks = jnp.array([[1], [2]], jnp.int32)
    for _ in range(3):
        toks, logits, cache = serve(params, cache, toks)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", [
    "qwen2.5-3b",                           # rope + GQA, linear cache
    pytest.param("gemma3-27b", marks=pytest.mark.slow),        # swa ring
    pytest.param("deepseek-v3-671b", marks=pytest.mark.slow),  # mla latent
    pytest.param("recurrentgemma-2b", marks=pytest.mark.slow)])  # rglru
def test_per_row_cache_matches_scalar(arch):
    """A per-row position cache run in lockstep is bitwise-identical to
    the scalar-position cache — the shape-compatible special case the
    continuous batcher's parity rests on (ring indexing, masking and
    RoPE lookups row-indexed vs shared)."""
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 8)), jnp.int32)
    c_s = model.init_cache(2, 12, jnp.float32)
    c_r = model.init_cache(2, 12, jnp.float32, per_row=True)
    assert c_r["pos"].shape == (2,) and c_s["pos"].shape == ()
    step = jax.jit(model.decode_step)
    for t in range(8):
        lg_s, c_s = step(params, c_s, toks[:, t:t + 1])
        lg_r, c_r = step(params, c_r, toks[:, t:t + 1])
        np.testing.assert_array_equal(np.asarray(lg_r), np.asarray(lg_s))
    np.testing.assert_array_equal(np.asarray(c_r["pos"]), [8, 8])


@pytest.mark.parametrize("arch", [
    "qwen2.5-3b",                           # rope + GQA, plain pool pages
    pytest.param("gemma3-27b", marks=pytest.mark.slow),        # swa ring
    pytest.param("deepseek-v3-671b", marks=pytest.mark.slow),  # mla latent
    pytest.param("whisper-medium", marks=pytest.mark.slow)])  # enc-dec
def test_paged_cache_matches_contiguous(arch):
    """The block-table pool layout decodes like the contiguous per-row
    cache: identical cache contents at every written slot and the same
    greedy argmax at every step (logits match to fp-reassociation
    tolerance — the gather-based contraction may fuse differently)."""
    from repro.models.paging import PagedCacheConfig
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    paging = PagedCacheConfig(page_size=4, n_pages=8, max_ctx=16)
    paged = build_model(cfg, paging=paging)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 8)), jnp.int32)
    c_r = model.init_cache(2, 16, jnp.float32, per_row=True)
    c_p = paged.init_cache(2, 16, jnp.float32, per_row=True)
    if cfg.encoder is not None:
        feats = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)),
                            jnp.float32)
        c_r = model.prefill_cache(params, feats, c_r)
        c_p = paged.prefill_cache(params, feats, c_p)
    # hand each row a disjoint page run (what the serve-side allocator
    # does); page 0 stays the trash page
    c_p["pages"]["tables"] = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]],
                                         jnp.int32)
    c_p["pages"]["caps"] = jnp.asarray([16, 16], jnp.int32)
    step_r = jax.jit(model.decode_step)
    step_p = jax.jit(paged.decode_step)
    for t in range(8):
        lg_r, c_r = step_r(params, c_r, toks[:, t:t + 1])
        lg_p, c_p = step_p(params, c_p, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_r),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(lg_p).argmax(-1),
                                      np.asarray(lg_r).argmax(-1))
    np.testing.assert_array_equal(np.asarray(c_p["pos"]), [8, 8])


def test_per_row_ragged_reset_matches_solo():
    """Rows at *different* positions in one batch: row 1 is admitted
    mid-decode via reset_cache_rows and fed its own stream — each row's
    logits match its solo decode (row purity + per-row positions)."""
    cfg = reduced(get_arch("qwen2.5-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(6)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 10)), jnp.int32)
    step = jax.jit(model.decode_step)
    cache = model.init_cache(2, 16, jnp.float32, per_row=True)
    for t in range(4):                     # row 0 runs alone (row 1 junk)
        feed = jnp.stack([toks[0, t:t + 1], jnp.asarray([7], jnp.int32)])
        _, cache = step(params, cache, feed)
    cache = jax.jit(model.reset_cache_rows)(cache,
                                            jnp.asarray([False, True]))
    np.testing.assert_array_equal(np.asarray(cache["pos"]), [4, 0])
    got = {0: [], 1: []}
    for t in range(6):                     # ragged: rows 4 positions apart
        feed = jnp.stack([toks[0, 4 + t:5 + t], toks[1, t:t + 1]])
        lg, cache = step(params, cache, feed)
        got[0].append(np.asarray(lg[0, 0]))
        got[1].append(np.asarray(lg[1, 0]))
    for row, start in ((0, 4), (1, 0)):
        solo_cache = model.init_cache(1, 16, jnp.float32, per_row=True)
        ref = []
        for t in range(start + 6):
            lg, solo_cache = step(params, solo_cache,
                                  toks[row:row + 1, t:t + 1])
            ref.append(np.asarray(lg[0, 0]))
        np.testing.assert_allclose(np.stack(got[row]),
                                   np.stack(ref[start:]), atol=1e-4)


@pytest.mark.slow
def test_whisper_per_row_decode_smoke():
    """The enc-dec arch also exposes the per-row surface: positions
    advance per row and reset_cache_rows keeps the cross-attention K/V
    (encoder side) while zeroing the self-attention rows."""
    cfg = reduced(get_arch("whisper-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    enc = jnp.asarray(np.random.default_rng(7).normal(
        size=(2, 12, cfg.d_model)) * 0.1, jnp.float32)
    cache = model.init_cache(2, 12, jnp.float32, per_row=True)
    cache = model.prefill_cache(params, enc, cache)
    step = jax.jit(model.decode_step)
    toks = jnp.array([[1], [2]], jnp.int32)
    for _ in range(3):
        logits, cache = step(params, cache, toks)
        toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    assert bool(jnp.all(jnp.isfinite(logits)))
    np.testing.assert_array_equal(np.asarray(cache["pos"]), [3, 3])
    ck_before = np.asarray(cache["ck"])
    cache = model.reset_cache_rows(cache, jnp.asarray([True, False]))
    np.testing.assert_array_equal(np.asarray(cache["pos"]), [0, 3])
    np.testing.assert_array_equal(np.asarray(cache["ck"]), ck_before)
    assert float(jnp.abs(cache["k"][:, 0]).max()) == 0.0   # row 0 zeroed


@pytest.mark.parametrize("arch", [
    "qwen2.5-3b",
    pytest.param("xlstm-350m", marks=pytest.mark.slow),
    pytest.param("recurrentgemma-2b", marks=pytest.mark.slow),
    pytest.param("gemma3-27b", marks=pytest.mark.slow)])
def test_decode_matches_apply(arch):
    """Strong consistency: token-by-token decode logits == full forward."""
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(3)
    s = 12
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, s)), jnp.int32)
    h, _ = model.apply(params, toks)
    full_logits = model.unembed(params, h)          # (1, S, V)
    cache = model.init_cache(1, s, jnp.float32)
    outs = []
    step = jax.jit(model.decode_step)
    for t in range(s):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=0.05, atol=0.15)


@pytest.mark.slow
def test_mla_absorbed_decode_matches_apply():
    """deepseek-v3's absorbed decode == decompressed full attention."""
    cfg = reduced(get_arch("deepseek-v3-671b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(4)
    s = 10
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, s)), jnp.int32)
    h, _ = model.apply(params, toks)
    full_logits = model.unembed(params, h)
    cache = model.init_cache(1, s, jnp.float32)
    outs = []
    step = jax.jit(model.decode_step)
    for t in range(s):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=0.05, atol=0.2)


def test_moe_aux_outputs():
    cfg = reduced(get_arch("qwen3-moe-30b-a3b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.ones((2, 16), jnp.int32)
    _, aux = model.apply(params, toks)
    lb = [v for k, v in aux.items() if k.endswith("moe_lb_loss")]
    assert lb and all(jnp.isfinite(v) for v in lb)
    # load-balance loss >= 1 for any router (equality at perfect balance)
    assert all(float(v) > 0.5 for v in lb)


@pytest.mark.slow
def test_whisper_encdec_shapes():
    cfg = reduced(get_arch("whisper-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    enc = jnp.zeros((2, 24, cfg.d_model), jnp.float32)
    toks = jnp.ones((2, 8), jnp.int32)
    h, _ = model.apply(params, toks, enc_embeds=enc)
    assert h.shape == (2, 8, cfg.d_model)
    logits = model.unembed(params, h)
    assert logits.shape == (2, 8, cfg.vocab_size)
