"""repro.runtime (ISSUE 7): env bootstrap ordering, cluster launch
no-op/parsing, process primitives (locks, heartbeats, crash points),
shared-ledger stale-claim stealing, and the real multi-process
generation fleet — two OS processes racing the ledger produce a
manifest bitwise-identical to the in-process path, and survive a
SIGKILL mid-range."""
import json
import os
import signal
import subprocess
import sys
import textwrap
import time
import warnings

import numpy as np
import pytest

from repro.pipeline.generate import (WorkLedger, generate_sharded,
                                     shard_ranges)
from repro.runtime import cluster, env, procs
from repro.store import LogitStoreV2

K, V = 4, 30


def _batches(n=7, b=2, t=5, f=8, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        out.append({
            "feats": rng.normal(size=(b, t, f)).astype(np.float32),
            "mask": np.ones((b, t), np.float32)})
    return out


PROBE = "repro.runtime.workers:linear_probe_engine"
PROBE_KW = {"k": K, "vocab": V, "seed": 3}


# ================================================================== env

def test_compose_xla_flags_idempotent_and_preserving():
    cfg = env.EnvConfig(host_device_count=8)
    once = env.compose_xla_flags("--some_other_flag=keep", cfg)
    assert "--some_other_flag=keep" in once
    assert "--xla_force_host_platform_device_count=8" in once
    twice = env.compose_xla_flags(once, cfg)
    assert twice == once                          # replace, not duplicate
    # a changed count replaces the old spelling in place
    re8to4 = env.compose_xla_flags(once, env.EnvConfig(host_device_count=4))
    assert re8to4.count("--xla_force_host_platform_device_count") == 1
    assert "=4" in re8to4 and "=8" not in re8to4


def test_bootstrap_writes_environ_dict():
    e = {}
    cfg = env.bootstrap(host_device_count=8, platform="gpu",
                        enable_x64=True, environ=e)
    assert cfg.host_device_count == 8
    assert env.forced_host_device_count(e) == 8
    assert e["JAX_PLATFORMS"] == "gpu"
    assert e["JAX_ENABLE_X64"] == "1"
    for flag in env.GPU_XLA_FLAGS:                # overlap flags applied
        assert flag in e["XLA_FLAGS"]


def test_bootstrap_cpu_skips_gpu_flags():
    e = {}
    env.bootstrap(host_device_count=2, platform="cpu", environ=e)
    assert "--xla_gpu" not in e["XLA_FLAGS"]


def test_bootstrap_after_jax_import_warns(monkeypatch):
    # jax is long imported in the test process: flag changes can't land.
    monkeypatch.setenv("XLA_FLAGS", "")           # restored on teardown
    assert "jax" in sys.modules
    with pytest.warns(RuntimeWarning, match="already imported"):
        env.bootstrap(host_device_count=4)


def test_envconfig_from_env_parsing():
    cfg = env.EnvConfig.from_env({
        "REPRO_HOST_DEVICES": "8", "REPRO_PLATFORM": "GPU",
        "REPRO_X64": "1", "REPRO_DEBUG_NANS": "no",
        "REPRO_XLA_FLAGS": "--a=1 --b=2"})
    assert cfg.host_device_count == 8
    assert cfg.platform == "gpu"
    assert cfg.enable_x64 is True
    assert cfg.debug_nans is False
    assert cfg.preallocate is None                # unset stays neutral
    assert cfg.extra_xla_flags == ("--a=1", "--b=2")
    neutral = env.EnvConfig.from_env({})
    assert neutral == env.EnvConfig()


def test_forced_host_device_count_unforced():
    assert env.forced_host_device_count({}) == 0
    assert env.forced_host_device_count({"XLA_FLAGS": "--other=1"}) == 0


def test_describe_snapshot_keys(tmp_path):
    snap = env.save_describe(str(tmp_path / "env.json"))
    with open(tmp_path / "env.json") as f:
        assert json.load(f) == snap
    for key in ("jax_version", "backend", "device_count", "devices",
                "process_index", "process_count", "forced_host_devices",
                "xla_flags", "python", "pid"):
        assert key in snap, key
    assert snap["device_count"] == len(snap["devices"])


@pytest.mark.slow
def test_bootstrap_forces_device_count_in_fresh_interpreter():
    """The whole point of the subsystem: bootstrap *before* the first
    jax import yields a real N-device host-platform mesh."""
    code = textwrap.dedent("""
        from repro.runtime.env import bootstrap
        bootstrap(host_device_count=4)
        import jax
        assert len(jax.devices()) == 4, jax.devices()
        print("DEVICES", len(jax.devices()))
    """)
    ev = dict(procs.child_env())
    ev.pop("XLA_FLAGS", None)                     # a clean slate
    out = subprocess.run([sys.executable, "-c", code], env=ev,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "DEVICES 4" in out.stdout


# ============================================================== cluster

def test_widest_divisor():
    assert cluster.widest_divisor(16, 8) == 8
    assert cluster.widest_divisor(16, 5) == 4
    assert cluster.widest_divisor(7, 8) == 7
    assert cluster.widest_divisor(7, 3) == 1      # prime > devices
    assert cluster.widest_divisor(1, 64) == 1
    with pytest.raises(ValueError):
        cluster.widest_divisor(0, 8)


def test_worker_mesh_divides_worker_count():
    import jax
    for w in (1, 2, 3, 4, 16):
        mesh = cluster.worker_mesh(w)
        size = mesh.devices.size
        assert w % size == 0
        assert size <= len(jax.devices())
        assert mesh.axis_names == ("data",)


def test_topology_mesh_names():
    assert cluster.topology_mesh("gtc-16").axis_names == ("data",)
    with pytest.raises(KeyError):
        cluster.topology_mesh("bmuf-1024")


def test_cluster_config_from_spec():
    cfg = cluster.ClusterConfig.from_spec("host0:1234, 4, 2")
    assert cfg == cluster.ClusterConfig("host0:1234", 4, 2)
    env_cfg = cluster.ClusterConfig.from_spec(
        "env", environ={"REPRO_COORDINATOR": "c:1", "JAX_NUM_PROCESSES": "3",
                        "REPRO_PROCESS_ID": "1"})
    assert env_cfg == cluster.ClusterConfig("c:1", 3, 1)
    # REPRO_* wins over JAX_* when both are set
    both = cluster.ClusterConfig.from_env(
        {"REPRO_NUM_PROCESSES": "2", "JAX_NUM_PROCESSES": "9",
         "REPRO_COORDINATOR": "c:1"})
    assert both.num_processes == 2
    with pytest.raises(ValueError):
        cluster.ClusterConfig.from_spec("host:1,2")


def test_cluster_config_validate():
    cluster.ClusterConfig().validate()            # single-process: fine
    with pytest.raises(ValueError, match="coordinator"):
        cluster.ClusterConfig(num_processes=2).validate()
    with pytest.raises(ValueError, match="process_id"):
        cluster.ClusterConfig("c:1", 2, 5).validate()


def test_initialize_single_process_noop_and_idempotent():
    cluster._reset_for_tests()
    try:
        info = cluster.initialize(cluster.ClusterConfig())
        assert info == cluster.ClusterInfo(False, 0, 1)
        assert info.is_coordinator
        assert not info.initialized               # jax.distributed untouched
        # idempotent: a second call (even with a different cfg) returns
        # the recorded info instead of re-initializing
        again = cluster.initialize(
            cluster.ClusterConfig("c:1", 2, 1))
        assert again is info
        assert cluster.active() is info
    finally:
        cluster._reset_for_tests()


# ================================================================ procs

def test_file_lock_excludes_second_holder(tmp_path):
    lock = str(tmp_path / "x.lock")
    with procs.file_lock(lock):
        with pytest.raises(TimeoutError):
            with procs.file_lock(lock, timeout_s=0.2, poll_s=0.02):
                pass
    with procs.file_lock(lock, timeout_s=0.2):    # released: re-acquirable
        pass


def test_heartbeat_thread_and_age(tmp_path):
    hb = str(tmp_path / "hb")
    assert procs.heartbeat_age(hb, "w") is None   # never beat
    with procs.Heartbeat(hb, "w", interval_s=0.05):
        # first beat is synchronous in start()
        age0 = procs.heartbeat_age(hb, "w")
        assert age0 is not None and age0 < 1.0
        time.sleep(0.2)
    path = procs.heartbeat_path(hb, "w")
    past = time.time() - 60
    os.utime(path, (past, past))                  # silence the dead owner
    assert procs.heartbeat_age(hb, "w") > 30


def test_crash_point_disarmed_and_armed(tmp_path):
    cp = procs.CrashPoint(after=None)             # production default
    for _ in range(100):
        cp.tick()
    # armed: the (after+1)-th tick SIGKILLs — prove it on a subprocess
    code = ("from repro.runtime.procs import CrashPoint\n"
            "cp = CrashPoint(after=1)\n"
            "cp.tick(); print('one', flush=True)\n"
            "cp.tick()\n"
            "print('unreachable', flush=True)\n")
    out = subprocess.run([sys.executable, "-c", code],
                         env=procs.child_env(), capture_output=True,
                         text=True, timeout=60)
    assert out.returncode == -signal.SIGKILL
    assert "one" in out.stdout and "unreachable" not in out.stdout


# ===================================================== shared-mode ledger

def _open_shared(tmp_path, n=4):
    path = str(tmp_path / "ledger.json")
    return WorkLedger.open(path, shard_ranges(8, n))


def test_reclaim_stale_by_heartbeat_age(tmp_path):
    led = _open_shared(tmp_path)
    procs.beat(led.heartbeat_dir, "a")
    claim = led.claim_shared("a")
    assert claim is not None
    # fresh heartbeat: nothing to steal
    assert led.reclaim_stale(max_age_s=5.0) == []
    # age the heartbeat past the timeout: the claim comes back
    hb = procs.heartbeat_path(led.heartbeat_dir, "a")
    past = time.time() - 60
    os.utime(hb, (past, past))
    stolen = led.reclaim_stale(max_age_s=5.0)
    assert [(r.lo, r.hi) for r in stolen] == [(claim.lo, claim.hi)]
    led.refresh()
    assert led.ranges[0].status == "pending"
    # the range is claimable again by a rival
    assert led.claim_shared("b") is not None


def test_reclaim_stale_never_beat_ages_the_claim(tmp_path):
    """A worker that died before its first beat has no heartbeat file:
    the claim's own timestamp ages it into stealability."""
    led = _open_shared(tmp_path)
    led.claim_shared("ghost")                     # no beat ever
    assert led.reclaim_stale(max_age_s=5.0) == []           # too young
    stolen = led.reclaim_stale(max_age_s=5.0, now=time.time() + 60)
    assert len(stolen) == 1


def test_reclaim_stale_owner_fast_path(tmp_path):
    """The supervisor's dead-child path: reclaim by exact owner, no
    heartbeat-age wait — and other owners' fresh claims are untouched."""
    led = _open_shared(tmp_path)
    procs.beat(led.heartbeat_dir, "dead")
    procs.beat(led.heartbeat_dir, "live")
    led.claim_shared("dead")
    keep = led.claim_shared("live")
    stolen = led.reclaim_stale(max_age_s=0.0, owners=["dead"])
    assert len(stolen) == 1 and stolen[0].owner == "dead"
    led.refresh()
    by_range = {(r.lo, r.hi): r for r in led.ranges}
    assert by_range[(keep.lo, keep.hi)].status == "claimed"
    assert by_range[(keep.lo, keep.hi)].owner == "live"


def test_mark_done_shared_idempotent_and_strict(tmp_path):
    led = _open_shared(tmp_path)
    claim = led.claim_shared("a")
    led.mark_done_shared(claim)
    led.mark_done_shared(claim)                   # stolen-and-finished twice
    led.refresh()
    assert led.n_done == 1
    from repro.pipeline.generate import WorkRange
    with pytest.raises(ValueError):
        led.mark_done_shared(WorkRange(100, 200))


def test_two_processes_race_claims_disjointly(tmp_path):
    """Two real OS processes hammer claim_shared on one ledger file:
    every range is claimed exactly once across both (the flock
    serializes the read-modify-write)."""
    path = str(tmp_path / "ledger.json")
    WorkLedger.open(path, shard_ranges(12, 12))
    code = textwrap.dedent("""
        import json, sys
        from repro.pipeline.generate import WorkLedger
        led = WorkLedger.attach(sys.argv[1])
        owner, out = sys.argv[2], []
        while True:
            c = led.claim_shared(owner)
            if c is None:
                break
            out.append([c.lo, c.hi])
            led.mark_done_shared(c)
        json.dump(out, open(sys.argv[3], "w"))
    """)
    ps = [subprocess.Popen(
        [sys.executable, "-c", code, path, f"p{i}",
         str(tmp_path / f"claims{i}.json")],
        env=procs.child_env()) for i in range(2)]
    for p in ps:
        assert p.wait(timeout=60) == 0
    claims = []
    for i in range(2):
        with open(tmp_path / f"claims{i}.json") as f:
            claims.append([tuple(c) for c in json.load(f)])
    merged = sorted(claims[0] + claims[1])
    assert merged == shard_ranges(12, 12)         # disjoint and complete
    led = WorkLedger.attach(path)
    assert led.all_done


# ==================================================== the process fleet

def _reference_manifest(tmp_path, batches):
    """The in-process manifest the fleet must reproduce byte-for-byte."""
    store = LogitStoreV2(str(tmp_path / "ref"), k=K, vocab=V)
    generate_sharded(PROBE, batches, store, n_workers=2,
                     engine_kwargs=PROBE_KW)
    with open(os.path.join(store.root, "manifest.json"), "rb") as f:
        return f.read()


def test_two_process_generation_bitwise_manifest(tmp_path):
    """generate_sharded(processes=2): two real worker processes race the
    ledger and the resulting manifest is bitwise identical to the
    in-process path."""
    batches = _batches(7)
    ref = _reference_manifest(tmp_path, batches)

    store = LogitStoreV2(str(tmp_path / "fleet"), k=K, vocab=V)
    rep = generate_sharded(PROBE, batches, store, n_workers=2,
                           engine_kwargs=PROBE_KW, processes=2,
                           supervisor_opts={"timeout_s": 90.0})
    assert rep["n_written"] == 7 and rep["processes"] == 2
    with open(os.path.join(store.root, "manifest.json"), "rb") as f:
        assert f.read() == ref
    assert store.verify() == 7                    # checksums intact
    assert store.gc() == []                       # no orphans left behind


def test_sigkill_mid_range_survivor_completes(tmp_path):
    """Chaos pin: worker 0 is SIGKILLed after its first shard write
    (mid-range, holding a claim).  The supervisor reclaims by owner,
    respawns, and the wave completes — with the manifest still bitwise
    identical to the in-process reference."""
    batches = _batches(8)
    ref = _reference_manifest(tmp_path, batches)

    store = LogitStoreV2(str(tmp_path / "fleet"), k=K, vocab=V)
    rep = generate_sharded(
        PROBE, batches, store, n_workers=2, engine_kwargs=PROBE_KW,
        processes=2, crash={"worker": 0, "after_shards": 1},
        supervisor_opts={"heartbeat_timeout_s": 1.0, "timeout_s": 90.0})
    assert rep["restarts"] >= 1                   # a replacement spawned
    assert rep["reclaimed"] >= 1                  # the orphaned claim stolen
    assert rep["n_written"] == 8
    with open(os.path.join(store.root, "manifest.json"), "rb") as f:
        assert f.read() == ref
    assert store.verify() == 8
    assert store.gc() == []


def test_processes_requires_engine_spec(tmp_path):
    store = LogitStoreV2(str(tmp_path / "s"), k=K, vocab=V)
    with pytest.raises(ValueError, match="module:function"):
        generate_sharded(lambda w: None, _batches(2), store, processes=2)


def test_save_load_batches_roundtrip(tmp_path):
    from repro.runtime.workers import load_batches, save_batches
    batches = _batches(3)
    path = save_batches(str(tmp_path / "b.npz"), batches)
    back = load_batches(path)
    assert len(back) == 3
    for a, b in zip(batches, back):
        assert sorted(a) == sorted(b)
        for key in a:
            np.testing.assert_array_equal(a[key], b[key])


def test_worker_blind_engine_factory():
    """The determinism precondition for claim stealing: the probe
    engine's output is identical no matter which worker built it."""
    from repro.runtime.workers import linear_probe_engine
    batch = _batches(1)[0]
    v0, i0 = linear_probe_engine(0, PROBE_KW).forward_topk(batch)
    v7, i7 = linear_probe_engine(7, PROBE_KW).forward_topk(batch)
    np.testing.assert_array_equal(v0, v7)
    np.testing.assert_array_equal(i0, i7)
