"""Sharded teacher target generation (paper §3.2: "parallelize target
generation").

The corpus is partitioned into contiguous shard ranges, one range claim
at a time: each worker runs its own ``StreamingEngine`` (an engine per
mesh slice or process) and writes its claimed range's shards into the
manifest — ranges are disjoint, so workers never contend on a shard id,
and the store's per-shard commit keeps the manifest consistent no
matter the interleaving.

Progress is tracked in a resumable **work ledger** (JSON next to the
store): a range is pending -> claimed -> done, the file is rewritten
atomically on every transition, and claims left behind by a killed
worker demote back to pending when the ledger is reopened — a fresh
invocation re-claims exactly the unfinished ranges.  Shard contents are
deterministic, so re-running a half-finished range rewrites its shards
idempotently.

At laptop scale the "workers" run round-robin inside one process; the
claim/ledger protocol is identical to what N real processes against a
shared filesystem would execute — and ``generate_sharded(processes=N)``
actually executes it that way, spawning N OS processes through
``repro.runtime.workers`` that race ``claim_shared`` (an
``fcntl``-locked read-modify-write) on the same ledger file, with
heartbeat files and stale-claim stealing for hung or killed workers.
``TeacherRunner.generate_to_store`` and ``generate_corpus_to_store``
(repro.core.teacher) are thin single-worker special cases of the
helpers here.
"""
from __future__ import annotations

import importlib
import json
import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.runtime.procs import file_lock, heartbeat_age


def shard_ranges(n_items: int, n_workers: int) -> List[Tuple[int, int]]:
    """Partition [0, n_items) into n_workers contiguous [lo, hi) ranges
    (the first ``n_items % n_workers`` ranges get the extra item)."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    base, extra = divmod(n_items, n_workers)
    ranges, lo = [], 0
    for w in range(n_workers):
        hi = lo + base + (1 if w < extra else 0)
        if hi > lo:
            ranges.append((lo, hi))
        lo = hi
    return ranges


@dataclass
class WorkRange:
    lo: int
    hi: int
    status: str = "pending"          # pending | claimed | done
    owner: Optional[str] = None
    claim_ts: Optional[float] = None  # wall time of the claim (shared mode)


class WorkLedger:
    """Resumable range ledger with atomic on-disk transitions.

    ``open`` on an existing file demotes stale "claimed" entries back to
    "pending" — any claim in a freshly-loaded ledger belongs to a dead
    worker by definition (live claims exist only in the process that
    made them).  "done" survives reopen: that is the resume contract.

    **Shared (multi-process) mode**: N processes race the same ledger
    file through ``claim_shared`` / ``mark_done_shared`` — each is an
    ``fcntl``-locked reload-modify-save, so claims serialize across
    processes on a shared filesystem.  Workers join via :meth:`attach`
    (NO reopen-time demotion — other processes' claims are live, not
    stale); liveness is instead tracked by heartbeat files
    (``repro.runtime.procs``) and :meth:`reclaim_stale` steals claims
    whose owner's heartbeat has gone quiet — covering *hung* workers,
    which never reopen anything, as well as dead ones.  Stealing is
    safe because shard contents are deterministic and commits
    idempotent: if a presumed-dead worker wakes up and finishes, it
    rewrites byte-identical shards and its ``mark_done_shared`` is a
    no-op on an already-done range.
    """

    def __init__(self, path: str, ranges: List[WorkRange], *, wave: int = 0):
        self.path = path
        self.ranges = ranges
        self.wave = wave
        # structured steal log (this process's sweeps only — events are
        # observability, not shared state; see reclaim_stale)
        self.events: List[dict] = []

    # ------------------------------------------------------------ open/io

    @classmethod
    def open(cls, path: str, ranges: Sequence[Tuple[int, int]], *,
             wave: int = 0) -> "WorkLedger":
        if os.path.exists(path):
            with open(path) as f:
                d = json.load(f)
            stored = [(r["lo"], r["hi"]) for r in d["ranges"]]
            if stored != [tuple(r) for r in ranges]:
                raise ValueError(
                    f"ledger {path} partitions {stored}, caller wants "
                    f"{list(ranges)} — delete the ledger to repartition")
            led = cls(path, [WorkRange(r["lo"], r["hi"],
                                       "pending" if r["status"] == "claimed"
                                       else r["status"], None)
                             for r in d["ranges"]],
                      wave=int(d.get("wave", wave)))
        else:
            led = cls(path, [WorkRange(lo, hi) for lo, hi in ranges],
                      wave=wave)
        led._save()
        return led

    @classmethod
    def attach(cls, path: str) -> "WorkLedger":
        """Join an existing ledger as one of several live processes:
        load as-is — no demotion (other workers' claims are live), no
        partition check (the supervisor already wrote the partition),
        no save (attaching must not race a writer)."""
        with open(path) as f:
            d = json.load(f)
        return cls(path,
                   [WorkRange(r["lo"], r["hi"], r["status"],
                              r.get("owner"), r.get("claim_ts"))
                    for r in d["ranges"]],
                   wave=int(d.get("wave", 0)))

    @classmethod
    def fresh(cls, path: str, ranges: Sequence[Tuple[int, int]], *,
              wave: int = 0) -> "WorkLedger":
        """Start over (new generation wave): forget any previous ledger."""
        if os.path.exists(path):
            os.remove(path)
        return cls.open(path, ranges, wave=wave)

    def _save(self):
        payload = {"wave": self.wave,
                   "ranges": [{"lo": r.lo, "hi": r.hi, "status": r.status,
                               "owner": r.owner, "claim_ts": r.claim_ts}
                              for r in self.ranges]}
        tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1)
            f.flush()
            os.fsync(f.fileno())    # the crash-resume record must itself
        os.replace(tmp, self.path)  # survive a crash (as manifest.save)

    @classmethod
    def peek_all_done(cls, path: str) -> bool:
        """Is the ledger at `path` a *completed* pass?  False for a
        missing or unreadable file — used to decide fresh-vs-resume
        before the partition check (a completed pass may be freshly
        repartitioned; an unfinished one must keep its ranges)."""
        try:
            with open(path) as f:
                d = json.load(f)
            return bool(d["ranges"]) and all(
                r["status"] == "done" for r in d["ranges"])
        except (OSError, ValueError, KeyError):
            return False

    # ------------------------------------------------------- transitions

    def claim(self, owner: str) -> Optional[WorkRange]:
        """Claim the next pending range for `owner` (None when none left).
        Committed to disk before returning, so a worker killed mid-range
        leaves a visible "claimed" entry for the next run to demote."""
        for r in self.ranges:
            if r.status == "pending":
                r.status, r.owner = "claimed", owner
                self._save()
                return r
        return None

    def mark_done(self, rng: WorkRange):
        rng.status, rng.owner = "done", None
        self._save()

    # --------------------------------------- shared (multi-process) mode

    @property
    def lock_path(self) -> str:
        return self.path + ".lock"

    @property
    def heartbeat_dir(self) -> str:
        return os.path.join(os.path.dirname(self.path) or ".",
                            "heartbeats")

    def _reload(self):
        """Adopt the on-disk state (caller holds the lock)."""
        with open(self.path) as f:
            d = json.load(f)
        self.ranges = [WorkRange(r["lo"], r["hi"], r["status"],
                                 r.get("owner"), r.get("claim_ts"))
                       for r in d["ranges"]]
        self.wave = int(d.get("wave", self.wave))

    def claim_shared(self, owner: str) -> Optional[WorkRange]:
        """Multi-process claim: locked reload -> first pending ->
        claimed(owner, now) -> save.  Two processes racing this see
        serialized ledgers and can never claim the same range."""
        with file_lock(self.lock_path):
            self._reload()
            for r in self.ranges:
                if r.status == "pending":
                    r.status, r.owner = "claimed", owner
                    r.claim_ts = time.time()
                    self._save()
                    return r
        return None

    def mark_done_shared(self, rng: WorkRange):
        """Locked done-transition, matched by (lo, hi) against the
        reloaded state.  Idempotent: an already-done range (a stolen
        claim the original owner also finished) stays done."""
        with file_lock(self.lock_path):
            self._reload()
            for r in self.ranges:
                if (r.lo, r.hi) == (rng.lo, rng.hi):
                    r.status, r.owner, r.claim_ts = "done", None, None
                    self._save()
                    return
        raise ValueError(f"range ({rng.lo}, {rng.hi}) not in ledger")

    def reclaim_stale(self, *, max_age_s: float,
                      owners: Optional[Sequence[str]] = None,
                      now: Optional[float] = None,
                      claim_timeout_s: Optional[float] = None
                      ) -> List[WorkRange]:
        """Steal claims from quiet owners (the heartbeat-age contract).

        A claimed range demotes back to pending when its owner's
        heartbeat file is older than ``max_age_s`` — or was never
        written, with the claim itself older than ``max_age_s`` (died
        before the first beat).  ``claim_timeout_s`` adds a second
        staleness signal: the claim's *own* age.  A worker whose beat
        thread outlives its hung main loop (it died between beat and
        claim progress) keeps a fresh heartbeat forever and the
        heartbeat path alone never steals from it; with a claim timeout
        the claim is stolen by age regardless.  Safe because done
        transitions are idempotent — a resurrected owner finishing a
        stolen range is a no-op.  ``owners`` narrows the sweep to known
        casualties (the supervisor passes a dead child's owner id for
        immediate reclaim without waiting out the heartbeat timeout).
        Returns the ranges stolen; each steal is also appended to
        ``self.events`` as a structured record (who stole what from
        whom, which signal fired, how old).
        """
        now = time.time() if now is None else now
        stolen: List[WorkRange] = []
        events: List[dict] = []
        with file_lock(self.lock_path):
            self._reload()
            for r in self.ranges:
                if r.status != "claimed" or r.owner is None:
                    continue
                if owners is not None:
                    if r.owner not in owners:
                        continue
                    mode, age = "owner", None
                else:
                    age = heartbeat_age(self.heartbeat_dir, r.owner,
                                        now=now)
                    if age is None:         # never beat: age the claim
                        age = now - (r.claim_ts or 0.0)
                        mode = "never_beat"
                    else:
                        mode = "hb_age"
                    if age <= max_age_s:
                        claim_age = (None if r.claim_ts is None
                                     else now - r.claim_ts)
                        if (claim_timeout_s is not None
                                and claim_age is not None
                                and claim_age > claim_timeout_s):
                            mode, age = "claim_age", claim_age
                        else:
                            continue
                stolen.append(WorkRange(r.lo, r.hi, "claimed", r.owner,
                                        r.claim_ts))
                events.append({"event": "steal", "lo": r.lo, "hi": r.hi,
                               "from": r.owner, "mode": mode,
                               "age_s": None if age is None
                               else round(float(age), 3), "t": now})
                r.status, r.owner, r.claim_ts = "pending", None, None
            if stolen:
                self._save()
        self.events.extend(events)
        return stolen

    def refresh(self):
        """Re-read the on-disk state (locked) — the supervisor's view."""
        with file_lock(self.lock_path):
            self._reload()

    # ------------------------------------------------------------ queries

    @property
    def all_done(self) -> bool:
        return all(r.status == "done" for r in self.ranges)

    @property
    def n_done(self) -> int:
        return sum(r.status == "done" for r in self.ranges)


# --------------------------------------------------------------- drivers

def _utt_lens_of(batch) -> Optional[np.ndarray]:
    mask = batch.get("mask") if isinstance(batch, dict) else None
    if mask is None:
        return None
    return np.asarray(mask).sum(axis=-1).astype(np.int32)


def resolve_engine_factory(spec: str) -> Callable:
    """``"module:function"`` -> the factory callable.  The factory
    contract (process-crossing, so it must be importable by name):
    ``factory(worker_id: int, kwargs: dict) -> engine`` with the engine
    exposing ``forward_topk(batch) -> (vals, idx)``."""
    mod, _, fn = spec.partition(":")
    if not mod or not fn:
        raise ValueError(f"engine spec {spec!r}: want 'module:function'")
    return getattr(importlib.import_module(mod), fn)


def prepare_ledger(store, n_items: int, n_workers: int, *,
                   ledger_path: Optional[str] = None,
                   wave: Optional[int] = None) -> WorkLedger:
    """Fresh-vs-resume wave selection shared by the in-process and
    multi-process drivers.

    A ledger with unfinished ranges is a killed run — resume it at its
    recorded wave.  Otherwise (no ledger, or a completed one) this is a
    fresh generation pass and (unless ``wave`` is forced) it supersedes
    the store's live shards at ``store.next_wave()`` — so a deleted
    ledger, a different ledger_path, or a completed re-run all start
    above the live wave instead of tripping stale-wave rejection.
    """
    ledger_path = ledger_path or os.path.join(store.root, "gen_ledger.json")
    ranges = shard_ranges(n_items, n_workers)
    fresh_wave = store.next_wave() if wave is None else wave
    if not os.path.exists(ledger_path):       # brand-new pass
        return WorkLedger.open(ledger_path, ranges, wave=fresh_wave)
    if WorkLedger.peek_all_done(ledger_path):
        # completed pass: a new wave, freely repartitionable (the old
        # partition is history — only an *unfinished* ledger pins ranges)
        return WorkLedger.fresh(ledger_path, ranges, wave=fresh_wave)
    return WorkLedger.open(ledger_path, ranges)


def generate_sharded(make_engine: Union[Callable[[int], object], str],
                     batches: Sequence[dict], store, *,
                     n_workers: int = 1, ledger_path: Optional[str] = None,
                     wave: Optional[int] = None, processes: int = 0,
                     engine_kwargs: Optional[dict] = None,
                     crash: Optional[dict] = None,
                     supervisor_opts: Optional[dict] = None) -> Dict:
    """Pre-formed dict batches -> manifest shards, partitioned over workers.

    make_engine(worker_id) -> an object with ``forward_topk(batch)``
    (a StreamingEngine or TeacherRunner); engines are created lazily,
    one per worker that actually claims work.  Shard i holds batch i's
    frames — the trainer-aligned layout ``distill_shard_source`` reads.
    ``make_engine`` may instead be a ``"module:function"`` factory spec
    (called as ``factory(worker_id, engine_kwargs)``) — required for
    the process driver, accepted in-process so both paths can run the
    byte-identical engine.

    ``processes=N`` (N >= 1) executes the SAME ledger protocol as N
    real OS processes through ``repro.runtime.workers``: a supervisor
    spawns N workers that race ``claim_shared`` on the ledger, write
    shards through locked manifest commits, and heartbeat; dead or hung
    workers have their claims stolen and the wave still completes.  The
    resulting manifest is **bitwise identical** to the in-process path
    (deterministic shard contents, same wave, sorted manifest) — pinned
    in tests.  ``crash``/``supervisor_opts`` are fault-injection and
    tuning passthroughs (see ``runtime.workers``).

    Wave selection (both drivers): see :func:`prepare_ledger`.
    """
    ledger = prepare_ledger(store, len(batches), n_workers,
                            ledger_path=ledger_path, wave=wave)
    resumed = ledger.n_done > 0

    if processes and processes >= 1:
        from repro.runtime.workers import run_supervised_generation
        if not isinstance(make_engine, str):
            raise ValueError(
                "generate_sharded(processes=N) needs a 'module:function' "
                "engine spec — a closure cannot cross a process boundary")
        rep = run_supervised_generation(
            ledger, batches, store, engine_spec=make_engine,
            engine_kwargs=engine_kwargs or {}, n_procs=processes,
            crash=crash, **(supervisor_opts or {}))
        rep.update({"n_shards": len(batches), "n_workers": n_workers,
                    "wave": ledger.wave, "resumed": resumed})
        return rep

    if isinstance(make_engine, str):
        factory = resolve_engine_factory(make_engine)
        kw = engine_kwargs or {}
        make_engine = lambda w: factory(w, kw)  # noqa: E731

    engines: Dict[int, object] = {}
    n_written = 0
    worker = 0
    while True:
        claim = ledger.claim(f"worker{worker}")
        if claim is None:
            break
        if worker not in engines:
            engines[worker] = make_engine(worker)
        eng = engines[worker]
        for i in range(claim.lo, claim.hi):
            vals, idx = eng.forward_topk(batches[i])
            store.append_shard(i, vals, idx, _utt_lens_of(batches[i]),
                               wave=ledger.wave)
            n_written += 1
        ledger.mark_done(claim)
        worker = (worker + 1) % n_workers
    assert ledger.all_done
    return {"n_shards": len(batches), "n_written": n_written,
            "n_workers": n_workers, "wave": ledger.wave,
            "resumed": resumed}


def generate_corpus(engine, store, utterances, *, shard_offset: int = 0,
                    wave_size: int = 0, store_wave: int = 0) -> List[str]:
    """The firehose path: raw (T, F) utterances -> bucketed batched
    inference -> one shard per utterance, numbered in submission order.
    Returns the shard paths (submission order).

    ``wave_size`` is the flush granularity (utterances per
    memory-bounded drain); ``store_wave`` the LogitStore generation tag
    — deliberately distinct names, because TeacherRunner's legacy
    ``wave`` argument means the former.

    ``utterances`` may be any iterable (including a generator — the
    1M-hour firehose is streamed, never materialized): work proceeds in
    waves of ``wave_size`` utterances (default: one policy batch), each
    wave's shards flushed to disk before the next is read, so host
    memory on both the input and output side stays bounded by one wave.

    Failure contract: if a wave's forward or a shard write raises, retry
    by re-running the *whole call* with the same corpus and
    shard_offset — shard contents are deterministic, so rewriting
    already-written shards is idempotent.  Each call is self-contained:
    stale work left queued by a failed call is discarded up front (its
    ordinals belong to that call's numbering).
    """
    wave_size = wave_size or engine.policy.max_batch
    engine.queue.discard_pending()
    engine.queue.pop_completed()
    it = iter(utterances)
    paths = {}
    j = 0
    while True:
        submitted = 0
        for u in it:
            engine.submit(u, meta={"ordinal": j})
            j += 1
            submitted += 1
            if submitted == wave_size:
                break
        if not submitted:
            break
        for r in engine.run().values():
            o = r.meta["ordinal"]
            paths[o] = store.append_shard(
                shard_offset + o, r.vals[None], r.idx[None],
                utt_lens=[r.vals.shape[0]], wave=store_wave)
    return [paths[o] for o in sorted(paths)]
