"""Sharded teacher target generation (paper §3.2: "parallelize target
generation").

The corpus is partitioned into contiguous shard ranges, one range claim
at a time: each worker runs its own ``StreamingEngine`` (an engine per
mesh slice or process) and writes its claimed range's shards into the
manifest — ranges are disjoint, so workers never contend on a shard id,
and the store's per-shard commit keeps the manifest consistent no
matter the interleaving.

Progress is tracked in a resumable **work ledger** (JSON next to the
store): a range is pending -> claimed -> done, the file is rewritten
atomically on every transition, and claims left behind by a killed
worker demote back to pending when the ledger is reopened — a fresh
invocation re-claims exactly the unfinished ranges.  Shard contents are
deterministic, so re-running a half-finished range rewrites its shards
idempotently.

At laptop scale the "workers" run round-robin inside one process; the
claim/ledger protocol is identical to what N real processes against a
shared filesystem would execute.  ``TeacherRunner.generate_to_store``
and ``generate_corpus_to_store`` (repro.core.teacher) are thin
single-worker special cases of the helpers here.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


def shard_ranges(n_items: int, n_workers: int) -> List[Tuple[int, int]]:
    """Partition [0, n_items) into n_workers contiguous [lo, hi) ranges
    (the first ``n_items % n_workers`` ranges get the extra item)."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    base, extra = divmod(n_items, n_workers)
    ranges, lo = [], 0
    for w in range(n_workers):
        hi = lo + base + (1 if w < extra else 0)
        if hi > lo:
            ranges.append((lo, hi))
        lo = hi
    return ranges


@dataclass
class WorkRange:
    lo: int
    hi: int
    status: str = "pending"          # pending | claimed | done
    owner: Optional[str] = None


class WorkLedger:
    """Resumable range ledger with atomic on-disk transitions.

    ``open`` on an existing file demotes stale "claimed" entries back to
    "pending" — any claim in a freshly-loaded ledger belongs to a dead
    worker by definition (live claims exist only in the process that
    made them).  "done" survives reopen: that is the resume contract.
    """

    def __init__(self, path: str, ranges: List[WorkRange], *, wave: int = 0):
        self.path = path
        self.ranges = ranges
        self.wave = wave

    # ------------------------------------------------------------ open/io

    @classmethod
    def open(cls, path: str, ranges: Sequence[Tuple[int, int]], *,
             wave: int = 0) -> "WorkLedger":
        if os.path.exists(path):
            with open(path) as f:
                d = json.load(f)
            stored = [(r["lo"], r["hi"]) for r in d["ranges"]]
            if stored != [tuple(r) for r in ranges]:
                raise ValueError(
                    f"ledger {path} partitions {stored}, caller wants "
                    f"{list(ranges)} — delete the ledger to repartition")
            led = cls(path, [WorkRange(r["lo"], r["hi"],
                                       "pending" if r["status"] == "claimed"
                                       else r["status"], None)
                             for r in d["ranges"]],
                      wave=int(d.get("wave", wave)))
        else:
            led = cls(path, [WorkRange(lo, hi) for lo, hi in ranges],
                      wave=wave)
        led._save()
        return led

    @classmethod
    def fresh(cls, path: str, ranges: Sequence[Tuple[int, int]], *,
              wave: int = 0) -> "WorkLedger":
        """Start over (new generation wave): forget any previous ledger."""
        if os.path.exists(path):
            os.remove(path)
        return cls.open(path, ranges, wave=wave)

    def _save(self):
        payload = {"wave": self.wave,
                   "ranges": [{"lo": r.lo, "hi": r.hi, "status": r.status,
                               "owner": r.owner} for r in self.ranges]}
        tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1)
            f.flush()
            os.fsync(f.fileno())    # the crash-resume record must itself
        os.replace(tmp, self.path)  # survive a crash (as manifest.save)

    @classmethod
    def peek_all_done(cls, path: str) -> bool:
        """Is the ledger at `path` a *completed* pass?  False for a
        missing or unreadable file — used to decide fresh-vs-resume
        before the partition check (a completed pass may be freshly
        repartitioned; an unfinished one must keep its ranges)."""
        try:
            with open(path) as f:
                d = json.load(f)
            return bool(d["ranges"]) and all(
                r["status"] == "done" for r in d["ranges"])
        except (OSError, ValueError, KeyError):
            return False

    # ------------------------------------------------------- transitions

    def claim(self, owner: str) -> Optional[WorkRange]:
        """Claim the next pending range for `owner` (None when none left).
        Committed to disk before returning, so a worker killed mid-range
        leaves a visible "claimed" entry for the next run to demote."""
        for r in self.ranges:
            if r.status == "pending":
                r.status, r.owner = "claimed", owner
                self._save()
                return r
        return None

    def mark_done(self, rng: WorkRange):
        rng.status, rng.owner = "done", None
        self._save()

    # ------------------------------------------------------------ queries

    @property
    def all_done(self) -> bool:
        return all(r.status == "done" for r in self.ranges)

    @property
    def n_done(self) -> int:
        return sum(r.status == "done" for r in self.ranges)


# --------------------------------------------------------------- drivers

def _utt_lens_of(batch) -> Optional[np.ndarray]:
    mask = batch.get("mask") if isinstance(batch, dict) else None
    if mask is None:
        return None
    return np.asarray(mask).sum(axis=-1).astype(np.int32)


def generate_sharded(make_engine: Callable[[int], object],
                     batches: Sequence[dict], store, *,
                     n_workers: int = 1, ledger_path: Optional[str] = None,
                     wave: Optional[int] = None) -> Dict:
    """Pre-formed dict batches -> manifest shards, partitioned over workers.

    make_engine(worker_id) -> an object with ``forward_topk(batch)``
    (a StreamingEngine or TeacherRunner); engines are created lazily,
    one per worker that actually claims work.  Shard i holds batch i's
    frames — the trainer-aligned layout ``distill_shard_source`` reads.

    Wave selection: a ledger with unfinished ranges is a killed run —
    resume it at its recorded wave.  Otherwise (no ledger, or a
    completed one) this is a fresh generation pass and (unless ``wave``
    is forced) it supersedes the store's live shards at
    ``store.next_wave()`` — so a deleted ledger, a different
    ledger_path, or a completed re-run all start above the live wave
    instead of tripping the store's stale-wave rejection.
    """
    ledger_path = ledger_path or os.path.join(store.root, "gen_ledger.json")
    ranges = shard_ranges(len(batches), n_workers)
    fresh_wave = store.next_wave() if wave is None else wave
    if not os.path.exists(ledger_path):       # brand-new pass
        ledger = WorkLedger.open(ledger_path, ranges, wave=fresh_wave)
    elif WorkLedger.peek_all_done(ledger_path):
        # completed pass: a new wave, freely repartitionable (the old
        # partition is history — only an *unfinished* ledger pins ranges)
        ledger = WorkLedger.fresh(ledger_path, ranges, wave=fresh_wave)
    else:
        ledger = WorkLedger.open(ledger_path, ranges)
    resumed = ledger.n_done > 0
    engines: Dict[int, object] = {}
    n_written = 0
    worker = 0
    while True:
        claim = ledger.claim(f"worker{worker}")
        if claim is None:
            break
        if worker not in engines:
            engines[worker] = make_engine(worker)
        eng = engines[worker]
        for i in range(claim.lo, claim.hi):
            vals, idx = eng.forward_topk(batches[i])
            store.append_shard(i, vals, idx, _utt_lens_of(batches[i]),
                               wave=ledger.wave)
            n_written += 1
        ledger.mark_done(claim)
        worker = (worker + 1) % n_workers
    assert ledger.all_done
    return {"n_shards": len(batches), "n_written": n_written,
            "n_workers": n_workers, "wave": ledger.wave,
            "resumed": resumed}


def generate_corpus(engine, store, utterances, *, shard_offset: int = 0,
                    wave_size: int = 0, store_wave: int = 0) -> List[str]:
    """The firehose path: raw (T, F) utterances -> bucketed batched
    inference -> one shard per utterance, numbered in submission order.
    Returns the shard paths (submission order).

    ``wave_size`` is the flush granularity (utterances per
    memory-bounded drain); ``store_wave`` the LogitStore generation tag
    — deliberately distinct names, because TeacherRunner's legacy
    ``wave`` argument means the former.

    ``utterances`` may be any iterable (including a generator — the
    1M-hour firehose is streamed, never materialized): work proceeds in
    waves of ``wave_size`` utterances (default: one policy batch), each
    wave's shards flushed to disk before the next is read, so host
    memory on both the input and output side stays bounded by one wave.

    Failure contract: if a wave's forward or a shard write raises, retry
    by re-running the *whole call* with the same corpus and
    shard_offset — shard contents are deterministic, so rewriting
    already-written shards is idempotent.  Each call is self-contained:
    stale work left queued by a failed call is discarded up front (its
    ordinals belong to that call's numbering).
    """
    wave_size = wave_size or engine.policy.max_batch
    engine.queue.discard_pending()
    engine.queue.pop_completed()
    it = iter(utterances)
    paths = {}
    j = 0
    while True:
        submitted = 0
        for u in it:
            engine.submit(u, meta={"ordinal": j})
            j += 1
            submitted += 1
            if submitted == wave_size:
                break
        if not submitted:
            break
        for r in engine.run().values():
            o = r.meta["ordinal"]
            paths[o] = store.append_shard(
                shard_offset + o, r.vals[None], r.idx[None],
                utt_lens=[r.vals.shape[0]], wave=store_wave)
    return [paths[o] for o in sorted(paths)]
