"""The pipeline half of the million-hour data plane.

Producers: ``generate`` partitions target generation across N workers
(engine per worker, disjoint manifest shard ranges, resumable work
ledger) — the paper's "parallelize target generation" made a first-class
subsystem over ``repro.store``.

Consumers: ``PrefetchingSource`` turns any DataSource into an async
double-buffered host->device feed for ``Trainer.fit`` (decode ahead on
a thread, ``jax.device_put`` staged, order-preserving).
"""
from repro.pipeline.generate import (WorkLedger, WorkRange,
                                     generate_corpus, generate_sharded,
                                     shard_ranges)
from repro.pipeline.prefetch import PrefetchingSource

__all__ = [
    "WorkLedger", "WorkRange", "shard_ranges",
    "generate_sharded", "generate_corpus",
    "PrefetchingSource",
]
