"""The pipeline half of the million-hour data plane.

Producers: ``generate`` partitions target generation across N workers
(engine per worker, disjoint manifest shard ranges, resumable work
ledger) — the paper's "parallelize target generation" made a first-class
subsystem over ``repro.store``.

Consumers: ``PrefetchingSource`` turns any DataSource into an async
double-buffered host->device feed for ``Trainer.fit`` (decode ahead on
a thread, ``jax.device_put`` staged, order-preserving).
"""
from repro.pipeline.generate import (WorkLedger, WorkRange,
                                     generate_corpus, generate_sharded,
                                     prepare_ledger, shard_ranges)

__all__ = [
    "WorkLedger", "WorkRange", "shard_ranges", "prepare_ledger",
    "generate_sharded", "generate_corpus",
    "PrefetchingSource",
]


def __getattr__(name):
    # lazy: PrefetchingSource stages batches with jax.device_put, but
    # the generation half of this package is numpy-only — and the
    # multi-process workers (repro.runtime.workers) import it on a
    # spawn-time budget, so the jax pull must wait for a consumer that
    # actually prefetches
    if name == "PrefetchingSource":
        from repro.pipeline.prefetch import PrefetchingSource
        return PrefetchingSource
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
