"""Async prefetching feed: decode ahead on a host thread, stage on device.

``PrefetchingSource`` wraps any DataSource (iterable of TrainBatch) so
that while the jitted update consumes batch *n*, a background thread is
already decoding batch *n+1..n+depth* (shard mmap/decompress, feature
assembly, checksum verification — whatever the wrapped source does) and
issuing its ``jax.device_put``.  JAX transfers are async, so with
depth >= 2 this is host->device double-buffering: the update never
blocks on host-side shard decode, and the H2D copy of the next batch
overlaps the current step's compute.

Determinism: one producer thread + one FIFO bounded queue — the wrapped
source's order is preserved exactly, so training through a prefetching
source is bitwise-identical to the synchronous feed (pinned by
tests/test_pipeline.py).  ``lr`` and ``loss`` ride through untouched
(Schedule objects included); only ``data`` is staged.

Lifecycle: each ``iter()`` spawns a fresh daemon producer; consumers
that stop early (Trainer.fit's ``max_updates``) call ``close()`` (the
Trainer does) or rely on the stop flag + daemon status — the producer
never blocks process exit.  A producer exception is re-raised at the
consumer's next ``__next__``, not swallowed.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterable, Iterator, Optional

import jax

# NOTE: no repro.train import here — repro.train re-exports this module,
# and the TrainBatch dataclass is handled structurally (dataclasses.replace)

_DONE = object()


class _Failure:
    def __init__(self, exc: BaseException):
        self.exc = exc


class _PrefetchIterator(Iterator):
    def __init__(self, source: Iterable, depth: int,
                 device_put: bool, skip_put: int = 0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._device_put = device_put
        self._skip_put = skip_put
        self._thread = threading.Thread(
            target=self._produce, args=(iter(source),),
            name="prefetch-producer", daemon=True)
        self._thread.start()

    def _produce(self, it):
        try:
            for n, tb in enumerate(it):
                # a resuming consumer replays-and-drops the first
                # skip_put items: don't pay their device transfer
                stage = self._device_put and n >= self._skip_put
                item = dataclasses.replace(
                    tb, data=jax.device_put(tb.data)) if stage else tb
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                else:
                    return                   # consumer closed early
            self._put_final(_DONE)
        except BaseException as e:           # surface in the consumer
            self._put_final(_Failure(e))

    def _put_final(self, item):
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        item = self._q.get()
        if item is _DONE:
            self._stop.set()        # exhausted stays exhausted: the next
            raise StopIteration     # call must not park on an empty queue
        if isinstance(item, _Failure):
            self._stop.set()
            raise item.exc
        return item

    def close(self):
        """Stop the producer and release the queue (idempotent)."""
        self._stop.set()
        while True:                          # unblock a parked producer
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=2.0)


class PrefetchingSource:
    """DataSource combinator: ``PrefetchingSource(source, depth=2)``.

    Composes with every source in ``repro.train.data`` (epoch, distill-
    shard, scheduled, chain) — anything iterable of TrainBatch.  Pass a
    zero-arg factory instead of an iterable when the source must be
    rebuilt per iteration (generators are single-shot).
    """

    def __init__(self, source, *, depth: int = 2, device_put: bool = True,
                 skip_put: int = 0):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._source = source
        self.depth = depth
        self.device_put = device_put
        # items known to be replay-skipped by the consumer (resume):
        # decoded and queued, but not staged on device
        self.skip_put = skip_put
        self._live: Optional[_PrefetchIterator] = None

    def __iter__(self) -> _PrefetchIterator:
        self.close()                 # never orphan a previous producer
        src = self._source() if callable(self._source) else self._source
        self._live = _PrefetchIterator(src, self.depth, self.device_put,
                                       self.skip_put)
        return self._live

    def close(self):
        if self._live is not None:
            self._live.close()
            self._live = None
