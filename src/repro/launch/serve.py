"""Batched serving loop: prefill + decode with a continuous request queue.

The paper's system is a training system; serving here exists because the
assigned decode shapes (decode_32k, long_500k) lower `serve_step`, and to
exercise KV-cache sharding end-to-end on CPU at reduced scale.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.launch.steps import make_serve_step
from repro.models import build_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Static-batch server: fixed B slots, per-slot request lifecycle.

    Prefill is run per-request (sequence form), decode steps are batched
    across slots — the standard static-batching serving shape; slots free
    as requests finish and are refilled from the queue.
    """

    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 max_seq: int = 256, cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.b = batch_slots
        self.max_seq = max_seq
        self.cache = self.model.init_cache(batch_slots, max_seq, cache_dtype)
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.serve = jax.jit(make_serve_step(self.model, cfg))
        self._tokens = jnp.zeros((batch_slots, 1), jnp.int32)

    def _prefill_slot(self, slot: int, req: Request):
        """Feed the prompt token-by-token through decode (slot-isolated).

        Per-slot prefill via the decode path keeps the cache layout
        identical for all slots; a production server would use the
        prefill_step + cache splice instead.
        """
        for t in req.prompt:
            tok = self._tokens.at[slot, 0].set(int(t))
            nxt, _, self.cache = self.serve(self.params, self.cache, tok)
            self._tokens = tok
        self.slots[slot] = req

    def submit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self._prefill_slot(i, req)
                return True
        return False

    def step(self):
        """One batched decode step for all active slots."""
        nxt, logits, self.cache = self.serve(self.params, self.cache,
                                             self._tokens)
        self._tokens = nxt
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            tok = int(nxt[i, 0])
            req.out.append(tok)
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None
        return nxt

    def drain(self, max_steps: int = 64):
        for _ in range(max_steps):
            if all(s is None for s in self.slots):
                break
            self.step()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    srv = BatchedServer(cfg, params, batch_slots=4, max_seq=128)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(1, cfg.vocab_size, rng.integers(3, 10)),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    pending = list(reqs)
    while pending or any(s is not None for s in srv.slots):
        while pending and srv.submit(pending[0]):
            pending.pop(0)
        srv.step()
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"[serve] {args.requests} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s)")
    for r in reqs:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
