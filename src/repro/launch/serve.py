"""Online serving entrypoint: both session types of the slot core.

All serving machinery lives in ``repro.serve`` — this module is the
CLI.  Token LMs go through ``serve.TokenServer``, streaming-capable
AMs through ``serve.StreamServer``: both are session types over the
same slot-based core (``serve.slots.SlotServer`` — mid-flight
admission, one host sync per window, SLO tiers).  Bidirectional AMs
have no streaming form and use ``StreamingEngine``'s batched path.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b
  PYTHONPATH=src python -m repro.launch.serve --arch lstm-am-7khr
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.models.api import supports_streaming
from repro.serve import (LATENCY, SLO_DEFAULT, BatchPolicy,
                         StreamingEngine, StreamServer, TokenServer)


def serve_tokens(cfg, params, *, n_requests: int = 6, max_new: int = 8,
                 policy: BatchPolicy = LATENCY, seed: int = 0):
    srv = TokenServer(cfg, params, policy=policy, max_seq=128)
    rng = np.random.default_rng(seed)
    rids = [srv.submit(rng.integers(1, cfg.vocab_size, rng.integers(3, 10)),
                       max_new=max_new) for _ in range(n_requests)]
    t0 = time.time()
    done = srv.drain()
    dt = time.time() - t0
    total = sum(len(done[r].out) for r in rids)
    st = srv.stats
    print(f"[serve] {n_requests} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s; {st['syncs']} host "
          f"syncs over {st['steps']} steps, slot occupancy "
          f"{st['active_slot_steps'] / max(st['slot_steps'], 1):.0%})")
    for r in rids:
        print(f"  req {r}: {done[r].out}")
    return done


def serve_batch(cfg, params, *, n_requests: int = 6,
                policy: BatchPolicy = LATENCY, seed: int = 0):
    """Batched full-utterance AM serving — the path for bidirectional
    models, which have no streaming form."""
    eng = StreamingEngine(cfg, params, k=10, policy=policy)
    rng = np.random.default_rng(seed)
    rids = [eng.submit(rng.normal(size=(int(rng.integers(24, 96)),
                                        cfg.feat_dim)).astype(np.float32))
            for _ in range(n_requests)]
    t0 = time.time()
    res = eng.run()
    dt = time.time() - t0
    frames = sum(res[r].vals.shape[0] for r in rids)
    print(f"[serve] {n_requests} utterances, {frames} frames batched "
          f"in {dt:.2f}s ({frames / dt:.0f} frames/s)")
    return res


def serve_stream(cfg, params, *, n_streams: int = 3, chunk: int = 16,
                 seed: int = 0):
    """Streaming AM serving on the slot core: long firehose streams
    plus interactive arrivals under SLO tiers, top-k senone posteriors
    per frame, one host sync per window."""
    srv = StreamServer(cfg, params, n_slots=n_streams, chunk_frames=chunk,
                       k=10, tiers=SLO_DEFAULT)
    rng = np.random.default_rng(seed)
    fire = [rng.normal(size=(int(rng.integers(8, 14)) * chunk,
                             cfg.feat_dim)).astype(np.float32)
            for _ in range(n_streams)]
    inter = [rng.normal(size=(chunk, cfg.feat_dim)).astype(np.float32)
             for _ in range(2)]
    t0 = time.time()
    rids = [srv.submit(u, tier="firehose") for u in fire]
    done = srv.pump()                  # firehose saturates the slots ...
    rids += [srv.submit(u, tier="interactive") for u in inter]
    done.update(srv.drain())           # ... interactive preempts it
    dt = time.time() - t0
    frames = sum(u.shape[0] for u in fire + inter)
    st = srv.stats
    print(f"[serve] {len(rids)} streams ({len(inter)} interactive), "
          f"{frames} frames in {dt:.2f}s ({frames / dt:.0f} frames/s; "
          f"{st['syncs']} host syncs over {st['steps']} steps, "
          f"{st['parked']} parks, utilization {srv.utilization():.0%})")
    for r in rids:
        v, _ = done[r].emissions()
        print(f"  stream {r} ({done[r].tier or 'default'}): "
              f"{v.shape[0]} emissions, finished sync "
              f"{done[r].finished_sync}")
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    if cfg.family == "lstm_am":
        if supports_streaming(cfg):
            serve_stream(cfg, params, n_streams=args.requests)
        else:                       # bidirectional: batch path only
            serve_batch(cfg, params, n_requests=args.requests)
    else:
        serve_tokens(cfg, params, n_requests=args.requests,
                     max_new=args.max_new)


if __name__ == "__main__":
    main()
