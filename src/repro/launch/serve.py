"""Online serving entrypoint: the unified engine under a latency policy.

All decode machinery lives in ``repro.serve`` — this module is the CLI.
Token LMs go through ``serve.TokenServer`` (slot-based continuous
batching over the per-row cache surface: ragged prefill, mid-flight
admit/retire, one host sync per decode window); the acoustic model goes
through ``serve.StreamingEngine``'s slot-based streaming path (chunked
audio with carried LSTM state, double-buffered feed).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b
  PYTHONPATH=src python -m repro.launch.serve --arch lstm-am-7khr
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.models.api import supports_streaming
from repro.serve import LATENCY, BatchPolicy, StreamingEngine, TokenServer


def serve_tokens(cfg, params, *, n_requests: int = 6, max_new: int = 8,
                 policy: BatchPolicy = LATENCY, seed: int = 0):
    srv = TokenServer(cfg, params, policy=policy, max_seq=128)
    rng = np.random.default_rng(seed)
    rids = [srv.submit(rng.integers(1, cfg.vocab_size, rng.integers(3, 10)),
                       max_new=max_new) for _ in range(n_requests)]
    t0 = time.time()
    done = srv.drain()
    dt = time.time() - t0
    total = sum(len(done[r].out) for r in rids)
    st = srv.stats
    print(f"[serve] {n_requests} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s; {st['syncs']} host "
          f"syncs over {st['steps']} steps, slot occupancy "
          f"{st['active_slot_steps'] / max(st['slot_steps'], 1):.0%})")
    for r in rids:
        print(f"  req {r}: {done[r].out}")
    return done


def serve_batch(cfg, params, *, n_requests: int = 6,
                policy: BatchPolicy = LATENCY, seed: int = 0):
    """Batched full-utterance AM serving — the path for bidirectional
    models, which have no streaming form."""
    eng = StreamingEngine(cfg, params, k=10, policy=policy)
    rng = np.random.default_rng(seed)
    rids = [eng.submit(rng.normal(size=(int(rng.integers(24, 96)),
                                        cfg.feat_dim)).astype(np.float32))
            for _ in range(n_requests)]
    t0 = time.time()
    res = eng.run()
    dt = time.time() - t0
    frames = sum(res[r].vals.shape[0] for r in rids)
    print(f"[serve] {n_requests} utterances, {frames} frames batched "
          f"in {dt:.2f}s ({frames / dt:.0f} frames/s)")
    return res


def serve_stream(cfg, params, *, n_streams: int = 3, chunk: int = 16,
                 policy: BatchPolicy = LATENCY, seed: int = 0):
    """Streaming AM serving: concurrent audio streams, chunked frames,
    top-k senone posteriors per frame."""
    eng = StreamingEngine(cfg, params, k=10, policy=policy,
                          n_slots=n_streams)
    rng = np.random.default_rng(seed)
    utts = [rng.normal(size=(int(rng.integers(2, 5)) * chunk, cfg.feat_dim)
                       ).astype(np.float32) for _ in range(n_streams)]
    sids = [eng.open_stream() for _ in range(n_streams)]
    got = {s: 0 for s in sids}

    def chunk_iter():
        # stage the next chunk while the current step computes: the
        # pipelined driver keeps one feed in flight (double buffering)
        sent = {s: 0 for s in sids}
        while True:
            chunks = {s: u[sent[s]:sent[s] + chunk]
                      for s, u in zip(sids, utts) if sent[s] < u.shape[0]}
            if not chunks:
                return
            for s, c in chunks.items():
                sent[s] += c.shape[0]
            yield chunks

    t0 = time.time()
    step = 0
    for out in eng.feed_pipelined(chunk_iter(), depth=2):
        for s, (vals, _) in out.items():
            got[s] += vals.shape[0]
        step += 1
    dt = time.time() - t0
    frames = sum(u.shape[0] for u in utts)
    print(f"[serve] {n_streams} streams, {frames} frames in {step} "
          f"batched steps, {dt:.2f}s ({frames / dt:.0f} frames/s)")
    for s in sids:
        eng.close_stream(s)
    return got


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    if cfg.family == "lstm_am":
        if supports_streaming(cfg):
            serve_stream(cfg, params, n_streams=args.requests)
        else:                       # bidirectional: batch path only
            serve_batch(cfg, params, n_requests=args.requests)
    else:
        serve_tokens(cfg, params, n_requests=args.requests,
                     max_new=args.max_new)


if __name__ == "__main__":
    main()
