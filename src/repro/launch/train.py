"""End-to-end trainer CLI — the paper's recipe on synthetic data.

Drives the full SSL pipeline (repro.core.ssl_pipeline) at laptop scale with
the exact *structure* of the 1M-hour build: baseline CE -> teacher (+sMBR)
-> teacher target generation into the logit store -> scheduled student
training (BMUF or GTC) -> student sMBR on labeled data only.

  PYTHONPATH=src python -m repro.launch.train --stage all --scale tiny
  PYTHONPATH=src python -m repro.launch.train --stage student --trainer bmuf

Every stage runs through repro.train.Trainer: a killed stage resumes
from its last periodic TrainState checkpoint on the next invocation
(pass nothing — resume is automatic; delete <out>/ckpt_<stage>/state to
force a fresh run).

For LLM archs (`--arch qwen2.5-3b --smoke`), runs a few CE steps on
synthetic token batches with the reduced config — the multi-arch smoke
path; the full-size path is the dry-run (launch/dryrun.py).
"""
from __future__ import annotations

from repro.runtime.env import bootstrap_from_env
bootstrap_from_env()
# ^ REPRO_HOST_DEVICES / REPRO_PLATFORM / ... must land in os.environ
# before the first jax import locks the XLA client config.

import argparse
import json
import os
import time

import jax
import numpy as np


def train_llm_smoke(arch: str, steps: int = 4, batch: int = 2, seq: int = 64):
    from repro.configs import get_arch, reduced
    from repro.data.loader import token_batches
    from repro.launch.steps import make_loss_fn
    from repro.models import build_model
    from repro.train import ListSink, Local, Trainer, epoch_source

    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    sink = ListSink()
    trainer = Trainer(Local(optimizer="adam"),
                      {"ce": make_loss_fn(model, cfg, "ce")}, metrics=sink)
    state = trainer.init_state(model.init(jax.random.key(0)))
    state = trainer.fit(state, epoch_source(
        lambda ep: token_batches(cfg.vocab_size, batch, seq, steps),
        1, 3e-4, "ce"))
    losses = sink.values("loss")
    for l in losses:
        print(f"  step loss={l:.4f}")
    assert np.isfinite(losses).all()
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm-am-7khr")
    ap.add_argument("--stage", default="all",
                    choices=["all", "baseline", "teacher", "targets",
                             "student", "smbr"])
    ap.add_argument("--trainer", default="gtc", choices=["gtc", "bmuf"])
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small"])
    ap.add_argument("--smoke", action="store_true",
                    help="LLM-arch reduced-config smoke run")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--gen-workers", type=int, default=None,
                    help="target-generation workers (ledgered disjoint "
                         "shard ranges; default: PipelineConfig's 2)")
    ap.add_argument("--gtc-workers", type=int, default=None,
                    help="sMBR sequence-training workers: >1 runs the "
                         "stage through GTCShardMap (int8 wire over a "
                         "mesh worker axis; default: PipelineConfig's 2)")
    ap.add_argument("--prefetch", type=int, default=None,
                    help="async feed depth for Trainer.fit "
                         "(0 = synchronous; default: PipelineConfig's 2)")
    ap.add_argument("--gen-procs", type=int, default=0,
                    help="target generation as N real OS processes "
                         "racing the shared ledger (0 = in-process; "
                         "the manifest is bitwise-identical either way)")
    ap.add_argument("--cluster", default="",
                    help="multi-host launch: 'env' (JAX_COORDINATOR_"
                         "ADDRESS/JAX_NUM_PROCESSES/JAX_PROCESS_ID or "
                         "REPRO_* equivalents) or 'host:port,N,i'; "
                         "single-process specs are a no-op")
    ap.add_argument("--out", default="experiments/train")
    args = ap.parse_args(argv)

    if args.cluster:
        from repro.runtime.cluster import ClusterConfig, initialize
        info = initialize(ClusterConfig.from_spec(args.cluster))
        print(f"[train] cluster: process {info.process_index}/"
              f"{info.process_count}"
              f"{' (coordinator)' if info.is_coordinator else ''}")

    if args.arch != "lstm-am-7khr" or args.smoke:
        print(f"[train] LLM smoke: {args.arch}")
        losses = train_llm_smoke(args.arch, steps=args.steps)
        print(f"[train] done, final loss {losses[-1]:.4f}")
        return

    from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline
    scale = {"tiny": PipelineConfig.tiny(), "small": PipelineConfig.small()}[
        args.scale]
    if args.gen_workers is not None:
        scale.gen_workers = args.gen_workers
    if args.gtc_workers is not None:
        scale.gtc_workers = args.gtc_workers
    if args.prefetch is not None:
        scale.prefetch = args.prefetch
    if args.gen_procs:
        scale.gen_procs = args.gen_procs
    pipe = SSLPipeline(scale, out_dir=args.out,
                       student_trainer=args.trainer)
    t0 = time.time()
    results = pipe.run(stage=args.stage)
    print(f"[train] stage={args.stage} done in {time.time()-t0:.1f}s")
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"train_{args.stage}.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    for k, v in results.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
