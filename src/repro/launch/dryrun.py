import os

from repro.runtime.env import bootstrap
bootstrap(host_device_count=512)
# ^ MUST precede the first jax import (jax locks device count on first
# init); runtime.env composes the flag idempotently with any existing
# XLA_FLAGS instead of blindly appending a duplicate.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) pair, lower + compile the step
function against the production mesh — 16x16=256 chips single-pod and
2x16x16=512 chips multi-pod — with ShapeDtypeStruct inputs (no
allocation), then record:

  memory_analysis()  — bytes/device: does it fit 16 GB v5e HBM
  cost_analysis()    — HLO FLOPs + bytes accessed (roofline numerator)
  collective bytes   — parsed from the post-SPMD HLO (utils/hlo.py)

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json — read by
benchmarks/roofline.py for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
  python -m repro.launch.dryrun --all --variant swa   # +swa long_500k rows
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch, get_shape, supports
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as step_lib
from repro.models import build_model
from repro.models.api import abstract_params, input_specs
from repro.utils import hlo as hlo_lib
from repro.utils.trees import map_with_path, param_count

PARAM_DTYPE = jnp.bfloat16        # storage dtype for the dry-run lowering
TOPK = 20                         # the paper's k


def _with_sharding(tree_sds, tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        tree_sds, tree_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _cast(tree_sds, dtype):
    def c(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, dtype)
        return s
    return jax.tree_util.tree_map(
        c, tree_sds, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def batch_specs_tree(batch_sds, mesh, mode="fsdp_tp"):
    """Shard every batch input's leading (global-batch) dim over
    (pod,data) — or all axes for pure-FSDP; cache entries use the cache
    policy."""
    def spec(path, s):
        if path.startswith("cache"):
            return sh.cache_spec(path.removeprefix("cache/"), s.shape, mesh)
        return sh.batch_spec(mesh, s.shape[0], extra_dims=len(s.shape) - 1,
                             mode=mode)
    return map_with_path(lambda p, a: spec(p, a), batch_sds)


def build_step(cfg, shape, *, loss_kind="distill_topk", vocab_chunk=8192,
               optimizer="momentum", shard_mode="fsdp_tp"):
    """-> (fn, example_args_fn(mesh) -> tuple of sharded SDS trees)."""
    model = build_model(cfg)
    if shape.kind == "train":
        kind = loss_kind
        if cfg.family == "lstm_am" and kind == "distill_topk":
            pass                                  # AM distills over senones
        fn = step_lib.make_train_step(model, cfg, loss_kind=kind,
                                      optimizer=optimizer,
                                      vocab_chunk=vocab_chunk)

        def args(mesh):
            params = _cast(abstract_params(cfg), PARAM_DTYPE)
            pspecs = sh.tree_param_specs(params, mesh, mode=shard_mode)
            opt = jax.eval_shape(
                lambda p: step_lib.init_opt_state(p, optimizer), params)
            ospecs = jax.tree_util.tree_map(
                lambda _: pspecs, {"x": 0})["x"]  # same structure per slot
            # opt state: momentum/adam slots mirror param specs leaf-wise
            ospecs = _opt_specs(opt, pspecs)
            batch = input_specs(cfg, shape,
                                topk=TOPK if kind == "distill_topk" else 0)
            bspecs = batch_specs_tree(batch, mesh, mode=shard_mode)
            # lr: traced replicated scalar (the lr-as-argument step)
            lr = jax.ShapeDtypeStruct(
                (), jnp.float32, sharding=NamedSharding(mesh, P()))
            return ((_with_sharding(params, pspecs, mesh),
                     _with_sharding(opt, ospecs, mesh),
                     _with_sharding(batch, bspecs, mesh), lr),
                    (pspecs, ospecs, bspecs, P()))
        return fn, args

    if shape.kind == "prefill":
        fn = step_lib.make_prefill_step(model, cfg)

        def args(mesh):
            params = _cast(abstract_params(cfg), PARAM_DTYPE)
            pspecs = sh.tree_param_specs(params, mesh, mode=shard_mode)
            batch = input_specs(cfg, shape)
            bspecs = batch_specs_tree(batch, mesh, mode=shard_mode)
            return ((_with_sharding(params, pspecs, mesh),
                     _with_sharding(batch, bspecs, mesh)),
                    (pspecs, bspecs))
        return fn, args

    # decode
    serve = step_lib.make_serve_step(model, cfg)

    def fn(params, cache, tokens):
        return serve(params, cache, tokens)

    def args(mesh):
        params = _cast(abstract_params(cfg), PARAM_DTYPE)
        pspecs = sh.tree_param_specs(params, mesh, mode=shard_mode)
        specs = input_specs(cfg, shape)
        cache, tokens = specs["cache"], specs["tokens"]
        cspecs = map_with_path(lambda p, a: sh.cache_spec(p, a.shape, mesh),
                               cache)
        tspec = sh.batch_spec(mesh, tokens.shape[0],
                              extra_dims=len(tokens.shape) - 1)
        return ((_with_sharding(params, pspecs, mesh),
                 _with_sharding(cache, cspecs, mesh),
                 jax.ShapeDtypeStruct(tokens.shape, tokens.dtype,
                                      sharding=NamedSharding(mesh, tspec))),
                (pspecs, cspecs, tspec))
    return fn, args


def _opt_specs(opt_sds, pspecs):
    """Momentum/adam state: each param-shaped slot inherits param specs;
    scalars (t) replicated."""
    def build(sub):
        if isinstance(sub, jax.ShapeDtypeStruct):
            return P()
        return None
    out = {}
    for k, v in opt_sds.items():
        if isinstance(v, jax.ShapeDtypeStruct):      # scalar like t
            out[k] = P()
        else:
            out[k] = pspecs
    return out


def _lower_compile(cfg, shape, mesh, *, loss_kind, vocab_chunk,
                   shard_mode="fsdp_tp"):
    fn, args_fn = build_step(cfg, shape, loss_kind=loss_kind,
                             vocab_chunk=vocab_chunk,
                             shard_mode=shard_mode)
    (args, _specs) = args_fn(mesh)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, donate_argnums=(0,) if shape.kind != "train"
                         else (0, 1))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               loss_kind: str = "distill_topk", donate: bool = True,
               vocab_chunk: int = 8192, extra_tag: str = "",
               out_dir: str = "experiments/dryrun", verbose: bool = True,
               probe: bool = True, shard_mode: str = "fsdp_tp",
               remat: bool = False):
    cfg = get_arch(arch)
    if remat:
        cfg = cfg.replace(remat=True)
    shape = get_shape(shape_name)
    ok, why = supports(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    # --- production artifact: scanned segments, chunked attention ---
    compiled, t_lower, t_compile = _lower_compile(
        cfg, shape, mesh, loss_kind=loss_kind, vocab_chunk=vocab_chunk,
        shard_mode=shard_mode)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # older jax: one dict per device
        cost = cost[0] if cost else {}
    txt = compiled.as_text()
    coll = hlo_lib.collective_stats(txt)
    n_dev = mesh.devices.size
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "status": "ok", "n_devices": int(n_dev),
        "tag": extra_tag,
        "loss_kind": loss_kind if shape.kind == "train" else shape.kind,
        "n_params": param_count(abstract_params(cfg)),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                         + mem.temp_size_in_bytes),
        },
        "collectives": coll.to_dict(),
        "wire_bytes_per_device": hlo_lib.wire_bytes(coll, n_dev),
    }
    # --- cost probe: unrolled segments + whole-seq attention + one vocab
    # chunk, so cost_analysis / collective parsing see every rep of every
    # op (XLA counts while-loop bodies once — configs/base.py note) ---
    if probe:
        pcfg = cfg.replace(scan_unroll=True, attn_whole_seq=True)
        try:
            pcomp, pl_, pc_ = _lower_compile(
                pcfg, shape, mesh, loss_kind=loss_kind,
                vocab_chunk=max(cfg.vocab_size, 1),
                shard_mode=shard_mode)
            pcost = pcomp.cost_analysis()
            pcoll = hlo_lib.collective_stats(pcomp.as_text())
            record["probe"] = {
                "flops": float(pcost.get("flops", 0.0)),
                "bytes_accessed": float(pcost.get("bytes accessed", 0.0)),
                "collectives": pcoll.to_dict(),
                "wire_bytes_per_device": hlo_lib.wire_bytes(pcoll, n_dev),
                "compile_s": round(pc_, 2),
            }
        except Exception as e:                     # probe is best-effort
            record["probe"] = {"error": repr(e)}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"__{extra_tag}" if extra_tag else ""
        fname = f"{arch.replace('/','_')}__{shape_name}__" \
                f"{record['mesh']}{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(record, f, indent=1)
    if verbose:
        gb = record["memory"]["peak_bytes_per_device"] / 2**30 / n_dev
        print(f"OK  {arch:20s} {shape_name:12s} {record['mesh']:8s} "
              f"compile={t_compile:6.1f}s flops={record['flops']:.3e} "
              f"coll={coll.total_bytes/2**30:8.2f}GiB", flush=True)
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default=None, choices=[None, "swa"])
    ap.add_argument("--loss", default="distill_topk",
                    choices=["ce", "distill_topk"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--probe", default="off", choices=["on", "off"],
                    help="also compile the cost probe (expensive; used "
                         "for the roofline subset)")
    ap.add_argument("--shard-mode", default="fsdp_tp",
                    choices=["fsdp_tp", "tp", "fsdp"],
                    help="param sharding policy (tp = inference TP-only)")
    ap.add_argument("--remat", action="store_true",
                    help="activation-checkpoint scanned segments")
    ap.add_argument("--tag", default="", help="artifact filename tag")
    args = ap.parse_args(argv)

    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        archs = [a for a in ARCHS if not a.startswith("lstm-am")]
        shapes = list(SHAPES)
    else:
        archs = [args.arch]
        shapes = [args.shape] if args.shape else list(SHAPES)
    if args.variant == "swa":
        archs = [a + "+swa" for a in archs]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    # cost probes only on the single-pod mesh: §Roofline is
                    # single-pod; the multipod pass proves the pod axis
                    rec = dryrun_one(arch, shape, multi_pod=mp,
                                     loss_kind=args.loss, out_dir=args.out,
                                     probe=(args.probe == "on" and not mp),
                                     shard_mode=args.shard_mode,
                                     remat=args.remat,
                                     extra_tag=args.tag)
                    if rec["status"] == "skipped":
                        print(f"SKIP {arch:20s} {shape:12s} "
                              f"{'multipod' if mp else 'pod':8s} "
                              f"({rec['reason']})", flush=True)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"FAIL {arch} {shape} "
                          f"{'multipod' if mp else 'pod'}: {e}", flush=True)
                    traceback.print_exc(limit=4)
    if failures:
        print(f"\n{len(failures)} failures"); sys.exit(1)
    print("\nall dry-runs green")


if __name__ == "__main__":
    main()
