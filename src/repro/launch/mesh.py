"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing
anything, then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod slice: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has, as a 1D data mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
