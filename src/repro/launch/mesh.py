"""Production mesh builders.

Functions, not module-level constants: importing this module never
touches jax device state.  Device *count* is the runtime layer's job —
entry points call ``repro.runtime.env.bootstrap`` (host-platform
device-count override, e.g. 512 for the dry-run) before their first
jax import, then build meshes here over whatever that produced.
Worker-axis meshes for the GTC/BMUF strategies live in
``repro.runtime.cluster.worker_mesh`` (re-exported here): the widest
1D mesh the worker count divides onto.
"""
from __future__ import annotations

import jax

from repro.runtime.cluster import worker_mesh  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod slice: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has, as a 1D data mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
