"""Jittable train / prefill / serve steps shared by the trainer, the serving
loop, and the multi-pod dry-run.

``train_step`` loss kinds:
  "ce"           — hard-label CE (baseline supervised recipe, paper §2)
  "distill_topk" — the paper's SSL objective: CE against reconstructed
                   top-k teacher logits (§3.2.2), vocab-chunked.
Both stream over vocab chunks; full (tokens x vocab) logits are never
materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import distill

MTP_WEIGHT = 0.3


def model_forward(model, cfg, params, batch):
    """Dispatch on input kind; returns (hidden, aux)."""
    if cfg.family == "lstm_am":
        return model.apply(params, batch["feats"])
    if cfg.encoder is not None:
        return model.apply(params, batch["tokens"],
                           enc_embeds=batch["enc_embeds"])
    return model.apply(params, batch["tokens"])


def make_loss_fn(model, cfg, loss_kind: str, *, vocab_chunk: int = 8192,
                 distill_kernel: bool = False):
    # the trailing ``rng`` opts into the Trainer's per-update key folding
    # (repro.train.strategies): today's forwards are deterministic so the
    # key is unused (and DCE'd), but any stochastic regularizer added to
    # a model family picks it up without touching the step plumbing
    def loss_fn(params, batch, rng=None):
        del rng
        h, aux = model_forward(model, cfg, params, batch)
        w = model.unembed_matrix(params)
        cap = cfg.logit_softcap
        mask = batch.get("mask")
        if loss_kind == "distill_topk":
            # distill_kernel: Pallas sparse_ce inner loop (grad via its
            # custom_vjp); default stays the streamed-XLA oracle
            loss = distill.chunked_topk_distill_ce(
                h, w, batch["topk_vals"], batch["topk_idx"],
                chunk=vocab_chunk, softcap=cap, mask=mask,
                use_kernel=distill_kernel)
        else:
            loss = distill.chunked_ce(h, w, batch["labels"],
                                      chunk=vocab_chunk, softcap=cap,
                                      mask=mask)
        metrics = {"loss": loss}
        # MoE auxiliary losses
        lb = sum(v for k_, v in aux.items() if k_.endswith("moe_lb_loss"))
        zl = sum(v for k_, v in aux.items() if k_.endswith("moe_z_loss"))
        if aux:
            loss = loss + cfg.router_aux_weight * lb + 1e-4 * zl
            metrics["moe_lb"] = jnp.asarray(lb)
        # multi-token prediction (deepseek-v3)
        if cfg.mtp_depth and loss_kind == "ce" and cfg.family != "lstm_am" \
                and cfg.encoder is None:
            nxt = jnp.roll(batch["tokens"], -1, axis=1)
            h2 = model.mtp_hidden(params, h, nxt,
                                  jnp.arange(batch["tokens"].shape[1]))
            if h2 is not None:
                mtp_labels = jnp.roll(batch["labels"], -1, axis=1)
                loss = loss + MTP_WEIGHT * distill.chunked_ce(
                    h2, w, mtp_labels, chunk=vocab_chunk, softcap=cap)
        metrics["total_loss"] = loss
        return loss, metrics
    return loss_fn


def make_train_step(model, cfg, *, loss_kind: str = "ce",
                    optimizer: str = "momentum", clip: float = 1.0,
                    vocab_chunk: int = 8192, distill_kernel: bool = False):
    """-> train_step(params, opt_state, batch, lr).

    lr is a *traced* argument (not baked into the closure): an LR
    schedule sweeping any number of phases reuses one executable per
    batch shape — tests/test_trainer.py pins the compile count.
    """
    from repro.train.strategies import make_sgd_step
    loss_fn = make_loss_fn(model, cfg, loss_kind, vocab_chunk=vocab_chunk,
                           distill_kernel=distill_kernel)
    return make_sgd_step(loss_fn, optimizer=optimizer, clip=clip)


def init_opt_state(params, optimizer: str = "momentum"):
    from repro.train.strategies import init_opt
    return init_opt(params, optimizer)


def make_prefill_step(model, cfg):
    """Forward over the prompt; emit last-position logits."""
    def prefill_step(params, batch):
        h, _ = model_forward(model, cfg, params, batch)
        return model.unembed(params, h[:, -1:])
    return prefill_step


def make_serve_step(model, cfg, *, greedy: bool = True,
                    use_kernel: bool = False, wide_fallback: bool = False):
    """One decode step: next-token + logits + updated cache.

    ``greedy=False`` returns a step taking an extra ``samp`` dict of
    (B,)-shaped per-row knobs (``temperature``/``top_k``/``top_p``/
    ``seed``); rows with temperature <= 0 still take bitwise argmax.
    The sampling key is derived from the *pre-step* cache position so a
    request samples identically regardless of batch composition.

    ``use_kernel=True`` routes next-token selection through the fused
    ``kernels.topk_sample`` op (one top-k extraction + Gumbel-max over
    a k_cap candidate set instead of a full-vocab argsort).  Greedy
    tokens stay bitwise identical to ``jnp.argmax``; sampled tokens
    follow the fused sampler's truncated-nucleus semantics (see
    kernels/topk_sample/ref.py), so the fused path is an explicit
    opt-in, never a silent swap.

    ``wide_fallback=True`` (fused-sampling only) builds the *mixed*
    step: rows whose ``top_k`` the k_cap candidate set can't honor
    (``top_k <= 0`` — full vocab — or ``top_k > k_cap``) take the
    full-vocab argsort sampler, bitwise what the non-kernel server
    draws; every other row keeps the fused path.  The server picks this
    step only for windows that actually hold a wide row.
    """
    if use_kernel:
        # serve/kernels packages import this module at import time;
        # keep these edges lazy and one-directional
        from repro.kernels.topk_sample import K_CAP_DEFAULT, topk_sample

    if greedy:
        def serve_step(params, cache, tokens):
            logits, cache = model.decode_step(params, cache, tokens)
            if use_kernel:
                _, _, nxt = topk_sample(logits[:, -1], greedy=True)
                nxt = nxt[:, None]
            else:
                nxt = jnp.argmax(logits[:, -1],
                                 axis=-1).astype(jnp.int32)[:, None]
            return nxt, logits, cache
        return serve_step

    if not use_kernel or wide_fallback:
        from repro.serve.sampling import sample_tokens

    def serve_step_sample(params, cache, tokens, samp):
        pos = cache["pos"]
        logits, cache = model.decode_step(params, cache, tokens)
        if use_kernel:
            _, _, nxt = topk_sample(logits[:, -1], samp["temperature"],
                                    samp["top_k"], samp["top_p"],
                                    samp["seed"], pos)
            if wide_fallback:
                wide_nxt = sample_tokens(logits[:, -1], samp["temperature"],
                                         samp["top_k"], samp["top_p"],
                                         samp["seed"], pos)
                wide = ((samp["top_k"] <= 0)
                        | (samp["top_k"] > K_CAP_DEFAULT))
                nxt = jnp.where(wide, wide_nxt, nxt)
        else:
            nxt = sample_tokens(logits[:, -1], samp["temperature"],
                                samp["top_k"], samp["top_p"], samp["seed"],
                                pos)
        return nxt[:, None], logits, cache
    return serve_step_sample
