"""Feature frontend (paper §2, faithfully):

  64-d log mel-warped energies, 10 ms hop / 25 ms window
  -> stack 3, subsample to a 30 ms advance (192-d)
  -> causal (running) mean subtraction
  -> global mean/variance normalization
  -> 3 feature offsets (0/1/2 frame start) to compensate sub-sampling.

Pure numpy: the feature pipeline is CPU-side in production too (the paper
parallelized it "over several thousand CPU cores"); jnp enters at the
trainer boundary.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.data.synthetic import SAMPLE_RATE, Utterance


@dataclass(frozen=True)
class FeatureConfig:
    n_mels: int = 64
    win_ms: float = 25.0
    hop_ms: float = 10.0
    stack: int = 3                   # frames stacked -> 30ms advance
    causal_mean_decay: float = 0.995
    n_offsets: int = 3
    fmin: float = 60.0
    fmax: float = 7600.0

    @property
    def stacked_dim(self) -> int:
        return self.n_mels * self.stack


def _hz_to_mel(f):
    return 2595.0 * np.log10(1.0 + np.asarray(f) / 700.0)


def _mel_to_hz(m):
    return 700.0 * (10.0 ** (np.asarray(m) / 2595.0) - 1.0)


def mel_filterbank(n_mels: int, n_fft: int, sr: int, fmin: float,
                   fmax: float) -> np.ndarray:
    """(n_mels, n_fft//2+1) triangular filters."""
    mels = np.linspace(_hz_to_mel(fmin), _hz_to_mel(fmax), n_mels + 2)
    freqs = _mel_to_hz(mels)
    bins = np.floor((n_fft + 1) * freqs / sr).astype(int)
    fb = np.zeros((n_mels, n_fft // 2 + 1))
    for m in range(1, n_mels + 1):
        l, c, r = bins[m - 1], bins[m], bins[m + 1]
        c = max(c, l + 1)
        r = max(r, c + 1)
        fb[m - 1, l:c] = (np.arange(l, c) - l) / (c - l)
        fb[m - 1, c:r] = (r - np.arange(c, r)) / (r - c)
    return fb


def log_mel(audio: np.ndarray, cfg: FeatureConfig) -> np.ndarray:
    """(n_samples,) -> (n_frames, n_mels) float32, 10ms frames."""
    win = int(SAMPLE_RATE * cfg.win_ms / 1000)
    hop = int(SAMPLE_RATE * cfg.hop_ms / 1000)
    n_fft = 1 << (win - 1).bit_length()
    if len(audio) < win:
        audio = np.pad(audio, (0, win - len(audio)))
    n_frames = 1 + (len(audio) - win) // hop
    idx = np.arange(win)[None, :] + hop * np.arange(n_frames)[:, None]
    frames = audio[idx] * np.hanning(win)[None, :]
    spec = np.abs(np.fft.rfft(frames, n_fft, axis=-1)) ** 2
    fb = mel_filterbank(cfg.n_mels, n_fft, SAMPLE_RATE, cfg.fmin, cfg.fmax)
    return np.log(spec @ fb.T + 1e-10).astype(np.float32)


def stack_subsample(feats: np.ndarray, cfg: FeatureConfig, offset: int = 0
                    ) -> np.ndarray:
    """(T, M) -> (T', stack*M) with a `stack`-frame advance.

    `offset` in [0, stack): which 10ms phase the stacked stream starts on —
    the paper creates features at three offsets per utterance and rotates
    through them across epochs.
    """
    t = feats.shape[0]
    n = max(0, (t - offset) // cfg.stack)
    if n == 0:
        return np.zeros((1, cfg.stacked_dim), np.float32)
    f = feats[offset: offset + n * cfg.stack]
    return f.reshape(n, cfg.stacked_dim)


def causal_mean_norm(feats: np.ndarray, decay: float,
                     init_mean: Optional[np.ndarray] = None,
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Running (causal) cepstral-mean subtraction.

    The paper sorts a speaker's utterances and *carries the running mean
    across them* instead of requiring a pre-roll — ``init_mean`` is the
    carry.  Returns (normalized, final_mean).
    """
    mean = np.zeros(feats.shape[1], np.float64) if init_mean is None \
        else init_mean.astype(np.float64).copy()
    out = np.empty_like(feats)
    # scan: mean_t = decay*mean_{t-1} + (1-decay)*x_t  (vectorized via
    # exponential weights would lose the carry; T is small per utterance)
    for t in range(feats.shape[0]):
        mean = decay * mean + (1.0 - decay) * feats[t]
        out[t] = feats[t] - mean
    return out.astype(np.float32), mean


@dataclass
class GlobalMVN:
    mean: np.ndarray
    std: np.ndarray

    @classmethod
    def estimate(cls, feat_list) -> "GlobalMVN":
        cat = np.concatenate([f.reshape(-1, f.shape[-1]) for f in feat_list])
        return cls(mean=cat.mean(0), std=cat.std(0) + 1e-5)

    def __call__(self, feats: np.ndarray) -> np.ndarray:
        return ((feats - self.mean) / self.std).astype(np.float32)


def featurize(audio: np.ndarray, cfg: FeatureConfig, *, offset: int = 0,
              mvn: Optional[GlobalMVN] = None,
              carry_mean: Optional[np.ndarray] = None,
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Full frontend for one utterance -> ((T', stack*M), carry)."""
    lm = log_mel(audio, cfg)
    lm, carry = causal_mean_norm(lm, cfg.causal_mean_decay, carry_mean)
    st = stack_subsample(lm, cfg, offset)
    if mvn is not None:
        st = mvn(st)
    return st, carry


def align_labels(senones: np.ndarray, cfg: FeatureConfig, offset: int,
                 n_out: int, lookahead: int = 0) -> np.ndarray:
    """Subsample 10ms senone alignment to the stacked 30ms frame rate.

    Label of a stacked frame = senone at its center 10ms frame, *delayed*
    by ``lookahead`` stacked frames: with a 3-frame look-ahead the model
    emits the senone of frame t once it has seen frames up to t+3, i.e.
    the target at output index t is the senone of input frame t-3.
    """
    centers = offset + cfg.stack * np.arange(n_out) + cfg.stack // 2
    centers = np.clip(centers - lookahead * cfg.stack, 0,
                      len(senones) - 1)
    return senones[centers].astype(np.int32)


def featurize_utterance(utt: Utterance, cfg: FeatureConfig, *,
                        offset: int = 0, mvn: Optional[GlobalMVN] = None,
                        carry_mean: Optional[np.ndarray] = None,
                        lookahead: int = 0):
    """-> (feats (T', D), labels (T',), carry_mean)."""
    feats, carry = featurize(utt.audio, cfg, offset=offset, mvn=mvn,
                             carry_mean=carry_mean)
    labels = align_labels(utt.senones, cfg, offset, feats.shape[0],
                          lookahead)
    return feats, labels, carry
