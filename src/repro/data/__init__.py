from repro.data.synthetic import SynthConfig, Utterance, synth_corpus, synth_utterance
from repro.data.features import FeatureConfig, featurize, featurize_utterance
from repro.data.chunking import chunk_utterances, pad_batch
from repro.data.loader import CorpusLoader, speaker_hash

__all__ = [
    "SynthConfig", "Utterance", "synth_corpus", "synth_utterance",
    "FeatureConfig", "featurize", "featurize_utterance",
    "chunk_utterances", "pad_batch", "CorpusLoader", "speaker_hash",
]
