"""Chunked BPTT batching (paper §2):

"utterances are split into smaller sub-sequence chunks (here, 32 frames)
and the sub-sequences are randomized" — greater parallelization efficiency
for the early sub-epochs; full-sequence BPTT for fine-tuning.

Chunks carry (utt_id, chunk_index) so a stateful trainer *could* thread
LSTM state; the paper resets state per chunk (that is the efficiency
trade), which is what ``chunk_utterances`` produces.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Chunk:
    feats: np.ndarray          # (chunk_len, D)
    labels: np.ndarray         # (chunk_len,)  (or top-k target rows)
    utt_id: int
    chunk_index: int
    valid: int                 # frames before padding


def chunk_utterances(feat_label_pairs: Sequence[Tuple[np.ndarray, np.ndarray, int]],
                     chunk_len: int = 32, *, rng: Optional[np.random.Generator] = None,
                     drop_last_partial: bool = False) -> List[Chunk]:
    """[(feats (T,D), labels (T,), utt_id)] -> randomized list of Chunks."""
    chunks: List[Chunk] = []
    for feats, labels, utt_id in feat_label_pairs:
        t = feats.shape[0]
        n = t // chunk_len if drop_last_partial else (t + chunk_len - 1) // chunk_len
        for ci in range(max(n, 0)):
            s = ci * chunk_len
            e = min(s + chunk_len, t)
            f = feats[s:e]
            l = labels[s:e]
            valid = e - s
            if valid < chunk_len:
                f = np.pad(f, ((0, chunk_len - valid), (0, 0)))
                l = np.pad(l, (0, chunk_len - valid))
            chunks.append(Chunk(f, l, utt_id, ci, valid))
    if rng is not None:
        rng.shuffle(chunks)
    return chunks


def batch_chunks(chunks: Sequence[Chunk], batch_size: int
                 ) -> Iterator[dict]:
    """Yield {'feats' (B,L,D), 'labels' (B,L), 'mask' (B,L)} dicts."""
    for s in range(0, len(chunks) - batch_size + 1, batch_size):
        group = chunks[s: s + batch_size]
        feats = np.stack([c.feats for c in group])
        labels = np.stack([c.labels for c in group])
        mask = np.zeros(labels.shape, np.float32)
        for i, c in enumerate(group):
            mask[i, :c.valid] = 1.0
        yield {"feats": feats, "labels": labels, "mask": mask}


def pad_batch(feat_label_pairs: Sequence[Tuple[np.ndarray, np.ndarray, int]],
              *, max_len: Optional[int] = None) -> dict:
    """Full-sequence batch: pad to the longest (or max_len) utterance."""
    t = max(f.shape[0] for f, _, _ in feat_label_pairs)
    if max_len is not None:
        t = min(t, max_len)
    b = len(feat_label_pairs)
    d = feat_label_pairs[0][0].shape[1]
    feats = np.zeros((b, t, d), np.float32)
    labels = np.zeros((b, t), np.int32)
    mask = np.zeros((b, t), np.float32)
    for i, (f, l, _) in enumerate(feat_label_pairs):
        n = min(f.shape[0], t)
        feats[i, :n] = f[:n]
        labels[i, :n] = l[:n]
        mask[i, :n] = 1.0
    return {"feats": feats, "labels": labels, "mask": mask}
