"""Corpus loader (paper §3.1):

"a feature pipeline that uses an efficient hashing mechanism to cluster
speakers and sort utterances belonging to a speaker for performing running
cepstral mean normalization. This could then be parallelized over several
thousand CPU cores."

``speaker_hash`` buckets speakers onto workers; each worker sorts its
utterances by (speaker, utt_id) and carries the causal mean across a
speaker's utterances.  No pre-roll needed — exactly the paper's trick.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.data import features as F
from repro.data.chunking import batch_chunks, chunk_utterances, pad_batch
from repro.data.synthetic import SynthConfig, Utterance, synth_utterance


def speaker_hash(speaker: int, n_buckets: int) -> int:
    """Stable speaker -> worker-bucket assignment."""
    h = hashlib.blake2b(int(speaker).to_bytes(8, "little"),
                        digest_size=8).digest()
    return int.from_bytes(h, "little") % n_buckets


@dataclass
class CorpusLoader:
    """Streams featurized batches from the (synthetic) firehose.

    One loader per worker: it draws the utterance-id range assigned to the
    worker, keeps only speakers hashing into its bucket, sorts per speaker,
    and threads the running-CMN carry across a speaker's utterances.
    """
    synth: SynthConfig
    feat: F.FeatureConfig = field(default_factory=F.FeatureConfig)
    worker: int = 0
    n_workers: int = 1
    lookahead: int = 0
    mvn: Optional[F.GlobalMVN] = None

    def estimate_mvn(self, n_utts: int = 24) -> F.GlobalMVN:
        feats = []
        for uid in range(n_utts):
            u = synth_utterance(self.synth, uid)
            f, _ = F.featurize(u.audio, self.feat)
            feats.append(f)
        self.mvn = F.GlobalMVN.estimate(feats)
        return self.mvn

    def _utts_for_range(self, start: int, count: int) -> List[Utterance]:
        mine = []
        for uid in range(start, start + count):
            u = synth_utterance(self.synth, uid)
            if speaker_hash(u.speaker, self.n_workers) == self.worker:
                mine.append(u)
        # sort utterances belonging to a speaker (running CMN order)
        mine.sort(key=lambda u: (u.speaker, u.utt_id))
        return mine

    def featurized(self, start: int, count: int, *, offset: int = 0):
        """-> [(feats, labels, utt_id)] with per-speaker CMN carry."""
        carries: Dict[int, np.ndarray] = {}
        out = []
        for u in self._utts_for_range(start, count):
            f, l, carry = F.featurize_utterance(
                u, self.feat, offset=offset, mvn=self.mvn,
                carry_mean=carries.get(u.speaker), lookahead=self.lookahead)
            carries[u.speaker] = carry
            out.append((f, l, u.utt_id))
        return out

    # ------------------------------------------------------------ batches

    def chunked_batches(self, start: int, count: int, *, batch_size: int,
                        chunk_len: int = 32, offset: int = 0,
                        seed: int = 0) -> Iterator[dict]:
        pairs = self.featurized(start, count, offset=offset)
        rng = np.random.default_rng(seed)
        chunks = chunk_utterances(pairs, chunk_len, rng=rng)
        yield from batch_chunks(chunks, batch_size)

    def full_seq_batches(self, start: int, count: int, *, batch_size: int,
                         offset: int = 0, max_len: Optional[int] = None
                         ) -> Iterator[dict]:
        pairs = self.featurized(start, count, offset=offset)
        for s in range(0, len(pairs) - batch_size + 1, batch_size):
            yield pad_batch(pairs[s: s + batch_size], max_len=max_len)


def token_batches(vocab: int, batch: int, seq: int, n_batches: int,
                  seed: int = 0) -> Iterator[dict]:
    """Synthetic token batches for the LLM-arch examples/tests."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
