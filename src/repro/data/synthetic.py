"""Deterministic synthetic far-field speech generator (DESIGN.md §6).

Stands in for the paper's production Alexa audio: per-speaker formant-like
AR processes, device/noise conditions, and frame-level senone alignments
from a synthetic left-to-right HMM.  Everything is seeded — the same
(utt_id) always produces the same audio and alignment, so the corpus can be
"streamed" at any scale without storing it (this is exactly how we emulate
a 1M-hour firehose: utterance ids are the dataset).

Acoustic recipe (cheap but structured):
  speaker  -> 3 formant center freqs + AR(2) pole radii + f0
  senone   -> per-state formant perturbation + energy envelope
  device   -> room response proxy (one-pole lowpass + echo tap) + SNR range
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np

SAMPLE_RATE = 16_000

# device placement / type distribution, loosely "similar to the labeled
# data" (paper §3.1)
DEVICES = ("near", "mid", "far", "noisy")
DEVICE_PROBS = (0.35, 0.30, 0.20, 0.15)
DEVICE_SNR_DB = {"near": (25.0, 35.0), "mid": (18.0, 28.0),
                 "far": (12.0, 22.0), "noisy": (6.0, 16.0)}


@dataclass(frozen=True)
class SynthConfig:
    n_speakers: int = 200
    n_phones: int = 42
    states_per_phone: int = 1          # low-frame-rate single-state units
    n_senones: int = 97                # clustered states (<= n_phones usually
                                       # not; senones = hashed (phone, ctx))
    mean_utt_sec: float = 2.0
    min_utt_sec: float = 0.6
    frame_ms: float = 10.0
    seed: int = 0


@dataclass
class Utterance:
    utt_id: int
    speaker: int
    device: str
    snr_db: float
    audio: np.ndarray                  # (n_samples,) float32
    senones: np.ndarray                # (n_frames,) int32, 10ms frames
    phones: np.ndarray                 # (n_phones_seq,) int32
    n_frames: int = 0

    def __post_init__(self):
        self.n_frames = len(self.senones)


def _rng(*salts: int) -> np.random.Generator:
    return np.random.default_rng(np.array(salts, np.uint64))


def _speaker_voice(speaker: int, seed: int):
    r = _rng(seed, 0xA5, speaker)
    formants = r.uniform([420, 1100, 2100], [620, 1500, 2700])
    f0 = r.uniform(90, 220)
    radius = r.uniform(0.93, 0.97)
    return formants, f0, radius


def senone_of(phone: int, left_ctx: int, n_senones: int) -> int:
    """Synthetic decision tree: deterministic hash of (phone, left context).

    Mimics triphone state clustering down to n_senones classes.
    """
    h = (phone * 1_000_003 + left_ctx * 7919 + 1) % 2_147_483_647
    return int(h % n_senones)


def synth_utterance(cfg: SynthConfig, utt_id: int) -> Utterance:
    r = _rng(cfg.seed, 0x5EED, utt_id)
    speaker = int(r.integers(cfg.n_speakers))
    device = str(r.choice(DEVICES, p=DEVICE_PROBS))
    lo, hi = DEVICE_SNR_DB[device]
    snr_db = float(r.uniform(lo, hi))

    dur = max(cfg.min_utt_sec, float(r.exponential(cfg.mean_utt_sec)))
    dur = min(dur, 4.0 * cfg.mean_utt_sec)
    n_frames = max(8, int(dur * 1000 / cfg.frame_ms))

    # phone sequence with random durations (geometric-ish, >=6 frames so
    # each senone spans >=2 stacked 30ms frames)
    phones, senones = [], []
    left = 0
    while len(senones) < n_frames:
        ph = int(r.integers(cfg.n_phones))
        d = int(np.clip(r.geometric(0.12), 6, 60))
        phones.append(ph)
        senones.extend([senone_of(ph, left, cfg.n_senones)] * d)
        left = ph
    senones = np.asarray(senones[:n_frames], np.int32)
    phones = np.asarray(phones, np.int32)

    # audio synthesis: per-frame AR filterbank excitation
    formants, f0, radius = _speaker_voice(speaker, cfg.seed)
    spf = int(SAMPLE_RATE * cfg.frame_ms / 1000)
    n = n_frames * spf
    t = np.arange(n) / SAMPLE_RATE
    # glottal-ish excitation: pulse train + noise
    exc = 0.6 * np.sign(np.sin(2 * np.pi * f0 * t)) * \
        (np.sin(2 * np.pi * f0 * t) ** 8) + 0.05 * r.standard_normal(n)
    # senone-dependent formant perturbation, piecewise constant per frame.
    # Speaker-INDEPENDENT by construction (the senone->acoustics map must
    # be consistent across speakers for the task to be learnable; speaker
    # identity enters via base formants/f0 only).  Per-senone directions
    # come from a hashed global codebook for maximal class spread.
    code = np.stack([np.random.default_rng(1000 + s).uniform(-1, 1, 3)
                     for s in range(cfg.n_senones)])
    pert = 1.0 + 0.4 * code[senones]
    sig = np.zeros(n)
    for fi in range(3):
        fr = np.repeat(formants[fi] * pert[:, fi], spf)
        # time-varying AR(2) resonator driven by exc
        w = 2 * np.pi * fr / SAMPLE_RATE
        a1 = 2 * radius * np.cos(w)
        a2 = -radius * radius
        y = np.zeros(n)
        y0 = y1 = 0.0
        # vectorize over frames: constant coefficients within a frame
        for f_ in range(n_frames):
            s0, s1 = f_ * spf, (f_ + 1) * spf
            aa1, aa2 = a1[s0], a2          # a2 is pole-radius const
            seg = exc[s0:s1]
            yy = np.empty(spf)
            for i, e in enumerate(seg):       # spf=160; fine for tests
                y2 = e + aa1 * y1 + aa2 * y0
                yy[i] = y2
                y0, y1 = y1, y2
            y[s0:s1] = yy
        sig += y / 3.0

    # senone-coded narrowband component: per-senone amplitude pattern over
    # four fixed carrier bands (formant-like spectral envelope cues).  The
    # resonator chain alone leaves too little class information after the
    # mel frontend at laptop scale; this keeps the task audio-realistic
    # (everything still flows audio -> log-mel -> model) AND learnable.
    carriers = np.array([500.0, 1100.0, 1900.0, 3100.0])
    amp_code = np.stack([np.random.default_rng(7000 + s_).uniform(0.1, 1.0, 4)
                         for s_ in range(cfg.n_senones)])
    amps = amp_code[senones]                       # (n_frames, 4)
    tone = np.zeros(n)
    for j, fc in enumerate(carriers):
        tone += np.repeat(amps[:, j], spf) * np.sin(2 * np.pi * fc * t)
    sig = sig + 0.5 * tone

    # device channel: lowpass + echo tap, then noise at the drawn SNR
    alpha = {"near": 0.1, "mid": 0.3, "far": 0.5, "noisy": 0.45}[device]
    filt = np.copy(sig)
    filt[1:] += alpha * sig[:-1]
    echo_delay = {"near": 0, "mid": 400, "far": 1200, "noisy": 800}[device]
    if echo_delay:
        filt[echo_delay:] += 0.3 * sig[:-echo_delay]
    p_sig = np.mean(filt ** 2) + 1e-12
    p_noise = p_sig / (10 ** (snr_db / 10))
    audio = filt + np.sqrt(p_noise) * r.standard_normal(n)
    audio = (audio / (np.max(np.abs(audio)) + 1e-9)).astype(np.float32)

    return Utterance(utt_id=utt_id, speaker=speaker, device=device,
                     snr_db=snr_db, audio=audio, senones=senones,
                     phones=phones)


def synth_corpus(cfg: SynthConfig, n_utts: int, *, start_id: int = 0
                 ) -> List[Utterance]:
    return [synth_utterance(cfg, start_id + i) for i in range(n_utts)]


def corpus_hours(utts: List[Utterance]) -> float:
    return sum(u.audio.shape[0] for u in utts) / SAMPLE_RATE / 3600.0
