"""DeepSeek-V3 671B: MLA attention, 1 shared + 256 routed experts top-8, MTP.

[arXiv:2412.19437] 61L d_model=7168 128H (MLA; spec lists kv=128) expert
d_ff=2048 vocab=129280. First 3 layers dense (d_ff=18432), rest MoE.
"""
from repro.configs.base import LayerSpec, MLAConfig, ModelConfig, Segment

DENSE = LayerSpec(mixer="attn", ffn="mlp")
MOE = LayerSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,              # dense layers (first 3)
    vocab_size=129_280,
    segments=(
        Segment((DENSE,), repeat=3),
        Segment((MOE,), repeat=58),
    ),
    norm="rmsnorm",
    act="silu",
    pos_emb="rope",
    rope_theta=10_000.0,
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    moe_renorm_topk=True,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_rope_head_dim=64,
                  qk_nope_head_dim=128, v_head_dim=128),
    mtp_depth=1,
)
