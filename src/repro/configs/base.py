"""Config dataclasses: model topology, input shapes, run options.

A model is a stack of *segments*; each segment is a repeating *pattern* of
LayerSpecs executed ``repeat`` times with ``jax.lax.scan`` over stacked
params (HLO size stays depth-independent). ``repeat == 1`` segments are
unrolled (used for remainder layers that don't fill a pattern group).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class LayerSpec:
    """One block = sequence mixer + channel mixer (ffn)."""
    mixer: str = "attn"      # attn | swa | rglru | mlstm | slstm | lstm | bilstm
    ffn: str = "mlp"         # mlp | moe | none
    window: int = 0          # sliding window size for mixer == "swa"


@dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSpec, ...]
    repeat: int = 1

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style bidirectional encoder (conv frontend is a stub)."""
    n_layers: int = 24
    # encoder reuses d_model/n_heads/d_ff of the parent config


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | audio | vlm | lstm_am
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Segment, ...]
    source: str = ""         # citation for the config
    head_dim: int = 0        # 0 -> d_model // n_heads
    # norm / act / embeddings
    norm: str = "rmsnorm"    # rmsnorm | layernorm
    act: str = "silu"        # silu | gelu
    pos_emb: str = "rope"    # rope | learned | none
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    emb_scale: bool = False          # gemma: scale embeddings by sqrt(d_model)
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    moe_renorm_topk: bool = True
    # MLA (deepseek-v3)
    mla: Optional[MLAConfig] = None
    # recurrent
    lru_width: int = 0               # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4              # temporal conv in recurrent blocks
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    # enc-dec (whisper)
    encoder: Optional[EncoderConfig] = None
    max_target_len: int = 448
    # lstm AM (paper baseline)
    lstm_hidden: int = 768
    n_senones: int = 3183
    feat_dim: int = 192              # 64 log-mel x3 stacked
    lookahead: int = 3
    # MTP (deepseek-v3 multi-token prediction)
    mtp_depth: int = 0
    # --- cost-probe mode (dry-run only; see launch/dryrun.py) ---
    # XLA's cost_analysis counts a while-loop body ONCE, so scanned-segment
    # and chunked-attention FLOPs/bytes/collectives are undercounted in the
    # production artifact.  The dry-run lowers a second "probe" variant with
    # these flags set: segments unrolled (Python loop over the same stacked
    # params — shardings unchanged) and attention in one whole-sequence
    # chunk (same executed FLOPs as the chunked schedule, incl. masked
    # blocks).  Never enabled for real training.
    scan_unroll: bool = False
    attn_whole_seq: bool = False
    # activation checkpointing: recompute each scanned segment group in the
    # backward pass instead of saving its activations (train-shape §Perf
    # lever for the >16GB/chip archs)
    remat: bool = False

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.segments)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def mixers(self) -> Tuple[str, ...]:
        out = []
        for s in self.segments:
            for _ in range(s.repeat):
                out.extend(spec.mixer for spec in s.pattern)
        return tuple(out)

    @property
    def subquadratic(self) -> bool:
        """True if every sequence mixer has bounded-per-token prefill cost."""
        return all(m in ("swa", "rglru", "mlstm", "slstm", "lstm", "bilstm")
                   for m in self.mixers())

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def supports(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is runnable; reason string if not."""
    if shape.name == "long_500k":
        if cfg.encoder is not None:
            return False, "enc-dec full attention; 500k context not meaningful"
        if cfg.family == "lstm_am":
            return False, "frame-synchronous hybrid AM; no autoregressive decode"
        if not cfg.subquadratic:
            # sliding-window-dominant hybrids (gemma3's 5:1 local:global)
            # run: their few global layers decode with an O(S) cache that
            # stays shardable; pure full-attention archs skip (use +swa)
            mixers = cfg.mixers()
            full = sum(m == "attn" for m in mixers)
            if full / max(len(mixers), 1) > 0.25:
                return False, ("pure full-attention arch "
                               "(use --variant swa to run)")
    if shape.kind == "decode" and cfg.family == "lstm_am":
        return False, "hybrid AM has no autoregressive decode step"
    return True, ""


def swa_variant(cfg: ModelConfig, window: int = 4096) -> ModelConfig:
    """Sliding-window variant of a full-attention arch (for long_500k)."""
    segs = tuple(
        Segment(tuple(
            dataclasses.replace(sp, mixer="swa", window=window)
            if sp.mixer == "attn" else sp for sp in s.pattern), s.repeat)
        for s in cfg.segments)
    return cfg.replace(name=cfg.name + "+swa", segments=segs)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: <=2 layers per distinct pattern element, tiny dims."""
    d_model = min(cfg.d_model, 256)
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    # keep one group of each distinct segment pattern, truncated to <=2 layers
    segs = []
    for s in cfg.segments[:2]:
        pat = s.pattern[: max(1, min(2, len(s.pattern)))]
        segs.append(Segment(pat, 1))
    mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_rope_head_dim=16,
                    qk_nope_head_dim=32, v_head_dim=32) if cfg.mla else None
    n_sen = min(cfg.n_senones, 97)
    return cfg.replace(
        name=cfg.name + "-smoke",
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=64 if cfg.head_dim else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=(n_sen if cfg.family == "lstm_am"
                    else min(cfg.vocab_size, 512)),
        segments=tuple(segs),
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_d_ff=min(cfg.moe_d_ff, 128),
        capacity_factor=4.0,     # smoke scale: no capacity drops, so
                                 # decode == apply exactly (tests rely on it)
        mla=mla,
        lru_width=min(cfg.lru_width, d_model) if cfg.lru_width else 0,
        encoder=EncoderConfig(n_layers=2) if cfg.encoder else None,
        lstm_hidden=min(cfg.lstm_hidden, 128),
        n_senones=n_sen,
        feat_dim=min(cfg.feat_dim, 48),
        max_target_len=min(cfg.max_target_len, 64),
        mtp_depth=min(cfg.mtp_depth, 1),
    )
