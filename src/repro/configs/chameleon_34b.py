"""Chameleon-34B: early-fusion mixed-modal decoder; VQ image tokens live in the
same vocab (the VQ tokenizer itself is the stubbed frontend — inputs are token
ids that may index the image-code range).

[arXiv:2405.09818] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
QK-norm for mixed-modal logit stability (per the paper).
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

B = LayerSpec(mixer="attn", ffn="mlp")

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65_536,
    segments=(Segment((B,), repeat=48),),
    norm="rmsnorm",
    act="silu",
    pos_emb="rope",
    rope_theta=10_000.0,
    qk_norm=True,
)
