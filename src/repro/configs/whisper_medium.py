"""Whisper-medium: encoder-decoder; conv/mel frontend is a stub (precomputed
frame embeddings are the encoder input, per the carve-out in DESIGN.md).

[arXiv:2212.04356] 24+24L d_model=1024 16H (MHA, kv=16) d_ff=4096 vocab=51865.
"""
from repro.configs.base import EncoderConfig, LayerSpec, ModelConfig, Segment

B = LayerSpec(mixer="attn", ffn="mlp")

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    segments=(Segment((B,), repeat=24),),   # decoder stack
    encoder=EncoderConfig(n_layers=24),
    norm="layernorm",
    act="gelu",
    pos_emb="learned",
    max_target_len=448,
)
