"""xLSTM-350M: alternating mLSTM (matrix memory, parallelizable) and sLSTM
(scalar memory, strictly recurrent) blocks.

[arXiv:2405.04517] 24L d_model=1024 4H d_ff=0 (blocks carry their own
up-projections) vocab=50304.
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

M = LayerSpec(mixer="mlstm", ffn="none")
S = LayerSpec(mixer="slstm", ffn="none")

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    source="arXiv:2405.04517",
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    # xLSTM[7:1]-ish mix: mostly mLSTM with periodic sLSTM
    segments=(Segment((M, M, M, S), repeat=6),),
    norm="layernorm",
    act="gelu",
    pos_emb="none",
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    conv_width=4,
)
