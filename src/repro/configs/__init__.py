"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs.base import (SHAPES, LayerSpec, MLAConfig, ModelConfig,
                                Segment, ShapeConfig, reduced, supports,
                                swa_variant)

from repro.configs import (chameleon_34b, deepseek_67b, deepseek_v3_671b,
                           gemma3_27b, h2o_danube3_4b, lstm_am_7khr,
                           qwen2_5_3b, qwen3_moe_30b_a3b, recurrentgemma_2b,
                           whisper_medium, xlstm_350m)

ARCHS = {
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
    "gemma3-27b": gemma3_27b.CONFIG,
    "deepseek-67b": deepseek_67b.CONFIG,
    "h2o-danube-3-4b": h2o_danube3_4b.CONFIG,
    "whisper-medium": whisper_medium.CONFIG,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b.CONFIG,
    "qwen2.5-3b": qwen2_5_3b.CONFIG,
    "chameleon-34b": chameleon_34b.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "xlstm-350m": xlstm_350m.CONFIG,
    # the paper's own acoustic model
    "lstm-am-7khr": lstm_am_7khr.CONFIG,
    "lstm-am-teacher": lstm_am_7khr.TEACHER,
}

ASSIGNED = [k for k in ARCHS if not k.startswith("lstm-am")]


def get_arch(name: str) -> ModelConfig:
    if name.endswith("+swa"):
        return swa_variant(get_arch(name[: -len("+swa")]))
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]

__all__ = ["ARCHS", "ASSIGNED", "SHAPES", "get_arch", "get_shape", "supports",
           "reduced", "swa_variant", "ModelConfig", "ShapeConfig", "LayerSpec",
           "Segment", "MLAConfig"]
