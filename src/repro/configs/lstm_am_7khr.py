"""The paper's baseline acoustic model (Section 2): HMM-LSTM hybrid.

5x768 unidirectional LSTM student (~24M params), 3,183 senones, 64-d log-mel
stacked x3 / subsampled to 30ms (feat_dim 192), 3-frame look-ahead.
Teacher: 5x768 bidirectional LSTM (~78M params) — see configs/lstm_am_teacher.
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

B = LayerSpec(mixer="lstm", ffn="none")

CONFIG = ModelConfig(
    name="lstm-am-7khr",
    family="lstm_am",
    source="arXiv:1904.01624 (the paper)",
    d_model=768,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=3183,         # senone outputs
    segments=(Segment((B,), repeat=5),),
    norm="layernorm",
    pos_emb="none",
    lstm_hidden=768,
    n_senones=3183,
    feat_dim=192,
    lookahead=3,
)

TEACHER = CONFIG.replace(
    name="lstm-am-teacher",
    segments=(Segment((LayerSpec(mixer="bilstm", ffn="none"),), repeat=5),),
)
