"""H2O-Danube3-4B: llama+mistral mix with sliding-window attention.

[arXiv:2401.16818 / danube3 card] 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, SWA window 4096.
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

B = LayerSpec(mixer="swa", ffn="mlp", window=4096)

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="arXiv:2401.16818",
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32_000,
    segments=(Segment((B,), repeat=24),),
    norm="rmsnorm",
    act="silu",
    pos_emb="rope",
    rope_theta=500_000.0,
)
