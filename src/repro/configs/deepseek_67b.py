"""DeepSeek-LLM 67B: llama-architecture dense decoder.

[arXiv:2401.02954] 95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

B = LayerSpec(mixer="attn", ffn="mlp")

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    source="arXiv:2401.02954",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102_400,
    segments=(Segment((B,), repeat=95),),
    norm="rmsnorm",
    act="silu",
    pos_emb="rope",
    rope_theta=10_000.0,
)
