"""RecurrentGemma-2B (Griffin): RG-LRU recurrent blocks + local attention, 2:1.

[arXiv:2402.19427] 26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000.
Pattern: (recurrent, recurrent, local-attn) repeated; window 2048.
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

R = LayerSpec(mixer="rglru", ffn="mlp")
L = LayerSpec(mixer="swa", ffn="mlp", window=2048)

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    # 26 layers = 8 full (R,R,L) groups + (R,R) tail
    segments=(
        Segment((R, R, L), repeat=8),
        Segment((R, R), repeat=1),
    ),
    norm="rmsnorm",
    act="gelu",
    pos_emb="rope",
    rope_theta=10_000.0,
    emb_scale=True,
    tie_embeddings=True,
    logit_softcap=30.0,
    lru_width=2560,
    conv_width=4,
)
