"""Qwen2.5-3B: dense, GQA kv=2, QKV bias.

[hf:Qwen/Qwen2.5-0.5B family card, 3B dims] 36L d_model=2048 16H (GQA kv=2)
d_ff=11008 vocab=151936.
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

B = LayerSpec(mixer="attn", ffn="mlp")

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151_936,
    segments=(Segment((B,), repeat=36),),
    norm="rmsnorm",
    act="silu",
    pos_emb="rope",
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
)
