"""Gemma-3 27B: dense, 5 local (sliding-window 1024) : 1 global, 128k context.

[hf:google/gemma-3-1b-pt family card, 27B dims] 62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144.
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

LOCAL = LayerSpec(mixer="swa", ffn="mlp", window=1024)
GLOBAL = LayerSpec(mixer="attn", ffn="mlp")

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    # 62 layers = 10 x (5 local + 1 global) + 2 local tail
    segments=(
        Segment((LOCAL,) * 5 + (GLOBAL,), repeat=10),
        Segment((LOCAL, LOCAL), repeat=1),
    ),
    norm="rmsnorm",
    act="gelu",
    pos_emb="rope",
    rope_theta=1_000_000.0,
    qk_norm=True,
    emb_scale=True,
    tie_embeddings=True,
)
