"""Qwen3-30B-A3B: MoE, 128 experts top-8, all layers MoE.

[hf:Qwen/Qwen3-30B-A3B] 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936.
"""
from repro.configs.base import LayerSpec, ModelConfig, Segment

B = LayerSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=6144,               # dense-equivalent (unused; all layers MoE)
    vocab_size=151_936,
    segments=(Segment((B,), repeat=48),),
    norm="rmsnorm",
    act="silu",
    pos_emb="rope",
    rope_theta=1_000_000.0,
    qk_norm=True,
    n_experts=128,
    moe_top_k=8,
    moe_d_ff=768,
    moe_renorm_topk=True,
)
