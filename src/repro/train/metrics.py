"""Pluggable metrics sinks for the Trainer.

A sink receives one ``emit(step, tag, metrics)`` per optimizer update
with plain-float scalars (the Trainer host-syncs them — same cost as the
``float(m["loss"])`` every hand-rolled loop already paid).  ``tag`` is
the loss kind of the update ("ce", "distill_topk", "smbr", ...), so one
sink can separate the interleaved phases of a scheduled run.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Protocol, Tuple, runtime_checkable


@runtime_checkable
class MetricsSink(Protocol):
    def emit(self, step: int, tag: str, metrics: Dict[str, float]) -> None:
        ...


class ListSink:
    """In-memory record: [(step, tag, metrics)] + convenience accessors."""

    def __init__(self):
        self.records: List[Tuple[int, str, Dict[str, float]]] = []

    def emit(self, step, tag, metrics):
        self.records.append((step, tag, dict(metrics)))

    def values(self, key: str, tag: str = None) -> List[float]:
        return [m[key] for _, t, m in self.records
                if key in m and (tag is None or t == tag)]

    def last(self, key: str, tag: str = None):
        vs = self.values(key, tag)
        return vs[-1] if vs else None

    def first(self, key: str, tag: str = None):
        vs = self.values(key, tag)
        return vs[0] if vs else None

    def __len__(self):
        return len(self.records)


class JsonlSink:
    """Append-only JSONL file — the artifact form for experiment dirs."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def emit(self, step, tag, metrics):
        with open(self.path, "a") as f:
            f.write(json.dumps({"step": step, "tag": tag, **metrics}) + "\n")


class TeeSink:
    """Fan one emit out to several sinks."""

    def __init__(self, *sinks: MetricsSink):
        self.sinks = sinks

    def emit(self, step, tag, metrics):
        for s in self.sinks:
            s.emit(step, tag, metrics)
