"""Trainer.fit(): the one loop every stage of the paper's recipe runs.

    trainer = Trainer(strategy, {"ce": loss_fn}, checkpoint=store,
                      ckpt_every=25, metrics=sink)
    state = trainer.init_state(params)
    state = trainer.fit(state, source)

One jitted update per (loss kind x batch shape), with the learning rate
a *traced argument* — an LR schedule sweeping a hundred phases reuses
the same executable (the seed pipeline re-jitted its step on every
phase change).  The strategy decides how many source microbatches one
update consumes (tau*W for BMUF) and what the update does; the source
decides what data arrives with which lr/loss; the Trainer only grooms
batches into blocks, counts, checkpoints, and emits metrics.

Resume: every ``ckpt_every`` updates the full TrainState plus the
consumed-microbatch count goes to the CheckpointStore; ``fit`` with
``resume=True`` (default) reloads the latest state and fast-forwards
the (deterministic) source past the consumed prefix, so a killed stage
continues instead of restarting.

Stochasticity: each update folds the carried TrainState key with the
step counter (strategy-side) and threads the folded key into losses
that declare an ``rng`` parameter — dropout-style losses get a fresh
stream per update, and resume stays bitwise (the fold depends only on
checkpointed state).  LR: ``TrainBatch.lr`` may be a float or an
``optim.schedules.Schedule``; schedules are evaluated at the update
counter on the host and fed through the same traced lr argument.
``prefetch=N`` (constructor or fit kwarg) wraps the source in
``repro.pipeline.PrefetchingSource`` so shard decode + device_put run
ahead of the jitted update.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.train.data import DataSource, TrainBatch
from repro.train.metrics import MetricsSink
from repro.train.state import TrainState
from repro.train.strategies import DistributedStrategy


def _shape_sig(data):
    """Hashable (shape, dtype-free) signature of a batch pytree."""
    return tuple(tuple(getattr(l, "shape", ()))
                 for l in jax.tree_util.tree_leaves(data))


class Trainer:
    def __init__(self, strategy: DistributedStrategy,
                 loss_fns: Union[Callable, Dict[str, Callable]], *,
                 checkpoint: Optional[CheckpointStore] = None,
                 ckpt_every: int = 0,
                 metrics: Optional[MetricsSink] = None,
                 prefetch: int = 0):
        self.strategy = strategy
        if callable(loss_fns):
            loss_fns = {"default": loss_fns}
        self.updates = {tag: jax.jit(strategy.make_update(fn))
                        for tag, fn in loss_fns.items()}
        self.checkpoint = checkpoint
        self.ckpt_every = ckpt_every
        self.metrics = metrics
        # prefetch > 0: fit() wraps its source in a PrefetchingSource of
        # that depth — decode + device_put run ahead on a host thread so
        # the jitted update never blocks on shard reads (repro.pipeline)
        self.prefetch = prefetch

    # ------------------------------------------------------------- state

    def init_state(self, params, *, seed: int = 0) -> TrainState:
        state = TrainState(params=params,
                           opt_state=self.strategy.init_opt(params),
                           strategy_state=self.strategy.init_state(params),
                           step=jnp.zeros((), jnp.int32),
                           rng=jax.random.key(seed))
        return self._place(state)

    def _place(self, state: TrainState) -> TrainState:
        """Strategies that shard their state over a mesh (GTCShardMap)
        lay it out here so the first update hits the same executable as
        the steady state — identity for everything else."""
        place = getattr(self.strategy, "place", None)
        return state if place is None else place(state)

    def _save(self, state: TrainState, consumed: int):
        self.checkpoint.save(int(state.step), state.to_dict(),
                             meta={"consumed": consumed})

    def _try_resume(self, state: TrainState):
        """-> (state, consumed) from the latest checkpoint, or None."""
        if self.checkpoint is None:
            return None
        try:
            tree, step = self.checkpoint.load(state.to_dict())
        except FileNotFoundError:
            return None
        meta = self.checkpoint.load_meta(step) or {}
        return (self._place(TrainState.from_dict(tree)),
                int(meta.get("consumed", 0)))

    # --------------------------------------------------------------- fit

    def fit(self, state: TrainState, source: DataSource, *,
            resume: bool = True,
            max_updates: Optional[int] = None,
            prefetch: Optional[int] = None) -> TrainState:
        consumed = 0
        if resume:
            loaded = self._try_resume(state)
            if loaded is not None:
                state, consumed = loaded
        depth = self.prefetch if prefetch is None else prefetch
        wrapped = None
        if depth:
            from repro.pipeline.prefetch import PrefetchingSource
            if not isinstance(source, PrefetchingSource):
                # skip_put: the resume replay drops the consumed prefix,
                # so the producer must not pay its device transfers
                source = PrefetchingSource(source, depth=depth,
                                           skip_put=consumed)
            wrapped = source
        try:
            return self._fit_loop(state, source, consumed, max_updates)
        finally:
            if wrapped is not None:         # early exit must not leak the
                wrapped.close()             # producer thread across stages

    def _fit_loop(self, state: TrainState, source, consumed: int,
                  max_updates: Optional[int]) -> TrainState:
        # step is mirrored on the host (updates are +1 each) so the loop
        # never blocks on the device unless a sink/checkpoint needs to
        step = start_step = int(state.step)
        need = self.strategy.microbatches
        n_seen = 0
        group, gtag, gsig, glr = [], None, None, None
        for tb in source:
            n_seen += 1
            if n_seen <= consumed:          # resume: replay + skip
                continue
            # a partial block cannot straddle a loss-kind, batch-shape,
            # or lr boundary; drop it (BMUF block semantics — blocks
            # stack their microbatches, so ragged full-sequence batches
            # only fill blocks with exact shape-mates, and a block never
            # blurs two schedule phases' lrs.  Local/GTC never hit this:
            # need == 1 means no block is ever partial).  Schedule
            # objects compare by identity, so one schedule spanning many
            # updates never splits a block.
            sig = _shape_sig(tb.data) if need > 1 else None
            if group and (tb.loss != gtag or sig != gsig
                          or tb.lr != glr):
                group = []
            if not group:
                gtag, gsig, glr = tb.loss, sig, tb.lr
            group.append(tb.data)
            if len(group) < need:
                continue
            if gtag not in self.updates:
                raise KeyError(
                    f"source yielded loss kind {gtag!r} but the Trainer "
                    f"only has {sorted(self.updates)}")
            batch = self.strategy.stack(group)
            # an LR Schedule is evaluated here, at the update counter, on
            # the host — the update still sees a traced float, so the
            # one-compile-per-(loss kind, shape) property is untouched
            lr = glr(step) if callable(glr) else glr
            state, metrics = self.updates[gtag](
                state, batch, jnp.asarray(lr, jnp.float32))
            group = []
            consumed = n_seen
            step += 1
            if self.metrics is not None:
                host = jax.device_get(metrics)
                self.metrics.emit(step, gtag,
                                  {k: float(v) for k, v in host.items()
                                   if getattr(v, "size", 1) == 1})
            if (self.checkpoint is not None and self.ckpt_every
                    and step % self.ckpt_every == 0):
                self._save(state, consumed)
            if max_updates is not None and step - start_step >= max_updates:
                break
        return state

    # ------------------------------------------------------------ finish

    def finalize(self, state: TrainState):
        """Mark the run complete: drop the resume checkpoints so a fresh
        invocation of the same stage trains anew (a *killed* run, by
        contrast, still has them and resumes)."""
        if self.checkpoint is not None:
            self.checkpoint.clear()
        return state
