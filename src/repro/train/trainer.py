"""Trainer.fit(): the one loop every stage of the paper's recipe runs.

    trainer = Trainer(strategy, {"ce": loss_fn}, checkpoint=store,
                      ckpt_every=25, metrics=sink)
    state = trainer.init_state(params)
    state = trainer.fit(state, source)

One jitted update per (loss kind x batch shape), with the learning rate
a *traced argument* — an LR schedule sweeping a hundred phases reuses
the same executable (the seed pipeline re-jitted its step on every
phase change).  The strategy decides how many source microbatches one
update consumes (tau*W for BMUF) and what the update does; the source
decides what data arrives with which lr/loss; the Trainer only grooms
batches into blocks, counts, checkpoints, and emits metrics.

Resume: every ``ckpt_every`` updates the full TrainState plus the
consumed-microbatch count goes to the CheckpointStore; ``fit`` with
``resume=True`` (default) reloads the latest state and fast-forwards
the (deterministic) source past the consumed prefix, so a killed stage
continues instead of restarting.

Stochasticity: each update folds the carried TrainState key with the
step counter (strategy-side) and threads the folded key into losses
that declare an ``rng`` parameter — dropout-style losses get a fresh
stream per update, and resume stays bitwise (the fold depends only on
checkpointed state).  LR: ``TrainBatch.lr`` may be a float or an
``optim.schedules.Schedule``; schedules are evaluated at the update
counter on the host and fed through the same traced lr argument.
``prefetch=N`` (constructor or fit kwarg) wraps the source in
``repro.pipeline.PrefetchingSource`` so shard decode + device_put run
ahead of the jitted update.

Elasticity: ``fit(..., membership=...)`` polls a live-worker count at
update (== BMUF block) boundaries; when it changes, ``Trainer.resize``
re-partitions the TrainState through the strategy's ``resize`` hook and
rebuilds the jitted updates for the new W.  Checkpoints record the
membership they were saved at (``meta["n_workers"]``), and resume at a
*different* W re-partitions the loaded state — a W=4 save restarts
cleanly on a W=2 fleet.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.train.data import DataSource, TrainBatch
from repro.train.metrics import MetricsSink
from repro.train.state import TrainState
from repro.train.strategies import DistributedStrategy


def _shape_sig(data):
    """Hashable (shape, dtype-free) signature of a batch pytree."""
    return tuple(tuple(getattr(l, "shape", ()))
                 for l in jax.tree_util.tree_leaves(data))


class Trainer:
    def __init__(self, strategy: DistributedStrategy,
                 loss_fns: Union[Callable, Dict[str, Callable]], *,
                 checkpoint: Optional[CheckpointStore] = None,
                 ckpt_every: int = 0,
                 metrics: Optional[MetricsSink] = None,
                 prefetch: int = 0):
        self.strategy = strategy
        if callable(loss_fns):
            loss_fns = {"default": loss_fns}
        self._loss_fns = loss_fns
        self._build_updates()
        # membership-change accounting, read by the elastic bench
        self.resize_stats = {"count": 0, "seconds": 0.0}
        self.checkpoint = checkpoint
        self.ckpt_every = ckpt_every
        self.metrics = metrics
        # prefetch > 0: fit() wraps its source in a PrefetchingSource of
        # that depth — decode + device_put run ahead on a host thread so
        # the jitted update never blocks on shard reads (repro.pipeline)
        self.prefetch = prefetch

    def _build_updates(self):
        self.updates = {tag: jax.jit(self.strategy.make_update(fn))
                        for tag, fn in self._loss_fns.items()}

    # ------------------------------------------------------------- state

    def init_state(self, params, *, seed: int = 0) -> TrainState:
        state = TrainState(params=params,
                           opt_state=self.strategy.init_opt(params),
                           strategy_state=self.strategy.init_state(params),
                           step=jnp.zeros((), jnp.int32),
                           rng=jax.random.key(seed))
        return self._place(state)

    def _place(self, state: TrainState) -> TrainState:
        """Strategies that shard their state over a mesh (GTCShardMap)
        lay it out here so the first update hits the same executable as
        the steady state — identity for everything else."""
        place = getattr(self.strategy, "place", None)
        return state if place is None else place(state)

    def resize(self, state: TrainState, w_new: int) -> TrainState:
        """Adopt a new worker membership mid-run: re-partition the
        TrainState through the strategy, rebuild the jitted updates for
        the new W-shaped inputs, and re-place on the (possibly rebuilt)
        mesh.  Called from fit() at update boundaries when a membership
        poll reports a change, and from resume when the checkpoint was
        saved at a different W."""
        if w_new == getattr(self.strategy, "n_workers", w_new):
            return state
        t0 = time.perf_counter()
        state = self.strategy.resize(state, w_new)
        self._build_updates()
        state = self._place(state)
        self.resize_stats["count"] += 1
        self.resize_stats["seconds"] += time.perf_counter() - t0
        return state

    def _save(self, state: TrainState, consumed: int):
        meta = {"consumed": consumed}
        w = getattr(self.strategy, "n_workers", None)
        if w is not None:
            meta["n_workers"] = int(w)
        self.checkpoint.save(int(state.step), state.to_dict(), meta=meta)

    def _try_resume(self, state: TrainState):
        """-> (state, consumed) from the latest checkpoint, or None.

        Cross-W resume: when the checkpoint's saved membership differs
        from the strategy's current W, the load template is first
        resized to the *saved* W (load_tree is strict about shapes),
        then the loaded state is resized back to the current W — so a
        W=4 save resumes on a W=2 fleet with residuals folded
        sum-preservingly and BMUF replicas re-stacked."""
        if self.checkpoint is None:
            return None
        step = self.checkpoint.latest()
        if step is None:
            return None
        meta = self.checkpoint.load_meta(step) or {}
        cur_w = getattr(self.strategy, "n_workers", None)
        saved_w = meta.get("n_workers")
        if (cur_w is not None and saved_w is not None
                and int(saved_w) != int(cur_w)
                and hasattr(self.strategy, "resize")):
            template = self.strategy.resize(state, int(saved_w))
            tree, step = self.checkpoint.load(template.to_dict(), step)
            loaded = TrainState.from_dict(tree)
            return (self.resize(loaded, cur_w),
                    int(meta.get("consumed", 0)))
        tree, step = self.checkpoint.load(state.to_dict(), step)
        return (self._place(TrainState.from_dict(tree)),
                int(meta.get("consumed", 0)))

    # --------------------------------------------------------------- fit

    def fit(self, state: TrainState, source: DataSource, *,
            resume: bool = True,
            max_updates: Optional[int] = None,
            prefetch: Optional[int] = None,
            membership=None) -> TrainState:
        consumed = 0
        if resume:
            loaded = self._try_resume(state)
            if loaded is not None:
                state, consumed = loaded
        if membership is not None:
            state = self._poll_membership(state, membership)
        depth = self.prefetch if prefetch is None else prefetch
        wrapped = None
        if depth:
            from repro.pipeline.prefetch import PrefetchingSource
            if not isinstance(source, PrefetchingSource):
                # skip_put: the resume replay drops the consumed prefix,
                # so the producer must not pay its device transfers
                source = PrefetchingSource(source, depth=depth,
                                           skip_put=consumed)
            wrapped = source
        try:
            return self._fit_loop(state, source, consumed, max_updates,
                                  membership)
        finally:
            if wrapped is not None:         # early exit must not leak the
                wrapped.close()             # producer thread across stages

    def _poll_membership(self, state: TrainState, membership) -> TrainState:
        """One membership check (anything with live_count()); a changed
        live count resizes state + strategy + updates.  The floor is 1:
        an all-dead fleet freezes rather than divides by zero."""
        live = max(1, int(membership.live_count()))
        if live != getattr(self.strategy, "n_workers", live):
            state = self.resize(state, live)
        return state

    def _fit_loop(self, state: TrainState, source, consumed: int,
                  max_updates: Optional[int],
                  membership=None) -> TrainState:
        # step is mirrored on the host (updates are +1 each) so the loop
        # never blocks on the device unless a sink/checkpoint needs to
        step = start_step = int(state.step)
        need = self.strategy.microbatches
        n_seen = 0
        group, gtag, gsig, glr = [], None, None, None
        for tb in source:
            n_seen += 1
            if n_seen <= consumed:          # resume: replay + skip
                continue
            # a partial block cannot straddle a loss-kind, batch-shape,
            # or lr boundary; drop it (BMUF block semantics — blocks
            # stack their microbatches, so ragged full-sequence batches
            # only fill blocks with exact shape-mates, and a block never
            # blurs two schedule phases' lrs.  Local/GTC never hit this:
            # need == 1 means no block is ever partial).  Schedule
            # objects compare by identity, so one schedule spanning many
            # updates never splits a block.
            sig = _shape_sig(tb.data) if need > 1 else None
            if group and (tb.loss != gtag or sig != gsig
                          or tb.lr != glr):
                group = []
            if not group:
                gtag, gsig, glr = tb.loss, sig, tb.lr
            group.append(tb.data)
            if len(group) < need:
                continue
            if gtag not in self.updates:
                raise KeyError(
                    f"source yielded loss kind {gtag!r} but the Trainer "
                    f"only has {sorted(self.updates)}")
            batch = self.strategy.stack(group)
            # an LR Schedule is evaluated here, at the update counter, on
            # the host — the update still sees a traced float, so the
            # one-compile-per-(loss kind, shape) property is untouched
            lr = glr(step) if callable(glr) else glr
            state, metrics = self.updates[gtag](
                state, batch, jnp.asarray(lr, jnp.float32))
            group = []
            consumed = n_seen
            step += 1
            if self.metrics is not None:
                host = jax.device_get(metrics)
                self.metrics.emit(step, gtag,
                                  {k: float(v) for k, v in host.items()
                                   if getattr(v, "size", 1) == 1})
            if (self.checkpoint is not None and self.ckpt_every
                    and step % self.ckpt_every == 0):
                self._save(state, consumed)
            if max_updates is not None and step - start_step >= max_updates:
                break
            if membership is not None:
                # update == block boundary: the only membership-safe
                # point (BMUF lanes have just been re-broadcast, GTC
                # residuals are between compressions)
                new = self._poll_membership(state, membership)
                if new is not state:
                    state = new
                    need = self.strategy.microbatches
        return state

    # ------------------------------------------------------------ finish

    def finalize(self, state: TrainState):
        """Mark the run complete: drop the resume checkpoints so a fresh
        invocation of the same stage trains anew (a *killed* run, by
        contrast, still has them and resumes)."""
        if self.checkpoint is not None:
            self.checkpoint.clear()
        return state
