"""TrainState: the single carried state of every trainer in the repo.

One pytree holds everything a training loop mutates — params, optimizer
state, the update counter, an RNG key, and whatever the distributed
strategy carries between updates (BMUF's block momentum + worker
replicas, GTC's error-feedback residual).  ``params`` is always the
*canonical* model: for BMUF it is theta_g, never a worker replica, so
evaluation and checkpoint consumers are strategy-agnostic.

The state round-trips through ``repro.checkpoint`` as a plain dict
(``to_dict`` / ``from_dict``) so stored checkpoints carry no class
structure — robust to refactors, partially loadable, and the RNG key is
stored as raw key data (npz has no key dtype).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any                 # canonical model params (theta_g for BMUF)
    opt_state: Any              # possibly worker-stacked (BMUF)
    strategy_state: Any         # residuals / block momentum / replicas
    step: jax.Array             # () int32 — optimizer updates taken
    rng: jax.Array              # jax.random key

    def replace(self, **kw) -> "TrainState":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return {"params": self.params, "opt": self.opt_state,
                "strategy": self.strategy_state, "step": self.step,
                "rng": jax.random.key_data(self.rng)}

    @classmethod
    def from_dict(cls, d: dict) -> "TrainState":
        return cls(params=d["params"], opt_state=d["opt"],
                   strategy_state=d["strategy"],
                   step=jnp.asarray(d["step"], jnp.int32),
                   rng=jax.random.wrap_key_data(d["rng"]))
