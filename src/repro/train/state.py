"""TrainState: the single carried state of every trainer in the repo.

One pytree holds everything a training loop mutates — params, optimizer
state, the update counter, an RNG key, and whatever the distributed
strategy carries between updates (BMUF's block momentum + worker
replicas, GTC's error-feedback residual).  ``params`` is always the
*canonical* model: for BMUF it is theta_g, never a worker replica, so
evaluation and checkpoint consumers are strategy-agnostic.

The state round-trips through ``repro.checkpoint`` as a plain dict
(``to_dict`` / ``from_dict``) so stored checkpoints carry no class
structure — robust to refactors, partially loadable, and the RNG key is
stored as raw key data (npz has no key dtype).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


def restack_workers(tree, w_new: int, *, fold: bool = False):
    """Re-partition a W-stacked pytree onto a new leading worker dim.

    The elastic-membership primitive every strategy ``resize`` builds
    on.  Shrink (``w_new < W``): the first ``w_new`` rows survive; with
    ``fold=True`` the dropped rows are scatter-added onto the survivors
    round-robin (row ``j`` onto row ``j % w_new``) so the leading-dim
    *sum* is preserved — the GTC error-feedback residuals' conservation
    invariant (sum of sends + residuals == sum of grads) must hold
    across a membership change, so a dead worker's unshipped error mass
    moves to a survivor instead of vanishing.  Grow (``w_new > W``):
    new rows are zeros under ``fold`` (a joiner starts with no residual
    debt — again sum-preserving) and broadcasts of row 0 otherwise (a
    BMUF joiner warm-starts from a survivor's replica/optimizer state).
    """
    if w_new < 1:
        raise ValueError(f"w_new must be >= 1, got {w_new}")

    def leaf(x):
        x = jnp.asarray(x)
        w = x.shape[0]
        if w_new == w:
            return x
        if w_new < w:
            head = x[:w_new]
            if not fold:
                return head
            extra = x[w_new:]
            idx = jnp.arange(w - w_new) % w_new
            return head.at[idx].add(extra.astype(head.dtype))
        if fold:
            pad = jnp.zeros((w_new - w,) + x.shape[1:], x.dtype)
        else:
            pad = jnp.broadcast_to(x[0], (w_new - w,) + x.shape[1:])
        return jnp.concatenate([x, pad], axis=0)

    return jax.tree_util.tree_map(leaf, tree)


def worker_dim(tree) -> int:
    """Leading dim of the first leaf — the W a stacked tree is laid out
    for (0 for an empty tree).  Used to sanity-check resizes and to
    infer the saved worker count of legacy checkpoints without meta."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return 0
    shape = getattr(leaves[0], "shape", ())
    return int(shape[0]) if shape else 0


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any                 # canonical model params (theta_g for BMUF)
    opt_state: Any              # possibly worker-stacked (BMUF)
    strategy_state: Any         # residuals / block momentum / replicas
    step: jax.Array             # () int32 — optimizer updates taken
    rng: jax.Array              # jax.random key

    def replace(self, **kw) -> "TrainState":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return {"params": self.params, "opt": self.opt_state,
                "strategy": self.strategy_state, "step": self.step,
                "rng": jax.random.key_data(self.rng)}

    @classmethod
    def from_dict(cls, d: dict) -> "TrainState":
        return cls(params=d["params"], opt_state=d["opt"],
                   strategy_state=d["strategy"],
                   step=jnp.asarray(d["step"], jnp.int32),
                   rng=jax.random.wrap_key_data(d["rng"]))
