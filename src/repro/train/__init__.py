"""Unified Trainer API (ISSUE 2).

  TrainState            — params + opt + step + rng + strategy state
  DistributedStrategy   — Local / BMUFVmap / BMUFShardMap / GTC
  DataSource            — iterables of TrainBatch (epoch_source,
                          distill_shard_source, scheduled_source, chain)
  Trainer               — fit() with one lr-as-argument jitted update
                          per loss kind, periodic checkpointing,
                          mid-stage resume, pluggable metrics sinks
"""
from repro.train.data import (DataSource, TrainBatch, chain,
                              distill_shard_source, epoch_source,
                              scheduled_source)
from repro.train.metrics import (JsonlSink, ListSink, MetricsSink,
                                 TeeSink)
from repro.train.state import TrainState
from repro.train.strategies import (GTC, BMUFShardMap, BMUFVmap,
                                    DistributedStrategy, Local,
                                    init_opt, make_sgd_step)
from repro.train.trainer import Trainer

__all__ = [
    "TrainState", "Trainer", "TrainBatch", "DataSource",
    "DistributedStrategy", "Local", "BMUFVmap", "BMUFShardMap", "GTC",
    "make_sgd_step", "init_opt",
    "epoch_source", "distill_shard_source", "scheduled_source", "chain",
    "MetricsSink", "ListSink", "JsonlSink", "TeeSink",
]
