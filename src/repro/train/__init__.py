"""Unified Trainer API (ISSUE 2) + the data-plane feed (ISSUE 3).

  TrainState            — params + opt + step + rng + strategy state
  DistributedStrategy   — Local / BMUFVmap / BMUFShardMap / GTC /
                          GTCShardMap
  DataSource            — iterables of TrainBatch (epoch_source,
                          distill_shard_source, scheduled_source, chain);
                          compose with repro.pipeline.PrefetchingSource
                          for the async host->device feed
  Trainer               — fit() with one lr-as-argument jitted update
                          per loss kind (floats or Schedule objects),
                          per-update RNG folding for stochastic losses,
                          periodic checkpointing, mid-stage resume,
                          optional prefetching feed, metrics sinks
"""
from repro.optim.schedules import Schedule
from repro.pipeline.prefetch import PrefetchingSource
from repro.train.data import (DataSource, TrainBatch, chain,
                              distill_shard_source, epoch_source,
                              scheduled_source)
from repro.train.metrics import (JsonlSink, ListSink, MetricsSink,
                                 TeeSink)
from repro.train.state import TrainState, restack_workers
from repro.train.strategies import (GTC, BMUFShardMap, BMUFVmap,
                                    DistributedStrategy, GTCShardMap,
                                    Local, init_opt, make_sgd_step)
from repro.train.trainer import Trainer

__all__ = [
    "TrainState", "Trainer", "TrainBatch", "DataSource",
    "DistributedStrategy", "Local", "BMUFVmap", "BMUFShardMap", "GTC",
    "GTCShardMap",
    "make_sgd_step", "init_opt", "restack_workers",
    "epoch_source", "distill_shard_source", "scheduled_source", "chain",
    "PrefetchingSource", "Schedule",
    "MetricsSink", "ListSink", "JsonlSink", "TeeSink",
]
