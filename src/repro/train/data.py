"""DataSource: anything that yields (batch, lr, loss-kind) work items.

A data source is just an iterable of ``TrainBatch`` — the Trainer
consumes them in order, groups them into strategy-sized blocks, and
counts consumption so a killed run resumes mid-stream.  Three source
builders cover the paper's stages:

  epoch_source          labeled CE epochs (baseline / teacher / sMBR)
  distill_shard_source  unlabeled batches joined with LogitStore shards
  scheduled_source      the §3.3 scheduled-learning phase stream:
                        unlabeled distill sub-epochs interleaved with
                        labeled CE passes, per-phase LR from the
                        exponential schedule in core/scheduled.py

Sources must be *deterministic* (same items in the same order each time
they are built) — resume replays the stream and skips the consumed
prefix, which is exact because everything here derives from seeded
synthetic data.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Iterator

from repro.core import scheduled
from repro.optim.schedules import Schedule


@dataclass
class TrainBatch:
    """One microbatch of work: data pytree + the LR and loss to use.

    ``lr`` is a float or an ``optim.schedules.Schedule`` — schedules
    ride through the source untouched and are evaluated by Trainer.fit
    at the update counter (still one compile per loss kind: the jitted
    update sees a traced float either way).
    """
    data: Any
    lr: Any
    loss: str = "default"


DataSource = Iterable[TrainBatch]


def epoch_source(batches_fn: Callable[[int], Iterable[dict]],
                 n_epochs: int, lr, loss: str = "default"
                 ) -> Iterator[TrainBatch]:
    """n_epochs passes over batches_fn(epoch); lr a float, a Schedule
    (passed through for per-update evaluation), or fn(epoch)."""
    for ep in range(n_epochs):
        lr_ep = lr if isinstance(lr, Schedule) else (
            lr(ep) if callable(lr) else lr)
        for b in batches_fn(ep):
            yield TrainBatch(b, lr_ep, loss)


def distill_shard_source(batches, store, lo: int, hi: int, lr,
                         loss: str = "distill_topk", *,
                         verify: bool = False,
                         pin_wave: bool = False) -> Iterator[TrainBatch]:
    """Unlabeled batches [lo, hi) joined with their LogitStore shards
    (shard i holds batch i's teacher top-k — the trainer-aligned layout
    stage_targets writes).  Works against v1 (``core.logit_store``) and
    v2 (``repro.store``) stores alike; with a v2 store, ``verify=True``
    checksums each shard before it is fed (the decode-side integrity
    gate — pair with a PrefetchingSource so it runs off the hot path).

    ``pin_wave=True`` (v2 stores) snapshots the live manifest entries
    when iteration starts and reads through *those* for the whole
    sub-epoch: a teacher regeneration superseding shards mid-epoch
    cannot silently switch this pass onto new-wave targets half way
    through — retired files stay on disk until the store's next
    ``gc()``, so the pinned reads keep resolving.  (A mid-wave-killed
    regeneration may still leave the *snapshot itself* mixed across
    waves; closing that is the generation ledger's job.)
    """
    entries = None
    if pin_wave and hasattr(store, "manifest"):
        # taken lazily, at first next(): scheduled_source builds each
        # sub-epoch's source up front, but the pin belongs to the
        # moment the sub-epoch starts consuming
        entries = {bi: store.manifest.entry(bi)
                   for bi in range(lo, min(hi, len(batches)))}
    for bi in range(lo, min(hi, len(batches))):
        b = batches[bi]
        if entries is not None:
            vals, idx = store.read_entry(entries[bi], verify=verify)
        elif verify:
            vals, idx = store.read_shard(bi, verify=True)
        else:
            vals, idx = store.read_shard(bi)
        yield TrainBatch({"feats": b["feats"], "mask": b["mask"],
                          "topk_vals": vals, "topk_idx": idx}, lr, loss)


def scheduled_source(cfg: scheduled.ScheduleConfig, *,
                     unlabeled: Callable[[scheduled.Phase],
                                         Iterable[TrainBatch]],
                     labeled: Callable[[scheduled.Phase],
                                       Iterable[TrainBatch]]
                     ) -> Iterator[TrainBatch]:
    """Walk the paper's phase schedule, delegating batch production to
    per-phase callbacks (which see the phase's lr / chunking / offset)."""
    for phase in scheduled.schedule(cfg):
        fn = unlabeled if phase.kind == "unlabeled" else labeled
        yield from fn(phase)


def chain(*sources: DataSource) -> Iterator[TrainBatch]:
    """Concatenate sources into one resumable stream (e.g. chunked
    epochs followed by a full-sequence fine-tune)."""
    return itertools.chain(*sources)
