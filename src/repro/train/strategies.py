"""DistributedStrategy: how one optimizer update is computed.

The Trainer treats every trainer in the paper as the same loop; the
strategy is the only part that differs, and it is a constructor argument
instead of a forked code path:

  Local          — single-worker SGD/Adam (baseline CE, teacher, smoke)
  BMUFVmap       — blockwise model-update filtering, workers on a leading
                   vmapped W dim (paper §3.5's 64-GPU trainer, CPU/test
                   execution of the same math)
  BMUFShardMap   — identical math with the W dim sharded over mesh axes
                   (the production path in distributed/bmuf.py)
  GTC            — Strom threshold-compressed SGD with error feedback
                   (paper §2/§3.4's 16-GPU trainer; works with any loss,
                   including sMBR), single-process form
  GTCShardMap    — the same math with the worker axis sharded over mesh
                   axes: per-worker residuals, int8-packed wire psum
                   (the production path in distributed/gtc.py)

A strategy exposes:

  microbatches          how many source batches one update consumes
                        (1 for Local/GTC; tau*W for BMUF)
  n_workers             the *current* worker membership W — a runtime
                        value, not a construction-time constant
  stack(group)          fold that many batches into the update's input
  init_opt(params)      optimizer state (worker-stacked for BMUF)
  init_state(params)    strategy-private state carried in TrainState
  make_update(loss_fn)  (TrainState, batch, lr) -> (TrainState, metrics)
                        — pure and jittable, lr a traced scalar so one
                        compile serves every LR-schedule phase
  resize(state, W_new)  re-partition W-stacked state onto a new
                        membership (elastic join/leave, cross-W resume);
                        returns the adjusted TrainState and retunes the
                        strategy so subsequent make_update calls build
                        W_new-shaped executables
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.distributed import bmuf as bmuf_lib
from repro.distributed import gtc as gtc_lib
from repro.optim import (adam_init, adam_update, clip_by_global_norm,
                         momentum_init, momentum_update)
from repro.train.state import TrainState, restack_workers
from repro.utils.introspect import takes_rng

tmap = jax.tree_util.tree_map


def loss_takes_rng(loss_fn: Callable) -> bool:
    """A loss opts into stochasticity by declaring an ``rng`` parameter:
    loss_fn(params, batch, rng=key) -> (loss, metrics).  Two-argument
    losses stay deterministic and are called exactly as before."""
    return takes_rng(loss_fn)


def call_loss(loss_fn: Callable, params, batch, rng=None):
    """Dispatch on the loss's arity; a stochastic loss with no key gets
    a fixed one (the deterministic legacy behavior, e.g. direct step
    calls outside the Trainer)."""
    if loss_takes_rng(loss_fn):
        return loss_fn(params, batch,
                       rng=jax.random.key(0) if rng is None else rng)
    return loss_fn(params, batch)


def make_sgd_step(loss_fn: Callable, *, optimizer: str = "momentum",
                  clip: float = 1.0):
    """The shared local step: grad -> clip -> optimizer, lr traced.

    loss_fn(params, batch[, rng]) -> (loss, metrics).  Returns
    step(params, opt_state, batch, lr, rng=None) -> (params, opt_state,
    metrics), compiled once per batch shape regardless of how lr
    changes; ``rng`` (when given) is the per-update key the Trainer
    folds from TrainState — threaded into losses that declare it.
    """
    upd = momentum_update if optimizer == "momentum" else adam_update

    def step(params, opt_state, batch, lr, rng=None):
        (_, metrics), grads = jax.value_and_grad(
            lambda p, b: call_loss(loss_fn, p, b, rng),
            has_aux=True)(params, batch)
        if clip:
            grads, gn = clip_by_global_norm(grads, clip)
            metrics["grad_norm"] = gn
        params, opt_state = upd(params, grads, opt_state, lr=lr)
        return params, opt_state, metrics

    return step


def init_opt(params, optimizer: str = "momentum"):
    return (momentum_init if optimizer == "momentum" else adam_init)(params)


@runtime_checkable
class DistributedStrategy(Protocol):
    microbatches: int
    n_workers: int

    def init_opt(self, params) -> Any: ...
    def init_state(self, params) -> Any: ...
    def stack(self, group: List[dict]) -> Any: ...
    def make_update(self, loss_fn: Callable) -> Callable: ...
    def resize(self, state: "TrainState", w_new: int) -> "TrainState": ...


class _SingleWorker:
    """resize() for the strategies with no worker-stacked state: the
    only membership they can express is W=1, so any other target is a
    caller error, not something to silently absorb."""

    n_workers = 1

    def resize(self, state: TrainState, w_new: int) -> TrainState:
        if w_new != 1:
            raise ValueError(
                f"{type(self).__name__} is single-worker; cannot resize "
                f"to W={w_new}")
        return state


class Local(_SingleWorker):
    """Plain single-worker training — the degenerate strategy."""

    microbatches = 1

    def __init__(self, *, optimizer: str = "momentum", clip: float = 1.0):
        self.optimizer = optimizer
        self.clip = clip

    def init_opt(self, params):
        return init_opt(params, self.optimizer)

    def init_state(self, params):
        return {}

    def stack(self, group):
        return group[0]

    def make_update(self, loss_fn):
        step = make_sgd_step(loss_fn, optimizer=self.optimizer,
                             clip=self.clip)

        def update(state: TrainState, batch, lr):
            # per-update folding: the carried key is the stream root and
            # never advances; fold(root, step) is unique per update and
            # exact under mid-stream resume (step is checkpointed)
            rng = jax.random.fold_in(state.rng, state.step)
            params, opt, metrics = step(state.params, state.opt_state,
                                        batch, lr, rng)
            return state.replace(params=params, opt_state=opt,
                                 step=state.step + 1), metrics

        return update


class GTC(_SingleWorker):
    """Threshold-compressed SGD with error feedback (Strom 2015).

    Single-process form: grads are compressed against the carried
    residual by ``gtc_lib.compress_tree`` (the shared code path — the
    Pallas kernel behind ``cfg.use_kernel``) and the update ships
    through ``gtc_lib.wire_reduce``, which at one worker is a
    pack/unpack round-trip (bitwise identity on ternary sends) — so the
    arithmetic is literally the multi-worker wire's.  The accuracy-
    relevant math of the 16-GPU trainer, loss-agnostic (CE, distill,
    sMBR).  The multi-worker exchange is ``GTCShardMap``.
    """

    microbatches = 1

    def __init__(self, cfg: gtc_lib.GTCConfig = None, *,
                 optimizer: str = "momentum", clip: float = 1.0):
        self.cfg = cfg or gtc_lib.GTCConfig(n_workers=1)
        if self.cfg.n_workers != 1:
            raise ValueError(
                f"GTC is the single-process strategy; cfg.n_workers="
                f"{self.cfg.n_workers} needs GTCShardMap")
        self.optimizer = optimizer
        self.clip = clip

    def init_opt(self, params):
        return init_opt(params, self.optimizer)

    def init_state(self, params):
        return gtc_lib.gtc_init(params)

    def stack(self, group):
        return group[0]

    def make_update(self, loss_fn):
        upd = momentum_update if self.optimizer == "momentum" \
            else adam_update
        cfg = self.cfg
        clip = self.clip

        def update(state: TrainState, batch, lr):
            rng = jax.random.fold_in(state.rng, state.step)
            (_, metrics), grads = jax.value_and_grad(
                lambda p, b: call_loss(loss_fn, p, b, rng),
                has_aux=True)(state.params, batch)
            if clip:
                grads, gn = clip_by_global_norm(grads, clip)
                metrics["grad_norm"] = gn
            send, res = gtc_lib.compress_tree(
                grads, state.strategy_state["residual"], cfg.tau,
                use_kernel=cfg.use_kernel)
            applied = gtc_lib.wire_reduce(send, cfg)
            params, opt = upd(state.params, applied, state.opt_state,
                              lr=lr)
            metrics["gtc_density"] = gtc_lib.density(applied, cfg.tau)
            return state.replace(params=params, opt_state=opt,
                                 strategy_state={"residual": res},
                                 step=state.step + 1), metrics

        return update


class GTCShardMap:
    """Multi-worker GTC: the worker axis sharded over mesh axes.

    The paper's 16-GPU sequence trainer inside the unified Trainer:
    each update consumes ``n_workers`` microbatches (one per worker,
    stacked on a leading W dim and sharded over the mesh), every worker
    compresses its clipped grads against its own carried error-feedback
    residual (``TrainState.strategy_state`` — per-worker, W-stacked),
    and the wire is ``gtc_lib.wire_reduce``: int8-packed sends, integer
    accumulation (int8-exact to 127 workers, int32 beyond), one psum
    per leaf.  Params and optimizer state stay replicated — synchronous
    SGD, every worker applies the same averaged update.

    On a 1-device mesh with n_workers=1 and a deterministic loss this
    is bitwise-equal to the single-process ``GTC`` strategy (pinned in
    tests) — the BMUFVmap/BMUFShardMap validation story, repeated for
    the second of the paper's two distributed trainers.  Stochastic
    losses get per-(update, worker) folded keys (global worker index,
    folded outside the shard_map), matching the BMUF folding scheme.
    """

    def __init__(self, cfg: gtc_lib.GTCConfig, mesh, *,
                 worker_axes=("data",), optimizer: str = "momentum",
                 clip: float = 1.0):
        self.cfg = cfg
        self.mesh = mesh
        self.worker_axes = worker_axes
        self.optimizer = optimizer
        self.clip = clip

    @property
    def microbatches(self) -> int:
        return self.cfg.n_workers

    @property
    def n_workers(self) -> int:
        return self.cfg.n_workers

    def init_opt(self, params):
        return init_opt(params, self.optimizer)

    def init_state(self, params):
        return gtc_lib.gtc_init(params, self.cfg)

    def resize(self, state: TrainState, w_new: int) -> TrainState:
        """Re-partition the per-worker error-feedback residuals onto a
        new membership.  fold=True: a dropped worker's unshipped error
        mass is scatter-added onto a survivor, a joiner starts with zero
        residual — both sum-preserving, so the conservation invariant
        (sum of sends + final residuals == sum of grads) holds across
        the resize; pinned in tests.  The mesh is rebuilt for the new W
        when this strategy owns a plain 1-axis worker mesh."""
        if w_new == self.cfg.n_workers:
            return state
        self.cfg = dataclasses.replace(self.cfg, n_workers=w_new)
        if len(self.worker_axes) == 1:
            from repro.runtime.cluster import worker_mesh
            self.mesh = worker_mesh(w_new, axis=self.worker_axes[0])
        return state.replace(strategy_state=restack_workers(
            state.strategy_state, w_new, fold=True))

    def place(self, state: TrainState) -> TrainState:
        """Lay a (fresh or resumed) TrainState out on the mesh the way
        the sharded step returns it — params/opt replicated, per-worker
        residuals sharded over the worker axis — so the first update
        compiles the same executable as every later one (the Trainer
        calls this from init_state and after a resume load)."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        rep = NamedSharding(self.mesh, P())
        wrk = NamedSharding(self.mesh, self._wspec())
        return state.replace(
            params=jax.device_put(state.params, rep),
            opt_state=jax.device_put(state.opt_state, rep),
            strategy_state=jax.device_put(state.strategy_state, wrk),
            step=jax.device_put(state.step, rep),
            rng=jax.device_put(state.rng, rep))

    def _wspec(self):
        from jax.sharding import PartitionSpec as P
        # a worker axis of size 1 canonicalizes to replicated under
        # GSPMD; placing it that way keeps first-call == steady-state
        if all(self.mesh.shape[a] == 1 for a in self.worker_axes):
            return P()
        return P(self.worker_axes if len(self.worker_axes) > 1
                 else self.worker_axes[0])

    def stack(self, group):
        return tmap(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                    *group)

    def _grad_transform(self):
        clip = self.clip
        if not clip:
            return None

        def transform(grads):
            grads, gn = clip_by_global_norm(grads, clip)
            return grads, {"grad_norm": gn}

        return transform

    def make_update(self, loss_fn):
        upd = momentum_update if self.optimizer == "momentum" \
            else adam_update
        step = gtc_lib.make_sharded_gtc_train_step(
            loss_fn, upd, self.cfg, self.mesh,
            worker_axes=self.worker_axes,
            grad_transform=self._grad_transform())

        from jax.sharding import NamedSharding
        wrk = NamedSharding(self.mesh, self._wspec())

        def update(state: TrainState, batches, lr):
            rng = jax.random.fold_in(state.rng, state.step)
            params, opt, gstate, ms = step(
                state.params, state.opt_state, state.strategy_state,
                batches, lr, rng)
            # pin the residual's output sharding to the worker spec: on
            # a 1-axis-size mesh GSPMD would otherwise canonicalize it
            # to replicated, and the next call would miss the jit cache
            gstate = tmap(
                lambda r: jax.lax.with_sharding_constraint(r, wrk), gstate)
            # metrics arrive (W,)-shaped from the sharded worker slice
            metrics = tmap(jnp.mean, ms)
            return state.replace(params=params, opt_state=opt,
                                 strategy_state=gstate,
                                 step=state.step + 1), metrics

        return update


class _BMUFBase:
    """Shared plumbing of the two BMUF execution paths."""

    def __init__(self, cfg: bmuf_lib.BMUFConfig, *,
                 optimizer: str = "momentum", clip: float = 1.0):
        self.cfg = cfg
        self.optimizer = optimizer
        self.clip = clip

    @property
    def microbatches(self) -> int:
        return self.cfg.block_steps * self.cfg.n_workers

    @property
    def n_workers(self) -> int:
        return self.cfg.n_workers

    def resize(self, state: TrainState, w_new: int) -> TrainState:
        """Re-stack worker replicas + per-worker optimizer state onto a
        new membership.  Safe at block boundaries (the only place the
        Trainer calls it): the Nesterov restart has just broadcast
        identical params to every lane, so shrink keeps the first W_new
        replicas and grow warm-starts joiners from lane 0 — both exact.
        The block-momentum ``delta`` is global and carries unchanged,
        which is why a shrink-mid-run matches a fresh smaller-W run
        only to float32-ULP (the momentum history differs from a
        cold start) — pinned in tests."""
        if w_new == self.cfg.n_workers:
            return state
        self.cfg = dataclasses.replace(self.cfg, n_workers=w_new)
        ss = dict(state.strategy_state)
        ss["workers"] = restack_workers(ss["workers"], w_new)
        return state.replace(
            opt_state=restack_workers(state.opt_state, w_new),
            strategy_state=ss)

    def init_opt(self, params):
        one = init_opt(params, self.optimizer)
        return tmap(lambda x: jnp.broadcast_to(
            x, (self.cfg.n_workers,) + x.shape).copy(), one)

    def init_state(self, params):
        st = bmuf_lib.bmuf_init(params, self.cfg)
        return {"delta": st["delta"], "workers": st["workers"]}

    def stack(self, group):
        tau, w = self.cfg.block_steps, self.cfg.n_workers
        return tmap(lambda *xs: jnp.stack(
            [jnp.asarray(x) for x in xs]).reshape(tau, w, *xs[0].shape),
            *group)

    def _block(self, loss_fn):
        raise NotImplementedError

    def make_update(self, loss_fn):
        block = self._block(loss_fn)

        def update(state: TrainState, batches, lr):
            rng = jax.random.fold_in(state.rng, state.step)
            bstate = {"theta_g": state.params, **state.strategy_state}
            bstate, opts, ms = block(bstate, state.opt_state, batches, lr,
                                     rng)
            # metrics arrive (W, tau)-shaped from the vmapped scan
            metrics = tmap(jnp.mean, ms)
            return state.replace(
                params=bstate["theta_g"], opt_state=opts,
                strategy_state={"delta": bstate["delta"],
                                "workers": bstate["workers"]},
                step=state.step + 1), metrics

        return update


class BMUFVmap(_BMUFBase):
    """BMUF with the worker dim vmapped on one device (tests / laptop)."""

    def _block(self, loss_fn):
        step = make_sgd_step(loss_fn, optimizer=self.optimizer,
                             clip=self.clip)
        return bmuf_lib.make_bmuf_block_step(step, self.cfg)


class BMUFShardMap(_BMUFBase):
    """BMUF with the worker dim sharded over mesh axes (production)."""

    def __init__(self, cfg: bmuf_lib.BMUFConfig, mesh, *,
                 worker_axes=("data",), optimizer: str = "momentum",
                 clip: float = 1.0):
        super().__init__(cfg, optimizer=optimizer, clip=clip)
        self.mesh = mesh
        self.worker_axes = worker_axes

    def resize(self, state: TrainState, w_new: int) -> TrainState:
        if w_new == self.cfg.n_workers:
            return state
        state = super().resize(state, w_new)
        if len(self.worker_axes) == 1:
            from repro.runtime.cluster import worker_mesh
            self.mesh = worker_mesh(w_new, axis=self.worker_axes[0])
        return state

    def _block(self, loss_fn):
        step = make_sgd_step(loss_fn, optimizer=self.optimizer,
                             clip=self.clip)
        return bmuf_lib.make_sharded_bmuf_block_step(
            step, self.cfg, self.mesh, worker_axes=self.worker_axes)
