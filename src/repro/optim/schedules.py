"""LR schedules. The paper uses exponential decay over (sub-)epochs."""
from __future__ import annotations

import jax.numpy as jnp


def exponential_decay(lr0: float, decay: float, steps_per_epoch: int):
    def fn(step):
        epoch = step // steps_per_epoch
        return lr0 * (decay ** epoch.astype(jnp.float32)
                      if hasattr(epoch, "astype") else decay ** epoch)
    return fn


def warmup_exponential(lr0: float, warmup_steps: int, decay: float,
                       steps_per_epoch: int):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
        epoch = jnp.floor(s / steps_per_epoch)
        return lr0 * warm * (decay ** epoch)
    return fn
