"""LR schedules. The paper uses exponential decay over (sub-)epochs.

A ``Schedule`` is a per-*update* learning-rate policy: callable
``step -> lr`` plus a marker type the data plane recognizes.  Sources
(``repro.train.data``) pass Schedule objects through ``TrainBatch.lr``
untouched, and ``Trainer.fit`` evaluates them at the update counter on
the host, feeding the result through the jitted update's *traced* lr
argument — so a schedule sweeping a thousand values still compiles one
executable per (loss kind, batch shape) (pinned in tests/test_trainer.py).

Plain callables keep their legacy meaning in ``epoch_source`` (a
function of the *epoch*); only Schedule instances get per-step
treatment.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


class Schedule:
    """A per-update LR policy: ``schedule(step) -> float``.

    ``fn`` maps the 0-based optimizer-update counter to a learning
    rate; evaluation happens on the host (Trainer.fit), so returning
    jnp scalars is fine — they are cast to float.
    """

    def __init__(self, fn: Callable[[int], float], desc: str = ""):
        self._fn = fn
        self.desc = desc

    def __call__(self, step: int) -> float:
        return float(self._fn(step))

    def __repr__(self) -> str:
        return f"Schedule({self.desc or self._fn!r})"


def exponential_decay(lr0: float, decay: float,
                      steps_per_epoch: int) -> Schedule:
    def fn(step):
        epoch = step // steps_per_epoch
        return lr0 * (decay ** epoch.astype(jnp.float32)
                      if hasattr(epoch, "astype") else decay ** epoch)
    return Schedule(fn, f"exp(lr0={lr0}, decay={decay}, "
                        f"spe={steps_per_epoch})")


def warmup_exponential(lr0: float, warmup_steps: int, decay: float,
                       steps_per_epoch: int) -> Schedule:
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
        epoch = jnp.floor(s / steps_per_epoch)
        return lr0 * warm * (decay ** epoch)
    return Schedule(fn, f"warmup_exp(lr0={lr0}, warmup={warmup_steps}, "
                        f"decay={decay}, spe={steps_per_epoch})")


def warmup_hold_decay(lr0: float, warmup_steps: int, hold_steps: int,
                      decay: float, steps_per_epoch: int, *,
                      floor: float = 0.0) -> Schedule:
    """Linear warmup -> flat hold at lr0 -> per-epoch exponential decay.

    The long-horizon wave driver's shape: ramp in over ``warmup_steps``
    updates, hold the peak for ``hold_steps`` more (the bulk-data
    regime, where decaying early wastes the unlabeled firehose), then
    decay by ``decay`` per ``steps_per_epoch`` updates, clamped at
    ``floor``.  Evaluated at the update counter like every Schedule —
    still a host-side float into the traced lr argument, so an entire
    warmup-hold-decay sweep reuses one compiled update (the 1-compile
    pin extends to this shape in tests/test_trainer.py).
    """
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
        past_hold = jnp.maximum(0.0, s - warmup_steps - hold_steps)
        epoch = jnp.floor(past_hold / steps_per_epoch)
        return jnp.maximum(floor, lr0 * warm * (decay ** epoch))
    return Schedule(fn, f"warmup_hold_decay(lr0={lr0}, "
                        f"warmup={warmup_steps}, hold={hold_steps}, "
                        f"decay={decay}, spe={steps_per_epoch})")
