"""Optimizers, hand-rolled (no optax dependency): SGD-momentum (the paper's
trainer) and Adam (for LLM-arch configs).  States are explicit pytrees so
BMUF/GTC can wrap them."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ----------------------------------------------------------- SGD momentum

def momentum_init(params):
    return {"mu": jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def momentum_update(params, grads, state, *, lr, beta: float = 0.9,
                    nesterov: bool = True):
    mu = jax.tree_util.tree_map(
        lambda m, g: beta * m + g.astype(jnp.float32), state["mu"], grads)
    if nesterov:
        step = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), mu, grads)
    else:
        step = mu
    new_params = jax.tree_util.tree_map(
        lambda p, s: (p.astype(jnp.float32) - lr * s).astype(p.dtype),
        params, step)
    return new_params, {"mu": mu}


# ------------------------------------------------------------------ Adam

def adam_init(params):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.0):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
        state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["v"], grads)
    mh = 1.0 - b1 ** t.astype(jnp.float32)
    vh = 1.0 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = (m_ / mh) / (jnp.sqrt(v_ / vh) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    return (jax.tree_util.tree_map(upd, params, m, v),
            {"m": m, "v": v, "t": t})
