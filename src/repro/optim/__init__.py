from repro.optim.sgd import (adam_init, adam_update, clip_by_global_norm,
                             momentum_init, momentum_update)
from repro.optim.schedules import (Schedule, exponential_decay,
                                   warmup_exponential, warmup_hold_decay)

__all__ = ["momentum_init", "momentum_update", "adam_init", "adam_update",
           "clip_by_global_norm", "Schedule", "exponential_decay",
           "warmup_exponential", "warmup_hold_decay"]
