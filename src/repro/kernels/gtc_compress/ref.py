"""Pure-jnp oracle for the GTC threshold-compression kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gtc_compress_ref(grad, residual, tau):
    """(send, new_residual): error-feedback threshold sparsification.

    acc  = residual + grad
    send = tau * sign(acc) * [|acc| > tau]
    new_residual = acc - send
    """
    acc = residual.astype(jnp.float32) + grad.astype(jnp.float32)
    mask = jnp.abs(acc) > tau
    send = jnp.where(mask, jnp.sign(acc) * tau, 0.0).astype(jnp.float32)
    return send, acc - send
