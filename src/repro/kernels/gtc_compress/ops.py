"""jit'd public wrapper: pad-to-tile + reshape around the Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._dispatch import auto_interpret
from repro.kernels.gtc_compress.kernel import TILE, gtc_compress_flat


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gtc_compress_jit(grad, residual, tau, *, interpret: bool):
    shape = grad.shape
    n = grad.size
    npad = (-n) % TILE
    g = jnp.pad(grad.reshape(-1).astype(jnp.float32), (0, npad))
    r = jnp.pad(residual.reshape(-1).astype(jnp.float32), (0, npad))
    t = jnp.asarray([tau], jnp.float32)
    send, newr = gtc_compress_flat(g, r, t, interpret=interpret)
    return send[:n].reshape(shape), newr[:n].reshape(shape)


def gtc_compress(grad, residual, tau, *, interpret=None):
    """Tensor-shaped GTC compression via the TPU kernel.

    grad/residual: same shape, any dims; tau: python float or 0-d array.
    Returns (send, new_residual) shaped like grad, float32.
    ``interpret=None`` auto-selects via ``kernels._dispatch``: compiled
    on TPU, interpret mode everywhere else — so callers
    (``distributed.gtc.compress_leaf`` behind ``GTCConfig.use_kernel``)
    need no backend switch of their own.
    """
    return _gtc_compress_jit(grad, residual, tau,
                             interpret=auto_interpret(interpret))
