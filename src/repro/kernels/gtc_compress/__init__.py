from repro.kernels.gtc_compress.ops import gtc_compress
from repro.kernels.gtc_compress.ref import gtc_compress_ref

__all__ = ["gtc_compress", "gtc_compress_ref"]
