"""Pallas TPU kernel: GTC error-feedback threshold compression.

On GPU (Strom 2015) this was a warp-level compaction into (index, value)
pairs.  On TPU there is no efficient scatter/compaction in VMEM — and no
sparse ICI collective to feed it to — so the TPU-native form keeps the
*tile-shaped* send mask (DESIGN.md §2): one fused elementwise pass that
reads (grad, residual) tiles from HBM into VMEM and writes (send,
new_residual) tiles, saturating HBM bandwidth (arithmetic intensity ~1
FLOP/byte: purely memory-bound, so fusion — one pass instead of the 4
XLA would need — is the whole win).

Tiling: flat 1D view, (8, 1024) f32 tiles (8x128 VREG lanes, 32 KiB/tile
x 4 buffers = 128 KiB VMEM working set).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
SUB = 8
TILE = SUB * LANE


def _kernel(g_ref, r_ref, tau_ref, send_ref, newr_ref):
    g = g_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    tau = tau_ref[0]
    acc = r + g
    send = jnp.where(jnp.abs(acc) > tau, jnp.sign(acc) * tau, 0.0)
    send_ref[...] = send
    newr_ref[...] = acc - send


@functools.partial(jax.jit, static_argnames=("interpret",))
def gtc_compress_flat(grad_flat, residual_flat, tau, *, interpret=False):
    """grad/residual: (N,) f32 with N % TILE == 0; tau: (1,) f32."""
    n = grad_flat.shape[0]
    grid = (n // TILE,)
    g2 = grad_flat.reshape(-1, LANE)
    r2 = residual_flat.reshape(-1, LANE)
    bs = pl.BlockSpec((SUB, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[bs, bs, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[bs, bs],
        out_shape=[jax.ShapeDtypeStruct(g2.shape, jnp.float32),
                   jax.ShapeDtypeStruct(g2.shape, jnp.float32)],
        interpret=interpret,
    )(g2, r2, tau)
    return out[0].reshape(n), out[1].reshape(n)
