"""Shared backend dispatch for every kernel subpackage.

Two knobs, one convention, resolved here so the six kernel wrappers
can't drift:

  ``interpret`` — how a ``pl.pallas_call`` executes.  ``None`` (the
    default everywhere) auto-detects: compiled Mosaic on TPU, the Pallas
    interpreter everywhere else.  Callers that *measure or pin* the
    kernel body on CPU pass ``interpret=True`` explicitly.

  ``use_kernel`` — whether to run the Pallas kernel at all.  ``None``
    auto-detects: the kernel on TPU, the pure-jnp ref twin off-TPU.
    Ops that have a ref twin fast enough to serve as the off-TPU
    production path (decode_attention, topk_sample) take this second
    knob; the interpreter is *correct* everywhere but ~5x slower than
    plain XLA on CPU for small decode shapes, so it is the parity-test
    surface, never the serving path.
"""
from __future__ import annotations

from typing import Optional

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def auto_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret`` flag: compiled on TPU, interpreter else."""
    if interpret is None:
        return not on_tpu()
    return bool(interpret)


def auto_use_kernel(use_kernel: Optional[bool] = None) -> bool:
    """Resolve a ``use_kernel`` flag: Pallas on TPU, ref twin else."""
    if use_kernel is None:
        return on_tpu()
    return bool(use_kernel)
