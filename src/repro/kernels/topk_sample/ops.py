"""Public wrapper: fused top-k extraction + Gumbel-max sampling.

``topk_sample`` replaces the decode engine's full-vocab argsort sampler
with a two-stage kernel (per-tile top-k candidates, then merge+sample
over (B, k_cap) — see kernel.py) or, off-TPU, the pure-jnp ref twin
with the same bitwise semantics.  Dispatch follows kernels/_dispatch:
``use_kernel=None`` auto-selects kernel-on-TPU / ref elsewhere;
``interpret=None`` auto-selects compiled-on-TPU / interpreter elsewhere
(parity tests pass use_kernel=True, interpret=True).

The Gumbel noise is derived here, once, in plain XLA ops — (B, k_cap)
from fold_in(PRNGKey(seed), pos) per row, applied by candidate rank —
and handed to whichever backend runs, so sampled tokens are identical
across backends by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._dispatch import auto_interpret, auto_use_kernel
from repro.kernels.topk_logits.kernel import NEG, topk_logits_tiles
from repro.kernels.topk_sample.kernel import topk_sample_tiles
from repro.kernels.topk_sample.ref import topk_sample_ref

# Candidate-set width: the sampler's whole post-extraction state is
# (B, K_CAP_DEFAULT).  top_k requests beyond this can't be honored by
# the fused path (TokenServer rejects them at submit when fused).
K_CAP_DEFAULT = 32


def gumbel_rows(seeds, pos, k: int):
    """Per-row rank-indexed Gumbel noise: (B,) seeds x (B,) pos ->
    (B, k) f32.  Reproducible per (seed, pos) and independent of batch
    composition — the same contract as serve/sampling."""
    def row(seed, p):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), p)
        return jax.random.gumbel(key, (k,), jnp.float32)
    return jax.vmap(row)(seeds, pos)


@functools.partial(jax.jit,
                   static_argnames=("k_cap", "greedy", "v_tile",
                                    "interpret"))
def _topk_sample_kernel(logits, temperature, top_k, top_p, gumbel, *,
                        k_cap, greedy, v_tile=2048, interpret=False):
    b, v = logits.shape
    r_tile = 128 if b >= 128 else max(8, 1 << (b - 1).bit_length())
    vt = max(min(v_tile, 1 << (v - 1).bit_length()), 128)
    rpad = (-b) % r_tile
    vpad = (-v) % vt
    xp = jnp.pad(logits.astype(jnp.float32), ((0, rpad), (0, vpad)),
                 constant_values=NEG)
    cand_v, cand_i = topk_logits_tiles(xp, k=k_cap, r_tile=r_tile,
                                       v_tile=vt, interpret=interpret)
    cpad = (-cand_v.shape[1]) % 128
    cand_v = jnp.pad(cand_v, ((0, 0), (0, cpad)), constant_values=NEG)
    cand_i = jnp.pad(cand_i, ((0, 0), (0, cpad)))
    pad1 = lambda a, dt: jnp.pad(a.astype(dt), (0, rpad))[:, None]
    vals, idx, tok = topk_sample_tiles(
        cand_v, cand_i, pad1(temperature, jnp.float32),
        pad1(top_k, jnp.int32), pad1(top_p, jnp.float32),
        jnp.pad(gumbel, ((0, rpad), (0, 0))),
        k_cap=k_cap, greedy=greedy, interpret=interpret)
    return vals[:b], idx[:b], tok[:b, 0]


def topk_sample(logits, temperature=None, top_k=None, top_p=None,
                seeds=None, pos=None, *, k_cap: int = K_CAP_DEFAULT,
                greedy: bool = False, use_kernel=None, interpret=None):
    """logits (B, V) -> (vals (B,k_cap) f32 desc, idx (B,k_cap) i32,
    token (B,) i32) in one fused pass.

    ``greedy=True`` (static): token is argmax(logits) bitwise; the
    per-row knobs and seeds/pos are ignored.  Otherwise temperature /
    top_k / top_p / seeds / pos are (B,) per-row arrays; temperature<=0
    is the per-row greedy sentinel.  Nucleus mass is measured within
    the top-k_cap candidate set (see ref.py for the exact semantics and
    the cross-backend determinism contract).
    """
    b, v = logits.shape
    kc = min(k_cap, v)
    if greedy:
        z32 = jnp.zeros((b,), jnp.float32)
        temperature, top_k, top_p = z32, jnp.zeros((b,), jnp.int32), z32
        gumbel = jnp.zeros((b, kc), jnp.float32)
    else:
        gumbel = gumbel_rows(seeds, pos, kc)
    if not auto_use_kernel(use_kernel):
        return topk_sample_ref(logits, temperature, top_k, top_p, gumbel,
                               k_cap=kc, greedy=greedy)
    return _topk_sample_kernel(logits, temperature, top_k, top_p, gumbel,
                               k_cap=kc, greedy=greedy,
                               interpret=auto_interpret(interpret))
