from repro.kernels.topk_sample.ops import K_CAP_DEFAULT, gumbel_rows, topk_sample
from repro.kernels.topk_sample.ref import topk_sample_ref

__all__ = ["K_CAP_DEFAULT", "gumbel_rows", "topk_sample", "topk_sample_ref"]
