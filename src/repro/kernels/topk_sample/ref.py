"""Pure-jnp oracle for the fused top-k/top-p Gumbel sampler.

This ref *defines* the op's semantics; the kernel is pinned against it
exactly (vals, idx, and the sampled token).  It intentionally differs
from ``serve/sampling.sample_tokens`` in one documented way: the
nucleus (top-p) mass is measured inside the top-``k_cap`` candidate set
(a renormalized softmax over k_cap values) rather than over the full
vocabulary.  With k_cap=32 the truncated tail mass is negligible for
real decode distributions, and the payoff is a sampler that never
touches a (B, V) sort — one top-k extraction and (B, k_cap) arithmetic.

Determinism contract shared with the kernel path:

  * ``lax.top_k`` and the kernel's iterative max-extraction both break
    value ties toward the lower vocab index, so vals/idx agree bitwise.
  * the exclusive cumulative mass is a (k_cap, k_cap) strict-upper-
    triangular matmul in f32 HIGHEST — the same primitive the kernel
    lowers, so the nucleus keep-mask agrees bitwise (a parallel-prefix
    ``cumsum`` could differ in the last ulp at the top-p boundary).
  * the Gumbel noise is *passed in* (computed once in ops.py from
    (seed, pos)), never re-derived per backend.

temperature <= 0 is the greedy sentinel per row: the returned token is
``argmax(logits)`` bitwise (rank-0 of a stable top-k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def topk_sample_ref(logits, temperature=None, top_k=None, top_p=None,
                    gumbel=None, *, k_cap: int = 32, greedy: bool = False):
    """logits (B, V) -> (vals (B,k_cap) f32 desc, idx (B,k_cap) i32,
    token (B,) i32).

    ``greedy=True`` (static) skips the sampling math entirely: token is
    the rank-0 index.  Otherwise temperature/top_k/top_p are (B,)
    per-row knobs and ``gumbel`` is (B, k_cap) f32 noise applied by
    candidate rank.
    """
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k_cap)
    # identity barrier, load-bearing on CPU: with the (B, k_cap)
    # sampling arithmetic fused downstream, XLA's TopkRewriter no
    # longer matches the sort+slice pattern and lax.top_k stays a full
    # stable (B, V) sort — ~50x slower than the TopK custom call at
    # V=4k.  Isolating the consumers restores the rewrite; numerics
    # are unchanged.
    vals, idx = jax.lax.optimization_barrier((vals, idx))
    idx = idx.astype(jnp.int32)
    if greedy:
        return vals, idx, idx[:, 0]
    safe_t = jnp.where(temperature > 0, temperature, 1.0)
    svals = vals / safe_t.astype(jnp.float32)[:, None]
    e = jnp.exp(svals - svals[:, :1])          # rank 0 is the row max
    probs = e / e.sum(axis=1, keepdims=True)
    rank = jnp.arange(k_cap, dtype=jnp.int32)
    tri = (rank[:, None] < rank[None, :]).astype(jnp.float32)
    excl = jax.lax.dot(probs, tri,
                       precision=jax.lax.Precision.HIGHEST)  # mass before j
    k_eff = jnp.where(top_k > 0, jnp.minimum(top_k, k_cap), k_cap)
    keep = rank[None, :] < k_eff[:, None]
    keep &= excl < top_p[:, None]
    keep |= rank[None, :] == 0                 # rank 0 always sampleable
    pick = jnp.argmax(jnp.where(keep, svals, NEG_INF) + gumbel, axis=1)
    sampled = jnp.take_along_axis(idx, pick[:, None], axis=1)[:, 0]
    token = jnp.where(temperature > 0, sampled, idx[:, 0])
    return vals, idx, token.astype(jnp.int32)
