"""Pallas TPU kernel: candidate merge + Gumbel-max sampling, one pass.

Stage 2 of the fused sampler.  Stage 1 is ``topk_logits_tiles`` (reused
from kernels/topk_logits): per-vocab-tile top-k_cap candidates.  This
kernel takes the (R, C = nTiles*k_cap) candidate values/indices and, in
one VMEM pass per row block:

  1. merges them to the global top-k_cap (k_cap rounds of iterative
     max-extraction with min-position tie-break — candidate positions
     are ordered by vocab tile then rank, so min position == min vocab
     index, matching ``lax.top_k``'s stable ordering bitwise);
  2. temperature-scales, softmaxes over the k_cap candidates, builds
     the exclusive cumulative mass with a strict-upper-triangular
     matmul (no cumsum — Mosaic-friendly and bitwise vs the ref);
  3. applies the per-row top-k / top-p keep mask, adds the precomputed
     Gumbel noise, argmaxes, and emits the sampled vocab id — greedy
     sentinel rows (temperature <= 0) emit rank 0.

Everything after stage 1 is (R, k_cap)-shaped arithmetic: the sampler
never materializes a (B, V) sort or argsort.  ``greedy=True`` (static)
compiles steps 2–3 away entirely; the token is the rank-0 index, which
equals ``jnp.argmax(logits)`` bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.4e38          # candidate-extraction mask (~f32 min)
NEG_INF = -1e30        # sampling keep-mask, matches serve/sampling


def _kernel(cv_ref, ci_ref, t_ref, tk_ref, tp_ref, g_ref,
            vals_ref, idx_ref, tok_ref, *, k_cap: int, greedy: bool):
    cv = cv_ref[...].astype(jnp.float32)                  # (R, C)
    ci = ci_ref[...]
    r, c = cv.shape
    col = jax.lax.broadcasted_iota(jnp.int32, cv.shape, 1)

    def round_(i, carry):
        cv, vals, idx = carry
        m = jnp.max(cv, axis=1)
        is_max = cv == m[:, None]
        a = jnp.min(jnp.where(is_max, col, c), axis=1)    # min position
        one = col == a[:, None]
        vocab = jnp.sum(jnp.where(one, ci, 0), axis=1)
        vals = jax.lax.dynamic_update_slice(vals, m[:, None], (0, i))
        idx = jax.lax.dynamic_update_slice(
            idx, vocab[:, None].astype(jnp.int32), (0, i))
        cv = jnp.where(one, NEG, cv)
        return cv, vals, idx

    vals0 = jnp.full((r, k_cap), NEG, jnp.float32)
    idx0 = jnp.zeros((r, k_cap), jnp.int32)
    _, vals, idx = jax.lax.fori_loop(0, k_cap, round_, (cv, vals0, idx0))
    vals_ref[...] = vals
    idx_ref[...] = idx
    if greedy:
        tok_ref[...] = idx[:, :1]
        return

    t = t_ref[...]                                        # (R, 1)
    safe_t = jnp.where(t > 0, t, 1.0).astype(jnp.float32)
    svals = vals / safe_t
    e = jnp.exp(svals - svals[:, :1])                     # rank 0 = max
    probs = e / e.sum(axis=1, keepdims=True)
    rank = jax.lax.broadcasted_iota(jnp.int32, (r, k_cap), 1)
    ri = jax.lax.broadcasted_iota(jnp.int32, (k_cap, k_cap), 0)
    rj = jax.lax.broadcasted_iota(jnp.int32, (k_cap, k_cap), 1)
    tri = (ri < rj).astype(jnp.float32)
    excl = jax.lax.dot(probs, tri,
                       precision=jax.lax.Precision.HIGHEST)
    k_eff = jnp.where(tk_ref[...] > 0,
                      jnp.minimum(tk_ref[...], k_cap), k_cap)   # (R, 1)
    keep = rank < k_eff
    keep &= excl < tp_ref[...]
    keep |= rank == 0
    score = jnp.where(keep, svals, NEG_INF) + g_ref[...]
    m = jnp.max(score, axis=1)
    a = jnp.min(jnp.where(score == m[:, None], rank, k_cap), axis=1)
    sampled = jnp.sum(jnp.where(rank == a[:, None], idx, 0), axis=1)
    tok_ref[...] = jnp.where(t > 0, sampled[:, None],
                             idx[:, :1]).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("k_cap", "greedy", "interpret"))
def topk_sample_tiles(cand_v, cand_i, temp, top_k, top_p, gumbel, *,
                      k_cap: int, greedy: bool = False,
                      interpret: bool = False):
    """cand_v/cand_i (R, C) per-tile candidates (C padded, NEG-filled);
    temp/top_k/top_p (R, 1); gumbel (R, k_cap).  R % r_tile == 0.

    Returns (vals (R,k_cap) f32 desc, idx (R,k_cap) i32, token (R,1) i32).
    """
    rr, c = cand_v.shape
    r_tile = 128 if rr >= 128 else rr
    kern = functools.partial(_kernel, k_cap=k_cap, greedy=greedy)
    row = lambda i: (i, 0)
    vals, idx, tok = pl.pallas_call(
        kern,
        grid=(rr // r_tile,),
        in_specs=[pl.BlockSpec((r_tile, c), row),
                  pl.BlockSpec((r_tile, c), row),
                  pl.BlockSpec((r_tile, 1), row),
                  pl.BlockSpec((r_tile, 1), row),
                  pl.BlockSpec((r_tile, 1), row),
                  pl.BlockSpec((r_tile, k_cap), row)],
        out_specs=[pl.BlockSpec((r_tile, k_cap), row),
                   pl.BlockSpec((r_tile, k_cap), row),
                   pl.BlockSpec((r_tile, 1), row)],
        out_shape=[jax.ShapeDtypeStruct((rr, k_cap), jnp.float32),
                   jax.ShapeDtypeStruct((rr, k_cap), jnp.int32),
                   jax.ShapeDtypeStruct((rr, 1), jnp.int32)],
        interpret=interpret,
    )(cand_v, cand_i, temp, top_k, top_p, gumbel)
    return vals, idx, tok
