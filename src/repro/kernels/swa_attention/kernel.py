"""Pallas TPU kernel: causal sliding-window flash attention.

The long_500k shape lives or dies on this kernel: S=524,288 with window
W=4096 must cost O(S*W), never O(S^2).  The banded structure is expressed
*in the grid*, not in a mask over dead blocks: grid =
(B*H, S/Tq, n_kv_band) where n_kv_band = W/Tk + 1 covers exactly the
[qi*Tq - W, qi*Tq + Tq) key band of one query tile.  Blocks wholly outside
the band are never fetched from HBM — this is the "masked blocks still
execute" waste (DESIGN.md §5) going away; XLA's dense flash scan can't
skip them because its mask is data, not schedule.

kv tiles enter via BlockSpec index_map (qi - W/Tk + kj), clamped at 0;
out-of-range contributions are killed by the position mask (a clamped
duplicate fetch of block 0 is masked — same trick as JAX's own
splash-attention).  Online-softmax state (m, l, acc) lives in VMEM
scratch across the kv-band grid steps; MXU does the two (Tq,hd)x(hd,Tk)
matmuls per step; hd is padded to 128 lanes upstream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, a_sc, *,
            t_q: int, t_kv: int, window: int, band_blocks: int, n_band: int,
            scale: float, softcap: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc[...], NEG)
        l_sc[...] = jnp.zeros_like(l_sc[...])
        a_sc[...] = jnp.zeros_like(a_sc[...])

    # absolute positions of this (q-tile, kv-tile) pair; negative raw block
    # ids clamp to 0 in the BlockSpec (a duplicate fetch) — the `raw >= 0`
    # mask term kills those steps so block 0 is counted exactly once
    raw = qi * (t_q // t_kv) - band_blocks + kj
    kv_block = jnp.maximum(raw, 0)
    q_pos = qi * t_q + jax.lax.broadcasted_iota(jnp.int32, (t_q, t_kv), 0)
    k_pos = kv_block * t_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                       (t_q, t_kv), 1)

    q = q_ref[0].astype(jnp.float32)                     # (Tq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (Tk, hd)
    v = v_ref[0].astype(jnp.float32)                     # (Tk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            precision=jax.lax.Precision.HIGHEST) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = (k_pos <= q_pos) & (q_pos - k_pos < window) & (raw >= 0)
    s = jnp.where(mask, s, NEG)

    m_old = m_sc[...]                                    # (Tq, 1)
    m_new = jnp.maximum(m_old, s.max(axis=1, keepdims=True))
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1, keepdims=True)
    a_sc[...] = a_sc[...] * corr + jax.lax.dot(
        p.astype(jnp.float32), v, precision=jax.lax.Precision.HIGHEST)
    m_sc[...] = m_new

    @pl.when(kj == n_band - 1)
    def _finish():
        o_ref[0] = (a_sc[...] / jnp.maximum(l_sc[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "t_q", "t_kv",
                                             "softcap", "interpret"))
def swa_attention_tiles(q, k, v, *, window: int, t_q: int = 128,
                        t_kv: int = 128, softcap: float = 0.0,
                        interpret: bool = False):
    """q/k/v (BH, S, hd): S % t_q == 0, t_q % t_kv == 0.  ``window`` is the
    exact mask width; the fetched band rounds it up to whole kv tiles.

    Returns (BH, S, hd) f32.
    """
    bh, s, hd = q.shape
    assert t_q % t_kv == 0 and s % t_q == 0
    n_q = s // t_q
    band_blocks = -(-window // t_kv)          # ceil: fetched, mask trims
    n_band = band_blocks + t_q // t_kv        # band + the diagonal tiles
    scale = 1.0 / np.sqrt(hd)

    def kv_index(b, qi, kj):
        return (b, jnp.maximum(qi * (t_q // t_kv) - band_blocks + kj, 0), 0)

    kern = functools.partial(_kernel, t_q=t_q, t_kv=t_kv, window=window,
                             band_blocks=band_blocks, n_band=n_band,
                             scale=scale, softcap=softcap)
    out = pl.pallas_call(
        kern,
        grid=(bh, n_q, n_band),
        in_specs=[
            pl.BlockSpec((1, t_q, hd), lambda b, qi, kj: (b, qi, 0)),
            pl.BlockSpec((1, t_kv, hd), kv_index),
            pl.BlockSpec((1, t_kv, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, t_q, hd), lambda b, qi, kj: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((t_q, 1), jnp.float32),
            pltpu.VMEM((t_q, 1), jnp.float32),
            pltpu.VMEM((t_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
