"""Pure-jnp oracle: causal sliding-window attention, full (S,S) mask."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG = -1e30


def swa_attention_ref(q, k, v, window: int, *, softcap: float = 0.0):
    """q (B,H,S,hd) ; k/v (B,H,S,hd) (GQA pre-broadcast upstream).

    Causal + window: key j visible to query i iff  i - window < j <= i.
    Returns (B,H,S,hd) f32.
    """
    b, h, s, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = (j <= i) & (i - j < window)
    logits = jnp.where(mask[None, None], logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
