from repro.kernels.swa_attention.ops import swa_attention
from repro.kernels.swa_attention.ref import swa_attention_ref

__all__ = ["swa_attention", "swa_attention_ref"]
