"""Public wrapper: GQA head broadcast, padding, tile-size selection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._dispatch import auto_interpret
from repro.kernels.swa_attention.kernel import swa_attention_tiles


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def _swa_attention_jit(q, k, v, *, window: int, softcap: float,
                       interpret: bool):
    b, hq, s, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    t_q = 128 if s >= 128 else max(8, 1 << (s - 1).bit_length())
    t_kv = min(128, t_q)
    sp = (-s) % t_q
    hdp = (-hd) % 128 if hd >= 128 else (128 - hd)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sp), (0, hdp)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sp), (0, hdp)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sp), (0, hdp)))
    qf = qp.reshape(b * hq, s + sp, hd + hdp)
    # padded hd inflates 1/sqrt(hd); rescale q to compensate
    qf = qf * jnp.asarray(((hd + hdp) / hd) ** 0.5, qf.dtype)
    kf = kp.reshape(b * hq, s + sp, hd + hdp)
    vf = vp.reshape(b * hq, s + sp, hd + hdp)
    o = swa_attention_tiles(qf, kf, vf, window=window, t_q=t_q, t_kv=t_kv,
                            softcap=softcap, interpret=interpret)
    return o.reshape(b, hq, s + sp, hd + hdp)[:, :, :s, :hd]


def swa_attention(q, k, v, window: int, *, softcap: float = 0.0,
                  interpret=None):
    """q (B,Hq,S,hd); k/v (B,Hkv,S,hd), Hq % Hkv == 0.  Causal + window.

    Returns (B,Hq,S,hd) f32.  Pads S to the query tile and hd to 128
    lanes; GQA is realized by broadcasting kv heads (the kernel is
    bandwidth-bound on kv tiles either way).  ``interpret=None``
    auto-detects via ``kernels._dispatch``.
    """
    return _swa_attention_jit(q, k, v, window=window, softcap=softcap,
                              interpret=auto_interpret(interpret))
