"""Pallas TPU kernels for the paper's compute hot-spots.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper), ref.py (pure-jnp oracle).  All validated
in interpret=True mode on CPU; `interpret=False` is the TPU path.
Backend resolution is shared (``kernels/_dispatch``): every wrapper
takes ``interpret=None`` = compiled-on-TPU / interpreter-elsewhere, and
ops with a production-grade ref twin additionally take
``use_kernel=None`` = Pallas-on-TPU / ref-twin-elsewhere.

  topk_logits      — teacher target generation: top-k=20 over senone/token
                     vocab via k-round max-extraction on VMEM tiles (§3.2.2)
  sparse_ce        — student loss: fused full-vocab logsumexp + teacher-index
                     gather streaming (D,Vt) unembedding tiles (§3.2.2);
                     differentiable (custom_vjp, streamed backward)
  swa_attention    — banded flash attention whose *grid* skips out-of-window
                     kv blocks (long_500k path for SWA archs)
  gtc_compress     — error-feedback threshold sparsification, fused
                     elementwise pass (§3.5 / Strom 2015)
  decode_attention — fused single-token decode tail: RoPE + one-hot ring
                     write + slot-validity mask + softmax·V in one pass
                     (linear / SWA-ring / paged-gather variants)
  topk_sample      — fused top-k/top-p Gumbel sampler: per-tile top-k
                     candidates merged and sampled in one (B, k_cap) pass
"""
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.gtc_compress import gtc_compress, gtc_compress_ref
from repro.kernels.sparse_ce import (sparse_ce_lse_gather,
                                     sparse_ce_lse_gather_ref,
                                     topk_distill_ce, topk_distill_ce_ref)
from repro.kernels.swa_attention import swa_attention, swa_attention_ref
from repro.kernels.topk_logits import topk_logits, topk_logits_ref
from repro.kernels.topk_sample import topk_sample, topk_sample_ref

__all__ = [
    "decode_attention", "decode_attention_ref",
    "gtc_compress", "gtc_compress_ref",
    "sparse_ce_lse_gather", "sparse_ce_lse_gather_ref",
    "topk_distill_ce", "topk_distill_ce_ref",
    "swa_attention", "swa_attention_ref",
    "topk_logits", "topk_logits_ref",
    "topk_sample", "topk_sample_ref",
]
