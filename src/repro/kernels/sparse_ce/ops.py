"""Public wrappers: padding + the distill-CE loss built on the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparse_ce.kernel import sparse_ce_tiles


@functools.partial(jax.jit, static_argnames=("softcap", "interpret",
                                             "v_tile"))
def sparse_ce_lse_gather(h, w, idx, *, softcap: float = 0.0,
                         v_tile: int = 1024, interpret: bool = True):
    """h (T,D), w (D,V), idx (T,K) -> (lse (T,), gathered (T,K)) f32.

    Pads T to the 128-row tile and V to the vocab tile; padding rows cost
    compute but never flow back (caller slices).  For D > 8192 chunk D
    upstream (none of the assigned archs need it: max d_model is 8192).
    """
    t, d = h.shape
    v = w.shape[1]
    t_tile = 128 if t >= 128 else max(8, 1 << (t - 1).bit_length())
    vt = min(v_tile, 1 << (v - 1).bit_length())
    vt = max(vt, 128)
    tp, vp = (-t) % t_tile, (-v) % vt
    hp = jnp.pad(h, ((0, tp), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, vp)))
    ip = jnp.pad(idx.astype(jnp.int32), ((0, tp), (0, 0)))
    lse, g = sparse_ce_tiles(hp, wp, ip, t_tile=t_tile, v_tile=vt,
                             softcap=softcap, interpret=interpret,
                             v_total=v)
    return lse[:t, 0], g[:t]


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def topk_distill_ce(h, w, topk_vals, topk_idx, *, softcap: float = 0.0,
                    interpret: bool = True):
    """The paper's SSL loss, fused-kernel path.  h (T,D) flat frames."""
    lse, z = sparse_ce_lse_gather(h, w, topk_idx, softcap=softcap,
                                  interpret=interpret)
    q = jax.nn.softmax(topk_vals.astype(jnp.float32), axis=-1)
    return jnp.mean(jnp.sum(q * (lse[:, None] - z), axis=-1))
