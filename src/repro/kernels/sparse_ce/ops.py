"""Public wrappers: padding + the distill-CE loss built on the kernel.

``interpret=None`` auto-detects via kernels/_dispatch (compiled on TPU,
interpreter elsewhere).  Both ops are differentiable wrt (h, w): JAX
cannot autodiff through a ``pallas_call``, so ``sparse_ce_lse_gather``
carries a ``custom_vjp`` whose backward pass is a *streamed XLA chunk
recompute* — for each vocab chunk it rebuilds the capped logits,
reconstitutes the exact softmax from the saved forward ``lse``,
scatter-adds the gathered-logit cotangent at the teacher indices, and
chains through the softcap (d tanh(x/c)*c = 1 - (capped/c)^2) before
accumulating dh and the dw chunk.  Peak memory stays O(T*chunk + D*V),
never (T, V) — the same contract as the forward kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._dispatch import auto_interpret
from repro.kernels.sparse_ce.kernel import sparse_ce_tiles


@functools.partial(jax.jit, static_argnames=("softcap", "interpret",
                                             "v_tile"))
def _sparse_ce_lse_gather_jit(h, w, idx, *, softcap: float,
                              v_tile: int, interpret: bool):
    """Pads T to the 128-row tile and V to the vocab tile; padding rows
    cost compute but never flow back (caller slices).  For D > 8192
    chunk D upstream (none of the assigned archs need it)."""
    t, d = h.shape
    v = w.shape[1]
    t_tile = 128 if t >= 128 else max(8, 1 << (t - 1).bit_length())
    vt = min(v_tile, 1 << (v - 1).bit_length())
    vt = max(vt, 128)
    tp, vp = (-t) % t_tile, (-v) % vt
    hp = jnp.pad(h, ((0, tp), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, vp)))
    ip = jnp.pad(idx.astype(jnp.int32), ((0, tp), (0, 0)))
    lse, g = sparse_ce_tiles(hp, wp, ip, t_tile=t_tile, v_tile=vt,
                             softcap=softcap, interpret=interpret,
                             v_total=v)
    return lse[:t, 0], g[:t]


@functools.partial(jax.jit, static_argnames=("softcap", "chunk"))
def _lse_gather_bwd(h, w, idx, lse, g_lse, g_z, *, softcap: float,
                    chunk: int = 1024):
    t, d = h.shape
    v = w.shape[1]
    nchunks = (v + chunk - 1) // chunk
    wpad = jnp.pad(w, ((0, 0), (0, nchunks * chunk - v)))
    rows = jnp.arange(t)

    def body(carry, ci):
        dh, dw = carry
        wc = jax.lax.dynamic_slice_in_dim(wpad, ci * chunk, chunk, axis=1)
        raw = (h @ wc.astype(h.dtype)).astype(jnp.float32)
        if softcap:
            capped = jnp.tanh(raw / softcap) * softcap
        else:
            capped = raw
        vid = ci * chunk + jnp.arange(chunk)
        # exact softmax from the saved forward lse; padded tail -> 0
        p = jnp.where(vid[None, :] < v,
                      jnp.exp(capped - lse[:, None]), 0.0)
        dz = g_lse[:, None] * p
        loc = idx - ci * chunk
        inside = (loc >= 0) & (loc < chunk)
        dz = dz.at[rows[:, None], jnp.clip(loc, 0, chunk - 1)].add(
            jnp.where(inside, g_z, 0.0))
        if softcap:
            dz = dz * (1.0 - (capped / softcap) ** 2)
        dh = dh + dz @ wc.astype(jnp.float32).T
        dwc = h.astype(jnp.float32).T @ dz
        dw = jax.lax.dynamic_update_slice_in_dim(dw, dwc, ci * chunk,
                                                 axis=1)
        return (dh, dw), None

    init = (jnp.zeros((t, d), jnp.float32),
            jnp.zeros((d, nchunks * chunk), jnp.float32))
    (dh, dw), _ = jax.lax.scan(body, init, jnp.arange(nchunks))
    return dh.astype(h.dtype), dw[:, :v].astype(w.dtype)


@functools.lru_cache(maxsize=None)
def _lse_gather_fn(softcap: float, v_tile: int, interpret: bool):
    @jax.custom_vjp
    def f(h, w, idx):
        return _sparse_ce_lse_gather_jit(h, w, idx, softcap=softcap,
                                         v_tile=v_tile, interpret=interpret)

    def fwd(h, w, idx):
        out = _sparse_ce_lse_gather_jit(h, w, idx, softcap=softcap,
                                        v_tile=v_tile, interpret=interpret)
        return out, (h, w, idx, out[0])

    def bwd(res, g):
        h, w, idx, lse = res
        g_lse, g_z = g
        dh, dw = _lse_gather_bwd(h, w, idx, lse, g_lse, g_z,
                                 softcap=softcap)
        return dh, dw, None

    f.defvjp(fwd, bwd)
    return f


def sparse_ce_lse_gather(h, w, idx, *, softcap: float = 0.0,
                         v_tile: int = 1024, interpret=None):
    """h (T,D), w (D,V), idx (T,K) -> (lse (T,), gathered (T,K)) f32.

    Differentiable wrt h and w (custom_vjp; see module docstring).
    ``interpret=None`` auto-detects the backend.
    """
    fn = _lse_gather_fn(float(softcap), int(v_tile),
                        auto_interpret(interpret))
    return fn(h, w, idx)


def topk_distill_ce(h, w, topk_vals, topk_idx, *, softcap: float = 0.0,
                    interpret=None, mask=None):
    """The paper's SSL loss, fused-kernel path.  h (T,D) flat frames;
    ``mask`` (T,) optional frame-validity weights (masked mean, matching
    ``core/distill.chunked_topk_distill_ce``)."""
    lse, z = sparse_ce_lse_gather(h, w, topk_idx, softcap=softcap,
                                  interpret=interpret)
    q = jax.nn.softmax(topk_vals.astype(jnp.float32), axis=-1)
    nll = jnp.sum(q * (lse[:, None] - z), axis=-1)
    if mask is not None:
        mk = mask.reshape(-1).astype(jnp.float32)
        return jnp.sum(nll * mk) / jnp.maximum(mk.sum(), 1.0)
    return jnp.mean(nll)
