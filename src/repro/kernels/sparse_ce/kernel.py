"""Pallas TPU kernel: fused logsumexp + top-k gather over vocab tiles.

The student loss (paper §3.2.2) needs, per frame, (a) the full-vocab
logsumexp of the student logits and (b) the k student logits at the
teacher's stored indices.  Materializing (T, V) logits for V=262k at
train batch sizes would blow HBM; the fused kernel streams (D, Vt) tiles
of the unembedding through the MXU and keeps only:

  m, l : online logsumexp state            (Tt, 1)   f32
  g    : gathered logits at teacher ids    (Tt, K)   f32

VMEM working set per program: h (Tt, D) + w (D, Vt) + logits (Tt, Vt)
+ scratch — with Tt=128, D<=8192 f32 h-tile is 4 MB; callers chunk D
upstream for the few archs above that (ops.py notes).  Grid is
(T/Tt, V/Vt), vocab innermost ("arbitrary" order semantics: scratch
accumulates across the V dimension; outputs written on the last step).

The gather never leaves VREGs: `gathered = max over tile columns of
(logits where col == idx)` via a one-hot mask matmul-free select —
TPU-native replacement for the GPU's per-thread gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(h_ref, w_ref, idx_ref, lse_ref, g_ref, m_sc, l_sc, g_sc, *,
            v_tile: int, v_total: int, n_v: int, softcap: float):
    vj = pl.program_id(1)
    base = vj * v_tile

    @pl.when(vj == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc[...], NEG)
        l_sc[...] = jnp.zeros_like(l_sc[...])
        g_sc[...] = jnp.full_like(g_sc[...], NEG)

    h = h_ref[...].astype(jnp.float32)                    # (Tt, D)
    w = w_ref[...].astype(jnp.float32)                    # (D, Vt)
    logits = jax.lax.dot(h, w, precision=jax.lax.Precision.HIGHEST)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = base + col < v_total
    logits = jnp.where(valid, logits, NEG)

    # online logsumexp
    m_old = m_sc[...]                                     # (Tt, 1)
    m_new = jnp.maximum(m_old, logits.max(axis=1, keepdims=True))
    l_sc[...] = (l_sc[...] * jnp.exp(m_old - m_new)
                 + jnp.exp(logits - m_new).sum(axis=1, keepdims=True))
    m_sc[...] = m_new

    # gather teacher ids that live in this tile: select-by-equality
    idx = idx_ref[...]                                    # (Tt, K)
    loc = idx - base
    k = idx.shape[1]
    # (Tt, K): for each k, pick logits[t, loc] iff 0 <= loc < v_tile
    picked = jnp.take_along_axis(logits, jnp.clip(loc, 0, v_tile - 1),
                                 axis=1)
    inside = (loc >= 0) & (loc < v_tile)
    g_sc[...] = jnp.where(inside, picked, g_sc[...])

    @pl.when(vj == n_v - 1)
    def _finish():
        lse_ref[...] = m_sc[...] + jnp.log(jnp.maximum(l_sc[...], 1e-30))
        g_ref[...] = g_sc[...]


@functools.partial(jax.jit, static_argnames=("t_tile", "v_tile", "softcap",
                                             "interpret", "v_total"))
def sparse_ce_tiles(h, w, idx, *, t_tile: int = 128, v_tile: int = 1024,
                    softcap: float = 0.0, interpret: bool = False,
                    v_total: int = 0):
    """h (T,D) T%Tt==0; w (D,V) V%Vt==0; idx (T,K).

    ``v_total``: the true (unpadded) vocab size — columns past it are
    masked out of the logsumexp.  Defaults to w's (padded) width.

    -> (lse (T,1) f32, gathered (T,K) f32).
    """
    t, d = h.shape
    v = w.shape[1]
    k = idx.shape[1]
    n_t, n_v = t // t_tile, v // v_tile
    kern = functools.partial(_kernel, v_tile=v_tile,
                             v_total=v_total or v, n_v=n_v,
                             softcap=softcap)
    lse, g = pl.pallas_call(
        kern,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((t_tile, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, v_tile), lambda i, j: (0, j)),
            pl.BlockSpec((t_tile, k), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t_tile, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((t_tile, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
            jax.ShapeDtypeStruct((t, k), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((t_tile, 1), jnp.float32),
            pltpu.VMEM((t_tile, 1), jnp.float32),
            pltpu.VMEM((t_tile, k), jnp.float32),
        ],
        interpret=interpret,
    )(h, w, idx)
    return lse, g
