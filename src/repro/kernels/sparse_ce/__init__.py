from repro.kernels.sparse_ce.ops import sparse_ce_lse_gather, topk_distill_ce
from repro.kernels.sparse_ce.ref import sparse_ce_lse_gather_ref, topk_distill_ce_ref

__all__ = ["sparse_ce_lse_gather", "topk_distill_ce",
           "sparse_ce_lse_gather_ref", "topk_distill_ce_ref"]
