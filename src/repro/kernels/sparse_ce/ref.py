"""Pure-jnp oracle for the fused (logsumexp + top-k gather) inner loop."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparse_ce_lse_gather_ref(h, w, idx, *, softcap: float = 0.0):
    """h (T,D), w (D,V), idx (T,K) -> (lse (T,), gathered (T,K)) f32.

    Full-logit reference: materializes (T,V) once — the thing the kernel
    exists to avoid.
    """
    logits = (h.astype(jnp.float32) @ w.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    lse = jax.nn.logsumexp(logits, axis=-1)
    gathered = jnp.take_along_axis(logits, idx, axis=-1)
    return lse, gathered


def topk_distill_ce_ref(h, w, topk_vals, topk_idx, *, softcap: float = 0.0):
    """Paper SSL loss from the fused primitive (reference path)."""
    lse, z = sparse_ce_lse_gather_ref(h, w, topk_idx, softcap=softcap)
    q = jax.nn.softmax(topk_vals.astype(jnp.float32), axis=-1)
    return jnp.mean(jnp.sum(q * (lse[:, None] - z), axis=-1))
