"""Public wrapper: pad, run the tile kernel, merge per-tile candidates."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._dispatch import auto_interpret
from repro.kernels.topk_logits.kernel import NEG, topk_logits_tiles


@functools.partial(jax.jit, static_argnames=("k", "v_tile", "interpret"))
def _topk_logits_jit(logits, k: int, *, v_tile: int, interpret: bool):
    shape = logits.shape
    v = shape[-1]
    x = logits.reshape(-1, v)
    r = x.shape[0]
    r_tile = 128 if r >= 128 else max(8, 1 << (r - 1).bit_length())
    vt = min(v_tile, 1 << (v - 1).bit_length())
    vt = max(vt, 128)
    rpad = (-r) % r_tile
    vpad = (-v) % vt
    xp = jnp.pad(x.astype(jnp.float32), ((0, rpad), (0, vpad)),
                 constant_values=NEG)
    cand_v, cand_i = topk_logits_tiles(xp, k=min(k, vt), r_tile=r_tile,
                                       v_tile=vt, interpret=interpret)
    # merge candidates (R, nV*k) -> global top-k
    mv, mi = jax.lax.top_k(cand_v[:r], k)
    idx = jnp.take_along_axis(cand_i[:r], mi, axis=1)
    return (mv.reshape(*shape[:-1], k),
            idx.reshape(*shape[:-1], k).astype(jnp.int32))


def topk_logits(logits, k: int = 20, *, v_tile: int = 2048,
                interpret=None):
    """logits (..., V) -> (vals (..., k) f32, idx (..., k) i32), sorted desc.

    Two-stage: Pallas per-tile top-k, then a lax.top_k merge over the
    (tiny) candidate set.  Exact — every global top-k element is a local
    tile top-k element.  ``interpret=None`` auto-detects via
    ``kernels._dispatch``.
    """
    return _topk_logits_jit(logits, k, v_tile=v_tile,
                            interpret=auto_interpret(interpret))
