"""Pallas TPU kernel: blockwise top-k selection over the vocab axis.

Teacher target generation (paper §3.2.2) runs top-k(V=3,183 senones or up
to 262k tokens, k=20) over every frame — the selection is the hot loop the
paper parallelizes.  GPU implementations use warp-level bitonic/heap
selection; the TPU-native adaptation (DESIGN.md §2) is *iterative
max-extraction over VMEM tiles*: k rounds of (rowmax -> argmax-by-iota ->
mask) on an (R, Vt) tile, entirely in VREGs, no scatter, no sort network.
k=20 rounds x cheap vector ops beat a full sort when k << V.

Two-stage scheme for large V:
  stage 1 (this kernel): grid (rows/R, V/Vt); each program extracts the
    local top-k of its (R, Vt) tile into (R, k) candidate (val, idx) pairs.
  stage 2 (ops.py): merge the per-tile candidates — (R, nV*k) is tiny —
    with one jax.lax.top_k (itself a k-round extraction on one tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.4e38          # ~f32 min: masks extracted candidates


def _kernel(x_ref, vals_ref, idx_ref, *, k: int, v_tile: int, v_total: int):
    vj = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)                    # (R, Vt)
    r = x.shape[0]
    base = vj * v_tile
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    # mask vocab padding tail so it never wins
    x = jnp.where(base + col < v_total, x, NEG)

    def round_(i, carry):
        x, vals, idx = carry
        m = jnp.max(x, axis=1)                            # (R,)
        # first column achieving the max (iota tie-break, matches lax.top_k)
        is_max = x == m[:, None]
        a = jnp.min(jnp.where(is_max, col, v_tile), axis=1)  # (R,)
        vals = jax.lax.dynamic_update_slice(vals, m[:, None], (0, i))
        idx = jax.lax.dynamic_update_slice(idx, (base + a)[:, None].astype(jnp.int32), (0, i))
        x = jnp.where(col == a[:, None], NEG, x)
        return x, vals, idx

    vals0 = jnp.full((r, k), NEG, jnp.float32)
    idx0 = jnp.zeros((r, k), jnp.int32)
    _, vals, idx = jax.lax.fori_loop(0, k, round_, (x, vals0, idx0))
    vals_ref[...] = vals
    idx_ref[...] = idx


@functools.partial(jax.jit,
                   static_argnames=("k", "r_tile", "v_tile", "interpret"))
def topk_logits_tiles(x, *, k: int, r_tile: int = 128, v_tile: int = 2048,
                      interpret: bool = False):
    """x (R, V) f32/bf16, R % r_tile == 0, V % v_tile == 0 (pre-padded).

    Returns per-tile candidates (R, nV*k) vals f32 + idx i32.
    """
    rr, vv = x.shape
    grid = (rr // r_tile, vv // v_tile)
    kern = functools.partial(_kernel, k=k, v_tile=v_tile, v_total=vv)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((r_tile, v_tile), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((r_tile, k), lambda i, j: (i, j)),
                   pl.BlockSpec((r_tile, k), lambda i, j: (i, j))],
        out_shape=[
            jax.ShapeDtypeStruct((rr, grid[1] * k), jnp.float32),
            jax.ShapeDtypeStruct((rr, grid[1] * k), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return vals, idx
