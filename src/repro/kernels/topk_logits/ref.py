"""Pure-jnp oracle for top-k logit selection (paper §3.2.2, k=20)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_logits_ref(logits, k: int):
    """logits (..., V) -> (vals (..., k) f32 desc-sorted, idx (..., k) i32).

    Matches repro.core.logit_store.topk_compress *before* the max-shift:
    raw top-k values and their vocab indices.
    """
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    return vals, idx.astype(jnp.int32)
