from repro.kernels.topk_logits.ops import topk_logits
from repro.kernels.topk_logits.ref import topk_logits_ref

__all__ = ["topk_logits", "topk_logits_ref"]
