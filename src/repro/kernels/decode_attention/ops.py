"""Public entry point for fused single-token decode attention.

``decode_attention`` is the op ``models/attention.attention_decode``
dispatches to when built with ``use_kernel`` — one call replacing the
separate RoPE / ring-write / mask / softmax·V passes of the XLA tail.

Backend resolution follows the ``kernels._dispatch`` convention:

  use_kernel=None   Pallas kernel on TPU, the pure-jnp ref twin
                    everywhere else (the twin is the *same math* as the
                    pre-kernel XLA path, so off-TPU greedy decode stays
                    bitwise token-identical; the Pallas interpreter is
                    ~5x slower than XLA on CPU and is reserved for
                    parity tests via use_kernel=True, interpret=True).
  interpret=None    compiled Mosaic on TPU, interpreter elsewhere.

Kernel-path layout notes: the head dim is zero-padded to a multiple of
128 lanes (zero lanes contribute nothing to either dot; RoPE rotates
only the real ``hd`` lanes), and the grouped-query dim G is zero-padded
to a sublane multiple of 8 (pad rows are sliced off the output).  The
cache slot count S is used as-is — padding S would corrupt the ring
``pos % S`` arithmetic — so the compiled path expects S % 8 == 0, which
every cache in this repo satisfies (slot counts are powers of two).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels._dispatch import auto_interpret, auto_use_kernel
from repro.kernels.decode_attention.kernel import decode_attention_tiles
from repro.kernels.decode_attention.ref import decode_attention_ref


def _pad_last(x, to: int):
    d = x.shape[-1]
    pad = -d % to
    if not pad:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


@functools.partial(jax.jit,
                   static_argnames=("window", "softcap", "rope_theta",
                                    "write", "interpret"))
def _decode_attention_kernel(q, k_new, v_new, cache_k, cache_v, pos, *,
                             window, softcap, rope_theta, write, interpret):
    from repro.models import layers  # avoid import cycle at module load

    b, hq, _, hd = q.shape
    hkv = cache_k.shape[1]
    g = hq // hkv
    gp = -g % 8
    qg = q.reshape(b, hkv, g, hd)
    if gp:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp), (0, 0)))
    qg = _pad_last(qg, 128)
    kn = _pad_last(k_new, 128)
    vn = _pad_last(v_new, 128)
    ck = _pad_last(cache_k, 128)
    cv = _pad_last(cache_v, 128)
    if rope_theta:
        cos, sin = layers.rope_tables(pos, hd, rope_theta)  # (B, hd/2)
        cos = cos.astype(jnp.float32)
        sin = sin.astype(jnp.float32)
    else:
        cos = sin = jnp.zeros((b, 1), jnp.float32)
    out = decode_attention_tiles(
        qg, kn, vn, ck, cv, pos[:, None].astype(jnp.int32), cos, sin,
        hd=hd, window=window, scale=float(1.0 / np.sqrt(hd)),
        softcap=softcap, rope=bool(rope_theta), write=write,
        interpret=interpret)
    o = out[0][:, :, :g, :hd].reshape(b, hq, 1, hd)
    if write:
        nk, nv = out[1], out[2]
        return o, nk[..., :hd], nv[..., :hd]
    return o, cache_k, cache_v


def decode_attention(q, k_new, v_new, cache_k, cache_v, pos, *,
                     window: int = 0, softcap: float = 0.0,
                     rope_theta: float = 0.0, write: bool = True,
                     use_kernel=None, interpret=None):
    """Fused decode-attention tail for one token per row.

    q (B,Hq,1,hd), k_new/v_new (B,Hkv,1,hd) post-projection pre-RoPE;
    cache_k/cache_v (B,Hkv,S,hd); pos (B,) int32.  Static knobs:
    ``rope_theta>0`` rotates q/k_new at pos inside the op; ``write``
    ring-writes the new token at ``pos % S`` (paged callers pre-write
    their pool and pass the gathered view with ``write=False``);
    ``window>0`` selects the SWA-ring validity mask.

    Returns (o (B,Hq,1,hd) f32, new cache_k, new cache_v) — caches are
    returned unchanged when ``write=False``.
    """
    if not auto_use_kernel(use_kernel):
        return decode_attention_ref(
            q, k_new, v_new, cache_k, cache_v, pos, window=window,
            softcap=softcap, rope_theta=rope_theta, write=write)
    return _decode_attention_kernel(
        q, k_new, v_new, cache_k, cache_v, pos, window=window,
        softcap=softcap, rope_theta=rope_theta, write=write,
        interpret=auto_interpret(interpret))
