"""Pallas TPU kernel: one-shot single-token decode attention.

The decode hot loop's tail — RoPE rotation, per-row one-hot K/V ring
write, slot-validity masking, masked softmax·V — is five separate XLA
passes today, each materializing a (B,Hkv,S,hd) intermediate (rotated
k, ck copy, cv copy, scores, probs).  At decode batch sizes the tail is
pure HBM bandwidth: ~5 full-cache round-trips per token.  This kernel
fuses all of it into one ``pallas_call`` over grid (B, Hkv): each
program pulls its row's (S, hd) K and V tiles into VMEM **once**,
applies the rotation to the incoming q/k vectors in VREGs, writes the
new token into its ring slot with an iota==slot select (no scatter),
masks by slot validity, and runs the (G,S)x(S,hd) softmax·V entirely
on-chip — cache traffic drops from ~5 passes to one read + one
token-row write (``input_output_aliases`` keeps the cache update
in-place on TPU).

Mask variants (static):
  window=0            linear layout: slot j valid iff j <= pos
  window=W            SWA ring: slot j holds the latest p <= pos with
                      p % S == j; valid iff 0 <= p and pos - p < W
  write=False         paged-gather view: the pool write + block-table
                      gather ran upstream (indices are data, not
                      schedule); the kernel fuses the mask + softmax·V
                      tail only, and emits no cache outputs.

The mask arithmetic mirrors ``models/attention.decode_slot_validity``
(the shared helper the ref oracle uses) in ``broadcasted_iota`` form —
parity is pinned kernel-vs-ref in tests/test_decode_kernels.py.

S and G are whole-row blocks: decode caches are short (a ring is at
most the window), so one program's VMEM working set — q (G,128) + 2x
(S,128) K/V + (G,S) scores — is ~70 KB at S=1024, far under the 16 MB
budget; no online-softmax banding is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _rope_rotate(x, cos, sin, hd: int):
    """Rotate the first ``hd`` lanes of x (rows, hd_padded) in f32;
    padding lanes pass through untouched (they are zero)."""
    hd2 = hd // 2
    x1 = x[:, :hd2]
    x2 = x[:, hd2:hd]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    parts = [o1, o2]
    if x.shape[1] > hd:
        parts.append(x[:, hd:])
    return jnp.concatenate(parts, axis=1)


def _kernel(q_ref, kn_ref, vn_ref, ck_ref, cv_ref, pos_ref, cos_ref,
            sin_ref, *refs, hd: int, window: int, scale: float,
            softcap: float, rope: bool, write: bool):
    if write:
        o_ref, nk_ref, nv_ref = refs
    else:
        (o_ref,) = refs
    p = pos_ref[0, 0]
    s = ck_ref.shape[2]
    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hdp)
    kn = kn_ref[0, 0].astype(jnp.float32)                # (1, hdp)
    if rope:
        cos = cos_ref[...].astype(jnp.float32)           # (1, hd/2)
        sin = sin_ref[...].astype(jnp.float32)
        q = _rope_rotate(q, cos, sin, hd)
        kn = _rope_rotate(kn, cos, sin, hd)
    ck = ck_ref[0, 0]                                    # (S, hdp)
    cv = cv_ref[0, 0]
    if write:
        slot = jax.lax.rem(p, s)
        row = jax.lax.broadcasted_iota(jnp.int32, (s, 1), 0)
        ck = jnp.where(row == slot, kn.astype(ck.dtype), ck)
        cv = jnp.where(row == slot, vn_ref[0, 0].astype(cv.dtype), cv)
        nk_ref[0, 0] = ck
        nv_ref[0, 0] = cv

    sc = jax.lax.dot_general(q, ck.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             precision=jax.lax.Precision.HIGHEST) * scale
    if softcap:
        sc = jnp.tanh(sc / softcap) * softcap
    idx = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)   # (G, S)
    if window:
        # decode_slot_validity ring math, iota form
        kpos = p - jax.lax.rem(p - idx, s)
        kpos = jnp.where(kpos > p, kpos - s, kpos)
        valid = (kpos >= 0) & (p - kpos < window) & (kpos <= p)
    else:
        valid = idx <= p
    sc = jnp.where(valid, sc, NEG_INF)
    m = sc.max(axis=1, keepdims=True)
    e = jnp.exp(sc - m)
    pr = e / e.sum(axis=1, keepdims=True)
    o_ref[0, 0] = jax.lax.dot(pr, cv.astype(jnp.float32),
                              precision=jax.lax.Precision.HIGHEST)


@functools.partial(jax.jit,
                   static_argnames=("hd", "window", "scale", "softcap",
                                    "rope", "write", "interpret"))
def decode_attention_tiles(q, k_new, v_new, ck, cv, pos, cos, sin, *,
                           hd: int, window: int, scale: float,
                           softcap: float, rope: bool, write: bool,
                           interpret: bool = False):
    """q (B,Hkv,G,hdp); k_new/v_new (B,Hkv,1,hdp); ck/cv (B,Hkv,S,hdp);
    pos (B,1) i32; cos/sin (B, hd/2) f32.  ``hd`` is the real head dim
    (lanes past it are padding).  Returns o (B,Hkv,G,hdp) f32 and, when
    ``write``, the updated caches (aliased in-place over ck/cv).
    """
    b, hkv, g, hdp = q.shape
    s = ck.shape[2]
    kern = functools.partial(_kernel, hd=hd, window=window, scale=scale,
                             softcap=softcap, rope=rope, write=write)
    row4 = lambda bi, hi: (bi, hi, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, g, hdp), row4),
        pl.BlockSpec((1, 1, 1, hdp), row4),
        pl.BlockSpec((1, 1, 1, hdp), row4),
        pl.BlockSpec((1, 1, s, hdp), row4),
        pl.BlockSpec((1, 1, s, hdp), row4),
        pl.BlockSpec((1, 1), lambda bi, hi: (bi, 0)),
        pl.BlockSpec((1, cos.shape[1]), lambda bi, hi: (bi, 0)),
        pl.BlockSpec((1, sin.shape[1]), lambda bi, hi: (bi, 0)),
    ]
    out_specs = [pl.BlockSpec((1, 1, g, hdp), row4)]
    out_shape = [jax.ShapeDtypeStruct((b, hkv, g, hdp), jnp.float32)]
    aliases = {}
    if write:
        out_specs += [pl.BlockSpec((1, 1, s, hdp), row4),
                      pl.BlockSpec((1, 1, s, hdp), row4)]
        out_shape += [jax.ShapeDtypeStruct(ck.shape, ck.dtype),
                      jax.ShapeDtypeStruct(cv.shape, cv.dtype)]
        aliases = {3: 1, 4: 2}          # ck -> new k, cv -> new v
    out = pl.pallas_call(
        kern,
        grid=(b, hkv),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(q, k_new, v_new, ck, cv, pos, cos, sin)
    return out if write else (out[0],)
