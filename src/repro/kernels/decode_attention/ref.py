"""Pure-jnp oracle for the fused decode-attention kernel.

This is *the same math as the XLA decode path* in
``models/attention.py:attention_decode`` — same helper for the RoPE
rotation (``layers.apply_rope``), same one-hot ring write
(``attention.row_update``), same slot-validity mask
(``attention.decode_slot_validity``), same einsum/cast ordering — so

  * the kernel's parity tests pin against exactly what production
    computes, and
  * off-TPU the ops wrapper can serve this twin as the production path
    with greedy decode staying *bitwise* token-identical to the
    pre-kernel engine (the Pallas interpreter is ~5x slower than plain
    XLA on CPU for decode shapes; it is the test surface, not the
    serving path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.attention import NEG_INF, decode_slot_validity, row_update


def decode_attention_ref(q, k_new, v_new, cache_k, cache_v, pos, *,
                         window: int = 0, softcap: float = 0.0,
                         rope_theta: float = 0.0, write: bool = True):
    """One-token decode tail.  q (B,Hq,1,hd) and k_new/v_new (B,Hkv,1,hd)
    are post-projection (and post-qk-norm), pre-RoPE; cache_k/cache_v
    (B,Hkv,S,hd); pos (B,) int32 per-row positions.

    ``rope_theta>0`` applies RoPE at ``pos`` to q and k_new;
    ``write=True`` ring-writes k_new/v_new at ``pos % S`` (the paged
    path pre-writes its pool and calls with ``write=False`` on the
    gathered view); ``window>0`` selects the SWA-ring validity mask.

    Returns (o (B,Hq,1,hd) f32, new cache_k, new cache_v).
    """
    b, hq, _, hd = q.shape
    hkv = cache_k.shape[1]
    slots = cache_k.shape[2]
    if rope_theta:
        cos, sin = layers.rope_tables(pos[:, None, None], hd, rope_theta)
        q = layers.apply_rope(q, cos, sin)
        k_new = layers.apply_rope(k_new, cos, sin)
    if write:
        slot = jax.lax.rem(pos, slots) if slots else pos
        cache_k = row_update(cache_k, k_new.astype(cache_k.dtype), slot)
        cache_v = row_update(cache_v, v_new.astype(cache_v.dtype), slot)
    valid = decode_slot_validity(pos, slots, window=window)
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, hkv, hq // hkv, 1, hd)
    s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                    cache_k.astype(jnp.float32)) * scale
    s_ = layers.softcap(s_, softcap)
    s_ = jnp.where(valid[:, None, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, cache_v.astype(jnp.float32))
    return o.reshape(b, hq, 1, hd), cache_k, cache_v
