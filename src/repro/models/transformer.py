"""Generic decoder LM assembled from a ModelConfig's segments.

Every segment (pattern x repeat) is executed with ``jax.lax.scan`` over
stacked params, so HLO size is independent of depth — 95-layer models
compile as a handful of scanned groups.  Mixers dispatch on LayerSpec.mixer:
attn | swa | rglru | mlstm | slstm; channel mixers on LayerSpec.ffn:
mlp | moe | none.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import layers, mla, moe, recurrent


# ------------------------------------------------------------------ blocks

def _init_mixer(key, cfg, spec):
    if spec.mixer in ("attn", "swa"):
        if cfg.mla is not None:
            return mla.init_mla(key, cfg)
        return attn_mod.init_attention(key, cfg, spec)
    if spec.mixer == "rglru":
        return recurrent.init_rglru_block(key, cfg)
    if spec.mixer == "mlstm":
        return recurrent.init_mlstm_block(key, cfg)
    if spec.mixer == "slstm":
        return recurrent.init_slstm_block(key, cfg)
    raise ValueError(f"unknown mixer {spec.mixer}")


def init_block(key, cfg, spec):
    ks = jax.random.split(key, 3)
    p = {"norm1": layers.norm_init(cfg.d_model, cfg.norm),
         "mixer": _init_mixer(ks[0], cfg, spec)}
    if spec.ffn == "mlp":
        p["norm2"] = layers.norm_init(cfg.d_model, cfg.norm)
        p["ffn"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=True)
    elif spec.ffn == "moe":
        p["norm2"] = layers.norm_init(cfg.d_model, cfg.norm)
        p["ffn"] = moe.init_moe(ks[1], cfg)
    return p


def block_apply(params, cfg, spec, x, positions):
    """Full-sequence block. Returns (x, aux, state) — state for recurrent
    mixers (None-free pytree only when requested via init_block_cache)."""
    aux = {}
    h = layers.norm_apply(params["norm1"], x, cfg.norm)
    if spec.mixer in ("attn", "swa"):
        if cfg.mla is not None:
            y = mla.mla_apply(params["mixer"], cfg, h, positions)
        else:
            y = attn_mod.attention_apply(params["mixer"], cfg, spec, h,
                                         positions)
    elif spec.mixer == "rglru":
        y, _ = recurrent.rglru_block_apply(params["mixer"], cfg, h)
    elif spec.mixer == "mlstm":
        y, _ = recurrent.mlstm_block_apply(params["mixer"], cfg, h)
    elif spec.mixer == "slstm":
        y, _ = recurrent.slstm_block_apply(params["mixer"], cfg, h)
    x = x + y
    if spec.ffn == "mlp":
        x = x + layers.mlp_apply(params["ffn"],
                                 layers.norm_apply(params["norm2"], x,
                                                   cfg.norm), cfg.act)
    elif spec.ffn == "moe":
        y, aux = moe.moe_apply(params["ffn"],
                               cfg, layers.norm_apply(params["norm2"], x,
                                                      cfg.norm))
        x = x + y
    return x, aux


def init_block_cache(cfg, spec, batch, seq_len, dtype, paging=None):
    if spec.mixer in ("attn", "swa"):
        if cfg.mla is not None:
            return mla.init_mla_cache(cfg, batch, seq_len, dtype,
                                      paging=paging)
        return attn_mod.init_attn_cache(cfg, spec, batch, seq_len, dtype,
                                        paging=paging)
    if spec.mixer == "rglru":
        return recurrent.init_rglru_state(cfg, batch, dtype)
    if spec.mixer == "mlstm":
        return recurrent.init_mlstm_state(cfg, batch, dtype)
    if spec.mixer == "slstm":
        return recurrent.init_slstm_state(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def block_decode(params, cfg, spec, x, cache, pos, pages=None,
                 use_kernel=False):
    h = layers.norm_apply(params["norm1"], x, cfg.norm)
    if spec.mixer in ("attn", "swa"):
        if cfg.mla is not None:
            # MLA decodes over the compressed latent cache — no fused
            # kernel variant; it shares decode_slot_validity with the
            # XLA path instead
            y, cache = mla.mla_decode(params["mixer"], cfg, h, cache, pos,
                                      pages=pages)
        else:
            y, cache = attn_mod.attention_decode(params["mixer"], cfg, spec,
                                                 h, cache, pos, pages=pages,
                                                 use_kernel=use_kernel)
    elif spec.mixer == "rglru":
        y, cache = recurrent.rglru_block_decode(params["mixer"], cfg, h,
                                                cache)
    elif spec.mixer == "mlstm":
        y, cache = recurrent.mlstm_block_decode(params["mixer"], cfg, h,
                                                cache)
    elif spec.mixer == "slstm":
        y, cache = recurrent.slstm_block_decode(params["mixer"], cfg, h,
                                                cache)
    x = x + y
    if spec.ffn == "mlp":
        x = x + layers.mlp_apply(params["ffn"],
                                 layers.norm_apply(params["norm2"], x,
                                                   cfg.norm), cfg.act)
    elif spec.ffn == "moe":
        y, _ = moe.moe_apply(params["ffn"],
                             cfg, layers.norm_apply(params["norm2"], x,
                                                    cfg.norm))
        x = x + y
    return x, cache


# --------------------------------------------------------------- the model

class Transformer:
    def __init__(self, cfg, paging=None, decode_kernel=False):
        self.cfg = cfg
        self.paging = paging        # PagedCacheConfig or None (contiguous)
        # route per-row decode attention through kernels/decode_attention
        # (fused RoPE + ring write + mask + softmax·V); scalar-pos
        # lockstep decode and MLA keep the XLA path regardless
        self.decode_kernel = decode_kernel

    # ---- init ----
    def init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, len(cfg.segments) + 3)
        params = {
            "embed": layers.embed_init(keys[-1], cfg.vocab_size, cfg.d_model),
            "final_norm": layers.norm_init(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            params["out"] = layers.dense_init(keys[-2], cfg.d_model,
                                              cfg.vocab_size)
        if cfg.pos_emb == "learned":
            params["pos"] = layers.embed_init(keys[-3], 33_216, cfg.d_model)
        for si, seg in enumerate(cfg.segments):
            seg_key = keys[si]

            def one_group(k):
                pks = jax.random.split(k, len(seg.pattern))
                return {f"p{i}": init_block(pks[i], cfg, sp)
                        for i, sp in enumerate(seg.pattern)}

            gkeys = jax.random.split(seg_key, seg.repeat)
            params[f"seg{si}"] = jax.vmap(one_group)(gkeys)
        if cfg.mtp_depth:
            params["mtp"] = {
                "norm": layers.norm_init(cfg.d_model, cfg.norm),
                "proj": layers.dense_init(keys[0], 2 * cfg.d_model,
                                          cfg.d_model),
                "block": jax.vmap(lambda k: init_block(
                    k, cfg, cfg.segments[-1].pattern[-1]))(
                        jax.random.split(keys[0], 1)),
            }
        return params

    # ---- embedding / unembedding ----
    def embed(self, params, tokens):
        h = params["embed"][tokens]
        if self.cfg.emb_scale:
            h = h * jnp.asarray(self.cfg.d_model ** 0.5, h.dtype)
        return h

    def unembed_matrix(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["out"]

    def unembed(self, params, h):
        logits = h @ self.unembed_matrix(params).astype(h.dtype)
        return layers.softcap(logits.astype(jnp.float32),
                              self.cfg.logit_softcap)

    # ---- full-sequence forward ----
    def apply(self, params, tokens, *, embeds=None, positions=None):
        """tokens (B,S) int32 (or embeds (B,S,D)). Returns (hidden, aux)."""
        cfg = self.cfg
        x = self.embed(params, tokens) if embeds is None else embeds
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.arange(s)
        if cfg.pos_emb == "learned":
            x = x + params["pos"].astype(x.dtype)[
                jnp.clip(positions, 0, params["pos"].shape[0] - 1)]
        aux_total = {}
        for si, seg in enumerate(cfg.segments):
            seg_params = params[f"seg{si}"]

            def body(carry, gp, seg=seg):
                x = carry
                auxs = {}
                for i, sp in enumerate(seg.pattern):
                    x, aux = block_apply(gp[f"p{i}"], cfg, sp, x, positions)
                    for k_, v_ in aux.items():
                        auxs[f"p{i}/{k_}"] = v_
                return x, auxs

            if cfg.scan_unroll:                     # cost-probe path
                accs = None
                for gi in range(seg.repeat):
                    gp = jax.tree_util.tree_map(lambda a: a[gi], seg_params)
                    x, auxs = body(x, gp)
                    accs = auxs if accs is None else {
                        k_: accs[k_] + v_ for k_, v_ in auxs.items()}
                auxs = accs or {}
                for k_, v_ in auxs.items():
                    aux_total[f"seg{si}/{k_}"] = jnp.asarray(v_)
                continue
            if cfg.remat:
                body = jax.checkpoint(body)
            x, auxs = jax.lax.scan(body, x, seg_params)
            for k_, v_ in auxs.items():
                aux_total[f"seg{si}/{k_}"] = jnp.sum(v_)
        x = layers.norm_apply(params["final_norm"], x, cfg.norm)
        return x, aux_total

    # ---- decode ----
    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16, *,
                   per_row=False):
        """Decode cache.  ``per_row=True`` carries one position *per batch
        row* ((B,) int32) instead of a shared scalar, making ragged
        continuous batching legal: rows may sit at different sequence
        positions within one decode step.  The scalar default keeps every
        existing lockstep jit bitwise.

        With a ``paging`` config (model built via ``build_model(cfg,
        paging=...)``) the full-attention/MLA caches become shared pools
        and the cache root carries the block table (``cache["pages"]``);
        swa rings and recurrent state keep their per-row layout.  Paged
        caches are per-row only."""
        cfg = self.cfg
        if self.paging is not None and not per_row:
            raise ValueError("paged caches are per-row only "
                             "(init_cache(per_row=True))")
        cache = {"pos": jnp.zeros((batch,) if per_row else (), jnp.int32)}
        if self.paging is not None:
            cache["pages"] = {
                "tables": jnp.zeros((batch, self.paging.max_blocks),
                                    jnp.int32),
                "caps": jnp.zeros((batch,), jnp.int32),
            }
        for si, seg in enumerate(cfg.segments):
            def one(sp):
                return init_block_cache(cfg, sp, batch, seq_len, dtype,
                                        paging=self.paging)
            group = {f"p{i}": one(sp) for i, sp in enumerate(seg.pattern)}
            cache[f"seg{si}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (seg.repeat,) + a.shape).copy()
                if seg.repeat else a, group)
        return cache

    def decode_step(self, params, cache, tokens):
        """tokens (B,1). Returns (logits (B,1,V), new cache).  With a
        per-row cache (see ``init_cache``) every positional lookup is
        row-indexed; the scalar-position path is unchanged."""
        cfg = self.cfg
        pos = cache["pos"]
        x = self.embed(params, tokens)
        if cfg.pos_emb == "learned":
            pe = params["pos"].astype(x.dtype)[
                jnp.clip(pos, 0, params["pos"].shape[0] - 1)]
            x = x + (pe[:, None] if pos.ndim else pe[None, None])
        new_cache = {"pos": pos + 1}
        pages = None
        if "pages" in cache:
            from repro.models.paging import PageRef
            pages = PageRef(cache["pages"]["tables"], cache["pages"]["caps"],
                            self.paging.page_size)
            new_cache["pages"] = cache["pages"]       # host-owned, carried
        for si, seg in enumerate(cfg.segments):
            seg_params = params[f"seg{si}"]

            def body(carry, xs, seg=seg):
                x = carry
                gp, gc = xs
                new_gc = {}
                for i, sp in enumerate(seg.pattern):
                    x, c = block_decode(gp[f"p{i}"], cfg, sp, x,
                                        gc[f"p{i}"], pos, pages=pages,
                                        use_kernel=self.decode_kernel)
                    new_gc[f"p{i}"] = c
                return x, new_gc

            if cfg.scan_unroll:                     # cost-probe path
                gcs = []
                for gi in range(seg.repeat):
                    take = lambda a: a[gi]
                    x, gc = body(x, (jax.tree_util.tree_map(take, seg_params),
                                     jax.tree_util.tree_map(
                                         take, cache[f"seg{si}"])))
                    gcs.append(gc)
                new_cache[f"seg{si}"] = jax.tree_util.tree_map(
                    lambda *a: jnp.stack(a), *gcs)
                continue
            x, new_seg_cache = jax.lax.scan(body, x,
                                            (seg_params, cache[f"seg{si}"]))
            new_cache[f"seg{si}"] = new_seg_cache
        x = layers.norm_apply(params["final_norm"], x, cfg.norm)
        return self.unembed(params, x), new_cache

    def reset_cache_rows(self, cache, rows, starts=None):
        """Reset the cache rows selected by the (B,) bool mask ``rows`` —
        the continuous batcher's slot admission hook.  Per-row caches
        only (pos must be (B,)).

        Contiguous mode zeroes everything (KV entries past a row's
        position are masked out by decode anyway; zeroing also covers
        recurrent/conv state, whose whole content is live) — bitwise
        unchanged from before paging existed.  Paged mode leaves the
        pools alone (a row's stale pages are unreachable once its table
        row changes; garbage past ``pos`` is masked) and zeroes only the
        per-row leaves (swa rings, recurrent state).

        ``starts`` ((B,) int32, default 0) is the admitted rows' initial
        position — nonzero when a prompt prefix was served from the
        prefix cache and the row resumes mid-prompt."""
        pos0 = jnp.zeros_like(cache["pos"]) if starts is None else starts
        new = {"pos": jnp.where(rows, pos0, cache["pos"])}

        def zero(a):
            m = rows.reshape((1, -1) + (1,) * (a.ndim - 2))   # (rep, B, ...)
            return jnp.where(m, jnp.zeros((), a.dtype), a)

        if self.paging is None:
            for si in range(len(self.cfg.segments)):
                new[f"seg{si}"] = jax.tree_util.tree_map(
                    zero, cache[f"seg{si}"])
            return new
        from repro.models.paging import is_paged_spec
        new["pages"] = cache["pages"]
        for si, seg in enumerate(self.cfg.segments):
            group = {}
            for i, sp in enumerate(seg.pattern):
                sub = cache[f"seg{si}"][f"p{i}"]
                if sp.mixer in ("attn", "swa") and is_paged_spec(sp):
                    group[f"p{i}"] = sub               # pooled: untouched
                else:
                    group[f"p{i}"] = jax.tree_util.tree_map(zero, sub)
            new[f"seg{si}"] = group
        return new

    # ---- MTP auxiliary hidden (deepseek-v3) ----
    def mtp_hidden(self, params, hidden, tokens_shifted, positions):
        """Predict t+2: combine hidden with embedding of the next token."""
        cfg = self.cfg
        if not cfg.mtp_depth or "mtp" not in params:
            return None
        h = layers.norm_apply(params["mtp"]["norm"], hidden, cfg.norm)
        e = self.embed(params, tokens_shifted)
        x = jnp.concatenate([h, e], axis=-1) @ params["mtp"]["proj"].astype(
            hidden.dtype)
        spec = cfg.segments[-1].pattern[-1]

        def body(carry, gp):
            y, _ = block_apply(gp, cfg, spec, carry, positions)
            return y, {}

        x, _ = jax.lax.scan(body, x, params["mtp"]["block"])
        return x
