"""GQA attention: flash-style KV-chunked full attention, sliding-window
attention with static banded slicing, and cached single-token decode.

Memory discipline: scores are never materialized beyond a
(chunk_q x chunk_kv) or (chunk_q x window+chunk_q) tile, so 32k prefill
lowers with bounded temporaries.  The Pallas kernel in
``repro.kernels.swa_attention`` is the TPU twin of the windowed path; this
file is the XLA-lowerable implementation used by the dry-run and on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models import paging as paging_mod

NEG_INF = -1e30


def init_attention(key, cfg, spec):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": layers.dense_init(ks[0], d, hq * hd),
        "wk": layers.dense_init(ks[1], d, hkv * hd),
        "wv": layers.dense_init(ks[2], d, hkv * hd),
        "wo": layers.dense_init(ks[3], hq * hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(params, cfg, x, positions, *, rope=True):
    """x (B,S,D) -> q (B,Hq,S,hd), k/v (B,Hkv,S,hd), rope applied.

    ``rope=False`` skips the rotation (the fused decode kernel applies
    it inside the ``pallas_call`` instead — see ``kernels/decode_attention``).
    """
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = layers.rms_head_norm(params["q_norm"], q)
        k = layers.rms_head_norm(params["k_norm"], k)
    if cfg.pos_emb == "rope" and rope:
        cos, sin = layers.rope_tables(positions, hd, cfg.rope_theta)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
    return q, k, v


def _group(q, hkv):
    """(B,Hq,S,hd) -> (B,Hkv,G,S,hd)."""
    b, hq, s, hd = q.shape
    return q.reshape(b, hkv, hq // hkv, s, hd)


def flash_full_attention(q, k, v, q_pos, kv_pos, *, causal=True,
                         attn_softcap=0.0, chunk_q=512, chunk_kv=1024,
                         bias_mask=None):
    """Two-level chunked flash attention.

    q (B,Hkv,G,Sq,hd); k/v (B,Hkv,Skv,hd); q_pos (Sq,), kv_pos (Skv,).
    Returns (B,Hkv,G,Sq,hd).
    """
    b, hkv, g, sq, hd = q.shape
    hdv = v.shape[-1]                 # may differ from hd (e.g. MLA)
    skv = k.shape[2]
    scale = 1.0 / np.sqrt(hd)
    cq = min(chunk_q, sq)
    ckv = min(chunk_kv, skv)
    # pad seq dims to chunk multiples
    pq = (-sq) % cq
    pkv = (-skv) % ckv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    qpos = jnp.pad(q_pos, (0, pq), constant_values=-1)
    kpos = jnp.pad(kv_pos, (0, pkv), constant_values=2**30)
    nq, nkv = (sq + pq) // cq, (skv + pkv) // ckv
    qp = qp.reshape(b, hkv, g, nq, cq, hd)
    kp = kp.reshape(b, hkv, nkv, ckv, hd)
    vp = vp.reshape(b, hkv, nkv, ckv, hdv)
    qpos = qpos.reshape(nq, cq)
    kpos = kpos.reshape(nkv, ckv)

    def q_chunk(carry, qi):
        qc, qpc = qi                      # (B,Hkv,G,cq,hd), (cq,)
        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, hdv), jnp.float32)

        def kv_chunk(acc, ki):
            m, l, a = acc
            kc, vc, kpc = ki              # (B,Hkv,ckv,hd), ..., (ckv,)
            s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(jnp.float32),
                            kc.astype(jnp.float32)) * scale
            s_ = layers.softcap(s_, attn_softcap)
            mask = qpc[:, None] >= 0
            if causal:
                mask = mask & (qpc[:, None] >= kpc[None, :])
            s_ = jnp.where(mask, s_, NEG_INF)
            m_new = jnp.maximum(m, s_.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s_ - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            a_new = a * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, a_new), None

        (m, l, a), _ = jax.lax.scan(
            kv_chunk, (m0, l0, a0),
            (kp.transpose(2, 0, 1, 3, 4), vp.transpose(2, 0, 1, 3, 4), kpos))
        out = a / jnp.maximum(l[..., None], 1e-30)
        return carry, out

    _, outs = jax.lax.scan(q_chunk, None,
                           (qp.transpose(3, 0, 1, 2, 4, 5), qpos))
    # outs (nq, B, Hkv, G, cq, hd)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, nq * cq, hdv)
    return out[..., :sq, :].astype(q.dtype)


def windowed_attention(q, k, v, q_pos0, window, *, attn_softcap=0.0,
                       chunk_q=512):
    """Sliding-window causal attention; Sq == Skv (prefill/train).

    q (B,Hkv,G,S,hd); k/v (B,Hkv,S,hd).  For query chunk i only the
    [i*cq - window, i*cq + cq) key band is touched (static slice), so FLOPs
    scale as S * (window + cq) instead of S^2.
    """
    b, hkv, g, s, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    cq = min(chunk_q, s)
    pq = (-s) % cq
    # pad keys left by `window` (masked) and right to a chunk multiple
    w = int(window)
    kp = jnp.pad(k, ((0, 0), (0, 0), (w, pq), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (w, pq), (0, 0)))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pq), (0, 0)))
    nq = (s + pq) // cq
    band = w + cq

    def q_chunk(carry, i):
        qc = jax.lax.dynamic_slice_in_dim(qp, i * cq, cq, axis=3)
        kc = jax.lax.dynamic_slice_in_dim(kp, i * cq, band, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(vp, i * cq, band, axis=2)
        qpos = q_pos0 + i * cq + jnp.arange(cq)          # absolute positions
        kpos = q_pos0 + i * cq - w + jnp.arange(band)
        s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(jnp.float32),
                        kc.astype(jnp.float32)) * scale
        s_ = layers.softcap(s_, attn_softcap)
        valid = (kpos[None, :] >= q_pos0) & (kpos[None, :] <= qpos[:, None]) \
            & (qpos[:, None] - kpos[None, :] < w)
        s_ = jnp.where(valid, s_, NEG_INF)
        p = jax.nn.softmax(s_, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
        return carry, out

    _, outs = jax.lax.scan(q_chunk, None, jnp.arange(nq))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, nq * cq, hd)
    return out[..., :s, :].astype(q.dtype)


def attention_apply(params, cfg, spec, x, positions):
    """Full-sequence (train/prefill) attention block body. x (B,S,D)."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    qg = _group(q, cfg.n_kv_heads)
    s = x.shape[1]
    # cost-probe mode: one whole-sequence chunk (no scan; same FLOPs as
    # the chunked schedule, which also executes masked blocks)
    cq = s if cfg.attn_whole_seq else 512
    ckv = s if cfg.attn_whole_seq else 1024
    if spec.mixer == "swa" and spec.window and spec.window < x.shape[1]:
        o = windowed_attention(qg, k, v, 0, spec.window,
                               attn_softcap=cfg.attn_softcap, chunk_q=cq)
    else:
        o = flash_full_attention(qg, k, v, positions, positions,
                                 attn_softcap=cfg.attn_softcap,
                                 chunk_q=cq, chunk_kv=ckv)
    b, s, _ = x.shape
    o = o.reshape(b, cfg.n_heads, s, cfg.resolved_head_dim)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return o @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------- decode

def init_attn_cache(cfg, spec, batch, seq_len, dtype, paging=None):
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if paging is not None and paging_mod.is_paged_spec(spec):
        # pooled layout: no batch axis — rows reach their pages through
        # the shared block table (cache root "pages"); see models/paging
        slots = paging.pool_slots
        return {"k": jnp.zeros((slots, hkv, hd), dtype),
                "v": jnp.zeros((slots, hkv, hd), dtype)}
    slots = min(spec.window, seq_len) if (spec.mixer == "swa" and spec.window) \
        else seq_len
    return {"k": jnp.zeros((batch, hkv, slots, hd), dtype),
            "v": jnp.zeros((batch, hkv, slots, hd), dtype)}


def row_update(cache_arr, new, slot, *, axis=2):
    """Per-row cache write: row b of ``cache_arr`` takes ``new[b]`` at its
    own slot index.  ``axis`` is the slot axis of the *full* batched array
    (2 for a (B, heads, S, hd) KV cache, 1 for a (B, S, d) latent cache);
    slot (B,) int32.  Written as a one-hot select rather than a vmapped
    dynamic_update_slice: identical values, but it lowers to a fused
    elementwise op instead of a scatter (~3x faster per step on CPU)."""
    slots = cache_arr.shape[axis]
    m = jnp.arange(slots)[None, :] == slot[:, None]            # (B, slots)
    m = m.reshape((slot.shape[0],) + (1,) * (axis - 1) + (slots,)
                  + (1,) * (cache_arr.ndim - axis - 1))
    return jnp.where(m, new, cache_arr)


def decode_slot_validity(pos, slots, *, window: int = 0):
    """Validity mask over cache slots for single-token decode — THE mask
    math shared by the XLA decode path, the MLA decode path, and the
    fused kernel's ref oracle (``kernels/decode_attention/ref.py``), so
    the implementations can't drift.

    ``pos``: scalar or (B,) int32 position(s); ``slots``: cache slot
    count.  ``window=0`` — linear layout: slot j holds position j, valid
    iff ``j <= pos``.  ``window>0`` — SWA ring: slot j holds the latest
    position ``p <= pos`` with ``p % slots == j``, valid iff that p is
    in ``(pos - window, pos]`` and ``>= 0``.  Returns bool, shaped
    (slots,) for scalar pos and (B, slots) for per-row pos.
    """
    idx = jnp.arange(slots)
    posb = pos[..., None] if getattr(pos, "ndim", 0) else pos
    if window:
        # slot j holds position: the latest p <= pos, p % slots == j
        kpos = posb - jax.lax.rem(posb - idx, slots)
        kpos = jnp.where(kpos > posb, kpos - slots, kpos)  # safety
        return (kpos >= 0) & (posb - kpos < window) & (kpos <= posb)
    return idx <= posb


def attention_decode(params, cfg, spec, x, cache, pos, pages=None,
                     use_kernel=False):
    """One-token decode. x (B,1,D); pos int32: a scalar (all rows in
    lockstep — the legacy shape, kept bitwise) or (B,) per-row positions
    (continuous batching: each row writes and reads its cache at its own
    position; ring indexing, masking and RoPE become row-indexed).

    A 3-D (pool) cache selects the paged path: the row's K/V live in the
    pages its block-table row (``pages``) maps, the write is a flat
    one-hot into the pool, and the read gathers the row's logical
    context back into the same (B, Hkv, S, hd) layout the contiguous
    masked-softmax tail consumes (masked columns contribute exact zeros,
    keeping greedy decode token-identical — tests/test_paged_cache.py).

    ``use_kernel=True`` routes per-row decode through the fused
    ``kernels/decode_attention`` op (RoPE + ring write + mask +
    softmax·V in one pass: compiled Pallas on TPU, the fused-XLA ref
    twin — bitwise-identical math — elsewhere).  Scalar-pos lockstep
    decode keeps the XLA path below."""
    b = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    per_row = pos.ndim == 1 and pos.shape[0] == b
    paged = cache["k"].ndim == 3
    if paged and (pages is None or not per_row):
        raise ValueError("paged attention cache requires per-row positions "
                         "and a PageRef (cache['pages'])")
    if use_kernel and per_row:
        return _attention_decode_fused(params, cfg, spec, x, cache, pos,
                                       pages)
    q, k, v = _project_qkv(params, cfg, x,
                           pos[:, None, None] if per_row
                           else (pos[None] if pos.ndim == 0 else pos))
    if paged:
        widx = paging_mod.write_index(pages, pos)
        pool_k = paging_mod.pool_write(cache["k"], k[:, :, 0], widx)
        pool_v = paging_mod.pool_write(cache["v"], v[:, :, 0], widx)
        gidx = paging_mod.gather_indices(pages)          # (B, max_ctx)
        ck = pool_k[gidx].transpose(0, 2, 1, 3)          # (B, Hkv, S, hd)
        cv = pool_v[gidx].transpose(0, 2, 1, 3)
        valid = decode_slot_validity(pos, gidx.shape[1])
        new_cache = {"k": pool_k, "v": pool_v}
    else:
        slots = cache["k"].shape[2]
        slot = jax.lax.rem(pos, slots) if slots else pos
        if per_row:
            ck = row_update(cache["k"], k.astype(cache["k"].dtype), slot)
            cv = row_update(cache["v"], v.astype(cache["v"].dtype), slot)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=2)
        new_cache = {"k": ck, "v": cv}
        # positions held by each cache slot (ring for swa, linear
        # otherwise); per-row, pos (B,1) broadcasts against idx (slots,)
        # -> (B, slots)
        win = spec.window if (spec.mixer == "swa" and spec.window
                              and slots < 2**30) else 0
        valid = decode_slot_validity(pos, slots, window=win)
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, hkv, hq // hkv, 1, hd)
    s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                    ck.astype(jnp.float32)) * scale
    s_ = layers.softcap(s_, cfg.attn_softcap)
    vmask = (valid[:, None, None, None, :] if per_row
             else valid[None, None, None, None, :])
    s_ = jnp.where(vmask, s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, cv.astype(jnp.float32))
    o = o.reshape(b, hq, 1, hd).transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
    o = o.astype(x.dtype) @ params["wo"].astype(x.dtype)
    return o, new_cache


def _attention_decode_fused(params, cfg, spec, x, cache, pos, pages):
    """Per-row decode through ``kernels/decode_attention``: projections
    stay XLA (MXU matmuls fuse fine), the memory-bound tail — RoPE
    rotation, one-hot ring write, slot-validity mask, softmax·V — runs
    as one fused op instead of five materializing passes.

    Paged dispatch: the pool write and block-table gather stay XLA
    (gather indices are data, not schedule), RoPE is applied before the
    pool write as on the XLA path, and the kernel fuses the mask +
    softmax·V tail over the gathered view (``write=False``)."""
    # local import: kernels/decode_attention/ref.py imports this module
    # for the shared mask helper, so the edge must stay lazy here
    from repro.kernels.decode_attention import decode_attention
    b = x.shape[0]
    hq, hd = cfg.n_heads, cfg.resolved_head_dim
    paged = cache["k"].ndim == 3
    theta = cfg.rope_theta if cfg.pos_emb == "rope" else 0.0
    if paged:
        q, k, v = _project_qkv(params, cfg, x, pos[:, None, None])
        widx = paging_mod.write_index(pages, pos)
        pool_k = paging_mod.pool_write(cache["k"], k[:, :, 0], widx)
        pool_v = paging_mod.pool_write(cache["v"], v[:, :, 0], widx)
        gidx = paging_mod.gather_indices(pages)          # (B, max_ctx)
        ck = pool_k[gidx].transpose(0, 2, 1, 3)          # (B, Hkv, S, hd)
        cv = pool_v[gidx].transpose(0, 2, 1, 3)
        o, _, _ = decode_attention(q, k, v, ck, cv, pos,
                                   softcap=cfg.attn_softcap, write=False)
        new_cache = {"k": pool_k, "v": pool_v}
    else:
        q, k, v = _project_qkv(params, cfg, x, pos[:, None, None],
                               rope=False)
        slots = cache["k"].shape[2]
        win = spec.window if (spec.mixer == "swa" and spec.window
                              and slots < 2**30) else 0
        o, ck, cv = decode_attention(q, k, v, cache["k"], cache["v"], pos,
                                     window=win, softcap=cfg.attn_softcap,
                                     rope_theta=theta)
        new_cache = {"k": ck, "v": cv}
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
    o = o.astype(x.dtype) @ params["wo"].astype(x.dtype)
    return o, new_cache
