"""Model factory + abstract input specs for the dry-run.

``build_model(cfg)`` -> model object with a uniform surface:
  init(key) -> params
  apply(params, tokens/feats, **kw) -> (hidden, aux)
  unembed(params, hidden) -> float32 logits
  init_cache(batch, seq_len, dtype) / decode_step(params, cache, tokens)

Streaming surface (frame-synchronous models; ``supports_streaming(cfg)``):
  init_stream_state(batch, dtype) -> per-stream recurrent state pytree
  stream_step(params, state, feats, lens=) -> (hidden, state)
Chunked stream_step calls are exactly equivalent to one full apply() —
the serving engine (``repro.serve``) carries this state per stream.

``input_specs(cfg, shape, ...)`` -> dict of jax.ShapeDtypeStruct stand-ins
for every model input of a (arch x shape) pair: weak-type-correct, shardable,
no device allocation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.lstm_am import LstmAM
from repro.models.transformer import Transformer
from repro.models.whisper import Whisper


def build_model(cfg: ModelConfig, *, paging=None, decode_kernel=False):
    """``paging`` (a ``models.paging.PagedCacheConfig``) switches the
    decode cache of attention-family models to the paged pool layout;
    training/prefill and the contiguous decode path are unaffected.

    ``decode_kernel=True`` routes per-row decode attention through the
    fused ``kernels/decode_attention`` op (decoder-only transformers;
    scalar-pos lockstep decode and MLA keep the XLA path)."""
    if cfg.family == "lstm_am":
        if paging is not None:
            raise ValueError("the LSTM acoustic model has no KV cache "
                             "to page")
        if decode_kernel:
            raise ValueError("decode_kernel applies to KV-cache decode; "
                             "the LSTM acoustic model has none")
        return LstmAM(cfg)
    if cfg.encoder is not None:
        if decode_kernel:
            raise ValueError("decode_kernel is not supported for "
                             "encoder-decoder models yet")
        return Whisper(cfg, paging=paging)
    return Transformer(cfg, paging=paging, decode_kernel=decode_kernel)


def supports_streaming(cfg: ModelConfig) -> bool:
    """True iff build_model(cfg) exposes the streaming surface
    (init_stream_state / stream_step / reset_stream_rows): causal
    frame-synchronous AMs, and enc-dec (whisper) via the chunked
    encoder + incremental decoder."""
    if cfg.family == "lstm_am":
        from repro.models.lstm_am import is_bidirectional
        return not is_bidirectional(cfg)
    return cfg.encoder is not None


def stream_frame_sync(cfg: ModelConfig) -> bool:
    """True when ``stream_step`` emits one output position per input
    frame (frame-synchronous AM: per-frame senone posteriors); False
    when it emits one decode position per chunk (whisper's incremental
    decoder).  The serving layer uses this to slice emissions and count
    useful work."""
    return cfg.family == "lstm_am"


def stream_feat_dim(cfg: ModelConfig) -> int:
    """Per-frame feature width a streaming chunk row must carry: log-mel
    stack width for the AM, encoder embedding width (the stubbed conv
    frontend's output) for whisper."""
    return cfg.feat_dim if cfg.family == "lstm_am" else cfg.d_model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, topk: int = 0,
                cache_dtype=jnp.bfloat16) -> dict:
    """Abstract inputs for train_step / prefill_step / serve_step.

    For train: tokens+labels (or teacher top-k targets when topk>0).
    audio/vlm carve-out: whisper gets precomputed frame embeddings;
    chameleon's VQ image tokens are ordinary ids inside its vocab.
    lstm_am gets features + senone alignments.
    """
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "lstm_am":
        specs = {"feats": _sds((b, s, cfg.feat_dim), jnp.bfloat16),
                 "labels": _sds((b, s), jnp.int32)}
        if topk:
            specs.pop("labels")
            specs["topk_vals"] = _sds((b, s, topk), jnp.bfloat16)
            specs["topk_idx"] = _sds((b, s, topk), jnp.int32)
        return specs

    if cfg.encoder is not None:                      # whisper
        st = min(cfg.max_target_len, s)
        if shape.kind in ("train", "prefill"):
            specs = {"enc_embeds": _sds((b, s, cfg.d_model), jnp.bfloat16),
                     "tokens": _sds((b, st), jnp.int32)}
            if shape.kind == "train":
                if topk:
                    specs["topk_vals"] = _sds((b, st, topk), jnp.bfloat16)
                    specs["topk_idx"] = _sds((b, st, topk), jnp.int32)
                else:
                    specs["labels"] = _sds((b, st), jnp.int32)
            return specs
        # decode: one token + caches sized seq_len
        model = build_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(b, s, cache_dtype))
        return {"tokens": _sds((b, 1), jnp.int32), "cache": cache}

    if shape.kind == "train":
        specs = {"tokens": _sds((b, s), jnp.int32)}
        if topk:
            specs["topk_vals"] = _sds((b, s, topk), jnp.bfloat16)
            specs["topk_idx"] = _sds((b, s, topk), jnp.int32)
        else:
            specs["labels"] = _sds((b, s), jnp.int32)
        return specs
    if shape.kind == "prefill":
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s, cache_dtype))
    return {"tokens": _sds((b, 1), jnp.int32), "cache": cache}


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct param tree without allocating anything."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))
