"""Whisper-style encoder-decoder backbone.

The mel-spectrogram + conv frontend is a STUB per the task carve-out:
``input_specs`` provides precomputed frame embeddings (B, S_enc, D) directly.
Encoder: bidirectional self-attention stack.  Decoder: causal self-attention
+ cross-attention to encoder output.  Learned positional embeddings (table
extended to 32k decode positions — a documented departure from the 448-token
original, required by the assigned decode_32k shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import layers

NEG_INF = -1e30
MAX_POS = 33_280


def _maybe_scan(cfg, body, x, stacked):
    """lax.scan over stacked block params, or a Python loop in the
    dry-run's cost-probe mode (cfg.scan_unroll) — see configs/base.py."""
    if cfg.scan_unroll:
        n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        for i in range(n):
            x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], stacked))
        return x
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _init_xattn(key, cfg):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {"wq": layers.dense_init(ks[0], d, h * hd),
            "wk": layers.dense_init(ks[1], d, h * hd),
            "wv": layers.dense_init(ks[2], d, h * hd),
            "wo": layers.dense_init(ks[3], h * hd, d)}


def _xattn_kv(params, cfg, enc):
    b, s, _ = enc.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    k = (enc @ params["wk"].astype(enc.dtype)).reshape(b, s, h, hd)
    v = (enc @ params["wv"].astype(enc.dtype)).reshape(b, s, h, hd)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def _xattn_apply(params, cfg, x, ck, cv):
    """Cross attention: queries from x (B,Sq,D), cached K/V from encoder."""
    b, sq, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, sq, h, hd)
    q = q.transpose(0, 2, 1, 3)
    skv = ck.shape[2]
    o = attn_mod.flash_full_attention(
        q[:, :, None], ck, cv,
        jnp.arange(sq), jnp.arange(skv), causal=False,
        chunk_q=sq if cfg.attn_whole_seq else 512,
        chunk_kv=skv if cfg.attn_whole_seq else 1024)
    o = o[:, :, 0].transpose(0, 2, 1, 3).reshape(b, sq, h * hd)
    return o @ params["wo"].astype(x.dtype)


def _init_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    from repro.configs.base import LayerSpec
    spec = LayerSpec(mixer="attn", ffn="mlp")
    return {
        "norm1": layers.norm_init(cfg.d_model, cfg.norm),
        "self": attn_mod.init_attention(ks[0], cfg, spec),
        "norm_x": layers.norm_init(cfg.d_model, cfg.norm),
        "cross": _init_xattn(ks[1], cfg),
        "norm2": layers.norm_init(cfg.d_model, cfg.norm),
        "ffn": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=False),
    }


class Whisper:
    def __init__(self, cfg, paging=None):
        self.cfg = cfg
        self.paging = paging        # PagedCacheConfig or None (contiguous)
        self.spec_self = None
        from repro.configs.base import LayerSpec
        self.attn_spec = LayerSpec(mixer="attn", ffn="mlp")

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        from repro.models.transformer import init_block

        def enc_block(k):
            return init_block(k, cfg, self.attn_spec)

        params = {
            "enc_pos": layers.embed_init(ks[0], MAX_POS, cfg.d_model),
            "enc_blocks": jax.vmap(enc_block)(
                jax.random.split(ks[1], cfg.encoder.n_layers)),
            "enc_norm": layers.norm_init(cfg.d_model, cfg.norm),
            "embed": layers.embed_init(ks[2], cfg.vocab_size, cfg.d_model),
            "dec_pos": layers.embed_init(ks[3], MAX_POS, cfg.d_model),
            "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(
                jax.random.split(ks[4], len(self._dec_specs()))),
            "final_norm": layers.norm_init(cfg.d_model, cfg.norm),
        }
        return params

    def _dec_specs(self):
        return [None] * self.cfg.n_layers

    # ---- encoder ----
    def encode(self, params, enc_embeds):
        """enc_embeds (B,S,D) from the stubbed conv frontend."""
        cfg = self.cfg
        b, s, _ = enc_embeds.shape
        x = enc_embeds + params["enc_pos"].astype(enc_embeds.dtype)[
            jnp.clip(jnp.arange(s), 0, MAX_POS - 1)]
        positions = jnp.arange(s)

        def body(carry, bp):
            h = layers.norm_apply(bp["norm1"], carry, cfg.norm)
            # bidirectional attention: non-causal full
            q, k, v = attn_mod._project_qkv(bp["mixer"], cfg, h, positions)
            qg = attn_mod._group(q, cfg.n_kv_heads)
            o = attn_mod.flash_full_attention(
                qg, k, v, positions, positions, causal=False,
                chunk_q=s if cfg.attn_whole_seq else 512,
                chunk_kv=s if cfg.attn_whole_seq else 1024)
            o = o.reshape(b, cfg.n_heads, s, cfg.resolved_head_dim)
            o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
            x = carry + o @ bp["mixer"]["wo"].astype(h.dtype)
            h2 = layers.norm_apply(bp["norm2"], x, cfg.norm)
            x = x + layers.mlp_apply(bp["ffn"], h2, cfg.act)
            return x, None

        x = _maybe_scan(cfg, body, x, params["enc_blocks"])
        return layers.norm_apply(params["enc_norm"], x, cfg.norm)

    # ---- decoder, full sequence (training) ----
    def apply(self, params, tokens, *, enc_embeds, positions=None):
        """Returns (hidden (B,St,D), aux)."""
        cfg = self.cfg
        enc = self.encode(params, enc_embeds)
        b, st = tokens.shape
        if positions is None:
            positions = jnp.arange(st)
        x = params["embed"][tokens]
        x = x + params["dec_pos"].astype(x.dtype)[
            jnp.clip(positions, 0, MAX_POS - 1)]

        def body(carry, bp):
            x = carry
            h = layers.norm_apply(bp["norm1"], x, cfg.norm)
            y = attn_mod.attention_apply(bp["self"], cfg, self.attn_spec, h,
                                         positions)
            x = x + y
            hx = layers.norm_apply(bp["norm_x"], x, cfg.norm)
            ck, cv = _xattn_kv(bp["cross"], cfg, enc)
            x = x + _xattn_apply(bp["cross"], cfg, hx, ck, cv)
            h2 = layers.norm_apply(bp["norm2"], x, cfg.norm)
            x = x + layers.mlp_apply(bp["ffn"], h2, cfg.act)
            return x, None

        x = _maybe_scan(cfg, body, x, params["dec_blocks"])
        return layers.norm_apply(params["final_norm"], x, cfg.norm), {}

    def unembed_matrix(self, params):
        return params["embed"].T

    def unembed(self, params, h):
        return (h @ self.unembed_matrix(params).astype(h.dtype)).astype(
            jnp.float32)

    # ---- decode ----
    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16, *,
                   per_row=False):
        """``per_row=True`` carries a (B,) position vector (ragged
        continuous batching); the scalar default stays bitwise for
        lockstep callers — see ``Transformer.init_cache``.  With paging,
        the decoder self-attention K/V become shared pools addressed
        through the block table; cross-attention K/V stay contiguous
        (they belong to the encoder pass, sized by the audio, and are
        refilled per admission by ``prefill_cache``)."""
        cfg = self.cfg
        h, hd = cfg.n_heads, cfg.resolved_head_dim
        n = cfg.n_layers
        cache = {
            "pos": jnp.zeros((batch,) if per_row else (), jnp.int32),
            # cross-attention K/V precomputed from the encoder output
            "ck": jnp.zeros((n, batch, h, seq_len, hd), dtype),
            "cv": jnp.zeros((n, batch, h, seq_len, hd), dtype),
        }
        if self.paging is not None:
            if not per_row:
                raise ValueError("paged caches are per-row only "
                                 "(init_cache(per_row=True))")
            slots = self.paging.pool_slots
            cache["pages"] = {
                "tables": jnp.zeros((batch, self.paging.max_blocks),
                                    jnp.int32),
                "caps": jnp.zeros((batch,), jnp.int32),
            }
            cache["k"] = jnp.zeros((n, slots, cfg.n_kv_heads, hd), dtype)
            cache["v"] = jnp.zeros((n, slots, cfg.n_kv_heads, hd), dtype)
        else:
            cache["k"] = jnp.zeros((n, batch, cfg.n_kv_heads, seq_len, hd),
                                   dtype)
            cache["v"] = jnp.zeros((n, batch, cfg.n_kv_heads, seq_len, hd),
                                   dtype)
        return cache

    def prefill_cache(self, params, enc_embeds, cache):
        """Run the encoder and fill cross-attention K/V."""
        enc = self.encode(params, enc_embeds)

        def per_layer(bp):
            return _xattn_kv(bp["cross"], self.cfg, enc)

        ck, cv = jax.vmap(per_layer)(params["dec_blocks"])
        s = ck.shape[3]
        cache = dict(cache)
        cache["ck"] = jax.lax.dynamic_update_slice_in_dim(
            cache["ck"], ck.astype(cache["ck"].dtype), 0, axis=3)
        cache["cv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["cv"], cv.astype(cache["cv"].dtype), 0, axis=3)
        return cache

    def decode_step(self, params, cache, tokens):
        x, new_cache = self._decode_hidden(params, cache, tokens)
        return self.unembed(params, x), new_cache

    def _decode_hidden(self, params, cache, tokens):
        """One cached decode step, returning the final-norm *hidden*
        (B, 1, D) — the streaming surface emits top-k over it; the
        token surface (``decode_step``) unembeds it."""
        cfg = self.cfg
        pos = cache["pos"]
        b = tokens.shape[0]
        x = params["embed"][tokens]
        pe = params["dec_pos"].astype(x.dtype)[jnp.clip(pos, 0, MAX_POS - 1)]
        x = x + (pe[:, None] if pos.ndim else pe[None, None])
        pages = None
        if "pages" in cache:
            from repro.models.paging import PageRef
            pages = PageRef(cache["pages"]["tables"], cache["pages"]["caps"],
                            self.paging.page_size)
        # cross-attention validity: the streaming state carries
        # ``enc_len`` — frames written so far per row — and masks the
        # unwritten tail of the K/V buffers.  Absent (the batch decode
        # path, whose ck/cv are always full), scores are untouched:
        # bitwise what this step always computed.
        xbias = None
        if "enc_len" in cache:
            s_enc = cache["ck"].shape[3]
            xvalid = jnp.arange(s_enc)[None, :] < cache["enc_len"][:, None]
            xbias = jnp.where(xvalid, 0.0, NEG_INF)[:, None, None, :]

        def body(carry, xs):
            x = carry
            bp, k_l, v_l, ck_l, cv_l = xs
            h = layers.norm_apply(bp["norm1"], x, cfg.norm)
            y, newc = attn_mod.attention_decode(bp["self"], cfg,
                                                self.attn_spec, h,
                                                {"k": k_l, "v": v_l}, pos,
                                                pages=pages)
            x = x + y
            hx = layers.norm_apply(bp["norm_x"], x, cfg.norm)
            # cross attention over cached encoder K/V
            hq = (hx @ bp["cross"]["wq"].astype(hx.dtype)).reshape(
                b, 1, cfg.n_heads, cfg.resolved_head_dim).transpose(0, 2, 1, 3)
            s_ = jnp.einsum("bhqd,bhkd->bhqk", hq.astype(jnp.float32),
                            ck_l.astype(jnp.float32))
            s_ = s_ / np.sqrt(cfg.resolved_head_dim)
            if xbias is not None:
                s_ = s_ + xbias
            p = jax.nn.softmax(s_, axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", p, cv_l.astype(jnp.float32))
            o = o.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, 1, -1)
            x = x + o @ bp["cross"]["wo"].astype(x.dtype)
            h2 = layers.norm_apply(bp["norm2"], x, cfg.norm)
            x = x + layers.mlp_apply(bp["ffn"], h2, cfg.act)
            return x, (newc["k"], newc["v"])

        xs_all = (params["dec_blocks"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"])
        if cfg.scan_unroll:                       # cost-probe path
            nks, nvs = [], []
            for i in range(cfg.n_layers):
                x, (k_i, v_i) = body(
                    x, jax.tree_util.tree_map(lambda a: a[i], xs_all))
                nks.append(k_i)
                nvs.append(v_i)
            nk, nv = jnp.stack(nks), jnp.stack(nvs)
        else:
            x, (nk, nv) = jax.lax.scan(body, x, xs_all)
        new_cache = dict(cache)
        new_cache.update({"pos": pos + 1, "k": nk, "v": nv})
        return layers.norm_apply(params["final_norm"], x, cfg.norm), \
            new_cache

    def reset_cache_rows(self, cache, rows, starts=None):
        """Zero the self-attention KV rows selected by the (B,) bool mask
        and reset their positions — continuous-batching slot admission.
        Cross-attention K/V is *kept*: it belongs to the encoder pass and
        is refilled by ``prefill_cache`` when the slot's new utterance
        arrives.  Per-row caches only.  With paging the self-attention
        pools are left alone (stale pages become unreachable when the
        table row changes)."""
        pos0 = jnp.zeros_like(cache["pos"]) if starts is None else starts
        new = dict(cache)
        new["pos"] = jnp.where(rows, pos0, cache["pos"])
        if self.paging is not None:
            return new
        m = rows[None, :, None, None, None]           # (n, B, H, S, hd)
        for key in ("k", "v"):
            new[key] = jnp.where(m, jnp.zeros((), cache[key].dtype),
                                 cache[key])
        return new

    # ------------------------------------------------- streaming surface
    # Chunked online inference (serve.StreamServer / StreamingEngine
    # feed): audio arrives as encoder-embedding chunks.  Each chunk is
    # encoded *chunk-locally* — bidirectional attention within the chunk
    # at the stream's running frame offset, a streaming approximation of
    # the full-utterance encoder — its cross-attention K/V are scattered
    # into the stream's row at that offset, and ONE incremental decoder
    # step runs per chunk over all audio heard so far, feeding back its
    # own greedy token.  Everything is per-row: ragged chunks batch
    # safely (lens masks encoder validity and the K/V scatter), dead
    # rows (lens == 0) are reverted wholesale, and a row's outputs are
    # independent of batch composition.  Unlike the LSTM AM, chunked
    # streaming is NOT equivalent to full-utterance apply() — encoder
    # context is chunk-local and token feedback is greedy — but it is
    # deterministic, and the slot-based server matches the lockstep
    # feed loop bitwise (pinned in tests/test_stream_server.py).

    def init_stream_state(self, batch, dtype=jnp.float32, *,
                          max_frames: int = 256, max_tokens: int = 64):
        """Per-stream streaming state: decoder self-attn cache rows
        (``max_tokens`` — one decoder token per chunk fed), growing
        cross-attn K/V buffers (``max_frames`` audio frames), the
        frames-written watermark (``enc_len``, doubling as the
        cross-attention validity bound) and the fed-back token."""
        if self.paging is not None:
            raise ValueError("streaming whisper uses contiguous per-row "
                             "caches; build the model without paging")
        cfg = self.cfg
        h, hkv = cfg.n_heads, cfg.n_kv_heads
        hd, n = cfg.resolved_head_dim, cfg.n_layers
        return {
            "pos": jnp.zeros((batch,), jnp.int32),     # decoder tokens fed
            "k": jnp.zeros((n, batch, hkv, max_tokens, hd), dtype),
            "v": jnp.zeros((n, batch, hkv, max_tokens, hd), dtype),
            "ck": jnp.zeros((n, batch, h, max_frames, hd), dtype),
            "cv": jnp.zeros((n, batch, h, max_frames, hd), dtype),
            "enc_len": jnp.zeros((batch,), jnp.int32),  # frames written
            "tok": jnp.zeros((batch, 1), jnp.int32),    # next decoder input
        }

    def stream_step(self, params, state, feats, *, lens=None):
        """One streaming chunk: feats (B,t,D) encoder embeddings ->
        (hidden (B,1,D), state).  One output position per chunk — the
        incremental decoder's next-token hidden, not per-frame senones
        (``models.api.stream_frame_sync``)."""
        cfg = self.cfg
        b, t, _ = feats.shape
        if lens is None:
            lens = jnp.full((b,), t, jnp.int32)
        lens = lens.astype(jnp.int32)
        alive = lens > 0
        hd = cfg.resolved_head_dim
        # ---- chunk-local encoder at per-row frame offsets
        pos_rows = state["enc_len"][:, None] + jnp.arange(t)     # (B,t)
        x = feats + params["enc_pos"].astype(feats.dtype)[
            jnp.clip(pos_rows, 0, MAX_POS - 1)]
        valid = jnp.arange(t)[None, :] < lens[:, None]           # (B,t)
        bias = jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]

        def enc_body(carry, bp):
            h = layers.norm_apply(bp["norm1"], carry, cfg.norm)
            q, k, v = attn_mod._project_qkv(bp["mixer"], cfg, h,
                                            jnp.arange(t))
            qg = attn_mod._group(q, cfg.n_kv_heads)    # (B,hkv,g,t,hd)
            s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) / np.sqrt(hd)
            p = jax.nn.softmax(s_ + bias, axis=-1)
            o = jnp.einsum("bhgqk,bhkd->bhgqd", p,
                           v.astype(jnp.float32)).astype(carry.dtype)
            o = o.reshape(b, cfg.n_heads, t, hd)
            o = o.transpose(0, 2, 1, 3).reshape(b, t, -1)
            x = carry + o @ bp["mixer"]["wo"].astype(h.dtype)
            h2 = layers.norm_apply(bp["norm2"], x, cfg.norm)
            x = x + layers.mlp_apply(bp["ffn"], h2, cfg.act)
            return x, None

        enc = _maybe_scan(cfg, enc_body, x, params["enc_blocks"])
        enc = layers.norm_apply(params["enc_norm"], enc, cfg.norm)
        # ---- scatter the chunk's cross-attn K/V at the row offsets;
        # target positions are fresh (zeros), so add == write, and the
        # validity mask keeps padded frames out of the buffers
        s_max = state["ck"].shape[3]
        onehot = ((pos_rows[:, :, None] == jnp.arange(s_max)[None, None, :])
                  & valid[:, :, None]).astype(state["ck"].dtype)  # (B,t,S)

        def per_layer(bp):
            return _xattn_kv(bp["cross"], cfg, enc)    # (B,h,t,hd) x2

        ck_c, cv_c = jax.vmap(per_layer)(params["dec_blocks"])
        ck = state["ck"] + jnp.einsum(
            "bts,nbhtd->nbhsd", onehot, ck_c.astype(state["ck"].dtype))
        cv = state["cv"] + jnp.einsum(
            "bts,nbhtd->nbhsd", onehot, cv_c.astype(state["cv"].dtype))
        enc_len = state["enc_len"] + lens
        # ---- one incremental decoder step over the audio heard so far
        cache = {"pos": state["pos"], "k": state["k"], "v": state["v"],
                 "ck": ck, "cv": cv, "enc_len": enc_len}
        hidden, new_cache = self._decode_hidden(params, cache,
                                                state["tok"])
        nxt = jnp.argmax(self.unembed(params, hidden)[:, -1],
                         axis=-1).astype(jnp.int32)[:, None]
        # ---- dead rows (lens == 0) must not advance: revert wholesale
        m5 = alive[None, :, None, None, None]
        state = {
            "pos": jnp.where(alive, new_cache["pos"], state["pos"]),
            "k": jnp.where(m5, new_cache["k"], state["k"]),
            "v": jnp.where(m5, new_cache["v"], state["v"]),
            "ck": jnp.where(m5, ck, state["ck"]),
            "cv": jnp.where(m5, cv, state["cv"]),
            "enc_len": jnp.where(alive, enc_len, state["enc_len"]),
            "tok": jnp.where(alive[:, None], nxt, state["tok"]),
        }
        return hidden, state

    def reset_stream_rows(self, state, rows):
        """Zero the streaming-state rows selected by the (B,) bool mask —
        slot admission for the stream surface, the ``reset_cache_rows``
        convention applied to the full streaming pytree."""
        m5 = rows[None, :, None, None, None]
        new = {"pos": jnp.where(rows, 0, state["pos"]),
               "enc_len": jnp.where(rows, 0, state["enc_len"]),
               "tok": jnp.where(rows[:, None], 0, state["tok"])}
        for key in ("k", "v", "ck", "cv"):
            new[key] = jnp.where(m5, jnp.zeros((), state[key].dtype),
                                 state[key])
        return new

    # stream-state batch axis per key: caches carry layers on axis 0
    _STREAM_ROW_AXIS = {"pos": 0, "enc_len": 0, "tok": 0,
                        "k": 1, "v": 1, "ck": 1, "cv": 1}

    def pull_stream_row(self, state, i):
        """Extract stream ``i``'s slice of every state buffer (detach:
        the serving layer parks it host-side).  Round-trips bitwise
        through ``put_stream_row``."""
        return {key: jnp.take(a, i, axis=self._STREAM_ROW_AXIS[key])
                for key, a in state.items()}

    def put_stream_row(self, state, i, row):
        """Write a previously pulled state row back into slot ``i``."""
        out = {}
        for key, a in state.items():
            idx = (slice(None),) * self._STREAM_ROW_AXIS[key] + (i,)
            out[key] = a.at[idx].set(jnp.asarray(row[key], a.dtype))
        return out
