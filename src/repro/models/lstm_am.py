"""The paper's acoustic models (Section 2 / 3.2).

Student: 5x768 unidirectional LSTM over 192-d stacked log-mel features,
3,183 senone outputs, ~24M params, 3-frame look-ahead (realized as a feature
shift in the data pipeline).  Teacher: 5x768 *bidirectional* LSTM (~78M).
No residuals/norms — faithful to the plain stacked-LSTM hybrid AM of 2019.
Supports chunked-BPTT: ``apply`` takes and returns per-layer (h, c) states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, recurrent


def is_bidirectional(cfg) -> bool:
    """Single source of truth for the AM's directionality (the streaming
    surface in models/api.py keys off the same predicate)."""
    return any(m == "bilstm" for m in cfg.mixers())


class LstmAM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.bidirectional = is_bidirectional(cfg)
        self.n_layers = cfg.n_layers

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, self.n_layers + 1)
        params = {"out": layers.dense_init(
            ks[-1],
            cfg.lstm_hidden * (2 if self.bidirectional else 1),
            cfg.n_senones)}
        d_in = cfg.feat_dim
        for i in range(self.n_layers):
            if self.bidirectional:
                kf, kb = jax.random.split(ks[i])
                params[f"l{i}"] = {
                    "fwd": recurrent.init_lstm(kf, d_in, cfg.lstm_hidden),
                    "bwd": recurrent.init_lstm(kb, d_in, cfg.lstm_hidden)}
                d_in = 2 * cfg.lstm_hidden
            else:
                params[f"l{i}"] = recurrent.init_lstm(ks[i], d_in,
                                                      cfg.lstm_hidden)
                d_in = cfg.lstm_hidden
        return params

    def apply(self, params, feats, *, state=None, positions=None, lens=None):
        """feats (B,T,F) -> (hidden (B,T,H), aux). state: list of (h,c).

        lens (B,) optional valid lengths for padded batches: recurrent
        state freezes at each row's length and the backward direction of a
        biLSTM starts at the last valid frame, so batched outputs match
        per-utterance runs on the valid region (see recurrent.lstm_apply).
        """
        x = feats
        new_state = []
        for i in range(self.n_layers):
            if self.bidirectional:
                x = recurrent.bilstm_apply(params[f"l{i}"]["fwd"],
                                           params[f"l{i}"]["bwd"], x,
                                           lens=lens)
                new_state.append(None)
            else:
                st = None if state is None else state[i]
                x, st = recurrent.lstm_apply(params[f"l{i}"], x, st,
                                             lens=lens)
                new_state.append(st)
        return x, {"state": new_state if not self.bidirectional else None}

    def unembed_matrix(self, params):
        return params["out"]

    def unembed(self, params, h):
        return (h @ params["out"].astype(h.dtype)).astype(jnp.float32)

    def logits(self, params, feats, state=None):
        h, aux = self.apply(params, feats, state=state)
        return self.unembed(params, h), aux

    def init_state(self, batch, dtype=jnp.float32):
        if self.bidirectional:
            return None
        h = self.cfg.lstm_hidden
        return [(jnp.zeros((batch, h), dtype), jnp.zeros((batch, h),
                                                         jnp.float32))
                for _ in range(self.n_layers)]

    # ------------------------------------------------- streaming surface
    # Chunked online inference: feed arbitrary-length feature chunks, carry
    # the per-layer (h, c) pytree across calls.  Feeding an utterance in
    # chunks is exactly equivalent to one full-utterance apply().

    def init_stream_state(self, batch, dtype=jnp.float32, **_sizing):
        """Fresh per-stream recurrent state (batch = concurrent streams).
        Sizing kwargs (``max_frames``/``max_tokens``) are accepted for
        surface uniformity with the whisper streaming state and ignored:
        LSTM state is O(1) per stream."""
        if self.bidirectional:
            raise ValueError(
                "bidirectional AM has no streaming form; use the batched "
                "full-utterance path (serve.StreamingEngine.run)")
        return self.init_state(batch, dtype)

    def stream_step(self, params, state, feats, *, lens=None):
        """One streaming chunk: feats (B,T,F) -> (hidden (B,T,H), state).

        lens (B,) marks each stream's valid frames within the chunk;
        shorter streams' states freeze at their length, so ragged chunks
        batch safely.
        """
        h, aux = self.apply(params, feats, state=state, lens=lens)
        return h, aux["state"]

    def reset_stream_rows(self, state, rows):
        """Zero the (h, c) rows selected by the (B,) bool mask — slot
        admission for the stream surface (the ``reset_cache_rows``
        convention of the decode caches, applied to recurrent state)."""
        return jax.tree_util.tree_map(
            lambda a: jnp.where(rows[:, None], jnp.zeros((), a.dtype), a),
            state)

    def pull_stream_row(self, state, i):
        """Extract stream ``i``'s state row (detach: the serving layer
        parks it host-side).  Round-trips bitwise through
        ``put_stream_row``."""
        return jax.tree_util.tree_map(lambda a: a[i], state)

    def put_stream_row(self, state, i, row):
        """Write a previously pulled state row back into slot ``i``."""
        return jax.tree_util.tree_map(
            lambda a, r: a.at[i].set(jnp.asarray(r, a.dtype)), state, row)
