"""Recurrent sequence mixers: LSTM / biLSTM (the paper's AM), RG-LRU
(RecurrentGemma/Griffin), mLSTM and sLSTM (xLSTM).

Training/prefill forms:
  - LSTM / sLSTM: strictly sequential -> ``lax.scan`` over time.
  - RG-LRU: linear recurrence -> ``lax.associative_scan`` (parallel).
  - mLSTM: baseline is the sequential scan; a chunkwise-parallel form lives in
    ``mlstm_chunked`` (used when seq is long) — both are tested equal.
Decode forms: single-step recurrences over an explicit state pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


# =================================================================== LSTM

def init_lstm(key, d_in: int, d_h: int):
    ks = jax.random.split(key, 2)
    return {"wx": layers.dense_init(ks[0], d_in, 4 * d_h),
            "wh": layers.dense_init(ks[1], d_h, 4 * d_h),
            "b": jnp.zeros((4 * d_h,), jnp.float32)}


def lstm_cell(params, x_t, h, c):
    z = x_t @ params["wx"].astype(x_t.dtype) \
        + h @ params["wh"].astype(x_t.dtype) + params["b"].astype(x_t.dtype)
    i, f, g, o = jnp.split(z.astype(jnp.float32), 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h.astype(x_t.dtype), c


def lstm_apply(params, x, state=None, lens=None):
    """x (B,S,D) -> (B,S,H). state: optional (h, c) carried (chunked BPTT).

    lens (B,) optional valid lengths: the carried (h, c) freezes once a
    row passes its length, so a padded batch hands back exactly the state
    an unpadded per-row run would (the serving engine's batching
    invariant).  Outputs past a row's length are unspecified — callers
    mask or slice them.
    """
    b = x.shape[0]
    d_h = params["wh"].shape[0]
    if state is None:
        state = (jnp.zeros((b, d_h), x.dtype), jnp.zeros((b, d_h), jnp.float32))

    if lens is None:
        def step(carry, x_t):
            h, c = carry
            h, c = lstm_cell(params, x_t, h, c)
            return (h, c), h

        (h, c), ys = jax.lax.scan(step, state, x.transpose(1, 0, 2))
        return ys.transpose(1, 0, 2), (h, c)

    mask = (jnp.arange(x.shape[1])[None, :] < lens[:, None])   # (B,S)

    def step(carry, xm):
        x_t, m_t = xm
        h, c = carry
        h2, c2 = lstm_cell(params, x_t, h, c)
        h = jnp.where(m_t, h2, h)
        c = jnp.where(m_t, c2, c)
        return (h, c), h2

    (h, c), ys = jax.lax.scan(
        step, state, (x.transpose(1, 0, 2), mask.T[..., None]))
    return ys.transpose(1, 0, 2), (h, c)


def masked_reverse(x, lens):
    """Reverse each row's first lens[b] steps along time; zero the tail.

    x (B,S,...), lens (B,) -> same shape.  Involution on the valid region:
    applying it twice restores the input (used to run the backward LSTM of
    a biLSTM over ragged batches without reading padding).
    """
    s = x.shape[1]
    ar = jnp.arange(s)
    idx = jnp.clip(lens[:, None] - 1 - ar[None, :], 0, s - 1)   # (B,S)
    idx = idx.reshape(idx.shape + (1,) * (x.ndim - 2))
    rev = jnp.take_along_axis(x, idx, axis=1)
    mask = (ar[None, :] < lens[:, None]).reshape(
        x.shape[:2] + (1,) * (x.ndim - 2))
    return jnp.where(mask, rev, jnp.zeros((), x.dtype))


def bilstm_apply(fwd_params, bwd_params, x, lens=None):
    """Bidirectional LSTM.  With lens, the backward pass starts at each
    row's last *valid* frame, so padded batches match per-row runs on the
    valid region (positions past lens are unspecified)."""
    if lens is None:
        yf, _ = lstm_apply(fwd_params, x)
        yb, _ = lstm_apply(bwd_params, x[:, ::-1])
        return jnp.concatenate([yf, yb[:, ::-1]], axis=-1)
    yf, _ = lstm_apply(fwd_params, x, lens=lens)
    yb, _ = lstm_apply(bwd_params, masked_reverse(x, lens), lens=lens)
    return jnp.concatenate([yf, masked_reverse(yb, lens)], axis=-1)


# ================================================================= RG-LRU

def init_rglru_block(key, cfg):
    """Griffin recurrent block: in/gate proj -> conv -> RG-LRU -> out proj."""
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(L) in (0.9, 0.999) roughly
    lam = jnp.log(jnp.expm1(
        jnp.linspace(2.0, 6.0, w, dtype=jnp.float32)))  # softplus^-1 spread
    return {
        "w_in": layers.dense_init(ks[0], d, w),
        "w_gate": layers.dense_init(ks[1], d, w),
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                 / np.sqrt(cfg.conv_width)),
        "w_a": layers.dense_init(ks[3], w, w, scale=0.5),
        "w_i": layers.dense_init(ks[4], w, w, scale=0.5),
        "lam": lam,
        "w_out": layers.dense_init(ks[5], w, d),
    }


def _causal_conv(x, kernel, state=None):
    """Depthwise causal conv along time. x (B,S,W), kernel (K,W).

    state (B,K-1,W) holds trailing context for decode; returns (y, new_state).
    """
    k = kernel.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * kernel[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return y, new_state


def _rglru_coeffs(params, x):
    """Per-step gate a_t (decay) and gated input, float32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"])
    i = jax.nn.sigmoid(xf @ params["w_i"])
    log_a = -8.0 * r * jax.nn.softplus(params["lam"])      # log a_t <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, gated


def rglru_scan(params, x, h0=None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan."""
    a, bseq = _rglru_coeffs(params, x)
    if h0 is not None:
        # fold initial state into the first input
        bseq = bseq.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bseq), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block_apply(params, cfg, x, state=None):
    """x (B,S,D) -> (B,S,D). state = {"h": (B,W) f32, "conv": (B,K-1,W)}."""
    gate = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype))
    u = x @ params["w_in"].astype(x.dtype)
    u, conv_state = _causal_conv(u, params["conv"],
                                 None if state is None else state["conv"])
    h, h_last = rglru_scan(params, u, None if state is None else state["h"])
    y = (h * gate) @ params["w_out"].astype(x.dtype)
    return y, {"h": h_last, "conv": conv_state}


def rglru_block_decode(params, cfg, x, state):
    """Single step. x (B,1,D)."""
    gate = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype))
    u = x @ params["w_in"].astype(x.dtype)
    u, conv_state = _causal_conv(u, params["conv"], state["conv"])
    a, b = _rglru_coeffs(params, u)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = (h[:, None].astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return y, {"h": h, "conv": conv_state}


def init_rglru_state(cfg, batch, dtype):
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype)}


# ================================================================== mLSTM

def init_mlstm_block(key, cfg):
    d = cfg.d_model
    inner = int(cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": layers.dense_init(ks[0], d, inner),
        "w_gate": layers.dense_init(ks[1], d, inner),
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, inner), jnp.float32)
                 / np.sqrt(cfg.conv_width)),
        "wq": layers.dense_init(ks[3], inner, inner),
        "wk": layers.dense_init(ks[4], inner, inner),
        "wv": layers.dense_init(ks[5], inner, inner),
        "w_if": layers.dense_init(ks[6], inner, 2 * h),   # i,f gate logits
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "gn": jnp.ones((inner,), jnp.float32),            # group-norm scale
        "w_down": layers.dense_init(ks[7], inner, d),
    }


def _mlstm_qkv(params, cfg, x, conv_state=None):
    """x (B,S,D) -> conv'd qkv (B,H,S,hd) and gate logits (B,S,2H)."""
    u = x @ params["w_up"].astype(x.dtype)
    c, conv_state = _causal_conv(u, params["conv"], conv_state)
    c = jax.nn.silu(c)
    b, s, inner = c.shape
    h = cfg.n_heads
    hd = inner // h

    def heads(m):
        return m.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    q = heads(c @ params["wq"].astype(x.dtype)) / np.sqrt(hd)
    k = heads(c @ params["wk"].astype(x.dtype)) / np.sqrt(hd)
    v = heads(u @ params["wv"].astype(x.dtype))
    gates = (c @ params["w_if"].astype(x.dtype)).astype(jnp.float32) \
        + params["b_if"]
    return q, k, v, gates, u, conv_state


def _mlstm_step(carry, t):
    C, n, m = carry
    qt, kt, vt, il, fl = t
    m_new = jnp.maximum(fl + m, il)
    i_ = jnp.exp(il - m_new)[..., None]
    f_ = jnp.exp(fl + m - m_new)[..., None]
    C = f_[..., None] * C + i_[..., None] * (vt[..., :, None]
                                             * kt[..., None, :])
    n = f_ * n + i_ * kt
    num = jnp.einsum("bhvk,bhk->bhv", C, qt)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                      jnp.exp(-m_new))[..., None]
    return (C, n, m_new), (num / den)


MLSTM_CHUNK = 64     # remat-chunk: backward saves only chunk-boundary
                     # states ((B,H,hd,hd) every 64 steps instead of every
                     # step) — see EXPERIMENTS.md §Perf (xlstm train_4k)


def mlstm_scan(q, k, v, gates, *, chunk: int = MLSTM_CHUNK):
    """Sequential stabilized mLSTM. q/k/v (B,H,S,hd); gates (B,S,2H).

    Chunked + remat: an outer scan over S/chunk blocks whose body (an
    inner scan over `chunk` steps) is jax.checkpoint'ed.  Numerically
    identical to the flat scan; activation residuals for backward drop
    from O(S) per-step (B,H,hd,hd) C-states to O(S/chunk) boundary states
    + recompute.  Returns h (B,H,S,hd) and final state (C, n, m).
    """
    b, h, s, hd = q.shape
    i_log = gates[..., :h].transpose(0, 2, 1)       # (B,H,S)
    f_log = jax.nn.log_sigmoid(gates[..., h:]).transpose(0, 2, 1)

    C0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.zeros((b, h), jnp.float32)
    xs = (q.transpose(2, 0, 1, 3).astype(jnp.float32),
          k.transpose(2, 0, 1, 3).astype(jnp.float32),
          v.transpose(2, 0, 1, 3).astype(jnp.float32),
          i_log.transpose(2, 0, 1), f_log.transpose(2, 0, 1))

    c = min(chunk, s)
    if s % c:
        (C, n, m), hs = jax.lax.scan(_mlstm_step, (C0, n0, m0), xs)
        return hs.transpose(1, 2, 0, 3), (C, n, m)

    nchunks = s // c
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape(nchunks, c, *a.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(carry, xc):
        return jax.lax.scan(_mlstm_step, carry, xc)

    (C, n, m), hs = jax.lax.scan(chunk_body, (C0, n0, m0), xs_c)
    hs = hs.reshape(s, b, h, hd)
    return hs.transpose(1, 2, 0, 3), (C, n, m)


def mlstm_block_apply(params, cfg, x, state=None):
    q, k, v, gates, u, conv_state = _mlstm_qkv(
        params, cfg, x, None if state is None else state["conv"])
    if state is not None:
        hseq, st = _mlstm_with_state(q, k, v, gates, state)
        st["conv"] = conv_state
    else:
        hseq, (C, n, m) = mlstm_scan(q, k, v, gates)
        st = {"C": C, "n": n, "m": m, "conv": conv_state}
    b, h, s, hd = hseq.shape
    y = hseq.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    y = _groupnorm(y, params["gn"], h)
    gate = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
    y = (y.astype(x.dtype) * gate) @ params["w_down"].astype(x.dtype)
    return y, st


def _mlstm_with_state(q, k, v, gates, state):
    # prefill continuing from a state: fold state via scan init
    b, h, s, hd = q.shape
    i_log = gates[..., :h].transpose(0, 2, 1)
    f_log = jax.nn.log_sigmoid(gates[..., h:]).transpose(0, 2, 1)

    def step(carry, t):
        C, n, m = carry
        qt, kt, vt, il, fl = t
        m_new = jnp.maximum(fl + m, il)
        i_ = jnp.exp(il - m_new)[..., None]
        f_ = jnp.exp(fl + m - m_new)[..., None]
        C = f_[..., None] * C + i_[..., None] * (vt[..., :, None]
                                                 * kt[..., None, :])
        n = f_ * n + i_ * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), (num / den)

    init = (state["C"], state["n"], state["m"])
    (C, n, m), hs = jax.lax.scan(
        step, init,
        (q.transpose(2, 0, 1, 3).astype(jnp.float32),
         k.transpose(2, 0, 1, 3).astype(jnp.float32),
         v.transpose(2, 0, 1, 3).astype(jnp.float32),
         i_log.transpose(2, 0, 1), f_log.transpose(2, 0, 1)))
    return hs.transpose(1, 2, 0, 3), {"C": C, "n": n, "m": m,
                                      "conv": state["conv"]}


def mlstm_block_decode(params, cfg, x, state):
    """x (B,1,D); single recurrent step."""
    u = x @ params["w_up"].astype(x.dtype)
    c, conv_state = _causal_conv(u, params["conv"], state["conv"])
    c = jax.nn.silu(c)
    b, _, inner = c.shape
    h = cfg.n_heads
    hd = inner // h

    def heads(m):
        return m.reshape(b, h, hd)
    q = heads(c[:, 0] @ params["wq"].astype(x.dtype)).astype(jnp.float32) / np.sqrt(hd)
    k = heads(c[:, 0] @ params["wk"].astype(x.dtype)).astype(jnp.float32) / np.sqrt(hd)
    v = heads(u[:, 0] @ params["wv"].astype(x.dtype)).astype(jnp.float32)
    gl = (c[:, 0] @ params["w_if"].astype(x.dtype)).astype(jnp.float32) \
        + params["b_if"]
    il, fl = gl[..., :h], jax.nn.log_sigmoid(gl[..., h:])
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(fl + m, il)
    i_ = jnp.exp(il - m_new)[..., None]
    f_ = jnp.exp(fl + m - m_new)[..., None]
    C = f_[..., None] * C + i_[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_ * n + i_ * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))[..., None]
    hvec = (num / den).reshape(b, 1, inner)
    y = _groupnorm(hvec, params["gn"], h)
    gate = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
    y = (y.astype(x.dtype) * gate) @ params["w_down"].astype(x.dtype)
    return y, {"C": C, "n": n, "m": m_new, "conv": conv_state}


def init_mlstm_state(cfg, batch, dtype):
    inner = int(cfg.mlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    hd = inner // h
    return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, inner), dtype)}


def _groupnorm(x, scale, n_groups, eps=1e-6):
    """Head-wise group norm over the channel axis. x (B,S,C)."""
    b, s, cdim = x.shape
    xf = x.astype(jnp.float32).reshape(b, s, n_groups, cdim // n_groups)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.reshape(b, s, cdim) * scale).astype(x.dtype)


# ================================================================== sLSTM

def init_slstm_block(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    ks = jax.random.split(key, 4)
    up = int(cfg.slstm_proj_factor * d)
    return {
        "conv": (jax.random.normal(ks[0], (cfg.conv_width, d), jnp.float32)
                 / np.sqrt(cfg.conv_width)),
        "wx": layers.dense_init(ks[1], d, 4 * d),
        # block-diagonal recurrent weights: per head (hd x 4hd)
        "rh": (jax.random.normal(ks[2], (h, d // h, 4 * (d // h)),
                                 jnp.float32) / np.sqrt(d // h)),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "gn": jnp.ones((d,), jnp.float32),
        # post-block gated MLP (the sLSTM block's own ffn)
        "mlp": layers.mlp_init(ks[3], d, up, gated=True),
    }


def _slstm_gates(params, cfg, xz, hprev):
    """xz (B,4D) precomputed input part; hprev (B,D)."""
    b, d4 = xz.shape
    d = d4 // 4
    h = cfg.n_heads
    hd = d // h
    rec = jnp.einsum("bhk,hkj->bhj", hprev.reshape(b, h, hd),
                     params["rh"]).reshape(b, 4 * d)
    z = xz + rec + params["b"]
    # layout: [i, f, z, o] each d wide
    return jnp.split(z, 4, axis=-1)


def slstm_block_apply(params, cfg, x, state=None):
    b, s, d = x.shape
    c_in, conv_state = _causal_conv(x, params["conv"],
                                    None if state is None else state["conv"])
    c_in = jax.nn.silu(c_in)
    xz = (c_in @ params["wx"].astype(x.dtype)).astype(jnp.float32)
    if state is None:
        st = init_slstm_state(cfg, b, x.dtype)
    else:
        st = state

    def step(carry, xz_t):
        c, n, m, hprev = carry
        il, fl, zl, ol = _slstm_gates(params, cfg, xz_t, hprev)
        m_new = jnp.maximum(fl + m, il)
        i_ = jnp.exp(il - m_new)
        f_ = jnp.exp(fl + m - m_new)
        c = f_ * c + i_ * jnp.tanh(zl)
        n = f_ * n + i_
        hv = jax.nn.sigmoid(ol) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, hv), hv

    init = (st["c"], st["n"], st["m"], st["h"])
    xs = xz.transpose(1, 0, 2)
    seq = xs.shape[0]
    ck = min(MLSTM_CHUNK, seq)
    if seq % ck == 0 and seq > ck:      # remat-chunked (see mlstm_scan)
        xs_c = xs.reshape(seq // ck, ck, *xs.shape[1:])

        @jax.checkpoint
        def chunk_body(carry, xc):
            return jax.lax.scan(step, carry, xc)

        (c, n, m, hlast), hs = jax.lax.scan(chunk_body, init, xs_c)
        hs = hs.reshape(seq, *hs.shape[2:])
    else:
        (c, n, m, hlast), hs = jax.lax.scan(step, init, xs)
    hs = hs.transpose(1, 0, 2)
    y = _groupnorm(hs, params["gn"], cfg.n_heads).astype(x.dtype)
    y = y + layers.mlp_apply(params["mlp"], y, "gelu")
    new_state = {"c": c, "n": n, "m": m, "h": hlast, "conv": conv_state}
    return y, new_state


def slstm_block_decode(params, cfg, x, state):
    y, st = slstm_block_apply(params, cfg, x, state)
    return y, st


def init_slstm_state(cfg, batch, dtype):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, d), dtype)}
