"""Multi-head Latent Attention (DeepSeek-V3).

Train/prefill: decompress the latent c_kv into per-head K/V and run standard
flash attention.  Decode: *absorbed* form — the cache stores only
(c_kv, k_rope) per token, W_uk is folded into the query and W_uv applied
after attention, so per-step work is O(S * (kv_rank + rope_dim)) per head
instead of rematerializing full K/V (which at 32k x 128 heads would be
hundreds of GB).  See DESIGN.md §Perf for the naive-vs-absorbed accounting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import layers

NEG_INF = -1e30


def init_mla(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": layers.dense_init(ks[0], d, m.q_lora_rank),
        "q_norm": layers.norm_init(m.q_lora_rank, "rmsnorm"),
        "w_uq": layers.dense_init(ks[1], m.q_lora_rank, h * qk_dim),
        "w_dkv": layers.dense_init(ks[2], d,
                                   m.kv_lora_rank + m.qk_rope_head_dim),
        "kv_norm": layers.norm_init(m.kv_lora_rank, "rmsnorm"),
        "w_uk": layers.dense_init(ks[3], m.kv_lora_rank,
                                  h * m.qk_nope_head_dim),
        "w_uv": layers.dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim),
        "wo": layers.dense_init(ks[5], h * m.v_head_dim, d),
    }


def _queries(params, cfg, x, positions):
    """-> q_nope (B,H,S,nope), q_rope (B,H,S,rope) with rope applied."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = x @ params["w_dq"].astype(x.dtype)
    cq = layers.norm_apply(params["q_norm"], cq, "rmsnorm")
    q = (cq @ params["w_uq"].astype(x.dtype)).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim).transpose(0, 2, 1, 3)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim:]
    cos, sin = layers.rope_tables(positions, m.qk_rope_head_dim,
                                  cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _latents(params, cfg, x, positions):
    """-> c_kv (B,S,rank) normalized, k_rope (B,S,rope) with rope applied."""
    m = cfg.mla
    dkv = x @ params["w_dkv"].astype(x.dtype)
    c_kv = layers.norm_apply(params["kv_norm"], dkv[..., :m.kv_lora_rank],
                             "rmsnorm")
    k_rope = dkv[..., m.kv_lora_rank:]
    cos, sin = layers.rope_tables(positions, m.qk_rope_head_dim,
                                  cfg.rope_theta)
    k_rope = layers.apply_rope(k_rope, cos, sin)
    return c_kv, k_rope


def mla_apply(params, cfg, x, positions):
    """Full-sequence MLA (decompressed path). x (B,S,D)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    k_nope = (c_kv @ params["w_uk"].astype(x.dtype)).reshape(
        b, s, h, m.qk_nope_head_dim).transpose(0, 2, 1, 3)
    v = (c_kv @ params["w_uv"].astype(x.dtype)).reshape(
        b, s, h, m.v_head_dim).transpose(0, 2, 1, 3)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, None],
                                          (b, h, s, m.qk_rope_head_dim))],
                        axis=-1)
    # MHA == GQA with G=1 groups per head
    cq = s if cfg.attn_whole_seq else 512
    ckv = s if cfg.attn_whole_seq else 1024
    o = attn_mod.flash_full_attention(q[:, :, None], k, v, positions,
                                      positions, chunk_q=cq, chunk_kv=ckv)
    o = o[:, :, 0].transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    return o @ params["wo"].astype(x.dtype)


def init_mla_cache(cfg, batch, seq_len, dtype, paging=None):
    m = cfg.mla
    if paging is not None:
        # pooled latent cache (no batch axis): rows reach their pages
        # through the shared block table — see models/paging
        slots = paging.pool_slots
        return {"c_kv": jnp.zeros((slots, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((slots, m.qk_rope_head_dim), dtype)}
    return {"c_kv": jnp.zeros((batch, seq_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, seq_len, m.qk_rope_head_dim), dtype)}


def mla_decode(params, cfg, x, cache, pos, pages=None):
    """Absorbed single-token decode. x (B,1,D); pos scalar (lockstep rows,
    kept bitwise) or (B,) per-row positions (continuous batching).  A 2-D
    (pool) latent cache selects the paged path — flat one-hot write, flat
    gather back to (B, S, rank); see models/paging."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    per_row = pos.ndim == 1 and pos.shape[0] == b
    paged = cache["c_kv"].ndim == 2
    if paged and (pages is None or not per_row):
        raise ValueError("paged MLA cache requires per-row positions and "
                         "a PageRef (cache['pages'])")
    q_nope, q_rope = _queries(params, cfg, x,
                              pos[:, None, None] if per_row else pos[None])
    c_new, kr_new = _latents(params, cfg, x,
                             pos[:, None] if per_row else pos[None])
    if paged:
        from repro.models import paging as paging_mod
        widx = paging_mod.write_index(pages, pos)
        pool_c = paging_mod.pool_write(cache["c_kv"], c_new[:, 0], widx)
        pool_kr = paging_mod.pool_write(cache["k_rope"], kr_new[:, 0], widx)
        gidx = paging_mod.gather_indices(pages)          # (B, max_ctx)
        c = pool_c[gidx]                                 # (B, S, rank)
        kr = pool_kr[gidx]                               # (B, S, rope)
        new_cache = {"c_kv": pool_c, "k_rope": pool_kr}
    elif per_row:
        c = attn_mod.row_update(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
        kr = attn_mod.row_update(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos,
            axis=1)
        new_cache = {"c_kv": c, "k_rope": kr}
    else:
        c = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos,
            axis=1)
        new_cache = {"c_kv": c, "k_rope": kr}
    # absorb W_uk into the query: q_c (B,H,rank)
    w_uk = params["w_uk"].astype(x.dtype).reshape(m.kv_lora_rank, h,
                                                  m.qk_nope_head_dim)
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0], w_uk)
    s_nope = jnp.einsum("bhr,bsr->bhs", q_c.astype(jnp.float32),
                        c.astype(jnp.float32))
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, :, 0].astype(jnp.float32),
                        kr.astype(jnp.float32))
    s_ = (s_nope + s_rope) * scale
    valid = attn_mod.decode_slot_validity(pos, c.shape[1])
    if per_row:
        s_ = jnp.where(valid[:, None], s_, NEG_INF)       # (B,1,S)
    else:
        s_ = jnp.where(valid[None, None], s_, NEG_INF)    # (1,1,S)
    p = jax.nn.softmax(s_, axis=-1)
    # attention over latents, then decompress once per head
    o_c = jnp.einsum("bhs,bsr->bhr", p, c.astype(jnp.float32))  # (B,H,rank)
    w_uv = params["w_uv"].astype(x.dtype).reshape(m.kv_lora_rank, h,
                                                  m.v_head_dim)
    o = jnp.einsum("bhr,rhv->bhv", o_c.astype(x.dtype), w_uv)
    o = o.reshape(b, 1, h * m.v_head_dim)
    return o @ params["wo"].astype(x.dtype), new_cache
