"""Mixture-of-Experts channel mixer with capacity-based sort dispatch.

TPU adaptation: GPU MoE stacks use index-list gather/scatter per expert; here
tokens are routed into a static (E, C, D) buffer via a cumsum-rank scatter
(all shapes static, jit/pjit friendly).  The expert dimension shards over the
`model` mesh axis (expert parallelism); XLA inserts the token all-to-all.
Overflow tokens beyond capacity are dropped (standard capacity-factor MoE);
dropped assignments fall back to the residual path.

Aux outputs: load-balance loss (Switch-style f*P) and router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


def init_moe(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], d, e, scale=0.1),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   / np.sqrt(d)),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 / np.sqrt(d)),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / np.sqrt(f)),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.mlp_init(ks[4], d,
                                      cfg.n_shared_experts * f, gated=True)
    return p


def capacity(n_tokens: int, cfg) -> int:
    """Per-group expert capacity: ceil(K*N/E * factor), 8-aligned."""
    c = int(np.ceil(cfg.moe_top_k * n_tokens / cfg.n_experts
                    * cfg.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)          # align to 8


def _group_dispatch_combine(xt, top_p, top_i, wg, wu, wd, *, e, k, cap,
                            act):
    """One routing group (GShard-style).  xt (N,D); top_* (N,K).

    Returns (y (N,D), counts (E,), n_dropped scalar).  All shapes static;
    the scatter/gather touch only group-local rows, so under vmap the
    SPMD partitioner shards the *group* dim and never sees a global
    data-dependent scatter (the auto-SPMD compile pathology — see
    EXPERIMENTS.md §Perf).
    """
    n, d = xt.shape
    flat_e = top_i.reshape(-1)                                # (N*K,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((n * k,), jnp.int32).at[sort_idx].set(pos_sorted)
    valid = pos < cap
    slot = jnp.where(valid, flat_e * cap + pos, e * cap)      # drop row

    src = jnp.repeat(xt, k, axis=0)                           # (N*K, D)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].add(
        src * valid[:, None].astype(xt.dtype))
    buf = buf[:-1].reshape(e, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
        * jnp.einsum("ecd,edf->ecf", buf, wu)
    y_e = jnp.einsum("ecf,efd->ecd", h, wd)

    y_flat = jnp.concatenate([y_e.reshape(e * cap, d),
                              jnp.zeros((1, d), xt.dtype)])
    y_tok = y_flat[slot] * (top_p.reshape(-1, 1).astype(xt.dtype)
                            * valid[:, None].astype(xt.dtype))
    y = y_tok.reshape(n, k, d).sum(axis=1)
    return y, counts, jnp.sum(1 - valid.astype(jnp.float32))


def moe_apply(params, cfg, x):
    """x (B,S,D) -> (y (B,S,D), aux dict).

    Grouped (GShard-style) dispatch: each batch row is a routing group
    with its own capacity; groups are vmapped, so the group dim inherits
    the batch's data-axis sharding and dispatch stays shard-local.
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.moe_top_k
    e = cfg.n_experts
    xt = x.reshape(t, d)

    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                    # (T,K)
    if cfg.moe_renorm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = capacity(s, cfg)                                    # per group
    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)

    y, counts, dropped = jax.vmap(
        lambda xg, pg, ig: _group_dispatch_combine(
            xg, pg, ig, wg, wu, wd, e=e, k=k, cap=cap, act=cfg.act)
    )(xt.reshape(b, s, d), top_p.reshape(b, s, k), top_i.reshape(b, s, k))
    y = y.reshape(t, d)

    if cfg.n_shared_experts:
        y = y + layers.mlp_apply(params["shared"], xt, cfg.act)

    # ---- aux losses (global across groups) ----
    counts = counts.sum(axis=0)
    frac_tokens = counts.astype(jnp.float32) / (t * k)        # f_e
    mean_prob = probs.mean(axis=0)                            # P_e
    lb_loss = e * jnp.sum(frac_tokens * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped.sum() / (t * k)}
    return y.reshape(b, s, d), aux
