"""Shared building blocks: initializers, norms, MLPs, RoPE, embeddings.

All modules are pure functions over explicit param dicts.  Params are created
in float32 and cast by the runtime's param-dtype policy (launch/train.py);
norm/statistics math always runs in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, fan_in: int, fan_out: int, *, scale: float = 1.0,
               dtype=jnp.float32):
    std = scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, (fan_in, fan_out), dtype=jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)).astype(dtype)


# ---------------------------------------------------------------- norms

def norm_init(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}       # (1 + scale) form
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def norm_apply(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm over the last (head_dim) axis."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(x.dtype)


# ---------------------------------------------------------------- mlp

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp_init(key, d: int, d_ff: int, *, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, d_ff),
         "down": dense_init(ks[2], d_ff, d)}
    if gated:
        p["gate"] = dense_init(ks[1], d, d_ff)
    return p


def mlp_apply(params, x, act: str):
    h = x @ params["up"].astype(x.dtype)
    if "gate" in params:
        h = act_fn(act)(x @ params["gate"].astype(x.dtype)) * h
    else:
        h = act_fn(act)(h)
    return h @ params["down"].astype(x.dtype)


# ---------------------------------------------------------------- rope

def rope_tables(positions, dim: int, theta: float):
    """positions (...,) int -> cos/sin (..., dim/2) float32."""
    inv = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, dim); cos/sin broadcastable (..., S, dim/2). Paired halves."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
