"""Device-side paged KV-cache layout (vLLM-style block tables).

A paged decode cache replaces the contiguous per-row ``(B, ..., max_seq,
...)`` KV region with a shared **pool** of fixed-size pages plus a
per-row **block table** mapping logical block -> physical page:

  * each pageable layer stores one pool with ``(n_pages + 1) * page_size``
    token slots and NO batch axis — attn KV pools are
    ``(pool_slots, n_kv_heads, head_dim)``, MLA latent pools
    ``(pool_slots, kv_lora_rank)`` / ``(pool_slots, qk_rope_head_dim)``;
  * one block table ``(B, max_blocks) int32`` + per-row capacities
    ``(B,) int32`` live in the cache root (``cache["pages"]``) and are
    shared by every pageable layer — each layer has its own pool, all
    pools use the same page ids;
  * **page 0 is a reserved trash page**: the host allocator only hands
    out ids ``1..n_pages``, and empty/retired slots (table row zeroed,
    cap 0) read and write page 0 harmlessly — window overshoot can never
    corrupt another row's pages.

Only full-context attention layers page (plain ``attn`` mixers and MLA
latent caches).  Sliding-window rings are already memory-bounded to
``window`` slots and recurrent states are O(1) per row, so both keep
their contiguous per-row layout — paging them would add indirection for
no density win.

Writes and reads stay one-hot/gather (no scatters), matching the
contiguous per-row path's lowering: a write is an einsum of a
``(B, pool_slots)`` one-hot against the new values, a read is a flat
gather of each row's ``max_ctx`` logical slots.  Masked (>= pos) columns
contribute exact zeros through softmax, so paged attention is
token-identical to the contiguous path under greedy decoding (pinned in
tests/test_paged_cache.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp


@dataclass(frozen=True)
class PagedCacheConfig:
    """Static paging geometry (host + device agree on it).

    page_size: token positions per page.
    n_pages: allocatable pages in every layer pool (page 0 is extra and
        reserved as the trash page).
    max_ctx: logical per-row context capacity (block-table width *
        page_size).  0 -> ``n_pages * page_size`` (one row may, in
        principle, own the whole pool).
    """
    page_size: int = 16
    n_pages: int = 64
    max_ctx: int = 0

    def __post_init__(self):
        if self.page_size < 1 or self.n_pages < 1:
            raise ValueError("page_size and n_pages must be >= 1")
        if self.max_ctx % self.page_size:
            raise ValueError(
                f"max_ctx ({self.max_ctx}) must be a multiple of "
                f"page_size ({self.page_size})")

    @property
    def resolved_max_ctx(self) -> int:
        return self.max_ctx or self.n_pages * self.page_size

    @property
    def max_blocks(self) -> int:
        return self.resolved_max_ctx // self.page_size

    @property
    def pool_slots(self) -> int:
        # +1: page 0, the trash page
        return (self.n_pages + 1) * self.page_size


class PageRef(NamedTuple):
    """The traced view of the shared block table, built inside
    ``decode_step`` from ``cache["pages"]`` (page_size stays a static
    Python int — it shapes the gather index arithmetic)."""
    tables: jnp.ndarray            # (B, max_blocks) int32, 0 = trash page
    caps: jnp.ndarray              # (B,) int32 allocated positions per row
    page_size: int


def is_paged_spec(spec) -> bool:
    """Does this attention-family LayerSpec page?  Windowed swa layers
    keep their contiguous ring (already bounded to ``window`` slots)."""
    return not (spec.mixer == "swa" and spec.window)


def prefix_sharing_supported(cfg) -> bool:
    """Prefix pages may only be shared when the *entire* cross-token
    state of a prompt position lives in pageable pools.  Any swa ring,
    recurrent state or encoder cross-attention would start a prefix-hit
    row with stale/zero non-paged state, so those archs admit at pos 0
    (no sharing) instead of returning wrong tokens."""
    if cfg.encoder is not None or cfg.family == "lstm_am":
        return False
    for seg in cfg.segments:
        for sp in seg.pattern:
            if sp.mixer not in ("attn", "swa") or not is_paged_spec(sp):
                return False
    return True


def paged_token_bytes(cfg, dtype) -> int:
    """Bytes of pool storage one token position occupies across every
    pageable layer (the unit of the serve bench's memory accounting)."""
    item = jnp.dtype(dtype).itemsize
    total = 0
    for seg in cfg.segments:
        for sp in seg.pattern:
            if sp.mixer in ("attn", "swa") and is_paged_spec(sp):
                if cfg.mla is not None:
                    per = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                else:
                    per = 2 * cfg.n_kv_heads * cfg.resolved_head_dim
                total += seg.repeat * per * item
    if cfg.encoder is not None:
        # whisper decoder self-attention K/V
        total = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.resolved_head_dim \
            * item
    return total


# -------------------------------------------------------- traced helpers

def write_index(pages: PageRef, pos) -> jnp.ndarray:
    """(B,) flat pool slot where each row writes position ``pos``.

    The position is clamped into the row's allocation: rows past their
    capacity (retired slots overshooting until the next host sync)
    rewrite their own last slot, and rows with cap 0 (empty slots, table
    row zeroed) land in trash page 0 — never in another row's pages."""
    ps = pages.page_size
    lpos = jnp.clip(pos, 0, jnp.maximum(pages.caps - 1, 0))
    blk = lpos // ps
    page = jnp.take_along_axis(pages.tables, blk[:, None], axis=1)[:, 0]
    return page * ps + lpos % ps


def gather_indices(pages: PageRef) -> jnp.ndarray:
    """(B, max_blocks * page_size) flat pool slot of every logical
    position — unallocated blocks (table entry 0) read the trash page
    and are masked by the ``<= pos`` validity check downstream."""
    ps = pages.page_size
    b, nb = pages.tables.shape
    flat = pages.tables[:, :, None] * ps + jnp.arange(ps)[None, None, :]
    return flat.reshape(b, nb * ps)


def pool_write(pool, new, flat_idx):
    """Write ``new[b]`` (leading dim B) into ``pool[flat_idx[b]]``.

    One-hot einsum + covered-select instead of a scatter — the paged twin
    of ``attention.row_update``.  Rows of one batch target disjoint slots
    (disjoint allocations), except the trash page, where colliding
    writes sum finite activations — harmless, it is never read validly."""
    slots = pool.shape[0]
    m = (jnp.arange(slots)[None, :] == flat_idx[:, None])       # (B, slots)
    upd = jnp.einsum("bt,b...->t...", m.astype(pool.dtype),
                     new.astype(pool.dtype))
    covered = m.any(axis=0).reshape((slots,) + (1,) * (pool.ndim - 1))
    return jnp.where(covered, upd, pool)
