"""Gradient Threshold Compression (paper §2/§3.5; Strom, Interspeech 2015).

The paper's 16-GPU trainer for labeled CE + sMBR.  Strom's algorithm, kept
bit-faithful on the *algorithm* side:

  r      <- r + g                      (error-feedback residual)
  send   <- tau * sign(r) * [|r| > tau]   (1-bit-quantized sparse message)
  r      <- r - send
  update <- sum_over_workers(send)

TPU adaptation (DESIGN.md §2): the GPU implementation ships sparse
(index, ±tau) pairs peer-to-peer; TPU ICI collectives have no sparse
all-reduce, so the transport is a dense psum of the (mostly-zero,
1.58-bit-entropy) send tensor — int8-packed, which is where the
bandwidth saving appears in the collective roofline term.  A psum of
ternary int8 messages over <= 127 workers cannot overflow int8, so the
wire stays 1 byte/element (4x under f32); beyond 127 workers the
accumulation must widen to int32 (``GTCConfig.int32_accum``) and
``pack_int8`` *refuses* to build the narrow wire rather than silently
wrapping.

One code path owns the math.  ``compress_tree`` is the error-feedback
selection (optionally dispatched to the fused Pallas kernel
``repro.kernels.gtc_compress`` via ``GTCConfig.use_kernel``, with the
pure-jnp ref as fallback); ``pack_int8`` / ``unpack_int8`` are the only
pack/unpack pair; ``wire_reduce`` is the wire itself — the same
function serves the single-process ``train.GTC`` strategy (a degenerate
pack/unpack round-trip), ``make_gtc_allreduce`` (inside an existing
shard_map/pmap), and ``make_sharded_gtc_train_step`` (the
worker-axis-sharded step that ``train.GTCShardMap`` wraps).

Adaptive threshold: Strom fixes tau; we also provide the common variant
that adapts tau per-tensor to hit a target sparsity, used when sweeping.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.gtc_compress import gtc_compress
from repro.kernels.gtc_compress.ref import gtc_compress_ref

tmap = jax.tree_util.tree_map

MAX_INT8_WORKERS = 127       # |sum of W ternary messages| <= W must fit int8


@dataclass(frozen=True)
class GTCConfig:
    tau: float = 1e-3
    quantize_int8: bool = True       # pack the send tensor to int8 on the wire
    n_workers: int = 16
    int32_accum: bool = False        # widen the psum to int32 (required
                                     # beyond 127 workers; the narrow int8
                                     # wire is exact below that)
    use_kernel: bool = False         # fused Pallas compression kernel
                                     # (interpret-mode on CPU) vs the ref


# ----------------------------------------------------------- compression

def compress_leaf(g, r, tau: float, *, use_kernel: bool = False):
    """One tensor: error-feedback threshold compression.

    Returns (send, new_residual); send has values in {-tau, 0, +tau}.
    ``use_kernel`` routes through the fused Pallas pass
    (``repro.kernels.gtc_compress`` — same math, one HBM round-trip);
    the default is the pure-jnp reference.  Both are float32 and
    bitwise-identical.
    """
    if use_kernel:
        return gtc_compress(g, r, tau)    # auto: compiled on TPU,
                                          # interpret mode elsewhere
    return gtc_compress_ref(jnp.asarray(g), jnp.asarray(r, jnp.float32), tau)


def compress_tree(grads, residuals, tau: float, *, use_kernel: bool = False):
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    sends, ress = [], []
    for g, r in zip(flat_g, flat_r):
        s, nr = compress_leaf(g, r, tau, use_kernel=use_kernel)
        sends.append(s)
        ress.append(nr)
    return treedef.unflatten(sends), treedef.unflatten(ress)


# ------------------------------------------------------------------ wire

def pack_int8(send, tau: float, *, n_workers: int = 1,
              int32_accum: bool = False):
    """{-tau,0,tau} -> int8 {-1,0,1}: the wire format (4x smaller than
    f32, 2x smaller than bf16).

    ``n_workers`` is the number of ternary messages the reduction will
    sum.  At int8 accumulation width the sum is exact only while
    ``n_workers <= 127``; past that the packed wire would silently wrap,
    so this *raises* unless the caller opted into int32 accumulation.
    """
    if n_workers > MAX_INT8_WORKERS and not int32_accum:
        raise ValueError(
            f"pack_int8: summing {n_workers} ternary int8 messages "
            f"overflows int8 (|sum| <= {n_workers} > {MAX_INT8_WORKERS}); "
            f"set int32_accum=True to widen the accumulation")
    return jnp.clip(jnp.round(send / tau), -1, 1).astype(jnp.int8)


def unpack_int8(packed, tau: float, n_workers_summed: int = 1):
    """Packed (possibly summed) wire integers -> the averaged float
    update: ``packed * tau / n_workers_summed``.  With
    ``n_workers_summed=1`` this is the exact inverse of ``pack_int8``
    on a single message."""
    out = packed.astype(jnp.float32) * tau
    if n_workers_summed != 1:
        out = out / n_workers_summed
    return out


def wire_pack(send, cfg: GTCConfig):
    """One worker's send tensor -> its wire message: ternary int8 (or
    int32-widened when ``cfg.int32_accum``), or the raw f32 send when
    the wire is unquantized.  Messages from co-resident workers add
    exactly (integers) before the psum."""
    if not cfg.quantize_int8:
        return send
    p = pack_int8(send, cfg.tau, n_workers=cfg.n_workers,
                  int32_accum=cfg.int32_accum)
    return p.astype(jnp.int32) if cfg.int32_accum else p

def wire_unpack(acc, cfg: GTCConfig, *, axis_name: Optional[str] = None):
    """Accumulated wire messages -> the averaged float update;
    ``axis_name`` adds the cross-device psum (THE collective — at int8
    width when quantized and not widened)."""
    if axis_name is not None:
        acc = jax.lax.psum(acc, axis_name)
    if cfg.quantize_int8:
        return unpack_int8(acc, cfg.tau, n_workers_summed=cfg.n_workers)
    return acc / cfg.n_workers if cfg.n_workers != 1 else acc


def wire_reduce(sends, cfg: GTCConfig, *,
                axis_name: Optional[str] = None):
    """THE wire for one local worker: pack -> (psum) -> unpack-average,
    one code path.  ``sends``: that worker's pytree of send tensors
    (values in {-tau, 0, +tau}).  With no ``axis_name`` this is the
    single-worker wire — for the int8 format a pack/unpack round-trip
    that is bitwise-identity on ternary sends, so the single-process
    strategy and the sharded step share the exact arithmetic.

    Returns the update averaged over ``cfg.n_workers`` (the paper
    applies the raw sum; we normalize so LR is worker-count
    independent).  Multi-worker-per-device accumulation happens in
    ``make_sharded_gtc_train_step`` via the same ``wire_pack`` /
    ``wire_unpack`` pair.
    """
    return tmap(lambda s: wire_unpack(wire_pack(s, cfg), cfg,
                                      axis_name=axis_name), sends)


def wire_bytes_per_update(params, cfg: GTCConfig) -> int:
    """Bytes one worker ships per update under ``cfg``'s wire format
    (the collective roofline term the int8 pack is buying down).

    Measured from what ``wire_pack`` — the function the trainer
    actually ships through — emits for each leaf (via eval_shape, no
    compute), so a regression in the packing path moves this number
    rather than leaving an analytic constant standing."""
    total = 0
    for p in jax.tree_util.tree_leaves(params):
        msg = jax.eval_shape(
            lambda s: wire_pack(s, cfg),
            jax.ShapeDtypeStruct(p.shape, jnp.float32))
        total += math.prod(msg.shape) * msg.dtype.itemsize
    return total


def gtc_init(params, cfg: Optional[GTCConfig] = None):
    """Error-feedback residuals.  With a ``cfg``, residuals are
    per-worker: stacked on a leading W dim, even at W=1 (each worker
    carries its own compression error — the state
    ``make_sharded_gtc_train_step`` shards over the worker axis).
    Without one, the single-process unstacked form."""
    if cfg is not None:
        return {"residual": tmap(
            lambda p: jnp.zeros((cfg.n_workers,) + p.shape, jnp.float32),
            params)}
    return {"residual": tmap(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)}


def make_gtc_allreduce(cfg: GTCConfig, axis_name: str):
    """Inside shard_map/pmap (one worker per shard): compress locally,
    reduce the sparse message over ``axis_name`` via ``wire_reduce``."""
    def allreduce(grads, gtc_state):
        send, res = compress_tree(grads, gtc_state["residual"], cfg.tau,
                                  use_kernel=cfg.use_kernel)
        avg = wire_reduce(send, cfg, axis_name=axis_name)
        return avg, {"residual": res}
    return allreduce


def make_gtc_train_step(loss_fn: Callable, optimizer_update: Callable,
                        cfg: GTCConfig, axis_name: str):
    """Data-parallel train step with GTC gradient exchange.

    loss_fn(params, batch) -> (loss, metrics); runs inside shard_map with
    `axis_name` = worker axis.  optimizer_update(params, grads, opt_state,
    lr=) -> (params, opt_state).  lr is a traced argument of the returned
    step — one compile serves every LR-schedule phase.
    """
    allreduce = make_gtc_allreduce(cfg, axis_name)

    def step(params, opt_state, gtc_state, batch, lr):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        update, gtc_state = allreduce(grads, gtc_state)
        params, opt_state = optimizer_update(params, update, opt_state,
                                             lr=lr)
        metrics = dict(metrics)
        metrics["gtc_density"] = density(update, cfg.tau)
        return params, opt_state, gtc_state, metrics

    return step


# ------------------------------------------------------ shard_map wrapper

def make_sharded_gtc_train_step(loss_fn: Callable,
                                optimizer_update: Callable,
                                cfg: GTCConfig, mesh,
                                worker_axes=("data",),
                                grad_transform: Optional[Callable] = None):
    """Production GTC: the worker dim sharded over `worker_axes` of `mesh`.

    The multi-worker form of ``make_gtc_train_step`` with the worker
    axis materialized: batches and error-feedback residuals carry a
    leading W dim sharded over the mesh (each shard vmaps its local
    worker slice), params/opt state are replicated (synchronous SGD:
    every worker applies the same averaged update), and the exchange is
    ``wire_reduce`` — local-W sum + one psum per leaf, int8-packed.

    loss_fn(params, batch[, rng]) -> (loss, metrics); a loss declaring
    ``rng`` receives a per-(update, worker) folded key — folded OUTSIDE
    the shard_map with the *global* worker index (crossing as raw key
    data), so device count never changes the streams.
    ``grad_transform(grads) -> (grads, extra_metrics)`` runs per worker
    before compression (gradient clipping lives here).  Returns
    step(params, opt_state, gtc_state, batches, lr, rng=None) with lr
    traced — one compile per loss kind.
    """
    from jax.sharding import PartitionSpec as P

    from repro.utils.compat import shard_map
    from repro.utils.introspect import takes_rng as _takes

    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    takes_rng = _takes(loss_fn)

    def shard_body(residuals, batches, params, opt_state, lr, wkd):
        def local_one(residual, batch, kd):
            if kd is not None:
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch, rng=jax.random.wrap_key_data(kd))
            else:
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch)
            m = dict(m)
            if grad_transform is not None:
                g, extra = grad_transform(g)
                m.update(extra)
            send, new_res = compress_tree(g, residual, cfg.tau,
                                          use_kernel=cfg.use_kernel)
            return tmap(lambda s: wire_pack(s, cfg), send), new_res, m

        # the local worker slice is unrolled, not vmapped: each worker's
        # compute lowers exactly as the single-worker path does (that is
        # what makes the W=1 strategy-equivalence and the
        # simulate_gtc_round comparisons *bitwise*, not approximate), and
        # in production the slice is one worker per device anyway
        local_w = jax.tree_util.tree_leaves(residuals)[0].shape[0]
        acc, res_i, ms_i = None, [], []
        for i in range(local_w):
            packed, new_res, m = local_one(
                tmap(lambda r: r[i], residuals),
                tmap(lambda b: b[i], batches),
                None if wkd is None else wkd[i])
            acc = packed if acc is None else tmap(jnp.add, acc, packed)
            res_i.append(new_res)
            ms_i.append(m)
        update = tmap(lambda a: wire_unpack(a, cfg, axis_name=ax), acc)
        new_res = tmap(lambda *xs: jnp.stack(xs), *res_i)
        ms = tmap(lambda *xs: jnp.stack(xs), *ms_i)
        ms["gtc_density"] = jnp.broadcast_to(density(update, cfg.tau),
                                             (local_w,))
        params, opt_state = optimizer_update(params, update, opt_state,
                                             lr=lr)
        return params, opt_state, new_res, ms

    wspec = P(ax)       # leading worker dim sharded
    rspec = P()         # params / opt state / lr replicated

    def step(params, opt_state, gtc_state, batches, lr, rng=None):
        lr = jnp.asarray(lr, jnp.float32)
        if rng is None or not takes_rng:
            fn = shard_map(
                lambda r, b, p, o, l: shard_body(r, b, p, o, l, None),
                mesh=mesh,
                in_specs=(wspec, wspec, rspec, rspec, rspec),
                out_specs=(rspec, rspec, wspec, wspec),
                check_rep=False)
            params, opt_state, res, ms = fn(gtc_state["residual"], batches,
                                            params, opt_state, lr)
        else:
            # per-worker keys folded OUTSIDE shard_map with the global
            # worker index (as the BMUF path does): device count never
            # changes the streams, and raw key data crosses the boundary
            wkd = jax.vmap(lambda i: jax.random.key_data(
                jax.random.fold_in(rng, i)))(jnp.arange(cfg.n_workers))
            fn = shard_map(
                shard_body, mesh=mesh,
                in_specs=(wspec, wspec, rspec, rspec, rspec, wspec),
                out_specs=(rspec, rspec, wspec, wspec),
                check_rep=False)
            params, opt_state, res, ms = fn(gtc_state["residual"], batches,
                                            params, opt_state, lr, wkd)
        return params, opt_state, {"residual": res}, ms

    return step


def density(update_tree, tau: float) -> jnp.ndarray:
    """Fraction of nonzero elements actually shipped (diagnostic)."""
    nz = sum(jnp.sum(jnp.abs(u) > 0).astype(jnp.float32)
             for u in jax.tree_util.tree_leaves(update_tree))
    n = sum(u.size for u in jax.tree_util.tree_leaves(update_tree))
    return nz / max(n, 1)


def adaptive_tau(g, target_density: float):
    """Per-tensor tau that keeps ~target_density of elements (quantile)."""
    q = jnp.quantile(jnp.abs(g.astype(jnp.float32)).reshape(-1),
                     1.0 - target_density)
    return jnp.maximum(q, 1e-12)


# ------------------------------------------------- reference (single host)

def simulate_gtc_round(grads_per_worker, residuals_per_worker, tau: float,
                       *, quantize_int8: bool = False,
                       int32_accum: bool = False):
    """Numpy-free reference of one full ring exchange for tests: returns
    (applied_update, new_residuals).  grads/residuals: lists per worker.

    ``quantize_int8`` reproduces the packed wire exactly as
    ``wire_reduce`` ships it: each worker's send packed to ternary int8,
    summed at integer width (int8 unless ``int32_accum``), unpacked and
    averaged — integer sums are exact, so the sharded trainer must match
    this bitwise.
    """
    n = len(grads_per_worker)
    sends = []
    new_res = []
    for g, r in zip(grads_per_worker, residuals_per_worker):
        s, nr = compress_tree(g, r, tau)
        sends.append(s)
        new_res.append(nr)
    if quantize_int8:
        packed = [tmap(lambda s: pack_int8(s, tau, n_workers=n,
                                           int32_accum=int32_accum), sd)
                  for sd in sends]
        if int32_accum:
            packed = [tmap(lambda p: p.astype(jnp.int32), pk)
                      for pk in packed]
        summed = packed[0]
        for pk in packed[1:]:
            summed = tmap(jnp.add, summed, pk)
        avg = tmap(lambda p: unpack_int8(p, tau, n_workers_summed=n),
                   summed)
        return avg, new_res
    summed = sends[0]
    for s in sends[1:]:
        summed = tmap(jnp.add, summed, s)
    avg = tmap(lambda x: x / n, summed)
    return avg, new_res
