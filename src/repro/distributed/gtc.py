"""Gradient Threshold Compression (paper §2/§3.5; Strom, Interspeech 2015).

The paper's 16-GPU trainer for labeled CE + sMBR.  Strom's algorithm, kept
bit-faithful on the *algorithm* side:

  r      <- r + g                      (error-feedback residual)
  send   <- tau * sign(r) * [|r| > tau]   (1-bit-quantized sparse message)
  r      <- r - send
  update <- sum_over_workers(send)

TPU adaptation (DESIGN.md §2): the GPU implementation ships sparse
(index, ±tau) pairs peer-to-peer; TPU ICI collectives have no sparse
all-reduce, so the transport is a dense psum of the (mostly-zero,
1.58-bit-entropy) send tensor — optionally int8-packed, which is where the
bandwidth saving appears in the collective roofline term.  The selection /
residual math (the accuracy-relevant part) is unchanged and is also
implemented as a Pallas kernel (``repro.kernels.gtc_compress``).

Adaptive threshold: Strom fixes tau; we also provide the common variant
that adapts tau per-tensor to hit a target sparsity, used when sweeping.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


@dataclass(frozen=True)
class GTCConfig:
    tau: float = 1e-3
    quantize_int8: bool = True       # pack the send tensor to int8 on the wire
    n_workers: int = 16


def compress_leaf(g, r, tau: float):
    """One tensor: error-feedback threshold compression.

    Returns (send, new_residual); send has values in {-tau, 0, +tau}.
    """
    acc = r + g.astype(jnp.float32)
    mask = jnp.abs(acc) > tau
    send = jnp.where(mask, jnp.sign(acc) * tau, 0.0)
    return send, acc - send


def compress_tree(grads, residuals, tau: float):
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    sends, ress = [], []
    for g, r in zip(flat_g, flat_r):
        s, nr = compress_leaf(g, r, tau)
        sends.append(s)
        ress.append(nr)
    return treedef.unflatten(sends), treedef.unflatten(ress)


def pack_int8(send, tau: float):
    """{-tau,0,tau} -> int8 {-1,0,1}: the wire format (4x smaller than f32,
    2x smaller than bf16). psum of int8 over <=127 workers cannot overflow
    ... but XLA all-reduces int8 at int8 width, so accumulate in int32."""
    return jnp.clip(jnp.round(send / tau), -1, 1).astype(jnp.int8)


def unpack_int8(packed, tau: float, n_workers_summed: int = 1):
    return packed.astype(jnp.float32) * tau


def gtc_init(params):
    return {"residual": tmap(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)}


def make_gtc_allreduce(cfg: GTCConfig, axis_name: str):
    """Inside shard_map/pmap: compress locally, psum the sparse message."""
    def allreduce(grads, gtc_state):
        send, res = compress_tree(grads, gtc_state["residual"], cfg.tau)
        if cfg.quantize_int8:
            summed = tmap(
                lambda s: jax.lax.psum(pack_int8(s, cfg.tau)
                                       .astype(jnp.int32), axis_name)
                .astype(jnp.float32) * cfg.tau, send)
        else:
            summed = tmap(lambda s: jax.lax.psum(s, axis_name), send)
        # average over workers (the paper applies the summed update; we
        # normalize so LR is worker-count independent)
        avg = tmap(lambda s: s / cfg.n_workers, summed)
        return avg, {"residual": res}
    return allreduce


def make_gtc_train_step(loss_fn: Callable, optimizer_update: Callable,
                        cfg: GTCConfig, axis_name: str):
    """Data-parallel train step with GTC gradient exchange.

    loss_fn(params, batch) -> (loss, metrics); runs inside shard_map with
    `axis_name` = worker axis.  optimizer_update(params, grads, opt_state,
    lr=) -> (params, opt_state).  lr is a traced argument of the returned
    step — one compile serves every LR-schedule phase.
    """
    allreduce = make_gtc_allreduce(cfg, axis_name)

    def step(params, opt_state, gtc_state, batch, lr):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        update, gtc_state = allreduce(grads, gtc_state)
        params, opt_state = optimizer_update(params, update, opt_state,
                                             lr=lr)
        metrics = dict(metrics)
        metrics["gtc_density"] = density(update, cfg.tau)
        return params, opt_state, gtc_state, metrics

    return step


def density(update_tree, tau: float) -> jnp.ndarray:
    """Fraction of nonzero elements actually shipped (diagnostic)."""
    nz = sum(jnp.sum(jnp.abs(u) > 0).astype(jnp.float32)
             for u in jax.tree_util.tree_leaves(update_tree))
    n = sum(u.size for u in jax.tree_util.tree_leaves(update_tree))
    return nz / max(n, 1)


def adaptive_tau(g, target_density: float):
    """Per-tensor tau that keeps ~target_density of elements (quantile)."""
    q = jnp.quantile(jnp.abs(g.astype(jnp.float32)).reshape(-1),
                     1.0 - target_density)
    return jnp.maximum(q, 1e-12)


# ------------------------------------------------- reference (single host)

def simulate_gtc_round(grads_per_worker, residuals_per_worker, tau: float):
    """Numpy-free reference of one full ring exchange for tests: returns
    (applied_update, new_residuals).  grads/residuals: lists per worker."""
    sends = []
    new_res = []
    for g, r in zip(grads_per_worker, residuals_per_worker):
        s, nr = compress_tree(g, r, tau)
        sends.append(s)
        new_res.append(nr)
    summed = sends[0]
    for s in sends[1:]:
        summed = tmap(jnp.add, summed, s)
    avg = tmap(lambda x: x / len(grads_per_worker), summed)
    return avg, new_res
