"""Role-based 2D sharding policy ("FSDP+TP").

Mesh axes: ``("data", "model")`` single-pod (16,16) or
``("pod", "data", "model")`` multi-pod (2,16,16).  The batch and the FSDP
param dim shard over (pod,data); the tensor-parallel dim over model.  MoE
expert dims shard over model (expert parallelism).  Every assignment is
divisibility-checked against the actual mesh; non-divisible dims degrade
gracefully (fewer axes -> replicated) so the same rules serve the reduced
smoke configs on 1 device and the production mesh.
"""
from __future__ import annotations

import re

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# param leaves under these path fragments carry a leading scan/stack dim
STACKED = re.compile(r"(seg\d+|enc_blocks|dec_blocks|mtp/block)")


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def _fit(dim: int, candidates, mesh: Mesh):
    """First candidate axis (or axis tuple) that divides dim; else None."""
    for cand in candidates:
        if cand is None:
            return None
        if dim % _axis_size(mesh, cand) == 0:
            return cand
    return None


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1,
               mode: str = "fsdp_tp") -> P:
    """Shard the batch dim: (pod,data) for 2D FSDP+TP; every axis for
    pure-FSDP (ZeRO-3) where all chips are data-parallel."""
    if mode == "fsdp":
        aa = all_axes(mesh)
        cand = _fit(batch, [aa, aa[1:], aa[-1:], None], mesh)
    else:
        fa = fsdp_axes(mesh)
        cand = _fit(batch, [fa, fa[-1:], None], mesh)
    if isinstance(cand, tuple) and len(cand) == 1:
        cand = cand[0]
    return P(cand, *([None] * extra_dims))


def param_spec(path: str, shape, mesh: Mesh, *, mode: str = "fsdp_tp") -> P:
    """PartitionSpec for one param leaf, by role.

    mode "fsdp_tp": 2D policy (default, training).
    mode "tp": tensor-parallel only — params replicated over (pod, data),
    sharded over model.  For inference steps this removes every per-layer
    param all-gather (each chip holds its TP shard permanently); the cost
    is params/model_axis bytes of HBM per chip, which fits every assigned
    arch at 16-way TP.  See EXPERIMENTS.md §Perf (gemma3 prefill).
    mode "fsdp": pure ZeRO-3 — every chip data-parallel, each weight's
    largest shardable dim split over ALL mesh axes, no tensor parallelism.
    Removes the per-layer TP activation all-reduces that dominate the
    train-shape collective term (§Perf gemma3 train_4k); collective volume
    becomes ~3x param bytes (weight all-gather fwd/bwd + grad
    reduce-scatter).
    """
    if mode == "fsdp":
        return _fsdp_only_spec(path, shape, mesh)
    fa = fsdp_axes(mesh) if mode == "fsdp_tp" else ()
    name = path.split("/")[-1]
    dims = list(shape)
    lead = []
    if STACKED.search(path):
        lead = [None]                            # scan/stack dim replicated
        dims = dims[1:]

    def spec(*assign):
        out = []
        for d, cands in zip(dims, assign):
            out.append(_fit(d, list(cands) + [None], mesh))
        return P(*lead, *out)

    nd = len(dims)
    FS = (tuple(fa), fa[-1]) if fa else (None,)  # fsdp candidates
    MD = ("model",)

    if nd == 0:
        return P(*lead)
    if nd == 1:
        # vectors: shard over model if divisible (biases over TP'd dims)
        if name in ("b", "bq", "bk", "bv", "b_if", "scale", "bias",
                    "q_norm", "k_norm", "lam", "gn"):
            return P(*lead, None)
        return spec(MD)
    if nd == 3 and name in ("w_gate", "w_up"):   # MoE (E, D, F)
        return spec(MD, FS, ())
    if nd == 3 and name == "w_down":             # MoE (E, F, D)
        return spec(MD, (), FS)
    if nd == 3 and name == "rh":                 # sLSTM (H, hd, 4hd)
        return spec((), FS, MD)
    if nd == 2:
        if name == "embed":                      # (V, D)
            return spec(MD, FS)
        if name in ("out",):                     # (D, V)
            return spec(FS, MD)
        if name in ("pos", "enc_pos", "dec_pos"):
            return spec((), MD)
        if name == "conv":                       # (K, W)
            return spec((), MD)
        if name in ("wo", "w_out", "down", "w_down"):   # (TP_in, D)
            return spec(MD, FS)
        # default projection: (D_in, TP_out)
        return spec(FS, MD)
    # fallback: shard the largest dim over model if possible
    big = int(np.argmax(dims))
    assign = [() for _ in dims]
    assign[big] = MD
    return spec(*assign)


def _fsdp_only_spec(path: str, shape, mesh: Mesh) -> P:
    """ZeRO-3: shard each weight's largest shardable dim over all axes
    (falling back to fewer axes, then replication); vectors replicated."""
    aa = all_axes(mesh)
    dims = list(shape)
    lead = []
    if STACKED.search(path):
        lead = [None]
        dims = dims[1:]
    if len(dims) < 2:
        return P(*lead, *([None] * len(dims)))
    order = sorted(range(len(dims)), key=lambda i: -dims[i])
    out = [None] * len(dims)
    for i in order:
        cand = _fit(dims[i], [aa, aa[1:], aa[-1:], None], mesh)
        if cand is not None:
            out[i] = cand
            break
    return P(*lead, *out)


def cache_spec(path: str, shape, mesh: Mesh) -> P:
    """KV caches / recurrent states: batch over fsdp, heads (or channels)
    over model, seq dims replicated (decode writes at a dynamic index)."""
    fa = fsdp_axes(mesh)
    name = path.split("/")[-1]
    dims = list(shape)
    if name == "pos" or not dims:
        return P()
    lead = []
    # scanned-segment caches (transformer) and whisper's stacked layer caches
    # carry a leading repeat/layer dim
    if path.startswith("seg") or "/seg" in path \
            or (name in ("k", "v", "ck", "cv") and len(dims) == 5):
        lead = [None]
        dims = dims[1:]

    def fit_b(d):
        return _fit(d, [tuple(fa), fa[-1], None], mesh)

    if name in ("k", "v", "ck", "cv"):           # (B, H, S, hd)
        b, h, s, hd = dims
        h_ax = _fit(h, [("model",), None], mesh)
        hd_ax = None if h_ax else _fit(hd, [("model",), None], mesh)
        return P(*lead, fit_b(b), h_ax, None, hd_ax)
    # recurrent states / MLA latents: batch over fsdp, last dim over model
    out = [fit_b(dims[0])] + [None] * (len(dims) - 1)
    if len(dims) >= 2:
        out[-1] = _fit(dims[-1], [("model",), None], mesh)
    return P(*lead, *out)


def tree_param_specs(abstract_params, mesh: Mesh, *, mode: str = "fsdp_tp"):
    from repro.utils.trees import map_with_path
    return map_with_path(lambda p, a: param_spec(p, a.shape, mesh,
                                                 mode=mode),
                         abstract_params)


def tree_cache_specs(abstract_cache, mesh: Mesh):
    from repro.utils.trees import map_with_path
    return map_with_path(lambda p, a: cache_spec(p, a.shape, mesh),
                         abstract_cache)


def shardings(tree_specs, mesh: Mesh):
    import jax
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  tree_specs,
                                  is_leaf=lambda x: isinstance(x, P))
