from repro.distributed import bmuf, gtc, sharding

__all__ = ["bmuf", "gtc", "sharding"]
