"""Thin helpers over mesh / shard_map plumbing used by BMUF, GTC and the
examples: building host-local worker meshes, replicating trees, and a
data-parallel shard_map runner that works on any device count (including 1).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import shard_map

tmap = jax.tree_util.tree_map


def worker_mesh(n: int = 0) -> Mesh:
    """1D worker mesh over the host's devices (capped at n if given)."""
    devs = jax.devices()
    if n:
        devs = devs[:n]
    return jax.make_mesh((len(devs),), ("worker",), devices=devs)


def replicate(tree, mesh: Mesh):
    sh = NamedSharding(mesh, P())
    return tmap(lambda x: jax.device_put(x, sh), tree)


def shard_batch(tree, mesh: Mesh, axis: str = "worker"):
    """Shard the leading dim over `axis`."""
    def put(x):
        return jax.device_put(x, NamedSharding(mesh, P(axis)))
    return tmap(put, tree)


def data_parallel(fn: Callable, mesh: Mesh, axis: str = "worker",
                  *, replicated_args=(0, 1)):
    """shard_map wrapper: args in `replicated_args` positions are replicated
    (params-like); the rest shard their leading dim over `axis`.  The
    returned fn has the same signature."""
    def wrapped(*args):
        in_specs = tuple(P() if i in replicated_args else P(axis)
                         for i in range(len(args)))

        def body(*sargs):
            return fn(*sargs)

        out = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=P(axis), check_rep=False)(*args)
        return out
    return wrapped


def psum_tree(tree, axis: str):
    return tmap(lambda x: jax.lax.psum(x, axis), tree)


def pmean_tree(tree, axis: str):
    return tmap(lambda x: jax.lax.pmean(x, axis), tree)
