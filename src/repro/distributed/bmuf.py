"""Blockwise Model-Update Filtering (paper §3.5; Chen & Huo, ICASSP 2016).

The paper's 64-GPU trainer for the SSL CE stage: each worker runs local
SGD for a *block* of steps on its own data shard, then the workers sync:

    G_t      = mean_w(theta_w) - theta_g            (block "gradient")
    Delta_t  = eta * Delta_{t-1} + zeta * G_t        (block momentum eta,
                                                      block LR zeta)
    theta_g <- theta_g + Delta_t
    restart  = theta_g + eta * Delta_t               (Nesterov, NBM —
                                                      "Nesterov-like momentum
                                                      updates at block level")

Two interchangeable execution paths over the same math:

  * ``vmap`` path (CPU tests / laptop): worker params carry a leading W dim,
    local steps via jax.vmap, sync via mean over W.
  * ``shard_map`` path (production): the W dim is sharded over the mesh's
    (pod, data) axes; local steps touch no cross-worker collective
    (BMUF's entire point — communication every tau steps instead of every
    minibatch), the block sync is one psum per leaf.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


@dataclass(frozen=True)
class BMUFConfig:
    n_workers: int = 64
    block_steps: int = 8             # tau: local steps per block
    block_momentum: float = 0.875    # eta; Chen&Huo suggest 1 - 1/W-ish
    block_lr: float = 1.0            # zeta
    nesterov: bool = True            # NBM variant


def bmuf_init(global_params, cfg: BMUFConfig):
    """-> {theta_g, delta, workers} — workers stacked on a leading W dim."""
    workers = tmap(
        lambda p: jnp.broadcast_to(p, (cfg.n_workers,) + p.shape).copy(),
        global_params)
    delta = tmap(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                 global_params)
    return {"theta_g": global_params, "delta": delta, "workers": workers}


def active_mean_fn(active):
    """Worker-mean over live lanes only: ``active`` is a (W,) 0/1 mask.

    Dead lanes contribute nothing to the block average; the divisor is
    the live count (floored at 1 so an all-dead mask degrades to a
    frozen model instead of NaNs).  The block-momentum ``delta`` is
    global, not per-worker, so it needs no masking — it carries
    unchanged across a membership change, which is what the
    shrink-mid-run-vs-fresh-W pin relies on.
    """
    a = jnp.asarray(active, jnp.float32)
    denom = jnp.maximum(jnp.sum(a), 1.0)

    def mean_fn(w):
        aw = a.reshape((-1,) + (1,) * (w.ndim - 1))
        return jnp.sum(w.astype(jnp.float32) * aw, axis=0) / denom

    return mean_fn


def block_sync(state, cfg: BMUFConfig, *, mean_fn=None, active=None):
    """One BMUF sync. ``mean_fn`` overrides the worker-mean (shard_map path
    passes a lax.pmean closure); default = mean over the leading W dim.
    ``active`` (a (W,) 0/1 mask, ignored when ``mean_fn`` is given)
    restricts the average to live workers — the elastic-membership hook.
    The Nesterov restart still broadcasts to *all* lanes, so a dead
    lane holds current params and can rejoin warm by flipping its mask
    bit back on."""
    if mean_fn is None:
        if active is not None:
            mean_fn = active_mean_fn(active)
        else:
            mean_fn = lambda w: jnp.mean(w.astype(jnp.float32), axis=0)
    theta_g, delta = state["theta_g"], state["delta"]
    wbar = tmap(mean_fn, state["workers"])
    g = tmap(lambda wb, tg: wb - tg.astype(jnp.float32), wbar, theta_g)
    delta = tmap(lambda d, g_: cfg.block_momentum * d + cfg.block_lr * g_,
                 delta, g)
    theta_g = tmap(lambda tg, d: (tg.astype(jnp.float32) + d).astype(tg.dtype),
                   theta_g, delta)
    if cfg.nesterov:
        restart = tmap(
            lambda tg, d: (tg.astype(jnp.float32)
                           + cfg.block_momentum * d).astype(tg.dtype),
            theta_g, delta)
    else:
        restart = theta_g
    workers = tmap(
        lambda r, w: jnp.broadcast_to(r, w.shape).astype(w.dtype),
        restart, state["workers"])
    return {"theta_g": theta_g, "delta": delta, "workers": workers}


def _make_local_tau(train_step: Callable, lr, rng):
    """tau local steps for one worker, scanned; ``rng`` (when given) is
    that worker's block key, folded per tau index so every microbatch
    in the block sees a distinct stream."""
    from repro.utils.introspect import takes_rng as _takes
    takes_rng = _takes(train_step)

    def local_tau(params, opt_state, bt, wkey):
        def one(carry, xs):
            p, o = carry
            b, ti = xs
            if takes_rng and wkey is not None:
                p, o, m = train_step(p, o, b, lr,
                                     rng=jax.random.fold_in(wkey, ti))
            else:
                p, o, m = train_step(p, o, b, lr)
            return (p, o), m

        tau = jax.tree_util.tree_leaves(bt)[0].shape[0]
        (params, opt_state), ms = jax.lax.scan(
            one, (params, opt_state), (bt, jnp.arange(tau)))
        return params, opt_state, ms

    if rng is None:
        return lambda p, o, bt: local_tau(p, o, bt, None)
    return local_tau


def make_bmuf_block_step(train_step: Callable, cfg: BMUFConfig):
    """One *block*: tau vmapped local steps + the sync, jittable.

    train_step(params, opt_state, batch, lr[, rng]) -> (params,
    opt_state, metrics) with lr a traced scalar — one compile serves
    every LR-schedule phase.  batches: pytree with leading dims
    (tau, W, ...).  ``rng`` (optional trailing argument of the returned
    block) is a per-block key folded per (worker, tau-step) and threaded
    into steps that declare it — legacy 4-argument calls are unchanged.
    ``active`` (optional (W,) 0/1 mask) drops dead lanes from the block
    average: their local steps still run (vmap lanes are free and keep
    shapes static) but contribute nothing to the sync.
    """
    def block(state, opt_states, batches, lr, rng=None, active=None):
        local_tau = _make_local_tau(train_step, lr, rng)
        if rng is None:
            workers, opt_states, metrics = jax.vmap(
                local_tau, in_axes=(0, 0, 1))(state["workers"], opt_states,
                                              batches)
        else:
            wkeys = jax.vmap(lambda i: jax.random.fold_in(rng, i))(
                jnp.arange(cfg.n_workers))
            workers, opt_states, metrics = jax.vmap(
                local_tau, in_axes=(0, 0, 1, 0))(state["workers"],
                                                 opt_states, batches, wkeys)
        state = dict(state, workers=workers)
        state = block_sync(state, cfg, active=active)
        return state, opt_states, metrics

    return block


# ----------------------------------------------------------- shard_map path

def make_sharded_bmuf_block_step(train_step: Callable, cfg: BMUFConfig,
                                 mesh, worker_axes=("data",)):
    """Production BMUF: worker dim sharded over `worker_axes` of `mesh`.

    Inside shard_map each shard holds W/|axes| worker replicas; local steps
    are collective-free, the sync is a single pmean over the worker axes.
    Model-parallel sharding *within* a worker stays on the 'model' axis and
    is handled by the step's own pjit partitioning (params enter with their
    usual 2D specs plus the leading worker dim).
    """
    from jax.sharding import PartitionSpec as P

    from repro.utils.compat import shard_map

    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]

    from repro.utils.introspect import takes_rng as _takes
    takes_rng = _takes(train_step)

    def block(state, opt_states, batches, lr, rng=None, active=None):
        have_rng = rng is not None
        have_act = active is not None

        def shard_body(workers, opt_states, batches, lr, theta_g, delta,
                       *extra):
            wkey_data = extra[0] if have_rng else None
            act = extra[int(have_rng)] if have_act else None
            def local_tau(params, opt_state, bt, wkd):
                def one(carry, xs):
                    p, o = carry
                    b, ti = xs
                    if takes_rng and wkd is not None:
                        k = jax.random.fold_in(
                            jax.random.wrap_key_data(wkd), ti)
                        p, o, m = train_step(p, o, b, lr, rng=k)
                    else:
                        p, o, m = train_step(p, o, b, lr)
                    return (p, o), m
                tau = jax.tree_util.tree_leaves(bt)[0].shape[0]
                (params, opt_state), ms = jax.lax.scan(
                    one, (params, opt_state), (bt, jnp.arange(tau)))
                return params, opt_state, ms

            if wkey_data is None:
                workers, opt_states, metrics = jax.vmap(
                    lambda p, o, bt: local_tau(p, o, bt, None),
                    in_axes=(0, 0, 1))(workers, opt_states, batches)
            else:
                workers, opt_states, metrics = jax.vmap(
                    local_tau, in_axes=(0, 0, 1, 0))(
                        workers, opt_states, batches, wkey_data)
            # block sync: mean over the local W slice, then over the axis.
            # With a mask: psum of masked local sums / psum'd live count —
            # each shard contributes only its live lanes.
            if act is None:
                def wmean(w):
                    local = jnp.mean(w.astype(jnp.float32), axis=0)
                    return jax.lax.pmean(local, ax)
            else:
                a = act.astype(jnp.float32)
                denom = jnp.maximum(jax.lax.psum(jnp.sum(a), ax), 1.0)

                def wmean(w):
                    aw = a.reshape((-1,) + (1,) * (w.ndim - 1))
                    s = jnp.sum(w.astype(jnp.float32) * aw, axis=0)
                    return jax.lax.psum(s, ax) / denom
            wbar = tmap(wmean, workers)
            g = tmap(lambda wb, tg: wb - tg.astype(jnp.float32), wbar,
                     theta_g)
            new_delta = tmap(
                lambda d, g_: cfg.block_momentum * d + cfg.block_lr * g_,
                delta, g)
            new_theta = tmap(
                lambda tg, d: (tg.astype(jnp.float32) + d).astype(tg.dtype),
                theta_g, new_delta)
            restart = tmap(
                lambda tg, d: (tg.astype(jnp.float32)
                               + (cfg.block_momentum * d if cfg.nesterov
                                  else 0.0)).astype(tg.dtype),
                new_theta, new_delta)
            workers = tmap(lambda r, w: jnp.broadcast_to(r, w.shape)
                           .astype(w.dtype), restart, workers)
            return workers, opt_states, metrics, new_theta, new_delta

        wspec = P(ax)       # leading worker dim sharded
        rspec = P()         # theta_g / delta / lr replicated
        in_specs = [wspec, wspec, P(None, ax), rspec, rspec, rspec]
        args = [state["workers"], opt_states, batches,
                jnp.asarray(lr, jnp.float32), state["theta_g"],
                state["delta"]]
        if have_rng:
            # per-worker keys are folded OUTSIDE shard_map with the
            # *global* worker index, so the sharded path stays bitwise
            # equal to the vmap path; raw key data crosses the shard_map
            # boundary (uint32 — extended key dtypes and sharding specs
            # don't mix on every jax version) and is re-wrapped inside
            wkd = jax.vmap(lambda i: jax.random.key_data(
                jax.random.fold_in(rng, i)))(jnp.arange(cfg.n_workers))
            in_specs.append(wspec)
            args.append(wkd)
        if have_act:
            in_specs.append(wspec)
            args.append(jnp.asarray(active, jnp.float32))
        fn = shard_map(
            shard_body, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=(wspec, wspec, P(None, ax), rspec, rspec),
            check_rep=False)
        workers, opt_states, metrics, theta_g, delta = fn(*args)
        return ({"theta_g": theta_g, "delta": delta, "workers": workers},
                opt_states, metrics)

    return block
