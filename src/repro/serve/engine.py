"""Batched streaming-inference engine.

One engine, two consumers (the paper's framing: target generation *is*
inference-as-a-service):

  * **Teacher target generation** (paper §3.2.2): submit the unlabeled
    firehose as per-utterance requests; the batcher buckets them into
    padded batches (THROUGHPUT policy), one jitted forward per bucket
    shape emits top-k logits, and the caller drains results into the
    LogitStore.  Embarrassingly parallel across engine instances — the
    paper's "parallelize target generation".
  * **Online serving**: the same engine under a LATENCY policy, plus a
    slot-based *streaming* path that carries each stream's LSTM (h, c)
    across chunks, so audio can be fed incrementally with batched compute
    across concurrent streams.  ``feed_async``/``feed_pipelined``
    double-buffer the host→device transfer: the next chunk is staged
    while the current jitted step computes (the serve-side analogue of
    the training feed's ``pipeline.PrefetchingSource``).

Length correctness is delegated to the model's ``lens`` support
(``models/recurrent.py``): padded rows freeze their recurrent state at
their true length and the biLSTM backward pass starts at the last valid
frame, so batched == sequential to fp tolerance (pinned by
tests/test_serve_engine.py).

Top-k emission reuses ``kernels/topk_logits`` (the Pallas selection
kernel) when ``topk_impl="kernel"``; the default "lax" path is the
``logit_store.topk_compress`` codec (same output format — shifted bf16
values + int32 indices).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import logit_store as ls
from repro.kernels import _dispatch
from repro.kernels.topk_logits import topk_logits
from repro.launch import steps
from repro.models import build_model
from repro.models.api import stream_feat_dim, supports_streaming
from repro.serve.batcher import (LATENCY, THROUGHPUT, BatchPolicy,
                                 bucket_length, form_batches)
from repro.serve.request import CompletedRequest, RequestQueue


def make_topk_emitter(k: int, impl: str = "lax", *,
                      interpret: Optional[bool] = None):
    """logits (..., V) -> (vals (..., k) bf16 shifted, idx (..., k) i32).

    impl="kernel" routes selection through the Pallas tile kernel
    (``kernels/topk_logits``); "lax" uses the logit-store codec.  Both
    produce the LogitStore wire format (max logit shifted to 0, bf16).
    ``interpret=None`` auto-detects via ``kernels._dispatch``: compiled
    on TPU, Pallas interpreter everywhere else.
    """
    interpret = _dispatch.auto_interpret(interpret)
    if impl == "kernel":
        def emit(logits):
            vals, idx = topk_logits(logits, k, interpret=interpret)
            vals = vals - vals[..., :1]
            return vals.astype(jnp.bfloat16), idx
        return emit
    if impl != "lax":
        raise ValueError(f"unknown topk impl {impl!r}")
    return lambda logits: ls.topk_compress(logits, k)


class StreamFeed:
    """Handle for a dispatched streaming step: holds the (still
    device-resident) padded outputs plus the chunk map needed to unpad.
    ``result()`` is the step's only host sync and is idempotent."""

    def __init__(self, vals, idx, chunk_lens: Dict[int, int]):
        self._vals, self._idx = vals, idx
        self._chunk_lens = chunk_lens
        self._out: Optional[dict] = None
        self._done = not chunk_lens

    def result(self) -> Dict[int, tuple]:
        """{sid: (vals (t, k), idx (t, k))} — blocks until the step's
        outputs are on host."""
        if self._done:
            return self._out or {}
        vals = np.asarray(jax.device_get(self._vals).astype(jnp.float32))
        idx = np.asarray(jax.device_get(self._idx))
        # copies, not views: accumulating consumers must not pin the
        # whole padded slot batch per chunk (same invariant as run())
        self._out = {sid: (vals[sid, :t].copy(), idx[sid, :t].copy())
                     for sid, t in self._chunk_lens.items()}
        self._vals = self._idx = None        # release the device refs
        self._done = True
        return self._out


class StreamingEngine:
    """Batched inference over an acoustic model with top-k emission.

    Batch path: ``submit()`` feature utterances, ``run()`` drains the
    queue through the policy's batcher.  Streaming path: ``open_stream``/
    ``feed``/``close_stream`` carry per-stream recurrent state across
    chunks (causal models only).
    """

    def __init__(self, cfg, params, *, k: int = 20, temperature: float = 1.0,
                 policy: BatchPolicy = THROUGHPUT, n_slots: int = 4,
                 topk_impl: str = "lax",
                 interpret: Optional[bool] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.k = k
        self.temperature = temperature
        self.policy = policy
        self.queue = RequestQueue()
        self._emit = make_topk_emitter(k, topk_impl, interpret=interpret)
        self._fwd = jax.jit(self._batch_forward)
        self._fwd_dict = jax.jit(self._dict_forward)
        # ---- streaming slots
        self.n_slots = n_slots
        self._stream_state = None
        self._slot_free = list(range(n_slots))
        self._stream_fwd = jax.jit(self._stream_forward)

    # ------------------------------------------------------------ forwards

    def _batch_forward(self, params, feats, lens):
        h, _ = self.model.apply(params, feats, lens=lens)
        return self._emit(self.model.unembed(params, h) / self.temperature)

    def _dict_forward(self, params, batch):
        """Family-generic forward for pre-formed dict batches (the
        teacher's legacy surface: works for AM, LM and enc-dec alike).
        The AM branch adds lens-aware padding; the rest delegates to the
        train path's dispatch."""
        if self.cfg.family == "lstm_am":
            lens = batch.get("lens")
            if lens is None and "mask" in batch:
                # chunked pipeline batches carry a frame mask, not lens —
                # without this the biLSTM backward pass would read the
                # zero padding of partial chunks
                lens = batch["mask"].sum(axis=-1).astype(jnp.int32)
            h, _ = self.model.apply(params, batch["feats"], lens=lens)
        else:
            h, _ = steps.model_forward(self.model, self.cfg, params, batch)
        return self._emit(self.model.unembed(params, h) / self.temperature)

    def _stream_forward(self, params, state, feats, lens):
        h, new_state = self.model.stream_step(params, state, feats,
                                              lens=lens)
        vals, idx = self._emit(self.model.unembed(params, h)
                               / self.temperature)
        return vals, idx, new_state

    # ---------------------------------------------------------- batch path

    def forward_topk(self, batch: dict):
        """One pre-formed batch -> (vals, idx).  No queue, no padding
        bookkeeping — the thinnest engine surface."""
        return self._fwd_dict(self.params, batch)

    def submit(self, feats: np.ndarray, meta: Optional[dict] = None) -> int:
        """Enqueue one (T, F) utterance; returns its request id.

        Shape is validated here, at the API boundary: a malformed
        request failing later inside run() would strand the valid
        requests batched alongside it.
        """
        if self.cfg.family != "lstm_am":
            raise ValueError(
                "the queued feature path is the acoustic-model surface; "
                "use forward_topk (dict batches) or TokenServer")
        feats = np.asarray(feats)
        if feats.ndim != 2 or feats.shape[1] != self.cfg.feat_dim:
            raise ValueError(
                f"expected (T, {self.cfg.feat_dim}) features, got "
                f"{feats.shape}")
        return self.queue.submit(feats, meta)

    def run(self, on_batch=None) -> Dict[int, CompletedRequest]:
        """Drain the queue: bucket, batch, forward, unpad, complete.

        Returns the results completed by *this* call, keyed by rid, and
        evicts them from the queue's ledger — the engine's memory must
        not grow with uptime, so results live with the caller.  One XLA
        program per distinct bucket length.  ``on_batch`` (FormedBatch ->
        None), if given, fires after each batch completes — load
        generators use it for per-request latency accounting.
        """
        reqs = self.queue.pop_pending()
        try:
            for fb in form_batches(reqs, self.policy):
                vals, idx = self._fwd(self.params, jnp.asarray(fb.feats),
                                      jnp.asarray(fb.lens))
                vals = np.asarray(jax.device_get(vals).astype(jnp.float32))
                idx = np.asarray(jax.device_get(idx))
                for i, r in enumerate(fb.requests):
                    # copy: a slice view would pin the whole padded batch
                    # array in the results ledger for its lifetime
                    self.queue.complete(r.rid, (vals[i, :r.length].copy(),
                                                idx[i, :r.length].copy()))
                if on_batch is not None:
                    on_batch(fb)
        except BaseException:
            # a failed forward must not strand its sibling requests:
            # everything unfulfilled goes back to pending for retry
            self.queue.restore_in_flight()
            raise
        return self.queue.pop_completed()

    # ------------------------------------------------------ streaming path

    def _ensure_stream_state(self):
        if self._stream_state is None:
            self._stream_state = self.model.init_stream_state(self.n_slots)

    def open_stream(self) -> int:
        """Claim a slot with fresh recurrent state; returns stream id."""
        if not supports_streaming(self.cfg):
            raise ValueError("model has no streaming form (bidirectional)")
        if not self._slot_free:
            raise RuntimeError("all stream slots busy")
        self._ensure_stream_state()
        sid = self._slot_free.pop(0)
        self._stream_state = jax.tree_util.tree_map(
            lambda a: a.at[sid].set(0), self._stream_state)
        return sid

    def close_stream(self, sid: int):
        if not 0 <= sid < self.n_slots or sid in self._slot_free:
            raise ValueError(f"stream {sid} is not open")
        self._slot_free.append(sid)
        self._slot_free.sort()

    def feed_async(self, chunks: Dict[int, np.ndarray]) -> "StreamFeed":
        """Stage and dispatch one batched streaming step without waiting
        for its results.

        The H2D transfer (``jax.device_put``) and the jitted step are
        both async, so a caller that dispatches chunk *n+1* before
        collecting chunk *n*'s results (``StreamFeed.result()``)
        overlaps next-chunk host-side staging with the current step's
        device compute — host↔device double buffering, the serve-side
        analogue of the training feed's ``pipeline.PrefetchingSource``.
        ``feed_pipelined`` is the packaged driver.

        A zero-frame ``(0, F)`` chunk is refused: it would write
        ``lens[sid] = 0`` and silently waste a batched step.  An empty
        ``chunks`` dict (e.g. every stream closed) is an explicit no-op
        — no step is dispatched.
        """
        if not chunks:
            return StreamFeed(None, None, {})
        chunks = {sid: np.asarray(c) for sid, c in chunks.items()}
        fd = stream_feat_dim(self.cfg)
        for sid, c in chunks.items():
            if not 0 <= sid < self.n_slots or sid in self._slot_free:
                raise ValueError(f"stream {sid} is not open")
            if c.ndim != 2 or c.shape[1] != fd:
                raise ValueError(
                    f"stream {sid}: expected (t, {fd}) chunk, got "
                    f"{c.shape}")
            if c.shape[0] == 0:
                raise ValueError(
                    f"stream {sid}: zero-frame chunk — skip the stream "
                    f"this step instead of feeding an empty chunk")
        self._ensure_stream_state()
        t_max = bucket_length(max(c.shape[0] for c in chunks.values()),
                              self.policy.bucket_multiple)
        feats = np.zeros((self.n_slots, t_max, fd), np.float32)
        lens = np.zeros((self.n_slots,), np.int32)
        for sid, c in chunks.items():
            feats[sid, :c.shape[0]] = c
            lens[sid] = c.shape[0]
        vals, idx, self._stream_state = self._stream_fwd(
            self.params, self._stream_state, jax.device_put(feats),
            jax.device_put(lens))
        return StreamFeed(vals, idx,
                          {sid: c.shape[0] for sid, c in chunks.items()})

    def feed(self, chunks: Dict[int, np.ndarray]):
        """One batched streaming step over all active streams.

        chunks: {sid: (t, F)} — chunk lengths may differ per stream
        (each stream's state freezes at its own valid length); every
        chunk must have at least one frame.  Returns
        {sid: (vals (t, k), idx (t, k))}.  Synchronous wrapper over
        ``feed_async``.
        """
        return self.feed_async(chunks).result()

    def feed_pipelined(self, chunk_iter, *, depth: int = 2):
        """Drive ``feed_async`` over an iterator of chunk dicts with a
        ``depth``-deep in-flight window, yielding each step's results in
        order.  While step *n* computes on device, step *n+1* is already
        assembled and its H2D transfer issued — the interactive path's
        double-buffered feed.  Results are identical to sequential
        ``feed()`` calls (pinned in tests/test_serve_engine.py)."""
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        pending: deque = deque()
        for chunks in chunk_iter:
            pending.append(self.feed_async(chunks))
            while len(pending) >= depth:
                yield pending.popleft().result()
        while pending:
            yield pending.popleft().result()
