"""Per-request sampling for the continuous-batching decode window.

``sample_tokens`` is traced inside the fused ``sync_every``-step
``lax.scan`` window, so everything is vectorised over rows and there is
no host traffic: temperature, top-k, top-p and seed arrive as (B,)
arrays chosen per-request at admission.

Reproducibility contract: the Gumbel noise for row b at position p is a
pure function of ``(seed_b, p)`` — ``fold_in(PRNGKey(seed_b), p)`` —
never of the batch composition or wall clock.  The same request replayed
solo, in a different slot, or next to different neighbours samples the
same tokens.  ``temperature <= 0`` is the greedy sentinel: that row
takes argmax bitwise, so mixing greedy and sampled requests in one
window is safe.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request knobs. Defaults are greedy (temperature 0)."""
    temperature: float = 0.0
    top_k: int = 0          # 0 = no top-k cut
    top_p: float = 1.0      # 1.0 = no nucleus cut
    seed: int = 0

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")

    @property
    def greedy(self):
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def sample_tokens(logits, temperature, top_k, top_p, seeds, pos):
    """Sample one token per row.  logits (B, V) float; temperature /
    top_p (B,) float; top_k / seeds / pos (B,) int.  Returns (B,) int32.

    One descending sort per step covers both filters: top-k keeps ranks
    < k, top-p keeps the shortest prefix whose mass reaches top_p (the
    ``cum - probs < top_p`` form always keeps rank 0, so a peaked
    distribution can never mask everything).  Selection is Gumbel-max
    over the surviving ranks, mapped back through the sort order.
    """
    b, v = logits.shape
    logits = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    safe_t = jnp.where(temperature > 0, temperature, 1.0).astype(jnp.float32)
    scaled = logits / safe_t[:, None]
    order = jnp.argsort(-scaled, axis=-1)                  # (B, V) desc
    svals = jnp.take_along_axis(scaled, order, axis=-1)
    probs = jax.nn.softmax(svals, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)

    k_eff = jnp.where(top_k > 0, top_k, v)
    keep = jnp.arange(v)[None, :] < k_eff[:, None]
    keep &= (cum - probs) < top_p[:, None]
    keep = keep.at[:, 0].set(True)
    masked = jnp.where(keep, svals, NEG_INF)

    def row_gumbel(seed, p):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), p)
        return jax.random.gumbel(key, (v,), jnp.float32)

    g = jax.vmap(row_gumbel)(seeds, pos)
    pick = jnp.argmax(masked + g, axis=-1)
    sampled = jnp.take_along_axis(order, pick[:, None], axis=-1)[:, 0]
    return jnp.where(temperature > 0, sampled,
                     greedy_tok).astype(jnp.int32)
