"""Generic slot-based session core shared by both serving surfaces.

PR 5/6 gave the token-LM decode surface slot-based continuous batching;
the streaming acoustic model kept a lockstep ``open_stream``/``feed``
loop.  Both workloads are the same machine underneath: ``n_slots``
device rows, each holding one long-lived *session* (a decode request, a
live audio stream), with

  * **mid-flight admission** — a retired/parked slot re-admits the
    queue head while the other rows keep working (no head-of-line
    drain barriers);
  * a **windowed pump**: ``sync_every`` fused device steps per host
    sync, with emissions accumulating in a device-side buffer — the
    host does all admit/retire bookkeeping at window cadence, O(steps/K)
    transfers instead of one per step;
  * **failure recovery** (``_abort``) — a failed window must never
    strand its sessions: outputs reset, sessions requeued, device state
    dropped;
  * honest **utilization stats** — ``useful_units`` vs ``padded_units``
    count the work actually requested against the work the padded batch
    computed, in each surface's own unit (slot-steps for token decode,
    frames for streaming audio), so one number compares both surfaces.

``SlotServer`` owns that machinery; the two session types subclass it:

  ``serve.decode.TokenServer``  — one session = one decode request;
      a window step consumes one token per row (ragged prefill, then
      generation until max_new/EOS).
  ``serve.stream.StreamServer`` — one session = one audio stream; a
      window step consumes one feature chunk per row (ragged chunk
      consumption), and streams **attach/detach mid-flight**: a
      detached stream's recurrent-state row is pulled to the host, its
      slot re-admits queued work, and a later reattach restores the row
      bitwise.

SLO tiers (``serve.batcher.TieredPolicy``): sessions carry a tier name
(``interactive`` / ``firehose``).  The core derives the window length
from the *active* tiers (interactive present -> short windows for fast
emission visibility; firehose-only -> long windows amortizing syncs),
caps per-tier slot occupancy, and under interactive pressure defers
admission of preemptible sessions ("sheds") and parks active ones
(``_park_slot``) to free their slots.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.serve.request import RequestQueue


class SlotServer:
    """Slot/session core: admission, the windowed pump, retirement,
    abort recovery and utilization accounting.

    Subclass hooks (see TokenServer / StreamServer):

      _admit_slot(slot, req) -> bool   host-side slot mirrors; False
                                       means "does not fit right now"
                                       (stops admission, FIFO no-skip)
      _retire_slot(slot)               release per-slot resources
      _pre_window(admitted)            device prep (row resets, uploads)
      _run_window(k) -> emissions      run k fused steps; ends with THE
                                       host sync; commits device state
      _consume(slot, req, emitted, k)  per-slot host bookkeeping; returns
                                       (live_steps, useful_units) and
                                       may mark the payload .done
      _padded_units(k)                 units ONE slot (occupied or dead)
                                       computes in a k-step window
      _reset_payload(payload)          abort hygiene: clear outputs
      _drop_state()                    abort hygiene: drop device state
      _park_slot(slot) -> bool         detach the session back to the
                                       queue (streams); False = cannot
    """

    def __init__(self, n_slots: int, *, sync_every: int, tiers=None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.b = n_slots
        self.sync_every = int(sync_every)
        if self.sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        self.tiers = tiers
        self.queue = RequestQueue()
        self._slots: List[Optional[object]] = [None] * self.b
        self.stats = {"steps": 0, "syncs": 0, "slot_steps": 0,
                      "active_slot_steps": 0, "admitted": 0, "parked": 0,
                      "useful_units": 0, "padded_units": 0}

    # --------------------------------------------------------- tier logic

    def _tier_of(self, payload):
        """Resolve a session's SLOTier (None when untiered)."""
        if self.tiers is None:
            return None
        return self.tiers.tier(getattr(payload, "tier", None))

    def _window_k(self) -> int:
        """Window length for this pump: the tightest ``sync_every``
        among the tiers currently holding slots (an active interactive
        session shortens everyone's window — its emissions must reach
        the host quickly), the server default otherwise."""
        if self.tiers is None:
            return self.sync_every
        ks = [self._tier_of(r.payload).sync_every
              for r in self._slots if r is not None]
        return min(ks) if ks else self.sync_every

    def _tier_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self._slots:
            if r is not None:
                name = self._tier_of(r.payload).name
                counts[name] = counts.get(name, 0) + 1
        return counts

    def _interactive_pressure(self) -> int:
        """Pending non-preemptible sessions that can't get a free slot."""
        pend = sum(1 for req in self.queue.peek_pending()
                   if not self._tier_of(req.payload).preemptible)
        free = sum(1 for s in self._slots if s is None)
        return max(0, pend - free)

    def _rebalance(self):
        """Admission control, park half: when non-preemptible sessions
        are waiting and no slot is free, detach preemptible sessions
        (newest slots first) until the pressure clears.  Parked sessions
        go back to pending and re-admit when occupancy allows."""
        if self.tiers is None:
            return
        need = self._interactive_pressure()
        if need <= 0:
            return
        for i in reversed(range(self.b)):
            if need <= 0:
                break
            req = self._slots[i]
            if req is None or not self._tier_of(req.payload).preemptible:
                continue
            if self._park_slot(i):
                self.stats["parked"] += 1
                need -= 1

    def _pop_admissible(self, max_n: int):
        """Admission control, shed half: pop up to ``max_n`` pending
        sessions in arrival order.  Untiered servers take the queue head
        verbatim (FIFO no-skip stays with ``_admit_slot``); tiered
        servers skip (leave pending) sessions whose tier is at its slot
        cap, and preemptible sessions while interactive occupancy is at
        or past ``shed_threshold`` — deferred, not dropped."""
        if self.tiers is None:
            return self.queue.pop_pending(max_n=max_n)
        counts = self._tier_counts()
        # non-preemptible sessions waiting: preemptible ones must not
        # take the slots just freed for them (parked sessions requeue at
        # the head, ahead of the interactive arrivals that evicted them)
        waiting = [sum(1 for req in self.queue.peek_pending()
                       if not self._tier_of(req.payload).preemptible)]

        def admissible(req):
            t = self._tier_of(req.payload)
            if t.max_batch is not None and counts.get(t.name, 0) >= t.max_batch:
                if not t.preemptible:
                    waiting[0] -= 1     # capped: can't use a slot, so it
                return False            # must not block preemptible work
            if t.preemptible:
                if waiting[0] > 0:
                    return False
                occ = sum(counts.get(u.name, 0) for u in self.tiers.tiers
                          if not u.preemptible) / self.b
                if occ >= self.tiers.shed_threshold:
                    return False
            else:
                waiting[0] -= 1
            counts[t.name] = counts.get(t.name, 0) + 1
            return True

        return self.queue.pop_pending_where(admissible, max_n=max_n)

    # ---------------------------------------------------------- admission

    def _admit(self) -> List[int]:
        """Fill free slots from the queue head (arrival order), after
        giving admission control a chance to park preemptible sessions
        under interactive pressure.  Stops at the first session
        ``_admit_slot`` can't place (FIFO no-skip: it and everything
        behind it requeue in order)."""
        self._rebalance()
        free = [i for i in range(self.b) if self._slots[i] is None]
        if not free:
            return []
        reqs = self._pop_admissible(len(free))
        admitted = []
        for n, (slot, req) in enumerate(zip(free, reqs)):
            if not self._admit_slot(slot, req):
                self.queue.requeue([q.rid for q in reqs[n:]])
                break
            self._slots[slot] = req
            admitted.append(slot)
        self.stats["admitted"] += len(admitted)
        return admitted

    # --------------------------------------------------------------- pump

    def pump(self) -> Dict[int, object]:
        """One sync window: admit into free slots, run ``_window_k()``
        fused device steps, one device→host sync for the window's
        emissions, then retire sessions that finished.  Returns (and
        evicts) the sessions completed by this window."""
        try:
            admitted = self._admit()
            if all(s is None for s in self._slots):
                return {rid: cr.result
                        for rid, cr in self.queue.pop_completed().items()}
            k = self._window_k()
            self._pre_window(admitted)
            emitted = self._run_window(k)
        except BaseException:
            # admission, row resets and the window itself all recover
            # the same way: nothing may stay stranded in a slot
            self._abort()
            raise
        self.stats["syncs"] += 1
        self.stats["steps"] += k
        self.stats["slot_steps"] += k * self.b
        # every slot — occupied, retired-overshooting, or empty — computed
        # the full window; the honest denominator counts them all
        self.stats["padded_units"] += self.b * self._padded_units(k)
        for i, req in enumerate(self._slots):
            if req is None:
                continue        # empty slots don't advance: their host
                                # mirrors must keep matching the device
                                # rows (reset on admission), not drift
            live, useful = self._consume(i, req, emitted, k)
            self.stats["active_slot_steps"] += live
            self.stats["useful_units"] += useful
            if req.payload.done:
                self._finish(i, req)
        return {rid: cr.result
                for rid, cr in self.queue.pop_completed().items()}

    def _finish(self, i: int, req):
        r = req.payload
        r.finished_sync = self.stats["syncs"]
        self._slots[i] = None
        self._retire_slot(i)
        self.queue.complete(r.rid, r)

    def _abort(self):
        """Failure recovery: a failed window must not strand its slots —
        outputs reset, sessions requeued, device state dropped."""
        for req in self._slots:
            if req is not None:
                self._reset_payload(req.payload)
        self._slots = [None] * self.b
        self._drop_state()
        self.queue.restore_in_flight()

    def drain(self) -> Dict[int, object]:
        """Pump until no pending or in-flight work remains.  Returns
        (and evicts) the sessions completed since the last drain — the
        server's ledger must not grow with uptime."""
        done: Dict[int, object] = {}
        while self.queue.n_pending or self.n_active:
            done.update(self.pump())
        done.update({rid: cr.result
                     for rid, cr in self.queue.pop_completed().items()})
        return done

    # -------------------------------------------------------------- stats

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    def utilization(self) -> float:
        """Useful work / computed work, in the surface's own unit
        (slot-steps for token decode, frames for streaming audio) — the
        one honest number both session types report."""
        return self.stats["useful_units"] / max(self.stats["padded_units"],
                                                1)

    def occupancy(self) -> Dict[str, float]:
        """Slot occupancy, total and per tier (fractions of ``b``)."""
        occ = {"total": self.n_active / self.b}
        if self.tiers is not None:
            for name, n in self._tier_counts().items():
                occ[name] = n / self.b
        return occ

    # -------------------------------------------------------------- hooks

    def _admit_slot(self, slot: int, req) -> bool:
        raise NotImplementedError

    def _retire_slot(self, slot: int):
        raise NotImplementedError

    def _pre_window(self, admitted: List[int]):
        raise NotImplementedError

    def _run_window(self, k: int) -> np.ndarray:
        raise NotImplementedError

    def _consume(self, slot: int, req, emitted, k: int):
        raise NotImplementedError

    def _reset_payload(self, payload):
        raise NotImplementedError

    def _drop_state(self):
        raise NotImplementedError

    def _padded_units(self, k: int) -> int:
        """Units one slot computes over a k-step window — slot-steps by
        default (token decode); the stream surface counts frames."""
        return k

    def _park_slot(self, slot: int) -> bool:
        """Detach the session in ``slot`` back to the queue (streams
        carry their recurrent state to the host).  Token sessions can't
        be parked — their KV rows die with the slot."""
        return False
