"""Host-side page allocator for the paged decode cache.

The device side (``repro.models.paging``) only sees a block table; every
policy decision — which pages a row gets, when they return to the free
list, which prompt-prefix blocks are shared between rows — lives here,
in plain Python, outside jit.  vLLM's design, scaled down: fixed-size
pages, a free list, refcounts for copy-on-nothing prefix sharing (a
shared page is never written: writes start at the first non-shared
block), and an LRU of ref-0 published pages that is only cannibalised
when the free list runs dry.

Page id 0 is the trash page (retired rows point at it) and is never
handed out; valid ids are 1..n_pages.
"""
from __future__ import annotations

from collections import OrderedDict


def block_hashes(tokens, page_size):
    """Chained content hashes for the *sharable* prompt blocks.

    Block j is sharable only if the prompt extends strictly past it
    (``(j+1)*page_size <= len(tokens) - 1``): the last prompt position
    may be overwritten in-place by the overshoot clamp when a row
    retires, so a block containing it can never be published.  Chaining
    makes hash j depend on all tokens before it, so equal hashes ⇒ equal
    prefixes (modulo hash collisions, same trade-off vLLM makes).
    """
    hashes = []
    h = hash(("paged-kv", page_size))
    for j in range(len(tokens) // page_size):
        if (j + 1) * page_size > len(tokens) - 1:
            break
        h = hash((h, tuple(int(t) for t in
                           tokens[j * page_size:(j + 1) * page_size])))
        hashes.append(h)
    return hashes


class PageAllocator:
    """Free-list allocator with refcounted prefix caching.

    Invariant (checked by ``check()``): every page 1..n_pages is in
    exactly one of {free list, live (ref > 0), cached (ref == 0, in the
    LRU awaiting reuse or eviction)}.
    """

    def __init__(self, n_pages, page_size, *, prefix_cache=True):
        if n_pages < 1:
            raise ValueError("need at least one page")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.prefix_cache = bool(prefix_cache)
        # pop() yields ascending ids — keeps early pages hot/debuggable
        self._free = list(range(self.n_pages, 0, -1))
        self._ref = {}            # page -> refcount (live pages only)
        self._hash_of = {}        # page -> content hash (published)
        self._page_of = {}        # content hash -> page (published)
        self._lru = OrderedDict() # ref-0 published pages, oldest first
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "allocs": 0, "peak_pages": 0}

    # ---- capacity ----
    def free_pages(self):
        return len(self._free) + len(self._lru)

    def live_pages(self):
        return sum(1 for r in self._ref.values() if r > 0)

    def can_alloc(self, n):
        return n <= self.free_pages()

    # ---- alloc / release ----
    def alloc(self, n):
        """Take ``n`` fresh pages (ref=1 each).  Evicts cached ref-0
        pages LRU-first only when the free list is empty."""
        if not self.can_alloc(n):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {self.free_pages()}")
        out = []
        for _ in range(n):
            if self._free:
                page = self._free.pop()
            else:
                page, _ = self._lru.popitem(last=False)
                h = self._hash_of.pop(page)
                del self._page_of[h]
                del self._ref[page]
                self.stats["evictions"] += 1
            self._ref[page] = 1
            out.append(page)
        self.stats["allocs"] += n
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.live_pages())
        return out

    def release(self, pages):
        """Drop one reference from each page.  A published page whose
        refcount hits zero parks in the LRU (contents stay valid for
        future prefix hits); an unpublished one returns to the free
        list."""
        for page in pages:
            self._ref[page] -= 1
            if self._ref[page] > 0:
                continue
            if page in self._hash_of:
                self._lru[page] = None
                self._lru.move_to_end(page)
            else:
                del self._ref[page]
                self._free.append(page)

    # ---- prefix cache ----
    def peek_prefix(self, hashes):
        """How many leading blocks of ``hashes`` are already resident."""
        if not self.prefix_cache:
            return 0
        n = 0
        for h in hashes:
            if h not in self._page_of:
                break
            n += 1
        return n

    def acquire_prefix(self, hashes):
        """Take a reference on each published block (must all be
        resident — call ``peek_prefix`` first).  Returns the pages."""
        pages = []
        for h in hashes:
            page = self._page_of[h]
            if page in self._lru:          # ref 0 -> back to live
                del self._lru[page]
                self._ref[page] = 1
            else:
                self._ref[page] += 1
            pages.append(page)
            self.stats["hits"] += 1
        return pages

    def publish(self, page, h):
        """Register a full, final block for future prefix sharing."""
        if not self.prefix_cache:
            return
        if page in self._hash_of or h in self._page_of:
            return                          # already published / dup hash
        self._hash_of[page] = h
        self._page_of[h] = page

    def note_miss(self, n):
        self.stats["misses"] += n

    # ---- lifecycle ----
    def reset(self):
        """Forget everything (device pools were just dropped, so cached
        page contents are invalid).  Stats survive."""
        self._free = list(range(self.n_pages, 0, -1))
        self._ref.clear()
        self._hash_of.clear()
        self._page_of.clear()
        self._lru.clear()

    def check(self):
        """Conservation invariant; raises AssertionError on a leak."""
        live = {p for p, r in self._ref.items() if r > 0}
        cached = set(self._lru)
        free = set(self._free)
        assert not (live & free), f"pages both live and free: {live & free}"
        assert not (cached & free), \
            f"pages both cached and free: {cached & free}"
        assert cached <= set(self._ref), "cached page missing refcount"
        assert all(self._ref[p] == 0 for p in cached), \
            "cached page with nonzero refcount"
        union = live | cached | free
        assert union == set(range(1, self.n_pages + 1)), \
            f"page leak: missing {set(range(1, self.n_pages + 1)) - union}"
        assert len(self._free) + len(live) + len(cached) == self.n_pages
