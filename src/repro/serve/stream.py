"""Slot-based continuous batching for the streaming-AM serving surface.

``StreamingEngine``'s ``open_stream``/``feed``/``close_stream`` loop is
lockstep: every caller synchronizes every chunk, and a slow stream
stalls the batch.  ``StreamServer`` is the same workload as the second
session type of the ``serve.slots.SlotServer`` core:

  * one session = one long-running audio stream; each slot carries the
    stream's recurrent state row (LSTM (h, c), or whisper's chunked
    encoder + incremental-decoder state);
  * a window step consumes one ``chunk_frames`` feature chunk per row —
    ragged per-stream consumption (a stream's last chunk may be short,
    a starved stream's row runs dead at lens 0), the streaming analogue
    of ragged prefill;
  * emissions (top-k posteriors per frame for the AM, one decode
    position per chunk for whisper) accumulate on device across the
    window — one host sync per ``sync_every`` chunks, not one per chunk
    (the lockstep loop's cost);
  * streams **attach and detach mid-flight**: ``detach`` pulls the
    slot's state row to the host and frees the slot for queued work;
    ``reattach`` queues the stream for re-admission, and its row is
    restored bitwise — an interrupted stream emits exactly what an
    uninterrupted one would (pinned in tests/test_stream_server.py).
    SLO admission control (``TieredPolicy``) parks preemptible
    (firehose) streams through the same mechanism when interactive
    streams are waiting.

Work accounting is in *frames*: ``useful_units`` counts frames streams
actually consumed, ``padded_units`` counts ``slots x window x chunk``
frames the padded batch computed — the same honest utilization number
the token surface reports in slot-steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.api import (stream_feat_dim, stream_frame_sync,
                              supports_streaming)
from repro.serve.engine import make_topk_emitter
from repro.serve.slots import SlotServer


@dataclass
class StreamSession:
    """One audio stream's host-side record (the slot payload)."""
    rid: int
    feats: np.ndarray               # (T, F) frames submitted so far
    closed: bool = True             # no more audio will arrive
    consumed: int = 0               # frames fed to the model
    out: List[tuple] = field(default_factory=list)  # per-chunk (vals, idx)
    done: bool = False
    finished_sync: int = -1         # pump index at completion (-1 in flight)
    tier: Optional[str] = None      # SLO tier name (None = default tier)
    parked_state: Any = None        # host copy of the state row (detached)

    def emissions(self):
        """Concatenated (vals (T_out, k), idx (T_out, k)) over every
        chunk emitted so far."""
        if not self.out:
            return (np.zeros((0, 0), np.float32), np.zeros((0, 0), np.int32))
        return (np.concatenate([v for v, _ in self.out], axis=0),
                np.concatenate([i for _, i in self.out], axis=0))


class StreamServer(SlotServer):
    """Continuous batcher over the model streaming surface
    (``init_stream_state`` / ``stream_step`` / ``reset_stream_rows``).

    ``submit(feats)`` enqueues a finite stream (audio known up front —
    the firehose shape); ``submit(feats, final=False)`` opens a live
    stream the caller extends with ``append`` and ends with ``close``.
    ``pump()`` runs one sync window and returns the sessions that
    finished; ``drain()`` pumps until nothing is pending (every live
    stream must be ``close``d first or drain would spin forever —
    refused loudly).
    """

    def __init__(self, cfg, params, *, n_slots: int = 4,
                 chunk_frames: int = 16, sync_every: int = 4,
                 k: int = 20, temperature: float = 1.0,
                 tiers=None, topk_impl: str = "lax",
                 interpret: Optional[bool] = None,
                 max_frames: int = 256, state_dtype=jnp.float32):
        if not supports_streaming(cfg):
            raise ValueError(f"{cfg.name} has no streaming form "
                             "(bidirectional AM / decoder-only LM)")
        if chunk_frames < 1:
            raise ValueError("chunk_frames must be >= 1")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.chunk = int(chunk_frames)
        self.k = k
        self.temperature = temperature
        self.frame_sync = stream_frame_sync(cfg)
        self.feat_dim = stream_feat_dim(cfg)
        # whisper's cross-attn buffers cap total audio per stream; the
        # frame-synchronous AM's O(1) state has no cap
        self.max_frames = None if self.frame_sync else int(max_frames)
        self.state_dtype = state_dtype
        super().__init__(n_slots, sync_every=sync_every, tiers=tiers)
        self._emit = make_topk_emitter(k, topk_impl, interpret=interpret)
        self._reset = jax.jit(self.model.reset_stream_rows)
        self._window_jits: Dict[int, Any] = {}   # window length -> jit
        self._state = None                       # device state (lazy)
        self._fresh: List[int] = []              # slots to zero-reset
        self._restores: Dict[int, Any] = {}      # slot -> host state row

    # ------------------------------------------------------- jitted window

    def _make_window(self, kw: int):
        """kw fused stream steps: feats (kw, B, chunk, F) / lens (kw, B)
        scan through ``stream_step``, top-k emission accumulating on
        device — one host sync per window."""
        model, emit, temp = self.model, self._emit, self.temperature

        def window(params, state, feats, lens):
            def body(state, inp):
                f, l = inp
                h, state = model.stream_step(params, state, f, lens=l)
                vals, idx = emit(model.unembed(params, h) / temp)
                return state, (vals, idx)

            state, (vals, idx) = jax.lax.scan(body, state, (feats, lens))
            return state, vals, idx     # vals (kw, B, t_out, k)
        return window

    # ------------------------------------------------------------- submit

    def _validate_feats(self, feats, *, base: int = 0) -> np.ndarray:
        feats = np.asarray(feats, np.float32)
        if feats.ndim != 2 or feats.shape[1] != self.feat_dim:
            raise ValueError(f"expected (T, {self.feat_dim}) features, "
                             f"got {feats.shape}")
        if self.max_frames is not None \
                and base + feats.shape[0] > self.max_frames:
            raise ValueError(
                f"stream would hold {base + feats.shape[0]} frames > "
                f"max_frames ({self.max_frames}) — the enc-dec streaming "
                f"state's cross-attention buffer capacity")
        return feats

    def submit(self, feats: np.ndarray, *, final: bool = True,
               tier: Optional[str] = None) -> int:
        """Enqueue a stream.  ``final=True``: the audio is complete and
        the session retires once it's consumed.  ``final=False``: a live
        stream — feed more with ``append(rid, ...)``, end with
        ``close(rid)``; until then its slot idles (dead row) whenever it
        runs out of submitted frames."""
        feats = self._validate_feats(feats)
        if final and feats.shape[0] < 1:
            raise ValueError("a final stream needs at least one frame")
        if self.tiers is not None:
            self.tiers.tier(tier)       # unknown tier names fail loudly
        s = StreamSession(-1, feats, closed=final, tier=tier)
        s.rid = self.queue.submit(s)
        return s.rid

    def _find(self, rid: int) -> StreamSession:
        for req in self._slots:
            if req is not None and req.rid == rid:
                return req.payload
        for req in self.queue.peek_pending():
            if req.rid == rid:
                return req.payload
        held = self.queue._in_flight.get(rid)
        if held is not None:
            return held.payload
        raise KeyError(f"stream {rid} is not live")

    def append(self, rid: int, feats: np.ndarray):
        """Extend a live stream's audio (any attachment state)."""
        s = self._find(rid)
        if s.closed:
            raise ValueError(f"stream {rid} is closed")
        feats = self._validate_feats(feats, base=s.feats.shape[0])
        s.feats = np.concatenate([s.feats, feats], axis=0)

    def close(self, rid: int):
        """Mark a live stream complete; it retires once consumed."""
        s = self._find(rid)
        s.closed = True

    # ----------------------------------------------------- detach/reattach

    def detach(self, rid: int):
        """Pull a stream out of its slot mid-flight: its state row goes
        to the host, the slot frees for queued work, and the session is
        *held* (neither pending nor active) until ``reattach``."""
        for i, req in enumerate(self._slots):
            if req is not None and req.rid == rid:
                req.payload.parked_state = jax.device_get(
                    self.model.pull_stream_row(self._state, i))
                self._slots[i] = None
                self.stats["parked"] += 1
                return
        raise KeyError(f"stream {rid} is not attached")

    def _held_rids(self) -> List[int]:
        """Detached sessions: in-flight in the queue but holding no slot
        (waiting for an explicit ``reattach``)."""
        slotted = {req.rid for req in self._slots if req is not None}
        return [rid for rid in self.queue._in_flight if rid not in slotted]

    def reattach(self, rid: int):
        """Queue a detached stream for re-admission; its state row is
        restored bitwise when a slot frees."""
        if rid not in self._held_rids():
            raise ValueError(f"stream {rid} is not detached")
        self.queue.requeue([rid])

    def _park_slot(self, i: int) -> bool:
        """SLO preemption: detach the (preemptible) stream and requeue
        it — unlike ``detach``, it re-admits automatically once
        interactive pressure clears."""
        req = self._slots[i]
        if self._state is None:
            return False
        req.payload.parked_state = jax.device_get(
            self.model.pull_stream_row(self._state, i))
        self._slots[i] = None
        self.queue.requeue([req.rid])
        return True

    # ----------------------------------------------------------- slot hooks

    def _ensure_state(self):
        if self._state is None:
            kw = {} if self.frame_sync else \
                {"max_frames": self.max_frames,
                 "max_tokens": self.max_frames}
            self._state = self.model.init_stream_state(
                self.b, self.state_dtype, **kw)

    def _admit_slot(self, slot: int, req) -> bool:
        s = req.payload
        if s.parked_state is not None:
            self._restores[slot] = s.parked_state   # bitwise row restore
            s.parked_state = None
        else:
            self._fresh.append(slot)                # zero-reset the row
        return True

    def _retire_slot(self, slot: int):
        pass        # state rows are zeroed on the *next* admission

    def _pre_window(self, admitted: List[int]):
        self._ensure_state()
        if self._fresh:
            mask = np.zeros((self.b,), bool)
            mask[self._fresh] = True
            self._state = self._reset(self._state, jnp.asarray(mask))
            self._fresh = []
        for slot, row in self._restores.items():
            self._state = self.model.put_stream_row(self._state, slot, row)
        self._restores = {}

    def _run_window(self, kw: int):
        feats = np.zeros((kw, self.b, self.chunk, self.feat_dim),
                         np.float32)
        lens = np.zeros((kw, self.b), np.int32)
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            s = req.payload
            c = s.consumed
            for j in range(kw):
                n = min(self.chunk, s.feats.shape[0] - c)
                if n <= 0:
                    break               # starved/finished: dead row
                feats[j, i, :n] = s.feats[c:c + n]
                lens[j, i] = n
                c += n
        if kw not in self._window_jits:
            self._window_jits[kw] = jax.jit(self._make_window(kw))
        state, vals, idx = self._window_jits[kw](
            self.params, self._state, jnp.asarray(feats),
            jnp.asarray(lens))
        vals, idx = jax.device_get((vals, idx))  # THE sync of this window
        vals = np.asarray(vals.astype(jnp.float32))
        idx = np.asarray(idx)
        self._state = state
        return vals, idx, lens

    def _consume(self, i: int, req, emitted, kw: int):
        vals, idx, lens = emitted
        s = req.payload
        live = useful = 0
        for j in range(kw):
            n = int(lens[j, i])
            if n > 0:
                live += 1
                useful += n
                t_out = n if self.frame_sync else 1
                # copies: the results ledger must not pin the window batch
                s.out.append((vals[j, i, :t_out].copy(),
                              idx[j, i, :t_out].copy()))
                s.consumed += n
        if s.closed and s.consumed >= s.feats.shape[0]:
            s.done = True
        return live, useful

    def _padded_units(self, kw: int) -> int:
        return kw * self.chunk          # frames one slot computed

    def _reset_payload(self, payload):
        # abort hygiene: device state is gone, so the stream restarts
        # from frame 0 on re-admission
        payload.out.clear()
        payload.consumed = 0
        payload.done = False
        payload.parked_state = None

    def _drop_state(self):
        self._state = None
        self._fresh = []
        self._restores = {}

    def drain(self):
        live = [req.rid for req in (list(self._slots)
                                    + self.queue.peek_pending())
                if req is not None and not req.payload.closed]
        if live:
            raise RuntimeError(
                f"drain() with open streams {live}: close() them or keep "
                f"pump()ing — draining an open stream would spin forever")
        held = self._held_rids()
        if held:
            raise RuntimeError(
                f"drain() with detached streams {held}: reattach() them "
                f"first — a held stream never completes on its own")
        return super().drain()
