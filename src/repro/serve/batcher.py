"""Dynamic bucketing / padding-aware batch formation.

The cost of a padded batch is ``B * T_bucket`` frames of compute for
``sum(lens)`` useful frames; the compile cost is one XLA program per
distinct (B, T_bucket) shape.  The batcher trades the two off:

  * lengths are rounded up to a multiple of ``bucket_multiple`` (few
    distinct T shapes -> few compiles),
  * requests are sorted by length and greedily packed so near-equal
    lengths share a batch (little padding waste),
  * the batch dim is always padded to ``max_batch`` with zero-length
    dummy rows (exactly one (B, T) shape per bucket length; masked rows
    cost compute but no recompilation — the standard serving trade).

Two shipped policies mirror the engine's two consumers: THROUGHPUT packs
big batches for the teacher's offline firehose (paper §3.2.2 target
generation); LATENCY keeps batches small and never waits for more work
than the queue already holds, for online serving.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.serve.request import InferenceRequest


@dataclass(frozen=True)
class BatchPolicy:
    """How the batcher groups pending requests.

    max_batch: rows per formed batch (batch dim is padded to this); for
        the token server this is the continuous batcher's slot count.
    bucket_multiple: time-length rounding quantum (padding/compile trade).
    sort_by_length: pack near-equal lengths together (throughput) or
        preserve arrival order (latency fairness).
    sync_every: the token server's decode-window length — fused device
        steps between host syncs (admit/retire cadence).  Small keeps
        first-token latency low; large amortizes host syncs.
    """
    name: str
    max_batch: int = 16
    bucket_multiple: int = 64
    sort_by_length: bool = True
    sync_every: int = 8


THROUGHPUT = BatchPolicy("throughput", max_batch=16, bucket_multiple=64,
                         sort_by_length=True, sync_every=16)
LATENCY = BatchPolicy("latency", max_batch=4, bucket_multiple=16,
                      sort_by_length=False, sync_every=4)


def bucket_length(t: int, multiple: int) -> int:
    """Round t up to the bucket grid (at least one multiple)."""
    return max(multiple, ((t + multiple - 1) // multiple) * multiple)


@dataclass
class FormedBatch:
    """A padded, mask-annotated batch ready for one engine forward."""
    requests: List[InferenceRequest]
    feats: np.ndarray               # (max_batch, T_bucket, F) float32
    lens: np.ndarray                # (max_batch,) int32; 0 for dummy rows

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def frames(self) -> int:
        return int(self.lens.sum())

    @property
    def padded_frames(self) -> int:
        return int(self.feats.shape[0] * self.feats.shape[1])


def form_batches(requests: Sequence[InferenceRequest],
                 policy: BatchPolicy) -> List[FormedBatch]:
    """Group requests into padded batches under the policy.

    Every request appears in exactly one batch; within a batch, rows are
    padded to the longest member's bucketed length.
    """
    if not requests:
        return []
    order = list(requests)
    if policy.sort_by_length:
        # stable: equal lengths keep arrival order
        order.sort(key=lambda r: r.length)
    feat_dim = order[0].feats.shape[1]

    batches: List[FormedBatch] = []
    for lo in range(0, len(order), policy.max_batch):
        group = order[lo:lo + policy.max_batch]
        t_bucket = bucket_length(max(r.length for r in group),
                                 policy.bucket_multiple)
        feats = np.zeros((policy.max_batch, t_bucket, feat_dim), np.float32)
        lens = np.zeros((policy.max_batch,), np.int32)
        for i, r in enumerate(group):
            feats[i, :r.length] = r.feats
            lens[i] = r.length
        batches.append(FormedBatch(group, feats, lens))
    return batches


def padding_efficiency(batches: Sequence[FormedBatch]) -> float:
    """Useful frames / computed frames over a set of formed batches."""
    useful = sum(b.frames for b in batches)
    total = sum(b.padded_frames for b in batches)
    return useful / max(total, 1)
