"""Dynamic bucketing / padding-aware batch formation.

The cost of a padded batch is ``B * T_bucket`` frames of compute for
``sum(lens)`` useful frames; the compile cost is one XLA program per
distinct (B, T_bucket) shape.  The batcher trades the two off:

  * lengths are rounded up to a multiple of ``bucket_multiple`` (few
    distinct T shapes -> few compiles),
  * requests are sorted by length and greedily packed so near-equal
    lengths share a batch (little padding waste),
  * the batch dim is always padded to ``max_batch`` with zero-length
    dummy rows (exactly one (B, T) shape per bucket length; masked rows
    cost compute but no recompilation — the standard serving trade).

Two shipped policies mirror the engine's two consumers: THROUGHPUT packs
big batches for the teacher's offline firehose (paper §3.2.2 target
generation); LATENCY keeps batches small and never waits for more work
than the queue already holds, for online serving.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.request import InferenceRequest


@dataclass(frozen=True)
class BatchPolicy:
    """How the batcher groups pending requests.

    max_batch: rows per formed batch (batch dim is padded to this); for
        the token server this is the continuous batcher's slot count.
    bucket_multiple: time-length rounding quantum (padding/compile trade).
    sort_by_length: pack near-equal lengths together (throughput) or
        preserve arrival order (latency fairness).
    sync_every: the token server's decode-window length — fused device
        steps between host syncs (admit/retire cadence).  Small keeps
        first-token latency low; large amortizes host syncs.
    """
    name: str
    max_batch: int = 16
    bucket_multiple: int = 64
    sort_by_length: bool = True
    sync_every: int = 8


THROUGHPUT = BatchPolicy("throughput", max_batch=16, bucket_multiple=64,
                         sort_by_length=True, sync_every=16)
LATENCY = BatchPolicy("latency", max_batch=4, bucket_multiple=16,
                      sort_by_length=False, sync_every=4)


@dataclass(frozen=True)
class SLOTier:
    """One service tier of the slot-based session core.

    name: the tier id sessions carry (``payload.tier``).
    sync_every: this tier's decode-window length.  The core runs the
        *tightest* window among active tiers — one interactive session
        shortens the window for everyone, keeping its emission latency
        bounded; a firehose-only batch runs long windows that amortize
        host syncs.
    max_batch: cap on slots this tier may hold concurrently (None = up
        to the whole server) — the per-tier analogue of
        ``BatchPolicy.max_batch``.
    preemptible: under interactive pressure this tier's sessions are
        shed (admission deferred) or parked (detached mid-flight, state
        pulled to host, slot re-admitted to waiting work).
    """
    name: str
    sync_every: int = 8
    max_batch: Optional[int] = None
    preemptible: bool = False


INTERACTIVE = SLOTier("interactive", sync_every=2, preemptible=False)
FIREHOSE = SLOTier("firehose", sync_every=16, preemptible=True)


@dataclass(frozen=True)
class TieredPolicy:
    """SLO-aware admission policy over a set of tiers.

    shed_threshold: once non-preemptible (interactive) sessions occupy
        this fraction of slots, preemptible (firehose) admissions stop
        — queued firehose sessions stay pending ("shed"), and
        ``SlotServer._rebalance`` parks active ones when interactive
        sessions are waiting with no free slot.
    """
    tiers: Tuple[SLOTier, ...] = (INTERACTIVE, FIREHOSE)
    shed_threshold: float = 0.75

    def __post_init__(self):
        if not self.tiers:
            raise ValueError("need at least one tier")
        if not 0.0 < self.shed_threshold <= 1.0:
            raise ValueError("shed_threshold must be in (0, 1]")
        names = [t.name for t in self.tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names in {names}")

    def tier(self, name: Optional[str]) -> SLOTier:
        """Look up a tier; None (untagged session) maps to the first
        (default) tier."""
        if name is None:
            return self.tiers[0]
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(f"unknown tier {name!r}; have "
                       f"{[t.name for t in self.tiers]}")


SLO_DEFAULT = TieredPolicy()


def bucket_length(t: int, multiple: int) -> int:
    """Round t up to the bucket grid (at least one multiple)."""
    return max(multiple, ((t + multiple - 1) // multiple) * multiple)


@dataclass
class FormedBatch:
    """A padded, mask-annotated batch ready for one engine forward."""
    requests: List[InferenceRequest]
    feats: np.ndarray               # (max_batch, T_bucket, F) float32
    lens: np.ndarray                # (max_batch,) int32; 0 for dummy rows

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def frames(self) -> int:
        return int(self.lens.sum())

    @property
    def padded_frames(self) -> int:
        return int(self.feats.shape[0] * self.feats.shape[1])


def form_batches(requests: Sequence[InferenceRequest],
                 policy: BatchPolicy) -> List[FormedBatch]:
    """Group requests into padded batches under the policy.

    Every request appears in exactly one batch; within a batch, rows are
    padded to the longest member's bucketed length.
    """
    if not requests:
        return []
    order = list(requests)
    if policy.sort_by_length:
        # stable: equal lengths keep arrival order
        order.sort(key=lambda r: r.length)
    feat_dim = order[0].feats.shape[1]

    batches: List[FormedBatch] = []
    for lo in range(0, len(order), policy.max_batch):
        group = order[lo:lo + policy.max_batch]
        t_bucket = bucket_length(max(r.length for r in group),
                                 policy.bucket_multiple)
        feats = np.zeros((policy.max_batch, t_bucket, feat_dim), np.float32)
        lens = np.zeros((policy.max_batch,), np.int32)
        for i, r in enumerate(group):
            feats[i, :r.length] = r.feats
            lens[i] = r.length
        batches.append(FormedBatch(group, feats, lens))
    return batches


def padding_efficiency(batches) -> float:
    """Useful work / computed work — ONE honest number for every
    serving surface.

    Accepts a sequence of ``FormedBatch`` (the batch path: useful vs
    padded frames), or a slot-server stats dict (``SlotServer.stats``:
    ``useful_units`` vs ``padded_units``, where the denominator already
    counts empty slots, retired-row overshoot and chunk-level dead rows
    of streaming sessions — a parked stream's idle window is waste, not
    invisible).
    """
    if isinstance(batches, dict):
        return batches["useful_units"] / max(batches["padded_units"], 1)
    useful = sum(b.frames for b in batches)
    total = sum(b.padded_frames for b in batches)
    return useful / max(total, 1)
