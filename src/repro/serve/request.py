"""Request/response plumbing for the batched inference engines.

The queue is payload-agnostic: one request = one unit of work — a
(T, F) feature matrix for the acoustic model, a TokenRequest for the
token-LM decode surface.  It is deliberately simple and
single-threaded: the engine drains it in arrival order, the batcher
regroups for padding efficiency (or the continuous batcher admits the
queue head into freed decode slots mid-flight), and completion order is
therefore *not* arrival order — results are keyed by request id and the
queue tracks completeness so callers can assert nothing was dropped.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

import numpy as np


@dataclass
class InferenceRequest:
    """A single unit of work awaiting inference.

    ``payload`` is engine-defined (the feature engine stores a (T, F)
    float matrix; the token server stores its TokenRequest record).
    ``meta`` rides along untouched (e.g. the corpus utterance id for
    LogitStore bookkeeping).
    """
    rid: int
    payload: Any
    meta: dict = field(default_factory=dict)

    @property
    def feats(self) -> np.ndarray:
        """Feature-engine view of the payload."""
        return self.payload

    @property
    def length(self) -> int:
        return int(self.payload.shape[0])


@dataclass
class CompletedRequest:
    """Result record; ``result`` is engine-defined — the feature engine
    stores a (vals, idx) top-k pair, the token server its finished
    TokenRequest."""
    rid: int
    result: Any
    meta: dict = field(default_factory=dict)

    @property
    def vals(self) -> np.ndarray:          # (T, k) shifted logit values
        return self.result[0]

    @property
    def idx(self) -> np.ndarray:           # (T, k) int32 vocab indices
        return self.result[1]


class RequestQueue:
    """FIFO of pending requests + completion ledger.

    submit() assigns monotonically increasing rids; the engine pops
    pending work, fulfils it in any order, and ``complete()`` records
    results.  ``drained`` is True only when every submitted rid has a
    result — the completeness invariant the tests pin down.
    """

    # diagnostic ring: recent completion order only — bounded so the
    # queue's memory stays flat over engine uptime
    ORDER_RING = 4096

    def __init__(self):
        self._next_rid = 0
        self._pending: deque[InferenceRequest] = deque()
        self._in_flight: Dict[int, InferenceRequest] = {}
        self._done: Dict[int, CompletedRequest] = {}
        self._completion_order: deque[int] = deque(maxlen=self.ORDER_RING)

    def submit(self, payload: Any, meta: Optional[dict] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(
            InferenceRequest(rid, payload, dict(meta or {})))
        return rid

    def pop_pending(self, max_n: Optional[int] = None
                    ) -> List[InferenceRequest]:
        """Move up to max_n requests (all, if None) into the in-flight set."""
        out = []
        while self._pending and (max_n is None or len(out) < max_n):
            req = self._pending.popleft()
            self._in_flight[req.rid] = req
            out.append(req)
        return out

    def peek_pending(self) -> List[InferenceRequest]:
        """Read-only view of the pending queue in arrival order — the
        admission controller's pressure probe (no state change)."""
        return list(self._pending)

    def pop_pending_where(self, pred, max_n: Optional[int] = None
                          ) -> List[InferenceRequest]:
        """Move up to max_n requests satisfying ``pred`` into the
        in-flight set, scanning in arrival order.  Non-matching requests
        stay pending *in place* (order preserved) — the tier-aware
        admission hook: a shed firehose session is deferred, not
        dropped, and doesn't block the interactive session behind it."""
        out: List[InferenceRequest] = []
        keep: List[InferenceRequest] = []
        while self._pending:
            req = self._pending.popleft()
            if (max_n is None or len(out) < max_n) and pred(req):
                self._in_flight[req.rid] = req
                out.append(req)
            else:
                keep.append(req)
        self._pending.extend(keep)
        return out

    def complete(self, rid: int, result: Any):
        req = self._in_flight.pop(rid)
        self._done[rid] = CompletedRequest(rid, result, req.meta)
        self._completion_order.append(rid)

    def pop_completed(self) -> Dict[int, CompletedRequest]:
        """Hand over (and evict) every completed result.  The ledger must
        not grow with engine uptime — results live with the caller, not
        the queue (the firehose writes them straight to the LogitStore)."""
        done, self._done = self._done, {}
        return done

    def discard_pending(self) -> int:
        """Drop every pending request (recovery hygiene: a consumer
        starting a fresh self-contained drain must not inherit another
        call's queued work).  Returns the number discarded."""
        n = len(self._pending)
        self._pending.clear()
        return n

    def requeue(self, rids: Iterable[int]):
        """Move specific in-flight requests back to the head of the
        queue in rid (arrival) order — the round-forming hook: an engine
        that popped everything but can only serve a subset this round
        returns the rest without losing their place."""
        back = sorted((self._in_flight.pop(r) for r in rids),
                      key=lambda r: r.rid)
        self._pending.extendleft(reversed(back))

    def restore_in_flight(self):
        """Put popped-but-unfulfilled requests back at the head of the
        queue (rid order) — the engine's failure-recovery hook, so a
        forward error mid-drain never strands its sibling requests."""
        self.requeue(list(self._in_flight))

    @property
    def n_submitted(self) -> int:
        return self._next_rid

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def n_in_flight(self) -> int:
        return len(self._in_flight)

    @property
    def n_completed(self) -> int:
        return len(self._done)

    @property
    def drained(self) -> bool:
        return not self._pending and not self._in_flight

    @property
    def completion_order(self) -> List[int]:
        return list(self._completion_order)
