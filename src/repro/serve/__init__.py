"""Unified batched streaming-inference engine (paper §3.2.2 framing:
teacher target generation and online serving are the same workload under
different batching policies).

  StreamingEngine — bucketed batch inference + per-stream chunked
      streaming with carried LSTM state and a double-buffered feed,
      top-k logit emission.
  TokenServer — slot-based continuous batcher for the token-LM serving
      surface (per-row cache positions, mid-flight admit/retire,
      chunked emission sync; launch/serve.py, examples/serve_lm.py).
      With ``paging=PagedCacheConfig(...)`` the KV cache is a shared
      page pool with prefix caching (serve/paging.PageAllocator);
      ``submit(..., sampling=SamplingParams(...))`` enables per-request
      temperature / top-k / top-p sampling.
  RoundTokenServer — the legacy generation-round engine (lockstep
      baseline for parity tests and benchmarks).
  BatchPolicy / THROUGHPUT / LATENCY — batch-formation policies.
"""
from repro.models.paging import PagedCacheConfig
from repro.serve.batcher import (LATENCY, THROUGHPUT, BatchPolicy,
                                 FormedBatch, bucket_length, form_batches,
                                 padding_efficiency)
from repro.serve.decode import RoundTokenServer, TokenRequest, TokenServer
from repro.serve.engine import (StreamingEngine, StreamFeed,
                                make_topk_emitter)
from repro.serve.paging import PageAllocator, block_hashes
from repro.serve.request import (CompletedRequest, InferenceRequest,
                                 RequestQueue)
from repro.serve.sampling import GREEDY, SamplingParams

__all__ = [
    "BatchPolicy", "THROUGHPUT", "LATENCY", "FormedBatch", "bucket_length",
    "form_batches", "padding_efficiency", "StreamingEngine", "StreamFeed",
    "make_topk_emitter", "TokenServer", "RoundTokenServer", "TokenRequest",
    "InferenceRequest", "CompletedRequest", "RequestQueue",
    "PagedCacheConfig", "PageAllocator", "block_hashes",
    "SamplingParams", "GREEDY",
]
