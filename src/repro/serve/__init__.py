"""Unified batched streaming-inference engine (paper §3.2.2 framing:
teacher target generation and online serving are the same workload under
different batching policies).

  StreamingEngine — bucketed batch inference + per-stream chunked
      streaming with carried LSTM state, top-k logit emission.
  TokenServer — generation-round batched decode for the token-LM
      serving surface (launch/serve.py, examples/serve_lm.py).
  BatchPolicy / THROUGHPUT / LATENCY — batch-formation policies.
"""
from repro.serve.batcher import (LATENCY, THROUGHPUT, BatchPolicy,
                                 FormedBatch, bucket_length, form_batches,
                                 padding_efficiency)
from repro.serve.decode import TokenRequest, TokenServer
from repro.serve.engine import StreamingEngine, make_topk_emitter
from repro.serve.request import (CompletedRequest, InferenceRequest,
                                 RequestQueue)

__all__ = [
    "BatchPolicy", "THROUGHPUT", "LATENCY", "FormedBatch", "bucket_length",
    "form_batches", "padding_efficiency", "StreamingEngine",
    "make_topk_emitter", "TokenServer", "TokenRequest", "InferenceRequest",
    "CompletedRequest", "RequestQueue",
]
