"""Unified batched streaming-inference engine (paper §3.2.2 framing:
teacher target generation and online serving are the same workload under
different batching policies).

Both serving surfaces are session types over ONE slot-based core
(``SlotServer`` in serve/slots.py: slot admit/retire, mid-flight
admission, device-side emission windows with one host sync per
``sync_every`` steps, failure recovery, honest utilization stats):

  TokenServer — token-LM sessions: per-row cache positions, ragged
      prefill, EOS retirement (launch/serve.py, examples/serve_lm.py).
      With ``paging=PagedCacheConfig(...)`` the KV cache is a shared
      page pool with prefix caching (serve/paging.PageAllocator);
      ``submit(..., sampling=SamplingParams(...))`` enables per-request
      temperature / top-k / top-p sampling.
  StreamServer — streaming-AM sessions: long-running audio streams
      with per-row recurrent state, ragged chunk consumption, and
      mid-flight detach/reattach (bitwise state round-trip).
  SLOTier / TieredPolicy / INTERACTIVE / FIREHOSE — SLO tiers with
      per-tier sync_every / max_batch and admission control that sheds
      or parks firehose streams under interactive pressure.

Lockstep baselines (parity tests and benchmarks):
  StreamingEngine — bucketed batch inference + per-stream chunked
      streaming with carried state and a double-buffered feed.
  RoundTokenServer — the legacy generation-round engine.
  BatchPolicy / THROUGHPUT / LATENCY — batch-formation policies.
"""
from repro.models.paging import PagedCacheConfig
from repro.serve.batcher import (FIREHOSE, INTERACTIVE, LATENCY, SLO_DEFAULT,
                                 THROUGHPUT, BatchPolicy, FormedBatch,
                                 SLOTier, TieredPolicy, bucket_length,
                                 form_batches, padding_efficiency)
from repro.serve.decode import RoundTokenServer, TokenRequest, TokenServer
from repro.serve.engine import (StreamingEngine, StreamFeed,
                                make_topk_emitter)
from repro.serve.paging import PageAllocator, block_hashes
from repro.serve.request import (CompletedRequest, InferenceRequest,
                                 RequestQueue)
from repro.serve.sampling import GREEDY, SamplingParams
from repro.serve.slots import SlotServer
from repro.serve.stream import StreamServer, StreamSession

__all__ = [
    "BatchPolicy", "THROUGHPUT", "LATENCY", "FormedBatch", "bucket_length",
    "form_batches", "padding_efficiency", "SLOTier", "TieredPolicy",
    "SLO_DEFAULT", "INTERACTIVE", "FIREHOSE", "SlotServer",
    "StreamingEngine", "StreamFeed", "StreamServer", "StreamSession",
    "make_topk_emitter", "TokenServer", "RoundTokenServer", "TokenRequest",
    "InferenceRequest", "CompletedRequest", "RequestQueue",
    "PagedCacheConfig", "PageAllocator", "block_hashes",
    "SamplingParams", "GREEDY",
]
