"""Slot-based batched decode engine for the token-LM serving surface.

Every assigned arch exposes the uniform ``init_cache``/``decode_step``
surface, but the cache keeps a *single scalar position shared by all
batch rows* — so rows of one batch must advance in lockstep.  The seed
``BatchedServer`` prefilled one slot at a time through the shared decode
step, silently appending garbage KV entries to every other active slot's
cache.  This engine replaces that with **generation rounds** that are
correct under the shared position:

  * requests are grouped by *exactly equal prompt length* (the batcher's
    bucketing, degenerate bucket size 1), up to ``policy.max_batch`` rows;
  * a round prefills all its rows together token-by-token (each row feeds
    its own prompt token — no cross-row pollution), then decodes batched
    until every row hit its ``max_new``;
  * rows that finish early keep stepping on their own cache (harmless:
    rows only ever read their own cache rows) with outputs discarded.

Under the LATENCY policy rounds are small and start as soon as work
exists; THROUGHPUT packs full rounds.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_serve_step
from repro.models import build_model
from repro.serve.batcher import LATENCY, BatchPolicy
from repro.serve.request import RequestQueue


@dataclass
class TokenRequest:
    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class TokenServer:
    """Generation-round batched decoding over the uniform decode surface.

    Request bookkeeping lives in the payload-agnostic
    ``serve.request.RequestQueue`` (the same FIFO + completion ledger
    the feature engine uses); this class only forms rounds and drives
    the decode step."""

    def __init__(self, cfg, params, *, policy: BatchPolicy = LATENCY,
                 max_seq: int = 256, cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.policy = policy
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.b = policy.max_batch
        self.serve = jax.jit(make_serve_step(self.model, cfg))
        self.queue = RequestQueue()

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.shape[0] < 1:
            raise ValueError(
                f"expected a non-empty 1-D token prompt, got shape "
                f"{prompt.shape}")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if prompt.shape[0] + max_new - 1 > self.max_seq:
            # a round writes plen prefill entries + (max_new - 1) decode
            # entries (the last token is emitted without a step); past
            # max_seq the shared cache position wraps its ring buffer
            # silently (attention_decode: slot = pos % slots) — refuse
            # rather than return corrupted output
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new ({max_new}) needs "
                f"{prompt.shape[0] + max_new - 1} cache entries > max_seq "
                f"({self.max_seq})")
        req = TokenRequest(-1, prompt, max_new)
        req.rid = self.queue.submit(req)
        return req.rid

    def _next_round(self) -> List[TokenRequest]:
        """Pop up to max_batch pending requests of one equal prompt
        length (arrival order decides which length goes first); the rest
        go back to the queue head via its requeue hook."""
        reqs = self.queue.pop_pending()
        if not reqs:
            return []
        length = reqs[0].payload.prompt.shape[0]
        round_, keep = [], []
        for r in reqs:
            if (r.payload.prompt.shape[0] == length
                    and len(round_) < self.b):
                round_.append(r.payload)
            else:
                keep.append(r.rid)
        self.queue.requeue(keep)
        return round_

    def _run_round(self, round_: List[TokenRequest]):
        plen = round_[0].prompt.shape[0]
        cache = self.model.init_cache(self.b, self.max_seq, self.cache_dtype)
        prompts = np.zeros((self.b, plen), np.int32)
        for i, r in enumerate(round_):
            prompts[i] = r.prompt
        prompts = jnp.asarray(prompts)
        # batched prefill through the decode path: each row feeds its own
        # prompt token, so caches stay row-pure
        for t in range(plen):
            nxt, _, cache = self.serve(self.params, cache,
                                       prompts[:, t:t + 1])
        tokens = nxt
        for _ in range(max(r.max_new for r in round_)):
            host_tok = np.asarray(tokens)   # one device->host sync per step
            for i, r in enumerate(round_):
                if not r.done:
                    r.out.append(int(host_tok[i, 0]))
                    if len(r.out) >= r.max_new:
                        r.done = True
            if all(r.done for r in round_):
                break
            nxt, _, cache = self.serve(self.params, cache, tokens)
            tokens = nxt
        for r in round_:
            r.done = True
            self.queue.complete(r.rid, r)

    def drain(self) -> Dict[int, TokenRequest]:
        """Run rounds until no pending work remains.  Returns (and
        evicts) the requests completed since the last drain — like
        StreamingEngine.run, the server's ledger must not grow with
        uptime."""
        while self.queue.n_pending:
            round_ = self._next_round()
            if not round_:
                break
            try:
                self._run_round(round_)
            except BaseException:
                # a failed step must not strand the round: reset partial
                # outputs and put the requests back for retry (same
                # invariant as StreamingEngine.run / restore_in_flight)
                for r in round_:
                    r.out.clear()
                    r.done = False
                self.queue.restore_in_flight()
                raise
        return {rid: cr.result
                for rid, cr in self.queue.pop_completed().items()}
