"""Continuous-batching decode engine for the token-LM serving surface.

Every assigned arch exposes the uniform ``init_cache``/``decode_step``
surface; with ``init_cache(per_row=True)`` the cache carries one int32
position *per batch row*, so rows of one batch may sit at different
sequence positions.  ``TokenServer`` exploits that as a **slot-based
continuous batcher** (the vLLM-style serving loop, scaled to this repo):

  * each of ``policy.max_batch`` device slots holds one in-flight
    request; a newly admitted request's row is zeroed
    (``model.reset_cache_rows``) and then consumes its own prompt
    token-by-token through the decode path at its own position — ragged
    batched prefill, no equal-length grouping, no head-of-line blocking;
  * rows retire individually on their own ``max_new`` (or ``eos_id``)
    and their slot is re-admitted from the queue mid-flight, while the
    other rows keep decoding;
  * the jitted step is a fused ``sync_every``-step ``lax.scan`` whose
    per-step emissions land in a device-side buffer — the host syncs
    **once per window**, not once per token (O(steps/K) transfers), and
    does all admit/retire bookkeeping at that cadence.

Rows are *row-pure* (a row only ever reads its own cache row), so a
retired slot overshooting until the next sync is waste, not corruption —
the host discards tokens past the request's retirement point and the
cost accounting (``stats["active_slot_steps"]``) excludes them.

With ``paging=PagedCacheConfig(...)`` the K/V cache becomes a pool of
fixed-size pages shared by all slots (vLLM-style): each admission rents
exactly ``ceil((plen + max_new - 1) / page_size)`` pages from a
host-side free list (``serve.paging.PageAllocator``), retirement
returns them, and rows with a common prompt prefix share read-only
prefix pages via refcounted content hashes.  Memory then scales with
*tokens in flight* instead of ``slots x max_seq``, and a single prompt
may be longer than an equal-budget contiguous cache would allow.
Admission is FIFO no-skip: if the head request's pages don't fit, it
(and everything behind it) waits — no starvation of big requests.

Per-request sampling (``submit(..., sampling=SamplingParams(...))``)
runs through a second jitted window that draws Gumbel-max samples
inside the fused scan — still one host sync per K steps.  Greedy
requests keep the original bitwise-argmax window.

``RoundTokenServer`` is the previous engine — generation rounds of
exactly equal prompt length over the shared-scalar-position cache.  It
is kept as the lockstep baseline: the continuous engine must match it
token-for-token on equal-length workloads (pinned in
tests/test_serve_engine.py) and beat it on ragged ones
(benchmarks/serve_bench.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_serve_step
from repro.models import build_model
from repro.models.paging import prefix_sharing_supported
from repro.serve.batcher import LATENCY, BatchPolicy
from repro.serve.paging import PageAllocator, block_hashes
from repro.serve.request import RequestQueue
from repro.serve.sampling import SamplingParams
from repro.serve.slots import SlotServer


@dataclass
class TokenRequest:
    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    finished_sync: int = -1         # pump index at completion (latency
                                    # accounting; -1 while in flight)
    sampling: Optional[SamplingParams] = None   # None = greedy
    tier: Optional[str] = None      # SLO tier name (None = default tier)


def _validate_submit(prompt, max_new, max_seq, paging=None):
    prompt = np.asarray(prompt, np.int32)
    if prompt.ndim != 1 or prompt.shape[0] < 1:
        raise ValueError(
            f"expected a non-empty 1-D token prompt, got shape "
            f"{prompt.shape}")
    if max_new < 1:
        raise ValueError("max_new must be >= 1")
    cap = prompt.shape[0] + max_new - 1
    if paging is not None:
        # paged capacity: the request needs ceil(cap / page_size) pages
        # and a block-table row wide enough to hold them — max_seq no
        # longer bounds the prompt, the page budget does
        blocks = -(-cap // paging.page_size)
        if cap > paging.resolved_max_ctx or blocks > paging.n_pages:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new ({max_new}) needs "
                f"{blocks} pages of {paging.page_size} (ctx {cap}) > page "
                f"budget (n_pages {paging.n_pages}, max_ctx "
                f"{paging.resolved_max_ctx})")
    elif cap > max_seq:
        # a request consumes plen prefill entries + (max_new - 1) decode
        # entries (the last token is emitted without being fed back);
        # past max_seq the cache position wraps its ring buffer silently
        # (attention_decode: slot = pos % slots) — refuse rather than
        # return corrupted output
        raise ValueError(
            f"prompt ({prompt.shape[0]}) + max_new ({max_new}) needs "
            f"{prompt.shape[0] + max_new - 1} cache entries > max_seq "
            f"({max_seq})")
    return prompt


class TokenServer(SlotServer):
    """Slot-based continuous batcher over the per-row decode surface —
    the token-decode session type of the ``serve.slots.SlotServer``
    core.

    Request bookkeeping lives in the payload-agnostic
    ``serve.request.RequestQueue``; the base class owns the slot
    lifecycle (admission, the windowed pump, retirement, abort
    recovery); this class owns the device side: the per-row KV cache,
    the fused K-step decode window, and token-level consumption.

    ``pump()`` runs one sync window and returns the requests it
    completed; ``drain()`` pumps until the queue is empty.  ``policy``
    sets the slot count (``max_batch``) and the default sync cadence
    (``sync_every`` — small under LATENCY for fast first-token
    visibility, larger under THROUGHPUT to amortize host syncs).
    ``tiers=TieredPolicy(...)`` makes the window length and admission
    SLO-aware (``submit(..., tier="interactive")``).
    """

    def __init__(self, cfg, params, *, policy: BatchPolicy = LATENCY,
                 max_seq: int = 256, cache_dtype=jnp.bfloat16,
                 sync_every: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 paging=None, prefix_cache: bool = True,
                 decode_kernel: bool = False, tiers=None):
        if cfg.family == "lstm_am":
            raise ValueError("TokenServer is the token-LM decode surface; "
                             "acoustic models go through StreamingEngine")
        self.cfg = cfg
        self.paging = paging
        # decode_kernel: fused attention tail (kernels/decode_attention)
        # + fused sampler (kernels/topk_sample) inside the jitted window.
        # Greedy output stays bitwise identical; sampled requests follow
        # the fused sampler's truncated-nucleus semantics when top_k fits
        # its candidate set, and fall back to the full-vocab argsort
        # sampler (mixed window) when it doesn't.
        self.decode_kernel = decode_kernel
        self.model = build_model(cfg, paging=paging,
                                 decode_kernel=decode_kernel)
        self.params = params
        self.policy = policy
        # with paging the context bound is the page budget, not max_seq
        self.max_seq = (paging.resolved_max_ctx if paging is not None
                        else max_seq)
        self.cache_dtype = cache_dtype
        super().__init__(policy.max_batch,
                         sync_every=int(sync_every if sync_every is not None
                                        else policy.sync_every),
                         tiers=tiers)
        self.eos_id = eos_id
        self.serve = jax.jit(self._make_window(self.sync_every))
        self._serve_sample = None       # jitted lazily on first sampled req
        self._windows = {}              # (k, mode) -> jit, tiered windows
        self._reset = jax.jit(self.model.reset_cache_rows)
        # device state (lazily built on first pump)
        self._cache = None
        self._tok = None
        self._prompts_d = None          # device-resident prompt buffer /
        self._plens_d = None            # lens, refreshed on admission only
        # host-side slot mirrors
        self._pos = np.zeros((self.b,), np.int64)       # tokens consumed
        self._prompts = np.zeros((self.b, self.max_seq), np.int32)
        self._plens = np.zeros((self.b,), np.int32)
        # per-row sampling knobs (greedy defaults; refreshed on admission)
        self._temp = np.zeros((self.b,), np.float32)
        self._topk = np.zeros((self.b,), np.int32)
        self._topp = np.ones((self.b,), np.float32)
        self._seed = np.zeros((self.b,), np.int32)
        # paged-mode host state: block table mirror + per-slot page leases
        if paging is not None:
            self.alloc = PageAllocator(
                paging.n_pages, paging.page_size,
                prefix_cache=prefix_cache and prefix_sharing_supported(cfg))
            self._tables = np.zeros((self.b, paging.max_blocks), np.int32)
            self._caps = np.zeros((self.b,), np.int32)
            self._tables_dirty = False
            self._blocks: List[Optional[List[int]]] = [None] * self.b
            self._hashes: List[Optional[List[int]]] = [None] * self.b
            self._nshared = [0] * self.b
        else:
            self.alloc = None
        self.stats["tokens_out"] = 0

    # ------------------------------------------------------- jitted window

    def _make_window(self, k: int, mode: str = "greedy"):
        """K fused decode steps: each row feeds its own prompt token while
        ``pos < plen`` (ragged prefill) and its last sampled token after;
        emissions accumulate on device, one host sync per window.

        ``mode`` picks the per-step sampler: ``greedy`` (bitwise argmax),
        ``sample`` (per-row knobs), or ``mixed`` (fused sampler with the
        argsort fallback for rows whose top_k exceeds the kernel's
        candidate set)."""
        sample = mode != "greedy"
        serve_step = make_serve_step(self.model, self.cfg,
                                     greedy=not sample,
                                     use_kernel=self.decode_kernel,
                                     wide_fallback=mode == "mixed")

        def window(params, cache, tok, prompts, plens, samp=None):
            pmax = prompts.shape[1]

            def body(carry, _):
                cache, tok = carry
                pos = cache["pos"]                       # (B,) per-row
                ptok = jnp.take_along_axis(
                    prompts, jnp.minimum(pos, pmax - 1)[:, None], axis=1)
                feed = jnp.where((pos < plens)[:, None], ptok, tok)
                if sample:
                    nxt, _, cache = serve_step(params, cache, feed, samp)
                else:
                    nxt, _, cache = serve_step(params, cache, feed)
                return (cache, nxt), nxt[:, 0]

            (cache, tok), emitted = jax.lax.scan(body, (cache, tok), None,
                                                 length=k)
            return cache, tok, emitted                   # emitted (k, B)
        if sample:
            return window

        def greedy_window(params, cache, tok, prompts, plens):
            return window(params, cache, tok, prompts, plens)
        return greedy_window

    def _get_window(self, k: int, mode: str):
        """Resolve the jitted window for this pump.  The default-length
        greedy/sample windows keep their dedicated attributes (``serve``
        is the failure-injection seam the tests patch); tiered lengths
        and the mixed sampler live in a small cache — one compile per
        distinct (k, mode)."""
        if k == self.sync_every and mode == "greedy":
            return self.serve
        if k == self.sync_every and mode == "sample":
            if self._serve_sample is None:
                self._serve_sample = jax.jit(self._make_window(k, mode))
            return self._serve_sample
        key = (k, mode)
        if key not in self._windows:
            self._windows[key] = jax.jit(self._make_window(k, mode))
        return self._windows[key]

    # ------------------------------------------------------------- submit

    def submit(self, prompt: np.ndarray, max_new: int = 16,
               sampling: Optional[SamplingParams] = None,
               tier: Optional[str] = None) -> int:
        prompt = _validate_submit(prompt, max_new, self.max_seq,
                                  paging=self.paging)
        if self.tiers is not None:
            self.tiers.tier(tier)       # unknown tier names fail loudly
        req = TokenRequest(-1, prompt, max_new, sampling=sampling,
                           tier=tier)
        req.rid = self.queue.submit(req)
        return req.rid

    # ---------------------------------------------------------- slot hooks

    def _ensure_device_state(self):
        if self._cache is None:
            cache = self.model.init_cache(
                self.b, self.max_seq, self.cache_dtype, per_row=True)
            # settle carry dtypes: some decode states come back in compute
            # dtype (e.g. recurrent conv tails, f32) while init_cache laid
            # them out in cache_dtype — a lax.scan carry must be
            # dtype-stable, so cast the (all-zero) init cache to the
            # post-step dtypes up front.  Values are unchanged (zeros),
            # keeping lockstep parity with the round engine bitwise.
            tok0 = jnp.zeros((self.b, 1), jnp.int32)
            settled = jax.eval_shape(self.model.decode_step, self.params,
                                     cache, tok0)[1]
            self._cache = jax.tree_util.tree_map(
                lambda a, s: a.astype(s.dtype), cache, settled)
            self._tok = jnp.zeros((self.b, 1), jnp.int32)

    def _admit_slot(self, slot: int, req) -> bool:
        """Install one request's host mirrors.  Paged mode additionally
        rents every page the request can ever need up front (no
        mid-flight OOM), reusing published prefix pages when the leading
        prompt blocks hash-match; False (doesn't fit) makes the base
        class requeue it and everything behind it — FIFO no-skip, no
        starvation of big requests."""
        r = req.payload
        start = 0
        if self.paging is not None:
            start = self._admit_pages(slot, r)
            if start < 0:
                return False
        self._pos[slot] = start
        self._prompts[slot] = 0
        self._prompts[slot, :r.prompt.shape[0]] = r.prompt
        self._plens[slot] = r.prompt.shape[0]
        s = r.sampling or SamplingParams()
        self._temp[slot] = s.temperature
        self._topk[slot] = s.top_k
        self._topp[slot] = s.top_p
        self._seed[slot] = np.int32(np.uint32(s.seed & 0xFFFFFFFF))
        return True

    def _admit_pages(self, slot, r) -> int:
        """Lease pages for one request.  Returns the row's start
        position (``cached_len`` — past the shared prefix pages) or -1
        if the pool can't cover it right now."""
        ps = self.paging.page_size
        plen = r.prompt.shape[0]
        cap = plen + r.max_new - 1
        total = -(-cap // ps)
        hashes = block_hashes(r.prompt, ps)
        n_hit = self.alloc.peek_prefix(hashes)
        if not self.alloc.can_alloc(total - n_hit):
            return -1
        shared = self.alloc.acquire_prefix(hashes[:n_hit])
        self.alloc.note_miss(len(hashes) - n_hit)
        blocks = shared + self.alloc.alloc(total - n_hit)
        self._blocks[slot] = blocks
        self._hashes[slot] = hashes
        self._nshared[slot] = n_hit
        self._tables[slot] = 0
        self._tables[slot, :len(blocks)] = blocks
        self._caps[slot] = cap
        self._tables_dirty = True
        # the row starts past the cached prefix; its first write lands in
        # block n_hit, so shared pages are never written.  block_hashes
        # guarantees n_hit * ps <= plen - 1: at least one prompt token is
        # always fed, so the row always produces a real first logit.
        return n_hit * ps

    def _reset_payload(self, payload):
        payload.out.clear()
        payload.done = False

    def _drop_state(self):
        """Abort hygiene (same invariant as StreamingEngine.run /
        restore_in_flight): device state dropped, host mirrors zeroed."""
        self._plens[:] = 0
        self._pos[:] = 0
        self._cache = None
        self._tok = None
        self._prompts_d = None
        self._plens_d = None
        if self.paging is not None:
            # device pools were just dropped, so every cached page's
            # contents are gone too — full allocator reset, not release
            # (a released published page would advertise stale contents)
            self.alloc.reset()
            self._tables[:] = 0
            self._caps[:] = 0
            self._tables_dirty = False
            self._blocks = [None] * self.b
            self._hashes = [None] * self.b
            self._nshared = [0] * self.b

    def _pre_window(self, admitted: List[int]):
        self._ensure_device_state()
        if self.paging is not None and self._tables_dirty:
            # block-table changes (admission leases, retirement
            # returns) reach the device as a fresh pages dict; rows
            # whose table row is all-zero point at the trash page
            self._cache = dict(self._cache)
            self._cache["pages"] = {
                "tables": jnp.asarray(self._tables),
                "caps": jnp.asarray(self._caps)}
            self._tables_dirty = False
        if admitted:
            mask = np.zeros((self.b,), bool)
            mask[admitted] = True
            if self.paging is not None:
                # prefix-cache hits start past the shared pages
                self._cache = self._reset(
                    self._cache, jnp.asarray(mask),
                    jnp.asarray(self._pos.astype(np.int32)))
            else:
                self._cache = self._reset(self._cache,
                                          jnp.asarray(mask))
            # prompts/plens only change on admission: refresh the
            # device copies here, not once per window (a retired
            # slot's stale device plen is harmless — the row is
            # garbage until its next admission re-uploads)
            self._prompts_d = jnp.asarray(self._prompts)
            self._plens_d = jnp.asarray(self._plens)

    def _window_mode(self) -> str:
        """greedy | sample | mixed, from the rows actually in flight.
        ``mixed`` (fused sampler + per-row argsort fallback) only when a
        fused server holds a row whose top_k its candidate set can't
        honor — greedy-only windows stay on the bitwise-argmax jit."""
        sampled = [req.payload.sampling for req in self._slots
                   if req is not None and req.payload.sampling is not None
                   and not req.payload.sampling.greedy]
        if not sampled:
            return "greedy"
        if self.decode_kernel:
            from repro.kernels.topk_sample import K_CAP_DEFAULT
            if any(s.top_k <= 0 or s.top_k > K_CAP_DEFAULT
                   for s in sampled):
                return "mixed"
        return "sample"

    def _run_window(self, k: int) -> np.ndarray:
        mode = self._window_mode()
        win = self._get_window(k, mode)
        if mode == "greedy":
            cache, tok, emitted = win(self.params, self._cache, self._tok,
                                      self._prompts_d, self._plens_d)
        else:
            samp = {"temperature": jnp.asarray(self._temp),
                    "top_k": jnp.asarray(self._topk),
                    "top_p": jnp.asarray(self._topp),
                    "seed": jnp.asarray(self._seed)}
            cache, tok, emitted = win(self.params, self._cache, self._tok,
                                      self._prompts_d, self._plens_d, samp)
        emitted = np.asarray(emitted)        # THE host sync of this window
        self._cache, self._tok = cache, tok
        return emitted

    def _consume(self, i: int, req, emitted, k: int):
        p0 = int(self._pos[i])
        self._pos[i] += k
        r = req.payload
        plen = int(self._plens[i])
        live = 0
        for j in range(k):
            if r.done:          # overshoot past retirement: excluded
                break           # from cost, tokens discarded
            live += 1
            g = p0 + j - (plen - 1)     # generated-token index
            if g < 0:                   # still consuming the prompt
                continue
            t = int(emitted[j, i])
            r.out.append(t)
            self.stats["tokens_out"] += 1
            if (self.eos_id is not None and t == self.eos_id) \
                    or len(r.out) >= r.max_new:
                r.done = True
        # useful == live: prefill consumption and kept generations are
        # both requested work; only post-retirement overshoot is waste
        return live, live

    def _retire_slot(self, i: int):
        self._plens[i] = 0
        self._temp[i] = 0.0          # stale rows back to cheap argmax
        if self.paging is not None:
            self._release_slot(i)

    def _release_slot(self, i):
        """Return a retired slot's pages.  Freshly written prompt blocks
        are published first so later requests with the same prefix can
        share them; the trash-page table row makes the retired row's
        overshoot writes land harmlessly in page 0."""
        blocks, hashes = self._blocks[i], self._hashes[i]
        for j in range(self._nshared[i], len(hashes)):
            self.alloc.publish(blocks[j], hashes[j])
        self.alloc.release(blocks)
        self._blocks[i] = None
        self._hashes[i] = None
        self._nshared[i] = 0
        self._tables[i] = 0
        self._caps[i] = 0
        self._tables_dirty = True

    def slot_positions(self):
        """(host, device) consumed-token positions for debugging and the
        slot-invariant test; device is None before the first pump."""
        host = self._pos.copy()
        dev = (np.asarray(self._cache["pos"]) if self._cache is not None
               else None)
        return host, dev

    def paging_stats(self):
        """Allocator counters + current occupancy (paged mode only)."""
        if self.alloc is None:
            return None
        s = dict(self.alloc.stats)
        s["free"] = self.alloc.free_pages()
        s["live"] = self.alloc.live_pages()
        return s


class RoundTokenServer:
    """Generation-round batched decoding over the *scalar*-position cache
    — the pre-continuous-batching engine, kept as the lockstep baseline
    (parity tests, benchmarks/serve_bench.py).

    Rounds group requests by exactly equal prompt length, prefill
    token-by-token in lockstep, and decode until every row hit its
    ``max_new`` — early-finished rows burn steps until the slowest row
    completes, and each decode step pays one device→host sync.  The
    continuous ``TokenServer`` removes all three costs."""

    def __init__(self, cfg, params, *, policy: BatchPolicy = LATENCY,
                 max_seq: int = 256, cache_dtype=jnp.bfloat16,
                 eos_id: Optional[int] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.policy = policy
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.b = policy.max_batch
        self.eos_id = eos_id
        self.serve = jax.jit(make_serve_step(self.model, cfg))
        self.queue = RequestQueue()

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        prompt = _validate_submit(prompt, max_new, self.max_seq)
        req = TokenRequest(-1, prompt, max_new)
        req.rid = self.queue.submit(req)
        return req.rid

    def _next_round(self) -> List[TokenRequest]:
        """Pop up to max_batch pending requests of one equal prompt
        length (arrival order decides which length goes first); the rest
        go back to the queue head via its requeue hook."""
        reqs = self.queue.pop_pending()
        if not reqs:
            return []
        length = reqs[0].payload.prompt.shape[0]
        round_, keep = [], []
        for r in reqs:
            if (r.payload.prompt.shape[0] == length
                    and len(round_) < self.b):
                round_.append(r.payload)
            else:
                keep.append(r.rid)
        self.queue.requeue(keep)
        return round_

    def _run_round(self, round_: List[TokenRequest]):
        plen = round_[0].prompt.shape[0]
        cache = self.model.init_cache(self.b, self.max_seq, self.cache_dtype)
        prompts = np.zeros((self.b, plen), np.int32)
        for i, r in enumerate(round_):
            prompts[i] = r.prompt
        prompts = jnp.asarray(prompts)
        # batched prefill through the decode path: each row feeds its own
        # prompt token, so caches stay row-pure
        for t in range(plen):
            nxt, _, cache = self.serve(self.params, cache,
                                       prompts[:, t:t + 1])
        tokens = nxt
        for _ in range(max(r.max_new for r in round_)):
            host_tok = np.asarray(tokens)   # one device->host sync per step
            for i, r in enumerate(round_):
                if not r.done:
                    t = int(host_tok[i, 0])
                    r.out.append(t)
                    if (self.eos_id is not None and t == self.eos_id) \
                            or len(r.out) >= r.max_new:
                        r.done = True
            if all(r.done for r in round_):
                break
            nxt, _, cache = self.serve(self.params, cache, tokens)
            tokens = nxt
        for r in round_:
            r.done = True
            self.queue.complete(r.rid, r)

    def drain(self) -> Dict[int, TokenRequest]:
        """Run rounds until no pending work remains.  Returns (and
        evicts) the requests completed since the last drain."""
        while self.queue.n_pending:
            round_ = self._next_round()
            if not round_:
                break
            try:
                self._run_round(round_)
            except BaseException:
                # a failed step must not strand the round: reset partial
                # outputs and put the requests back for retry
                for r in round_:
                    r.out.clear()
                    r.done = False
                self.queue.restore_in_flight()
                raise
        return {rid: cr.result
                for rid, cr in self.queue.pop_completed().items()}
