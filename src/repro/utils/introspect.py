"""Callable introspection shared by the training layers."""
from __future__ import annotations

import inspect
from typing import Callable


def takes_rng(fn: Callable) -> bool:
    """Does `fn` declare an ``rng`` parameter?

    The opt-in contract for per-update stochasticity: losses / train
    steps that declare ``rng`` receive the Trainer's folded key
    (repro.train.strategies threads it; distributed.bmuf folds it per
    (worker, tau-step) inside a block).  One probe, used by both layers
    — keep the detection rule in exactly one place.
    """
    try:
        return "rng" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False
