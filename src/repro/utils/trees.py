"""Pytree utilities: path-aware maps, param accounting, rng fanout."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_paths(tree):
    """[(path_str, leaf)] with '/'-joined dict-key paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for keypath, leaf in flat:
        parts = []
        for k in keypath:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def map_with_path(fn, tree):
    """tree_map where fn receives (path_str, leaf)."""
    def wrap(keypath, leaf):
        parts = []
        for k in keypath:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return fn("/".join(parts), leaf)
    return jax.tree_util.tree_map_with_path(wrap, tree)


def param_count(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(tree)))


def param_bytes(tree) -> int:
    return int(sum(np.prod(x.shape) * jnp.dtype(x.dtype).itemsize
                   for x in jax.tree_util.tree_leaves(tree)))


def split_key_like(key, tree):
    """Split an rng key into a tree of keys with the same structure."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))
