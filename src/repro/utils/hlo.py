"""Post-SPMD HLO analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but not inter-chip traffic;
we parse the compiled HLO text and sum the *output* tensor bytes of every
collective op (the standard convention: an all-reduce of N bytes moves
~2N(D-1)/D over the ring, an all-gather's output IS what crosses links —
we record raw tensor bytes per op kind and let the roofline apply the
ring-algorithm factors).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

# e.g.  %all-reduce.5 = f32[8,128]{1,0} all-reduce(...)
#       ROOT %t = (bf16[4,8]{...}, f32[]) all-to-all(...)
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>" + "|".join(COLLECTIVES) + r")\b")

_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def shape_bytes(text: str) -> int:
    """Bytes of one 'dtype[d0,d1]' or tuple '(a[..], b[..])' shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def to_dict(self):
        return {"bytes_by_kind": dict(self.bytes_by_kind),
                "count_by_kind": dict(self.count_by_kind),
                "total_bytes": self.total_bytes,
                "total_count": self.total_count}


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output-tensor bytes of every collective in (post-SPMD) HLO."""
    b = defaultdict(int)
    c = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        op = m.group("op")
        # '-start' ops carry the shape; ignore '-done' duplicates by op name
        b[op] += shape_bytes(m.group("shape"))
        c[op] += 1
    return CollectiveStats(bytes_by_kind=dict(b), count_by_kind=dict(c))


def wire_bytes(stats: CollectiveStats, n_devices: int) -> float:
    """Ring-algorithm bytes actually crossing links, per device.

    all-reduce: 2(D-1)/D x tensor bytes; all-gather / reduce-scatter:
    (D-1)/D; all-to-all: (D-1)/D; collective-permute: 1x.
    Approximation: uses the participating-device count = full mesh (XLA's
    replica-groups refine this; good enough for a roofline term).
    """
    d = max(n_devices, 2)
    f_ar = 2 * (d - 1) / d
    f_ag = (d - 1) / d
    total = 0.0
    for kind, by in stats.bytes_by_kind.items():
        if kind == "all-reduce":
            total += f_ar * by
        elif kind in ("all-gather", "reduce-scatter", "all-to-all",
                      "collective-broadcast"):
            total += f_ag * by
        else:                       # collective-permute
            total += by
    return total


def duplicate_op_fraction(hlo_text: str) -> float:
    """Fraction of fusion ops appearing >1x with identical shapes — a cheap
    remat/redundancy smell used by the §Perf iteration notes."""
    sig = re.findall(r"fusion(?:\.\d+)? = ([^ ]+)", hlo_text)
    if not sig:
        return 0.0
    from collections import Counter
    counts = Counter(sig)
    dup = sum(v - 1 for v in counts.values() if v > 1)
    return dup / max(len(sig), 1)
