from repro.utils.introspect import takes_rng
from repro.utils.trees import (map_with_path, param_count, param_bytes,
                               split_key_like, tree_paths)

__all__ = ["map_with_path", "param_count", "param_bytes", "split_key_like",
           "takes_rng", "tree_paths"]
