"""Version-compat shims for jax API churn.

The pin is ``jax>=0.4.37`` with no upper bound; every API that moved
between 0.4.x and current jax goes through a shim here instead of
version-gating at the call sites:

* ``AbstractMesh`` — 0.4.37 takes a single shape tuple
  ``((name, size), ...)``; 0.5+ split it into
  ``(axis_sizes, axis_names)``.
* ``shard_map`` — graduated from ``jax.experimental.shard_map`` to
  ``jax.shard_map`` (0.6+, experimental path deprecated then removed),
  and its ``check_rep`` kwarg was renamed ``check_vma``.
"""
from __future__ import annotations

from typing import Sequence, Tuple


def abstract_mesh(axes: Sequence[Tuple[str, int]]):
    """axes: ((name, size), ...) -> jax.sharding.AbstractMesh."""
    from jax.sharding import AbstractMesh
    axes = tuple((str(n), int(s)) for n, s in axes)
    try:
        return AbstractMesh(axes)                      # jax 0.4.37 form
    except TypeError:
        sizes = tuple(s for _, s in axes)              # jax 0.5+ form
        names = tuple(n for n, _ in axes)
        return AbstractMesh(sizes, names)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = True):
    """``shard_map`` across its module move and kwarg rename.

    Call sites keep the 0.4-era spelling (``check_rep``); here it maps
    to ``check_vma`` when the installed jax only knows the new name.
    """
    import jax
    impl = getattr(jax, "shard_map", None)
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl
    try:
        return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=check_rep)
    except TypeError:
        return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=check_rep)
