"""Version-compat shims for jax API churn.

``AbstractMesh``'s constructor changed across jax releases: 0.4.37 takes
a single shape tuple ``((name, size), ...)``; 0.5+ split it into
``(axis_sizes, axis_names)``.  The tests build device-free meshes for
divisibility checks, so they go through this helper instead of pinning
one signature (ROADMAP follow-up: lets the ``jax>=0.4.37,<0.5`` pin
relax once a 0.5+ toolchain is validated).
"""
from __future__ import annotations

from typing import Sequence, Tuple


def abstract_mesh(axes: Sequence[Tuple[str, int]]):
    """axes: ((name, size), ...) -> jax.sharding.AbstractMesh."""
    from jax.sharding import AbstractMesh
    axes = tuple((str(n), int(s)) for n, s in axes)
    try:
        return AbstractMesh(axes)                      # jax 0.4.37 form
    except TypeError:
        sizes = tuple(s for _, s in axes)              # jax 0.5+ form
        names = tuple(n for n, _ in axes)
        return AbstractMesh(sizes, names)
