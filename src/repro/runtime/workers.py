"""Multi-process target-generation workers + their supervisor.

The paper parallelizes teacher target generation as an embarrassingly-
parallel fleet over a shared store (§3.2; the "Petabyte Scale" sequel
makes the map/reduce framing explicit).  This module is that fleet at
process granularity:

* :func:`worker_main` — the worker CLI
  (``python -m repro.runtime.workers --spec job.json --worker-id 3``).
  Each worker attaches to the shared :class:`~repro.pipeline.generate
  .WorkLedger`, races ``claim_shared`` for shard ranges, runs its
  engine over the claimed batches, and commits shards through the
  store's locked manifest path — all while a :class:`~repro.runtime
  .procs.Heartbeat` thread proves it alive.  A worker that finds no
  pending range but an unfinished ledger *waits*: a sibling may die
  and its claims come back.
* :class:`Supervisor` — spawns N workers, watches children and
  heartbeats, reclaims claims of dead children immediately (by owner)
  and of hung ones by heartbeat age, respawns up to ``max_restarts``
  replacements, and drains: join everyone once the ledger completes.
* engine factories — process-crossing engines are named
  ``"module:function"`` specs resolved by ``pipeline.generate
  .resolve_engine_factory``; :func:`linear_probe_engine` is the
  deterministic numpy reference (tests/benchmarks),
  :func:`teacher_engine` builds a real jax TeacherRunner from a
  checkpoint on disk.

Work products are byte-deterministic: shard contents depend only on
the batch and the engine spec, never on which worker (or how many)
produced them — so the N-process manifest is bitwise identical to the
in-process one, and stealing a hung worker's claim is always safe.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.pipeline.generate import (WorkLedger, _utt_lens_of,
                                     resolve_engine_factory)
from repro.runtime import procs
from repro.runtime.env import bootstrap_from_env
from repro.store.logit_store import LogitStoreV2

# ---------------------------------------------------------------- job spec

def save_batches(path: str, batches: Sequence[dict]) -> str:
    """List-of-dict batches -> one .npz (keys ``"<i>.<field>"``)."""
    arrays = {}
    for i, b in enumerate(batches):
        for key, arr in b.items():
            arrays[f"{i}.{key}"] = np.asarray(arr)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)
    return path


def load_batches(path: str) -> List[dict]:
    """Inverse of :func:`save_batches` (order restored by index)."""
    z = np.load(path)
    out: Dict[int, dict] = {}
    for name in z.files:
        i, _, key = name.partition(".")
        out.setdefault(int(i), {})[key] = z[name]
    return [out[i] for i in sorted(out)]


def write_job_spec(path: str, *, store_root: str, k: int, vocab: int,
                   ledger_path: str, wave: int, batches_npz: str,
                   engine_spec: str, engine_kwargs: Optional[dict] = None,
                   heartbeat_interval_s: float = 0.25,
                   crash: Optional[dict] = None) -> str:
    """The JSON contract between supervisor and workers.

    ``crash`` is the fault-injection stanza:
    ``{"worker": id, "after_shards": n}`` arms a
    :class:`~repro.runtime.procs.CrashPoint` in that worker — SIGKILL
    after its n-th shard write, mid-range, exactly like losing the
    machine.
    """
    spec = {"store_root": store_root, "k": int(k), "vocab": int(vocab),
            "ledger_path": ledger_path, "wave": int(wave),
            "batches_npz": batches_npz, "engine_spec": engine_spec,
            "engine_kwargs": engine_kwargs or {},
            "heartbeat_interval_s": heartbeat_interval_s,
            "crash": crash}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(spec, f, indent=1)
    os.replace(tmp, path)
    return path


# ------------------------------------------------------------ worker side

def owner_name(worker_id: int, pid: Optional[int] = None) -> str:
    """Claim owner id: ``proc<worker>-<pid>``.  The pid makes a
    respawned replacement distinguishable from its dead predecessor, so
    the supervisor can reclaim the old claims by exact owner."""
    return f"proc{worker_id}-{os.getpid() if pid is None else pid}"


def run_worker(spec: dict, worker_id: int, *,
               poll_s: float = 0.05) -> int:
    """One worker's life: attach, claim, generate, commit, repeat.

    Returns the number of shards written.  Exits the claim loop only
    when the ledger is fully done — a worker with nothing pending but
    an unfinished ledger parks and re-polls, because a hung sibling's
    claims may be stolen back to pending at any moment and *someone*
    must be alive to take them.
    """
    owner = owner_name(worker_id)
    ledger = WorkLedger.attach(spec["ledger_path"])
    crash_cfg = spec.get("crash") or {}
    crash = procs.CrashPoint(
        crash_cfg.get("after_shards")
        if crash_cfg.get("worker") == worker_id else None)
    store = LogitStoreV2(spec["store_root"], k=spec["k"],
                         vocab=spec["vocab"], shared=True)
    batches = load_batches(spec["batches_npz"])
    engine = None
    n_written = 0
    with procs.Heartbeat(ledger.heartbeat_dir, owner,
                         interval_s=spec.get("heartbeat_interval_s",
                                             0.25)):
        while True:
            claim = ledger.claim_shared(owner)
            if claim is None:
                ledger.refresh()
                if ledger.all_done:
                    return n_written
                time.sleep(poll_s)          # park: claims may come back
                continue
            if engine is None:
                factory = resolve_engine_factory(spec["engine_spec"])
                engine = factory(worker_id, spec.get("engine_kwargs", {}))
            for i in range(claim.lo, claim.hi):
                vals, idx = engine.forward_topk(batches[i])
                store.append_shard(i, vals, idx, _utt_lens_of(batches[i]),
                                   wave=ledger.wave)
                n_written += 1
                crash.tick()                # fault injection fires HERE —
                # after a commit, before mark_done: the killed worker
                # leaves a claimed range with real partial work behind
            ledger.mark_done_shared(claim)


def worker_main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="ledgered target-generation worker (one process of "
                    "the fleet; spawned by runtime.workers.Supervisor)")
    ap.add_argument("--spec", required=True)
    ap.add_argument("--worker-id", type=int, required=True)
    args = ap.parse_args(argv)
    with open(args.spec) as f:
        spec = json.load(f)
    n = run_worker(spec, args.worker_id)
    print(f"[worker {args.worker_id}] wrote {n} shards", flush=True)
    return 0


# -------------------------------------------------------- supervisor side

class Supervisor:
    """Spawn/watch/reclaim/drain for a fleet of generation workers.

    The loop, every ``poll_s``:

    1. reap exited children — claims of a *dead* worker are reclaimed
       immediately by exact owner (no need to wait out the heartbeat
       timeout), and a replacement is spawned while restart budget
       remains and pending work exists;
    2. steal from *hung* workers — ``reclaim_stale`` demotes claims
       whose owner's heartbeat is older than ``heartbeat_timeout_s``
       (the worker may still be alive; determinism makes the steal
       safe);
    3. drain — once the ledger is all-done, workers exit on their own
       (their claim loop observes completion); join with a grace
       period, then terminate stragglers.

    ``run`` raises RuntimeError if the wave cannot complete (restart
    budget exhausted with work pending, or ``timeout_s`` elapsed).
    """

    def __init__(self, spec_path: str, n_procs: int, *,
                 heartbeat_timeout_s: float = 3.0, poll_s: float = 0.05,
                 max_restarts: Optional[int] = None,
                 claim_timeout_s: Optional[float] = None,
                 python: str = sys.executable):
        self.spec_path = spec_path
        self.n_procs = n_procs
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.poll_s = poll_s
        self.max_restarts = n_procs if max_restarts is None else max_restarts
        # second staleness signal: a claim held longer than this is
        # stolen even under a fresh heartbeat (zombie worker whose beat
        # thread outlived its hung main loop); None disables
        self.claim_timeout_s = claim_timeout_s
        self.python = python
        with open(spec_path) as f:
            self.spec = json.load(f)
        self.ledger = WorkLedger.attach(self.spec["ledger_path"])
        self.children: Dict[int, subprocess.Popen] = {}
        self.child_owner: Dict[int, str] = {}
        self.n_restarts = 0
        self.n_reclaimed = 0
        # structured lifecycle log (spawn/exit/respawn), merged with the
        # ledger's steal events into run()'s report
        self.events: List[dict] = []

    # ------------------------------------------------------------ spawn

    def _spawn(self, worker_id: int) -> subprocess.Popen:
        p = subprocess.Popen(
            [self.python, "-m", "repro.runtime.workers",
             "--spec", self.spec_path, "--worker-id", str(worker_id)],
            env=procs.child_env(), stdout=subprocess.DEVNULL,
            stderr=subprocess.DEVNULL)
        self.children[worker_id] = p
        self.child_owner[worker_id] = owner_name(worker_id, p.pid)
        self.events.append({"event": "spawn", "worker": worker_id,
                            "owner": self.child_owner[worker_id],
                            "t": time.time()})
        return p

    def _reap_and_respawn(self):
        for wid, p in list(self.children.items()):
            if p.poll() is None:
                continue
            del self.children[wid]
            owner = self.child_owner.pop(wid)
            stolen = self.ledger.reclaim_stale(
                max_age_s=0.0, owners=[owner])
            self.n_reclaimed += len(stolen)
            self.events.append({"event": "exit", "worker": wid,
                                "owner": owner,
                                "returncode": p.returncode,
                                "stolen": len(stolen), "t": time.time()})
            self.ledger.refresh()
            if (not self.ledger.all_done
                    and self.n_restarts < self.max_restarts
                    and (p.returncode != 0 or stolen)):
                # nonzero exit or died holding work: spawn a successor
                # (a clean exit with nothing stolen is just "done")
                self.n_restarts += 1
                self.events.append({"event": "respawn", "worker": wid,
                                    "t": time.time()})
                self._spawn(wid)

    # -------------------------------------------------------------- run

    def run(self, *, timeout_s: float = 120.0) -> Dict:
        t0 = time.monotonic()
        for wid in range(self.n_procs):
            self._spawn(wid)
        try:
            while True:
                self.ledger.refresh()
                if self.ledger.all_done:
                    break
                if time.monotonic() - t0 > timeout_s:
                    raise RuntimeError(
                        f"generation wave incomplete after {timeout_s}s "
                        f"({self.ledger.n_done}/"
                        f"{len(self.ledger.ranges)} ranges done)")
                self._reap_and_respawn()
                if not self.children and not self.ledger.all_done:
                    if self.n_restarts >= self.max_restarts:
                        raise RuntimeError(
                            "all workers dead, restart budget exhausted, "
                            "work pending")
                stolen = self.ledger.reclaim_stale(
                    max_age_s=self.heartbeat_timeout_s,
                    claim_timeout_s=self.claim_timeout_s)
                self.n_reclaimed += len(stolen)
                time.sleep(self.poll_s)
            self._drain()
        finally:
            self._terminate_all()
        return {"processes": self.n_procs, "restarts": self.n_restarts,
                "reclaimed": self.n_reclaimed,
                "events": self.events + self.ledger.events}

    def _drain(self, grace_s: float = 5.0):
        """Ledger complete: workers are exiting on their own — give
        them the grace period, then insist."""
        deadline = time.monotonic() + grace_s
        for wid, p in list(self.children.items()):
            try:
                p.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.terminate()
            self.children.pop(wid, None)

    def _terminate_all(self):
        for p in self.children.values():
            if p.poll() is None:
                p.kill()
        self.children.clear()


def run_supervised_generation(ledger: WorkLedger, batches, store, *,
                              engine_spec: str, engine_kwargs: dict,
                              n_procs: int, crash: Optional[dict] = None,
                              heartbeat_timeout_s: float = 3.0,
                              timeout_s: float = 120.0,
                              max_restarts: Optional[int] = None,
                              claim_timeout_s: Optional[float] = None
                              ) -> Dict:
    """``generate_sharded(processes=N)``'s backend: stage the job under
    ``<store>/_procs/``, run a Supervisor over the prepared ledger, and
    hand back a completion report.  The ledger/wave decisions were
    already made by ``prepare_ledger`` — this only executes them."""
    work_dir = os.path.join(store.root, "_procs")
    npz = save_batches(os.path.join(work_dir, "batches.npz"), batches)
    spec_path = write_job_spec(
        os.path.join(work_dir, "job.json"),
        store_root=store.root, k=store.k, vocab=store.vocab,
        ledger_path=ledger.path, wave=ledger.wave, batches_npz=npz,
        engine_spec=engine_spec, engine_kwargs=engine_kwargs, crash=crash)
    sup = Supervisor(spec_path, n_procs,
                     heartbeat_timeout_s=heartbeat_timeout_s,
                     max_restarts=max_restarts,
                     claim_timeout_s=claim_timeout_s)
    rep = sup.run(timeout_s=timeout_s)
    # adopt the workers' commits: the in-memory manifest predates them
    store.manifest = type(store.manifest).load(store.root)
    ledger.refresh()
    assert ledger.all_done
    rep["n_written"] = sum(r.hi - r.lo for r in ledger.ranges)
    return rep


# --------------------------------------------------------- engine factories

class _LinearProbeEngine:
    """Deterministic numpy engine: top-k of a fixed random projection.

    Content depends only on the batch and (k, vocab, seed) — never on
    the worker — so any partition of the corpus over any number of
    workers or processes produces byte-identical shards.  The reference
    engine for the bitwise in-process == multi-process pin, and the
    benchmark's stand-in for a teacher forward.
    """

    def __init__(self, k: int, vocab: int, seed: int = 0,
                 flops_per_frame: int = 0):
        self.k = k
        self.vocab = vocab
        self.seed = seed
        self.flops_per_frame = flops_per_frame
        self._w = None

    def forward_topk(self, batch):
        feats = np.asarray(batch["feats"], np.float32)
        if self._w is None:
            rng = np.random.default_rng(self.seed)
            self._w = rng.normal(
                size=(feats.shape[-1], self.vocab)).astype(np.float32)
        logits = feats @ self._w
        if self.flops_per_frame:            # simulated model cost knob
            for _ in range(self.flops_per_frame):
                logits = logits + 0.0
        idx = np.argsort(-logits, axis=-1)[..., :self.k].astype(np.int32)
        vals = np.take_along_axis(logits, idx, axis=-1)
        vals = vals - vals[..., :1]
        return vals, idx


def linear_probe_engine(worker_id: int, kwargs: dict):
    """Factory spec ``repro.runtime.workers:linear_probe_engine``."""
    del worker_id                           # determinism: worker-blind
    return _LinearProbeEngine(int(kwargs.get("k", 20)),
                              int(kwargs["vocab"]),
                              seed=int(kwargs.get("seed", 0)),
                              flops_per_frame=int(
                                  kwargs.get("flops_per_frame", 0)))


def teacher_engine(worker_id: int, kwargs: dict):
    """Factory spec ``repro.runtime.workers:teacher_engine`` — a real
    jax TeacherRunner from params on disk.

    kwargs: ``ckpt_dir`` (repro.checkpoint.CheckpointStore root holding
    the teacher params), ``k``, optional ``arch`` (default the paper's
    bidirectional teacher) and ``step`` (default: latest).  This is the
    factory a real multi-host generation fleet names in its job spec;
    each process pays its own jax import + forward compile, which is
    exactly the deployment cost model.
    """
    del worker_id
    import jax

    from repro.checkpoint.store import CheckpointStore
    from repro.configs import get_arch
    from repro.core.teacher import TeacherRunner
    from repro.models import build_model
    cfg = get_arch(kwargs.get("arch", "lstm-am-teacher"))
    like = build_model(cfg).init(jax.random.PRNGKey(0))
    params, _step = CheckpointStore(kwargs["ckpt_dir"]).load(
        like, kwargs.get("step"))
    return TeacherRunner(cfg, params, k=int(kwargs.get("k", 20)))


# ------------------------------------------------------ trainer membership

class TrainerMembership:
    """Shared membership roster for elastic trainers.

    The generation fleet's liveness machinery (``procs`` heartbeats +
    ``file_lock``) extended to *training* workers: a locked JSON roster
    records who joined/left, heartbeat files prove who is still alive,
    and ``live_count()`` is the runtime W the Trainer polls at block
    boundaries (``Trainer.fit(membership=...)``).  Multiple processes —
    or one driver simulating a fleet — share the same roster file.

        m = TrainerMembership(path, timeout_s=3.0)
        m.join("lane0"); m.join("lane1")
        m.live()          # ["lane0", "lane1"]
        m.kill("lane1")   # simulated SIGKILL: backdate the heartbeat
        m.live_count()    # 1 -> the next block shrinks to W=1

    A member is live iff it joined, has not left, and its heartbeat is
    no older than ``timeout_s``.  ``join`` is also the *re*-join path —
    a revived worker rejoins warm (BMUF lanes were kept broadcast-
    current exactly so this is cheap).
    """

    def __init__(self, path: str, *, timeout_s: float = 3.0,
                 interval_s: float = 0.25):
        self.path = path
        self.timeout_s = timeout_s
        self.interval_s = interval_s
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    @property
    def lock_path(self) -> str:
        return self.path + ".lock"

    @property
    def heartbeat_dir(self) -> str:
        return os.path.join(os.path.dirname(self.path) or ".",
                            "trainer_heartbeats")

    def _load(self) -> Dict:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return {"workers": {}}

    def _save(self, d: Dict):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(d, f, indent=1)
        os.replace(tmp, self.path)

    # -------------------------------------------------------- transitions

    def join(self, worker: str):
        """Register (or re-register) a live worker; beats synchronously
        so the member is live the moment join returns."""
        with procs.file_lock(self.lock_path):
            d = self._load()
            d["workers"][worker] = {"joined": time.time(), "left": None}
            self._save(d)
        procs.beat(self.heartbeat_dir, worker)

    def leave(self, worker: str):
        """Clean departure — immediately not-live, no timeout to wait."""
        with procs.file_lock(self.lock_path):
            d = self._load()
            if worker in d["workers"]:
                d["workers"][worker]["left"] = time.time()
                self._save(d)

    def beat(self, worker: str):
        procs.beat(self.heartbeat_dir, worker)

    def heartbeat(self, worker: str) -> procs.Heartbeat:
        """Background beat thread for a real trainer process."""
        return procs.Heartbeat(self.heartbeat_dir, worker,
                               interval_s=self.interval_s)

    def kill(self, worker: str, *, age_s: Optional[float] = None):
        """Fault injection: make a member look SIGKILLed *now* by
        backdating its heartbeat past the timeout — no sleeping in
        tests, same observable state as a real dead process."""
        age = self.timeout_s + 1.0 if age_s is None else age_s
        hb = procs.heartbeat_path(self.heartbeat_dir, worker)
        if not os.path.exists(hb):
            procs.beat(self.heartbeat_dir, worker)
        then = time.time() - age
        os.utime(hb, (then, then))

    # ------------------------------------------------------------ queries

    def roster(self) -> Dict:
        with procs.file_lock(self.lock_path):
            return self._load()["workers"]

    def live(self, *, now: Optional[float] = None) -> List[str]:
        now = time.time() if now is None else now
        out = []
        for name, rec in sorted(self.roster().items()):
            if rec.get("left") is not None:
                continue
            age = procs.heartbeat_age(self.heartbeat_dir, name, now=now)
            if age is not None and age <= self.timeout_s:
                out.append(name)
        return out

    def live_count(self) -> int:
        return len(self.live())


class LaneCrashPlan:
    """CrashPoint's deterministic after-N discipline, for membership.

    Wraps a :class:`TrainerMembership` as the object ``Trainer.fit``
    polls, firing scripted kills/revives at exact poll indices (one
    poll per update, i.e. per BMUF block) — chaos tests stay exactly
    reproducible: "kill lane2 after block 2, revive it after block 5".

        plan = LaneCrashPlan(m, kills={2: "lane2"}, revives={5: "lane2"})
        trainer.fit(state, source, membership=plan)

    ``log`` records every fired event for the bench/report.
    """

    def __init__(self, membership: TrainerMembership, *,
                 kills: Optional[Dict[int, str]] = None,
                 revives: Optional[Dict[int, str]] = None):
        self.membership = membership
        self.kills = dict(kills or {})
        self.revives = dict(revives or {})
        self.polls = 0
        self.log: List[dict] = []

    def live_count(self) -> int:
        n = self.polls
        self.polls += 1
        if n in self.kills:
            self.membership.kill(self.kills[n])
            self.log.append({"event": "kill", "poll": n,
                             "worker": self.kills[n]})
        if n in self.revives:
            self.membership.join(self.revives[n])
            self.log.append({"event": "revive", "poll": n,
                             "worker": self.revives[n]})
        return self.membership.live_count()


if __name__ == "__main__":
    bootstrap_from_env()        # before any jax the engine may import
    sys.exit(worker_main())
