"""Computation-environment bootstrap — applied *before* the first JAX
import.

JAX locks the XLA client configuration (platform, host device count,
GPU scheduler flags) when the backend first initializes, so everything
here operates on ``os.environ`` and must run ahead of ``import jax``.
Entry points call :func:`bootstrap_from_env` as their very first
statement (see ``repro.launch.train`` / ``launch.dryrun``); tests and
CI drive :func:`bootstrap` directly in a fresh interpreter.

The three knob families, mirroring the million-hour deployment:

* **host-platform device count** — ``--xla_force_host_platform_device_count=N``
  splits one CPU into N XLA devices, so the GTC/BMUF ``shard_map``
  worker axes exercise a real >1-device mesh in CI (the paper's
  BMUF-64 / GTC-16 topologies at laptop scale);
* **GPU execution flags** — async collectives + latency-hiding
  scheduler + highest-priority async stream, the overlap flags that let
  BMUF's block sync hide behind local steps on real GPUs;
* **numerics/debug toggles** — x64, NaN debugging, client preallocation.

:func:`describe` snapshots the *resulting* environment (jax version,
backend, devices, process topology, the exact flag string) and is
logged as a startup artifact — the first thing to diff when two hosts
of a fleet disagree.
"""
from __future__ import annotations

import json
import os
import re
import sys
import warnings
from dataclasses import dataclass, field
from typing import Dict, Mapping, MutableMapping, Optional, Tuple

_HOST_DEVICES_FLAG = "--xla_force_host_platform_device_count"

# the overlap flags for multi-GPU runs (SNIPPETS #1: async collectives
# so psums overlap compute, latency-hiding scheduler to move them early)
GPU_XLA_FLAGS: Tuple[str, ...] = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


@dataclass(frozen=True)
class EnvConfig:
    """What :func:`bootstrap` applies.  Zero/None means "leave alone"."""

    host_device_count: int = 0        # >0: N-device host-platform CPU mesh
    platform: str = ""                # "", "cpu", "gpu", "tpu"
    gpu_flags: bool = True            # apply GPU_XLA_FLAGS when platform=gpu
    enable_x64: Optional[bool] = None
    debug_nans: Optional[bool] = None
    preallocate: Optional[bool] = None
    extra_xla_flags: Tuple[str, ...] = field(default_factory=tuple)

    @classmethod
    def from_env(cls, environ: Optional[Mapping[str, str]] = None
                 ) -> "EnvConfig":
        """REPRO_* knobs -> EnvConfig (unset knobs stay neutral).

        REPRO_HOST_DEVICES=N, REPRO_PLATFORM=cpu|gpu|tpu,
        REPRO_X64=0|1, REPRO_DEBUG_NANS=0|1, REPRO_PREALLOCATE=0|1,
        REPRO_XLA_FLAGS="--flag=a --flag=b" (appended verbatim).
        """
        e = os.environ if environ is None else environ

        def _bool(name):
            v = e.get(name)
            return None if v is None else v.strip().lower() in (
                "1", "true", "yes", "on")

        return cls(
            host_device_count=int(e.get("REPRO_HOST_DEVICES", 0) or 0),
            platform=e.get("REPRO_PLATFORM", "").strip().lower(),
            enable_x64=_bool("REPRO_X64"),
            debug_nans=_bool("REPRO_DEBUG_NANS"),
            preallocate=_bool("REPRO_PREALLOCATE"),
            extra_xla_flags=tuple(e.get("REPRO_XLA_FLAGS", "").split()))


def _jax_already_imported() -> bool:
    return "jax" in sys.modules


def compose_xla_flags(existing: str, cfg: EnvConfig) -> str:
    """Merge cfg's managed flags into an existing XLA_FLAGS string.

    Idempotent: a managed flag already present is *replaced*, not
    duplicated, so repeated bootstraps (supervisor -> worker -> nested
    tool) converge to one spelling.  Unmanaged flags pass through in
    their original order.
    """
    managed: Dict[str, str] = {}
    if cfg.host_device_count > 0:
        managed[_HOST_DEVICES_FLAG] = (
            f"{_HOST_DEVICES_FLAG}={cfg.host_device_count}")
    gpu = GPU_XLA_FLAGS if (cfg.platform == "gpu" and cfg.gpu_flags) else ()
    for f in tuple(gpu) + tuple(cfg.extra_xla_flags):
        managed[f.split("=", 1)[0]] = f
    out = []
    for tok in existing.split():
        key = tok.split("=", 1)[0]
        if key in managed:
            out.append(managed.pop(key))      # replace in place
        else:
            out.append(tok)
    out.extend(managed.values())
    return " ".join(out)


def bootstrap(cfg: Optional[EnvConfig] = None, *,
              environ: Optional[MutableMapping[str, str]] = None,
              **kwargs) -> EnvConfig:
    """Apply cfg to the process environment.  Call before ``import jax``.

    Keyword form: ``bootstrap(host_device_count=8, platform="gpu")``.
    Returns the applied config.  If JAX is already imported the XLA
    flag changes cannot take effect — a loud warning is raised and the
    environment is still updated (children inherit it, which is exactly
    what the process-worker supervisor relies on).
    """
    if cfg is None:
        cfg = EnvConfig(**kwargs)
    elif kwargs:
        raise TypeError("pass either a config or kwargs, not both")
    e = os.environ if environ is None else environ

    wants_flags = (cfg.host_device_count > 0 or cfg.extra_xla_flags
                   or (cfg.platform == "gpu" and cfg.gpu_flags))
    if wants_flags and _jax_already_imported() and environ is None:
        warnings.warn(
            "repro.runtime.env.bootstrap: jax is already imported — "
            "XLA flag changes will NOT affect this process (only "
            "subprocesses inheriting the environment). Bootstrap "
            "before the first jax import.", RuntimeWarning, stacklevel=2)
    if wants_flags:
        e["XLA_FLAGS"] = compose_xla_flags(e.get("XLA_FLAGS", ""), cfg)
    if cfg.platform:
        e["JAX_PLATFORMS"] = cfg.platform
    if cfg.enable_x64 is not None:
        e["JAX_ENABLE_X64"] = "1" if cfg.enable_x64 else "0"
    if cfg.debug_nans is not None:
        e["JAX_DEBUG_NANS"] = "true" if cfg.debug_nans else "false"
    if cfg.preallocate is not None:
        e["XLA_PYTHON_CLIENT_PREALLOCATE"] = \
            "true" if cfg.preallocate else "false"
    return cfg


def bootstrap_from_env(environ: Optional[MutableMapping[str, str]] = None
                       ) -> EnvConfig:
    """``bootstrap(EnvConfig.from_env())`` — the entry-point one-liner."""
    return bootstrap(EnvConfig.from_env(environ), environ=environ)


def forced_host_device_count(
        environ: Optional[Mapping[str, str]] = None) -> int:
    """The host-platform device count the current XLA_FLAGS forces
    (0 when unforced) — readable without importing jax."""
    e = os.environ if environ is None else environ
    m = re.search(_HOST_DEVICES_FLAG + r"=(\d+)", e.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else 0


# ------------------------------------------------------------- describe

def describe() -> dict:
    """Snapshot the effective runtime environment (imports jax).

    Everything a fleet debugger wants in one JSON-serializable dict:
    versions, backend, device inventory, process topology, the exact
    flag strings, and the REPRO_*/JAX_* env vars that produced them.
    """
    import platform as _platform

    import jax

    devices = jax.devices()
    try:
        proc_idx, proc_cnt = jax.process_index(), jax.process_count()
    except Exception:                       # uninitializable backend
        proc_idx, proc_cnt = 0, 1
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": len(devices),
        "local_device_count": jax.local_device_count(),
        "devices": [str(d) for d in devices],
        "process_index": proc_idx,
        "process_count": proc_cnt,
        "forced_host_devices": forced_host_device_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "x64": bool(jax.config.jax_enable_x64),
        "debug_nans": bool(jax.config.jax_debug_nans),
        "env": {k: v for k, v in sorted(os.environ.items())
                if k.startswith(("REPRO_", "JAX_", "XLA_"))},
        "python": sys.version.split()[0],
        "hostname": _platform.node(),
        "pid": os.getpid(),
    }


def save_describe(path: str) -> dict:
    """Write the :func:`describe` snapshot to `path` (the startup
    artifact tier-2 CI uploads); returns the snapshot."""
    snap = describe()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(snap, f, indent=1)
    return snap


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="bootstrap the env, then print/save describe()")
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--platform", default="")
    ap.add_argument("--x64", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    bootstrap(host_device_count=args.host_devices, platform=args.platform,
              enable_x64=True if args.x64 else None)
    snap = save_describe(args.out) if args.out else describe()
    print(json.dumps(snap, indent=1))


if __name__ == "__main__":
    main()
