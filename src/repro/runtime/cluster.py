"""``jax.distributed`` launch paths and topology-aware mesh builders.

The paper's two trainers are defined by their topology — BMUF across 64
GPUs, GTC sequence training across 16 — and this module is where that
topology becomes a concrete ``jax.distributed`` launch plus a mesh:

* :class:`ClusterConfig` carries (coordinator address, process count,
  process id), resolved from ``REPRO_COORDINATOR`` /
  ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` env vars (falling back
  to the ``JAX_*`` spellings) or from a ``--cluster host:port,N,i``
  flag (``--cluster env`` reads the env vars).
* :func:`initialize` calls ``jax.distributed.initialize`` exactly once
  for multi-process configs and **degrades to a no-op for
  single-process runs** — every existing example/test runs unchanged,
  and the same entry point serves one laptop or a 64-host fleet.
* :func:`worker_mesh` builds the 1-D ``("data",)`` worker-axis mesh the
  GTCShardMap/BMUFShardMap strategies shard over: the widest axis the
  worker count divides onto the *global* device set (``jax.devices()``
  spans processes after ``initialize``), so W=16 on 16 GPUs is one
  worker per device, W=2 in 8-device CI spans 2 devices, and W=anything
  on one CPU degenerates to today's 1-device mesh with every worker
  vmap-carried — the same math either way, pinned bitwise in tests.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Mapping, Optional


@dataclass(frozen=True)
class ClusterConfig:
    """One process's view of the fleet.  num_processes<=1 means
    single-process: :func:`initialize` is then a no-op."""

    coordinator_address: str = ""     # "host:port" of process 0
    num_processes: int = 1
    process_id: int = 0

    @classmethod
    def from_env(cls, environ: Optional[Mapping[str, str]] = None
                 ) -> "ClusterConfig":
        e = os.environ if environ is None else environ

        def get(*names, default=""):
            for n in names:
                if e.get(n):
                    return e[n]
            return default

        return cls(
            coordinator_address=get("REPRO_COORDINATOR",
                                    "JAX_COORDINATOR_ADDRESS"),
            num_processes=int(get("REPRO_NUM_PROCESSES",
                                  "JAX_NUM_PROCESSES", default="1")),
            process_id=int(get("REPRO_PROCESS_ID", "JAX_PROCESS_ID",
                               default="0")))

    @classmethod
    def from_spec(cls, spec: str,
                  environ: Optional[Mapping[str, str]] = None
                  ) -> "ClusterConfig":
        """Parse a ``--cluster`` flag value.

        ``"env"`` -> :meth:`from_env`;
        ``"host:port,N,i"`` -> explicit coordinator, fleet size, rank.
        """
        if spec.strip().lower() in ("", "env"):
            return cls.from_env(environ)
        parts = [p.strip() for p in spec.split(",")]
        if len(parts) != 3:
            raise ValueError(
                f"--cluster spec {spec!r}: want 'host:port,num_procs,"
                f"process_id' or 'env'")
        return cls(coordinator_address=parts[0],
                   num_processes=int(parts[1]), process_id=int(parts[2]))

    def validate(self):
        if self.num_processes > 1:
            if not self.coordinator_address:
                raise ValueError(
                    "multi-process cluster needs a coordinator address")
            if not 0 <= self.process_id < self.num_processes:
                raise ValueError(
                    f"process_id {self.process_id} outside "
                    f"[0, {self.num_processes})")


@dataclass(frozen=True)
class ClusterInfo:
    """What :func:`initialize` actually did."""

    initialized: bool                 # did jax.distributed.initialize run
    process_index: int
    process_count: int

    @property
    def is_coordinator(self) -> bool:
        return self.process_index == 0


_ACTIVE: Optional[ClusterInfo] = None


def initialize(cfg: Optional[ClusterConfig] = None) -> ClusterInfo:
    """Bring this process into the fleet (idempotent).

    Single-process configs (the default, and every existing test /
    example) return a no-op ClusterInfo without touching
    ``jax.distributed`` at all.  Multi-process configs run
    ``jax.distributed.initialize`` once; a second call returns the
    recorded info instead of re-initializing (jax raises on double
    init — a supervisor retrying a launcher must not trip that).
    """
    global _ACTIVE
    if _ACTIVE is not None:
        return _ACTIVE
    cfg = cfg or ClusterConfig.from_env()
    cfg.validate()
    if cfg.num_processes <= 1:
        _ACTIVE = ClusterInfo(initialized=False, process_index=0,
                              process_count=1)
        return _ACTIVE
    import jax
    jax.distributed.initialize(
        coordinator_address=cfg.coordinator_address,
        num_processes=cfg.num_processes,
        process_id=cfg.process_id)
    _ACTIVE = ClusterInfo(initialized=True,
                          process_index=jax.process_index(),
                          process_count=jax.process_count())
    return _ACTIVE


def active() -> Optional[ClusterInfo]:
    """The ClusterInfo of a prior :func:`initialize`, or None."""
    return _ACTIVE


def _reset_for_tests():
    global _ACTIVE
    _ACTIVE = None


# ----------------------------------------------------------------- meshes

def widest_divisor(n_workers: int, n_devices: int) -> int:
    """The largest device count <= n_devices that divides n_workers —
    the worker axis size :func:`worker_mesh` uses."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    return max(d for d in range(1, min(n_workers, max(n_devices, 1)) + 1)
               if n_workers % d == 0)


def worker_mesh(n_workers: int, *, axis: str = "data"):
    """The worker-axis mesh for a W-worker shard_map strategy.

    Axis size = the widest divisor of W the global device set admits:
    each device then carries W/size unrolled workers (all of them on 1
    device at laptop scale; one each on the paper's 16-GPU shape).  The
    strategies' batch stacking requires W divisible by the axis size —
    this builder guarantees it by construction for any device count.
    """
    import jax
    n = widest_divisor(n_workers, len(jax.devices()))
    return jax.make_mesh((n,), (axis,))


# The paper's deployment shapes (§3.4-3.5): name -> worker count.  The
# names are CLI/StrEnum-ish on purpose — `--topology bmuf-64` in a
# launcher maps straight through topology_mesh.
PAPER_TOPOLOGIES = {
    "bmuf-64": 64,       # SSL CE stage: BMUF across 64 GPUs
    "gtc-16": 16,        # sMBR sequence training: GTC across 16 GPUs
}


def topology_mesh(name: str, *, axis: str = "data"):
    """worker_mesh for a named paper topology (``bmuf-64``/``gtc-16``)."""
    if name not in PAPER_TOPOLOGIES:
        raise KeyError(f"unknown topology {name!r}; "
                       f"have {sorted(PAPER_TOPOLOGIES)}")
    return worker_mesh(PAPER_TOPOLOGIES[name], axis=axis)
