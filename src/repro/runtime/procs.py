"""Process-level primitives for the multi-process runtime.

Three small, dependency-free building blocks shared by the work-ledger
and the generation worker fleet:

* :func:`file_lock` — an ``fcntl.flock`` advisory lock scoped to a
  ``with`` block.  Every cross-process read-modify-write of a shared
  JSON file (ledger claims, manifest commits) serializes through one of
  these; the lock file lives next to the data file so any process on
  the shared filesystem contends on the same inode.
* :class:`Heartbeat` — a daemon thread touching
  ``<dir>/<owner>.hb`` every ``interval_s``.  Liveness is the file's
  mtime: a supervisor (or a rival worker) reads
  :func:`heartbeat_age` and steals claims whose owner has gone quiet —
  the *hung*-worker case reopen-time demotion can never catch, because
  a hung process never reopens anything.
* :class:`CrashPoint` — deterministic fault injection for tests and
  chaos CI: ``SIGKILL`` the calling process after its N-th ``tick()``.
  A real kill (not an exception) so the death leaves exactly what a
  machine failure leaves: a claimed ledger entry, a stale heartbeat,
  possibly a staged-but-uncommitted shard.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from contextlib import contextmanager
from typing import Iterator, Optional

try:
    import fcntl
    _HAVE_FCNTL = True
except ImportError:                       # non-POSIX: single-process only
    _HAVE_FCNTL = False


@contextmanager
def file_lock(path: str, *, timeout_s: float = 30.0,
              poll_s: float = 0.01) -> Iterator[None]:
    """Exclusive advisory lock on `path` (created if missing).

    Blocks up to ``timeout_s`` (then raises TimeoutError) rather than
    forever: a worker must never deadlock the fleet on a lock whose
    holder died mid-critical-section — flock releases on process death,
    so the timeout only trips on genuine livelock or an NFS mount
    without lock support.
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd = os.open(path, os.O_CREAT | os.O_RDWR, 0o644)
    try:
        if _HAVE_FCNTL:
            deadline = time.monotonic() + timeout_s
            while True:
                try:
                    fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                    break
                except OSError:
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"file_lock({path}): not acquired within "
                            f"{timeout_s}s")
                    time.sleep(poll_s)
        yield
    finally:
        if _HAVE_FCNTL:
            fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)


# ------------------------------------------------------------ heartbeats

def heartbeat_path(hb_dir: str, owner: str) -> str:
    return os.path.join(hb_dir, f"{owner}.hb")


def beat(hb_dir: str, owner: str) -> str:
    """Touch the owner's heartbeat file once; returns its path."""
    os.makedirs(hb_dir, exist_ok=True)
    path = heartbeat_path(hb_dir, owner)
    with open(path, "a"):
        os.utime(path, None)
    return path


def heartbeat_age(hb_dir: str, owner: str, *,
                  now: Optional[float] = None) -> Optional[float]:
    """Seconds since the owner's last beat; None if it never beat
    (treat as infinitely stale — a worker that died before its first
    beat must still be stealable)."""
    try:
        mtime = os.path.getmtime(heartbeat_path(hb_dir, owner))
    except OSError:
        return None
    return (time.time() if now is None else now) - mtime


class Heartbeat:
    """Daemon thread beating ``<dir>/<owner>.hb`` every ``interval_s``.

    Used as a context manager inside worker processes; `stop()` is
    idempotent.  The first beat happens synchronously in start() so a
    claim made immediately after is never older than its heartbeat.
    """

    def __init__(self, hb_dir: str, owner: str, *,
                 interval_s: float = 0.25):
        self.hb_dir = hb_dir
        self.owner = owner
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "Heartbeat":
        beat(self.hb_dir, self.owner)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"hb-{self.owner}")
        self._thread.start()
        return self

    def _run(self):
        while not self._stop.wait(self.interval_s):
            try:
                beat(self.hb_dir, self.owner)
            except OSError:               # dir swept mid-shutdown: benign
                return

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()


# -------------------------------------------------------- fault injection

class CrashPoint:
    """Deterministic SIGKILL-after-N-ticks fault injector.

    ``CrashPoint(after=2)``: the 3rd ``tick()`` kills the process with
    SIGKILL — uncatchable, mid-whatever-it-was-doing, exactly like the
    fleet losing a machine.  ``after=None`` never fires (the production
    default); the worker CLI arms it from the job spec's ``crash``
    stanza so tests can point the gun at one specific worker.
    """

    def __init__(self, after: Optional[int] = None):
        self.after = after
        self.ticks = 0

    def tick(self):
        self.ticks += 1
        if self.after is not None and self.ticks > self.after:
            os.kill(os.getpid(), signal.SIGKILL)


# ------------------------------------------------------------- spawning

def repro_pythonpath() -> str:
    """A PYTHONPATH under which a child can ``import repro`` — the
    parent of the installed/source package, prepended to any existing
    setting so children resolve the same code the parent runs."""
    import repro
    # repro is a namespace package: __file__ is None, __path__ is real
    pkg_dir = (os.path.dirname(repro.__file__) if repro.__file__
               else next(iter(repro.__path__)))
    pkg_parent = os.path.dirname(os.path.abspath(pkg_dir))
    existing = os.environ.get("PYTHONPATH", "")
    if existing and pkg_parent not in existing.split(os.pathsep):
        return pkg_parent + os.pathsep + existing
    return existing or pkg_parent


def child_env(extra: Optional[dict] = None) -> dict:
    """The environment for a spawned worker: inherit, fix PYTHONPATH,
    apply overrides."""
    env = dict(os.environ)
    env["PYTHONPATH"] = repro_pythonpath()
    if extra:
        env.update(extra)
    return env
