"""Execution runtime: process/device topology under every other subsystem.

Four layers, lowest first:

* ``env`` — environment bootstrap that must run **before the first jax
  import** (XLA flags are read once, at backend init): host-platform
  device-count override (N-device CPU mesh on one machine), GPU XLA
  flags (async collectives, latency-hiding scheduler), x64/NaN-debug
  toggles, and ``describe()``, the topology snapshot CI archives.
* ``procs`` — process primitives with no jax anywhere: ``file_lock``
  (fcntl advisory locks serializing shared-filesystem JSON),
  ``Heartbeat`` (liveness files), ``CrashPoint`` (SIGKILL fault
  injection).
* ``workers`` — the multi-process target-generation fleet: worker CLI,
  supervisor with stale-claim stealing and respawn, engine factory
  specs.  Backend of ``pipeline.generate_sharded(processes=N)``.
* ``cluster`` — ``jax.distributed`` launch paths (coordinator /
  process-id / num-processes from env or flags; single-process no-op)
  and mesh topology helpers (``worker_mesh``: the widest device mesh
  the worker count divides).

Import discipline: ``procs`` imports nothing of repro, ``env`` imports
no jax at module level, ``workers`` stays numpy-only until an engine
factory runs.  Only ``cluster`` (and ``env.describe``) touch jax, both
lazily — so spawning a worker process never pays (or poisons) a jax
init.  This module re-exports lazily for the same reason.
"""
_LAZY = {
    "EnvConfig": "repro.runtime.env",
    "bootstrap": "repro.runtime.env",
    "bootstrap_from_env": "repro.runtime.env",
    "describe": "repro.runtime.env",
    "file_lock": "repro.runtime.procs",
    "Heartbeat": "repro.runtime.procs",
    "CrashPoint": "repro.runtime.procs",
    "ClusterConfig": "repro.runtime.cluster",
    "ClusterInfo": "repro.runtime.cluster",
    "initialize": "repro.runtime.cluster",
    "widest_divisor": "repro.runtime.cluster",
    "worker_mesh": "repro.runtime.cluster",
    "Supervisor": "repro.runtime.workers",
    "run_supervised_generation": "repro.runtime.workers",
    "linear_probe_engine": "repro.runtime.workers",
    "TrainerMembership": "repro.runtime.workers",
    "LaneCrashPlan": "repro.runtime.workers",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
