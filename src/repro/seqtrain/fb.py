"""Log-space forward-backward over a senone-level HMM (lax.scan).

Used by sMBR (paper §3.4): the denominator graph is a senone-bigram HMM
(graphs.py), the acoustic scores are scaled student log-posteriors.  All
recursions are in float32 log-space; time is the scanned axis so HLO size
is T-independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def _lse(x, axis=-1):
    return jax.nn.logsumexp(x, axis=axis)


def forward_log_norm(log_obs, log_trans, log_init, mask=None):
    """log p(O) under the graph.

    log_obs (B,T,S); log_trans (S,S) [from, to]; log_init (S,).
    mask (B,T) 1=real frame.  Returns (B,) log-normalizer.
    """
    b, t, s = log_obs.shape
    alpha0 = log_init[None] + log_obs[:, 0]            # (B,S)

    def step(alpha, xs):
        obs, mk = xs                                   # (B,S), (B,)
        nxt = _lse(alpha[:, :, None] + log_trans[None], axis=1) + obs
        alpha = jnp.where(mk[:, None] > 0, nxt, alpha)
        return alpha, None

    mk = jnp.ones((b, t), jnp.float32) if mask is None else mask
    alpha, _ = jax.lax.scan(step, alpha0,
                            (log_obs.transpose(1, 0, 2)[1:],
                             mk.transpose(1, 0)[1:]))
    return _lse(alpha, axis=-1)


def forward_backward(log_obs, log_trans, log_init, mask=None):
    """State posteriors gamma (B,T,S) + log-normalizer (B,)."""
    b, t, s = log_obs.shape
    mk = jnp.ones((b, t), jnp.float32) if mask is None else mask

    alpha0 = log_init[None] + log_obs[:, 0]

    def fstep(alpha, xs):
        obs, m = xs
        nxt = _lse(alpha[:, :, None] + log_trans[None], axis=1) + obs
        alpha = jnp.where(m[:, None] > 0, nxt, alpha)
        return alpha, alpha

    _, alphas = jax.lax.scan(fstep, alpha0,
                             (log_obs.transpose(1, 0, 2)[1:],
                              mk.transpose(1, 0)[1:]))
    alphas = jnp.concatenate([alpha0[None], alphas], axis=0)   # (T,B,S)

    beta_last = jnp.zeros((b, s), jnp.float32)

    def bstep(beta, xs):
        obs_next, m_next = xs       # obs at t+1, mask at t+1
        nxt = _lse(log_trans[None] + (beta + obs_next)[:, None, :], axis=2)
        beta = jnp.where(m_next[:, None] > 0, nxt, beta)
        return beta, beta

    _, betas_rev = jax.lax.scan(
        bstep, beta_last,
        (log_obs.transpose(1, 0, 2)[1:][::-1],
         mk.transpose(1, 0)[1:][::-1]))
    betas = jnp.concatenate([betas_rev[::-1], beta_last[None]], axis=0)

    log_gamma = alphas + betas                                  # (T,B,S)
    logz = _lse(log_gamma[0], axis=-1)                          # (B,)
    gamma = jnp.exp(log_gamma - logz[None, :, None])
    gamma = gamma * mk.transpose(1, 0)[:, :, None]
    return gamma.transpose(1, 0, 2), logz


def viterbi(log_obs, log_trans, log_init):
    """Best path (B,T) int32 — used by the toy decoder / WER proxy."""
    b, t, s = log_obs.shape
    d0 = log_init[None] + log_obs[:, 0]

    def step(delta, obs):
        scores = delta[:, :, None] + log_trans[None]            # (B,S,S)
        best = jnp.max(scores, axis=1) + obs
        arg = jnp.argmax(scores, axis=1)
        return best, arg

    delta, args = jax.lax.scan(step, d0, log_obs.transpose(1, 0, 2)[1:])
    last = jnp.argmax(delta, axis=-1)                           # (B,)

    def back(state, arg):
        prev = jnp.take_along_axis(arg, state[:, None], axis=1)[:, 0]
        return prev, prev

    _, path_rev = jax.lax.scan(back, last, args[::-1])
    path = jnp.concatenate([path_rev[::-1], last[None]], axis=0)
    return path.transpose(1, 0)
