"""State-level minimum Bayes risk (paper §2/§3.4/§5.3).

loss = - E_{path ~ p(path | O)} [ frame accuracy vs. reference alignment ]
     = - (1/T) sum_t sum_s gamma_t(s) * 1[s == ref_t]

with gamma from forward-backward over the denominator graph using scaled
acoustic scores  kappa * (log softmax(logits) - log prior).  The gradient
flows through the full alpha/beta recursion by autodiff — exact, and the
reverse pass is the textbook sMBR "gamma * (acc - E[acc])" outer product,
which XLA materializes for us.

The paper performs sMBR ONLY on the 7,000h labeled data (§3.4) with the
GTC trainer (§5.3) and CE-smoothing is not mentioned — we include optional
CE interpolation (f-smoothing) anyway, default off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.seqtrain.fb import forward_backward


def smbr_loss(logits, labels, graph, *, kappa: float = 0.3, mask=None):
    """logits (B,T,S) raw senone logits; labels (B,T) reference alignment.

    Returns (loss scalar, metrics dict).
    """
    log_post = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    log_obs = kappa * (log_post - graph.log_prior[None, None])
    gamma, logz = forward_backward(log_obs, graph.log_trans, graph.log_init,
                                   mask)
    acc = jnp.take_along_axis(gamma, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        n = jnp.maximum(mask.sum(), 1.0)
        eacc = jnp.sum(acc * mask) / n
    else:
        eacc = jnp.mean(acc)
    return -eacc, {"expected_frame_acc": eacc, "log_z": jnp.mean(logz)}


def make_smbr_loss_fn(model, cfg, graph, *, kappa: float = 0.3,
                      ce_smooth: float = 0.0):
    """Loss fn over the AM: hidden -> senone logits -> sMBR (+ CE smooth)."""
    def loss_fn(params, batch):
        h, _ = model.apply(params, batch["feats"])
        logits = model.unembed(params, h)
        mask = batch.get("mask")
        loss, metrics = smbr_loss(logits, batch["labels"], graph,
                                  kappa=kappa, mask=mask)
        if ce_smooth:
            lp = jax.nn.log_softmax(logits, axis=-1)
            ce = -jnp.take_along_axis(lp, batch["labels"][..., None],
                                      axis=-1)[..., 0]
            if mask is not None:
                ce = jnp.sum(ce * mask) / jnp.maximum(mask.sum(), 1.0)
            else:
                ce = jnp.mean(ce)
            loss = (1 - ce_smooth) * loss + ce_smooth * ce
            metrics["ce"] = ce
        metrics["loss"] = loss
        return loss, metrics
    return loss_fn


def frame_error_rate(logits, labels, mask=None):
    """The WER proxy used by EXPERIMENTS.md (no LM decode in-container)."""
    pred = jnp.argmax(logits, axis=-1)
    err = (pred != labels).astype(jnp.float32)
    if mask is not None:
        return jnp.sum(err * mask) / jnp.maximum(mask.sum(), 1.0)
    return jnp.mean(err)
