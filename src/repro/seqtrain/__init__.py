from repro.seqtrain.fb import forward_backward, forward_log_norm
from repro.seqtrain.graphs import DenominatorGraph, build_denominator_graph
from repro.seqtrain.smbr import smbr_loss, make_smbr_loss_fn

__all__ = ["forward_backward", "forward_log_norm", "DenominatorGraph",
           "build_denominator_graph", "smbr_loss", "make_smbr_loss_fn"]
