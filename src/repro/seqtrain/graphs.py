"""Denominator graph for sMBR: a senone-bigram HMM.

The production system uses a decoding-graph lattice; at senone granularity
the dense equivalent is a (S,S) transition matrix with self-loops (HMM
state persistence) and bigram senone transition probabilities estimated
from the labeled corpus' alignments — the synthetic twin of a phone-loop
denominator.  S=3,183 full / 97 reduced, so dense is fine (3183^2 f32 =
40 MB, resident once).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

NEG = -1e30


@dataclass
class DenominatorGraph:
    log_trans: np.ndarray        # (S,S) [from, to]
    log_init: np.ndarray         # (S,)
    log_prior: np.ndarray        # (S,) senone priors (for AM score scaling)
    n_senones: int


def build_denominator_graph(alignments, n_senones: int, *,
                            self_loop: float = 0.7,
                            smoothing: float = 0.1) -> DenominatorGraph:
    """Estimate bigram transitions + priors from labeled alignments.

    alignments: iterable of (T,) int senone sequences.
    """
    counts = np.full((n_senones, n_senones), smoothing, np.float64)
    init = np.full((n_senones,), smoothing, np.float64)
    prior = np.full((n_senones,), smoothing, np.float64)
    for al in alignments:
        al = np.asarray(al)
        if len(al) == 0:
            continue
        init[al[0]] += 1
        prior += np.bincount(al, minlength=n_senones)
        changes = al[1:] != al[:-1]
        src = al[:-1][changes]
        dst = al[1:][changes]
        np.add.at(counts, (src, dst), 1.0)
    np.fill_diagonal(counts, 0.0)
    # rows: self-loop mass + (1-self_loop) distributed by bigram counts
    row = counts / counts.sum(1, keepdims=True)
    trans = (1.0 - self_loop) * row
    trans[np.arange(n_senones), np.arange(n_senones)] += self_loop
    return DenominatorGraph(
        log_trans=np.log(trans + 1e-30).astype(np.float32),
        log_init=np.log(init / init.sum()).astype(np.float32),
        log_prior=np.log(prior / prior.sum()).astype(np.float32),
        n_senones=n_senones)


def uniform_graph(n_senones: int, *, self_loop: float = 0.7
                  ) -> DenominatorGraph:
    off = (1.0 - self_loop) / (n_senones - 1)
    trans = np.full((n_senones, n_senones), off, np.float32)
    np.fill_diagonal(trans, self_loop)
    flat = np.full((n_senones,), 1.0 / n_senones, np.float32)
    return DenominatorGraph(np.log(trans), np.log(flat), np.log(flat),
                            n_senones)
