"""Checkpointing: flat-key npz store for arbitrary pytrees + step metadata.

Path-keyed (same path strings as repro.utils.trees), so checkpoints are
robust to container-type changes and partially loadable (e.g. restoring a
teacher's params into a student-shaped tree for distillation init).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.trees import tree_paths


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for p, x in tree_paths(tree):
        a = np.asarray(jax.device_get(x))
        if a.dtype == jnp.bfloat16:       # npz has no bf16: store f32,
            a = a.astype(np.float32)      # load_tree casts back via template
        out[p] = a
    return out


def save_tree(path: str, tree, *, meta: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **{f"t::{k}": v for k, v in flat.items()})
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f)


def load_tree(path: str, like) -> Any:
    """Restore into the structure of `like` (params-shaped template)."""
    z = np.load(path if path.endswith(".npz") else path + ".npz")
    stored = {k[3:]: z[k] for k in z.files if k.startswith("t::")}
    flat, treedef = jax.tree_util.tree_flatten(like)
    paths = [p for p, _ in tree_paths(like)]
    out = []
    for p, template in zip(paths, flat):
        if p not in stored:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = stored[p]
        if tuple(arr.shape) != tuple(template.shape):
            raise ValueError(f"shape mismatch at {p}: ckpt {arr.shape} "
                             f"vs template {template.shape}")
        out.append(jnp.asarray(arr, template.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointStore:
    """<root>/step_<n>.npz rolling store with retention."""

    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def path(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}.npz")

    def save(self, step: int, tree, *, meta: Optional[dict] = None):
        save_tree(self.path(step), tree, meta={"step": step,
                                               **(meta or {})})
        self._gc()

    def steps(self):
        out = []
        for f in os.listdir(self.root):
            m = re.match(r"step_(\d+)\.npz$", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def load(self, like, step: Optional[int] = None):
        step = self.latest() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return load_tree(self.path(step), like), step

    def leaf_shapes(self, step: Optional[int] = None) -> Dict[str, tuple]:
        """{leaf path: stored shape} without materializing the arrays.

        The cross-W resume probe: a Trainer whose strategy was built at
        W_cur can discover the membership a checkpoint was *saved* at
        (leading dim of a worker-stacked leaf) before asking load_tree
        for it — load_tree is strict about shapes by design, so the
        caller must present a template already laid out for the saved W.
        """
        step = self.latest() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        z = np.load(self.path(step))
        return {k[3:]: tuple(z[k].shape) for k in z.files
                if k.startswith("t::")}

    def load_meta(self, step: int) -> Optional[dict]:
        meta = self.path(step) + ".meta.json"
        if not os.path.exists(meta):
            return None
        with open(meta) as f:
            return json.load(f)

    def clear(self):
        """Drop every checkpoint (a completed stage retires its resume
        state so a fresh invocation trains anew)."""
        for s in self.steps():
            os.remove(self.path(s))
            meta = self.path(s) + ".meta.json"
            if os.path.exists(meta):
                os.remove(meta)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            os.remove(self.path(s))
            meta = self.path(s) + ".meta.json"
            if os.path.exists(meta):
                os.remove(meta)
