from repro.checkpoint.store import CheckpointStore, load_tree, save_tree

__all__ = ["CheckpointStore", "save_tree", "load_tree"]
