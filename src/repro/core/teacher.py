"""Teacher-side target generation (paper §3.1-3.2).

The teacher (bidirectional LSTM for the AM; any built model for LLM archs)
runs inference over unlabeled data and emits top-k logits into the
LogitStore.  Generation is embarrassingly parallel over workers — exactly
the property the paper engineered for ("parallelize target generation"):
no decoder, no confidence model, no LM.

All decode loops live in ``repro.serve.StreamingEngine`` and all
multi-worker partitioning / ledger bookkeeping in
``repro.pipeline.generate``; ``TeacherRunner`` is the thin
*single-worker special case*: pre-formed dict batches go through
``engine.forward_topk`` (the trainer's chunked batches), the raw
utterance firehose through ``pipeline.generate_corpus`` over the
engine's bucketed queue.  Cross-worker sharded generation is
``pipeline.generate_sharded`` with one TeacherRunner per worker —
see ``core.ssl_pipeline.stage_targets``.
"""
from __future__ import annotations

from repro.pipeline.generate import generate_corpus


class TeacherRunner:
    def __init__(self, cfg, params, *, k: int = 20, temperature: float = 1.0,
                 policy=None, topk_impl: str = "lax"):
        from repro.serve import THROUGHPUT, StreamingEngine
        self.cfg = cfg
        self.k = k
        self.temperature = temperature
        self.engine = StreamingEngine(cfg, params, k=k,
                                      temperature=temperature,
                                      policy=policy or THROUGHPUT,
                                      topk_impl=topk_impl)
        self.model = self.engine.model
        self.params = params

    def generate(self, batch):
        """One pre-formed batch -> (vals (B,S,k) bf16, idx (B,S,k) int32)."""
        return self.engine.forward_topk(batch)

    # the spelling pipeline.generate duck-types on (engine-or-runner)
    forward_topk = generate

    def generate_to_store(self, store, batches, shard_offset: int = 0,
                          store_wave: int = 0):
        """Pre-formed dict batches -> one store shard each (trainer-aligned
        shard layout: shard i holds batch i's frames)."""
        paths = []
        for i, batch in enumerate(batches):
            vals, idx = self.generate(batch)
            paths.append(store.append_shard(shard_offset + i, vals, idx,
                                            wave=store_wave))
        return paths

    def generate_corpus_to_store(self, store, utterances,
                                 shard_offset: int = 0, wave: int = 0,
                                 store_wave: int = 0):
        """The firehose path — ``pipeline.generate_corpus`` with this
        runner's engine: raw (T, F) utterances -> bucketed batched
        inference -> one shard per utterance, numbered in submission
        order.  ``wave`` is the flush granularity (utterances per
        memory-bounded drain, default one policy batch); ``store_wave``
        the LogitStore generation tag.  Failure contract and streaming
        semantics are documented on ``generate_corpus``.
        """
        return generate_corpus(self.engine, store, utterances,
                               shard_offset=shard_offset, wave_size=wave,
                               store_wave=store_wave)


def make_teacher_config(student_cfg):
    """The paper's teacher: same depth/width but bidirectional (AM case).
    For token LMs the teacher is the same architecture (optionally deeper);
    we default to identical topology — the SSL machinery is agnostic."""
    if student_cfg.family == "lstm_am":
        from repro.configs.lstm_am_7khr import TEACHER
        return TEACHER.replace(
            lstm_hidden=student_cfg.lstm_hidden,
            n_senones=student_cfg.n_senones,
            feat_dim=student_cfg.feat_dim,
            vocab_size=student_cfg.vocab_size)
    return student_cfg
