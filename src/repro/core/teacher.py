"""Teacher-side target generation (paper §3.1-3.2).

The teacher (bidirectional LSTM for the AM; any built model for LLM archs)
runs inference over unlabeled data and emits top-k logits into the
LogitStore.  Generation is embarrassingly parallel over workers — exactly
the property the paper engineered for ("parallelize target generation"):
no decoder, no confidence model, no LM.

All decode loops live in ``repro.serve.StreamingEngine``; this module is
the thin target-generation consumer: pre-formed dict batches go through
``engine.forward_topk`` (the trainer's chunked batches), and the raw
utterance firehose goes through the engine's bucketed queue
(``generate_corpus_to_store``) — the paper's batch-inference-as-a-service
framing.
"""
from __future__ import annotations

from repro.core import logit_store as ls
from repro.serve import THROUGHPUT, BatchPolicy, StreamingEngine


class TeacherRunner:
    def __init__(self, cfg, params, *, k: int = 20, temperature: float = 1.0,
                 policy: BatchPolicy = THROUGHPUT, topk_impl: str = "lax"):
        self.cfg = cfg
        self.k = k
        self.temperature = temperature
        self.engine = StreamingEngine(cfg, params, k=k,
                                      temperature=temperature, policy=policy,
                                      topk_impl=topk_impl)
        self.model = self.engine.model
        self.params = params

    def generate(self, batch):
        """One pre-formed batch -> (vals (B,S,k) bf16, idx (B,S,k) int32)."""
        return self.engine.forward_topk(batch)

    def generate_to_store(self, store: ls.LogitStore, batches,
                          shard_offset: int = 0):
        """Pre-formed dict batches -> one store shard each (trainer-aligned
        shard layout: shard i holds batch i's frames)."""
        paths = []
        for i, batch in enumerate(batches):
            vals, idx = self.generate(batch)
            paths.append(store.write_shard(shard_offset + i, vals, idx))
        return paths

    def generate_corpus_to_store(self, store: ls.LogitStore, utterances,
                                 shard_offset: int = 0,
                                 wave: int = 0):
        """The firehose path: raw (T, F) utterances -> bucketed batched
        inference -> one shard per utterance, numbered in submission
        order.  Returns the shard paths (submission order).

        ``utterances`` may be any iterable (including a generator — the
        1M-hour firehose is streamed, never materialized): work proceeds
        in waves of ``wave`` utterances (default: one policy batch), each
        wave's shards flushed to disk before the next is read, so host
        memory on both the input and output side stays bounded by one
        wave.

        Failure contract: if a wave's forward or a shard write raises,
        retry by re-running the *whole call* with the same corpus and
        shard_offset — shard contents are deterministic, so rewriting
        already-written shards is idempotent.  Each call is
        self-contained: stale work left queued by a failed call is
        discarded up front (its ordinals belong to that call's
        numbering).
        """
        wave = wave or self.engine.policy.max_batch
        self.engine.queue.discard_pending()
        self.engine.queue.pop_completed()
        it = iter(utterances)
        paths = {}
        j = 0
        while True:
            submitted = 0
            for u in it:
                self.engine.submit(u, meta={"ordinal": j})
                j += 1
                submitted += 1
                if submitted == wave:
                    break
            if not submitted:
                break
            for r in self.engine.run().values():
                o = r.meta["ordinal"]
                paths[o] = store.write_shard(
                    shard_offset + o, r.vals[None], r.idx[None],
                    utt_lens=[r.vals.shape[0]])
        return [paths[o] for o in sorted(paths)]


def make_teacher_config(student_cfg):
    """The paper's teacher: same depth/width but bidirectional (AM case).
    For token LMs the teacher is the same architecture (optionally deeper);
    we default to identical topology — the SSL machinery is agnostic."""
    if student_cfg.family == "lstm_am":
        from repro.configs.lstm_am_7khr import TEACHER
        return TEACHER.replace(
            lstm_hidden=student_cfg.lstm_hidden,
            n_senones=student_cfg.n_senones,
            feat_dim=student_cfg.feat_dim,
            vocab_size=student_cfg.vocab_size)
    return student_cfg
