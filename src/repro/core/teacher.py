"""Teacher-side target generation (paper §3.1-3.2).

The teacher (bidirectional LSTM for the AM; any built model for LLM archs)
runs inference over unlabeled batches and emits top-k logits into the
LogitStore.  Generation is embarrassingly parallel over workers — exactly
the property the paper engineered for ("parallelize target generation"):
no decoder, no confidence model, no LM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import logit_store as ls
from repro.models import build_model


class TeacherRunner:
    def __init__(self, cfg, params, *, k: int = 20, temperature: float = 1.0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.k = k
        self.temperature = temperature
        self._fwd = jax.jit(self._forward)

    def _forward(self, params, batch):
        if self.cfg.family == "lstm_am":
            h, _ = self.model.apply(params, batch["feats"])
        elif self.cfg.encoder is not None:
            h, _ = self.model.apply(params, batch["tokens"],
                                    enc_embeds=batch["enc_embeds"])
        else:
            h, _ = self.model.apply(params, batch["tokens"])
        logits = self.model.unembed(params, h) / self.temperature
        return ls.topk_compress(logits, self.k)

    def generate(self, batch):
        """-> (vals (B,S,k) bf16, idx (B,S,k) int32)."""
        return self._fwd(self.params, batch)

    def generate_to_store(self, store: ls.LogitStore, batches,
                          shard_offset: int = 0):
        paths = []
        for i, batch in enumerate(batches):
            vals, idx = self.generate(batch)
            paths.append(store.write_shard(shard_offset + i, vals, idx))
        return paths


def make_teacher_config(student_cfg):
    """The paper's teacher: same depth/width but bidirectional (AM case).
    For token LMs the teacher is the same architecture (optionally deeper);
    we default to identical topology — the SSL machinery is agnostic."""
    if student_cfg.family == "lstm_am":
        from repro.configs.lstm_am_7khr import TEACHER
        return TEACHER.replace(
            lstm_hidden=student_cfg.lstm_hidden,
            n_senones=student_cfg.n_senones,
            feat_dim=student_cfg.feat_dim,
            vocab_size=student_cfg.vocab_size)
    return student_cfg
