"""End-to-end SSL pipeline — the paper's recipe, laptop-scaled.

Stages (paper sections in brackets):
  baseline : student-architecture LSTM AM, CE on labeled data [§2]
  teacher  : bidirectional LSTM AM, CE (+ sMBR) on labeled data [§3.2]
  targets  : teacher inference over the unlabeled firehose -> top-k=20
             logits into the LogitStore [§3.2.2]
  student  : scheduled learning over unlabeled sub-epochs with labeled
             interleaves [§3.3], GTC or BMUF trainer [§3.5]
  smbr     : sequence training on labeled data only [§3.4]

Every stage checkpoints into <out>/ckpt_<stage>; metrics include the
frame-error-rate (FER) on a held-out synthetic VAL set and the relative
FER reduction vs the baseline — the container-scale proxy for the paper's
relative WERR (the paper only ever reports relative numbers).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs.lstm_am_7khr import CONFIG as AM_CONFIG
from repro.configs.base import LayerSpec, Segment
from repro.core import scheduled
from repro.core.logit_store import LogitStore
from repro.core.teacher import TeacherRunner
from repro.data import FeatureConfig, SynthConfig
from repro.data.loader import CorpusLoader
from repro.distributed import bmuf as bmuf_lib
from repro.distributed import gtc as gtc_lib
from repro.launch.steps import (init_opt_state, make_loss_fn,
                                make_train_step)
from repro.models import build_model
from repro.optim import momentum_update
from repro.seqtrain import build_denominator_graph, make_smbr_loss_fn
from repro.seqtrain.smbr import frame_error_rate


@dataclass
class PipelineConfig:
    # data
    n_labeled: int = 48
    n_unlabeled: int = 192
    n_val: int = 16
    n_speakers: int = 16
    n_senones: int = 49
    mean_utt_sec: float = 1.2
    n_mels: int = 16
    # model
    n_layers: int = 2
    lstm_hidden: int = 64
    # training
    batch: int = 8
    chunk_len: int = 32
    epochs_baseline: int = 5
    lr: float = 5e-2
    topk: int = 10
    # schedule (paper-structured, scaled)
    n_sub_epochs: int = 4
    labeled_every: int = 2
    chunked_until: int = 3
    # trainers
    gtc_tau: float = 2e-4
    bmuf_workers: int = 4
    bmuf_block_steps: int = 2
    smbr_epochs: int = 2
    smbr_kappa: float = 0.3
    smbr_lr: float = 5e-3
    seed: int = 0

    @classmethod
    def tiny(cls) -> "PipelineConfig":
        return cls()

    @classmethod
    def small(cls) -> "PipelineConfig":
        return cls(n_labeled=128, n_unlabeled=640, n_val=32, n_speakers=32,
                   n_senones=97, lstm_hidden=128, n_layers=3,
                   epochs_baseline=4, n_sub_epochs=6, labeled_every=2,
                   chunked_until=4)

    @property
    def feat_dim(self) -> int:
        return self.n_mels * 3


class SSLPipeline:
    def __init__(self, pc: PipelineConfig, *, out_dir: str = "experiments/train",
                 student_trainer: str = "gtc"):
        self.pc = pc
        self.out = out_dir
        self.student_trainer = student_trainer
        os.makedirs(out_dir, exist_ok=True)

        self.synth = SynthConfig(n_speakers=pc.n_speakers,
                                 n_senones=pc.n_senones,
                                 mean_utt_sec=pc.mean_utt_sec, seed=pc.seed)
        self.feat = FeatureConfig(n_mels=pc.n_mels)
        # look-ahead 0 at laptop scale: the label-shift mechanism itself is
        # exercised by tests/test_data.py; a 30-90ms output delay is not
        # learnable by a 2x64 LSTM on minutes of audio (the paper's value
        # of 3 is one config knob away)
        self.loader = CorpusLoader(synth=self.synth, feat=self.feat,
                                   lookahead=0)
        self.loader.estimate_mvn(min(24, pc.n_labeled))

        base = AM_CONFIG.replace(
            segments=(Segment((LayerSpec(mixer="lstm", ffn="none"),),
                              repeat=pc.n_layers),),
            lstm_hidden=pc.lstm_hidden, n_senones=pc.n_senones,
            vocab_size=pc.n_senones, feat_dim=pc.feat_dim)
        self.student_cfg = base
        self.teacher_cfg = base.replace(
            name="teacher",
            segments=(Segment((LayerSpec(mixer="bilstm", ffn="none"),),
                              repeat=pc.n_layers),))

        # utterance-id ranges: labeled / unlabeled / val are disjoint
        self.rng_labeled = (0, pc.n_labeled)
        self.rng_unlabeled = (10_000, pc.n_unlabeled)
        self.rng_val = (100_000, pc.n_val)
        self._val_batch = None

    # ------------------------------------------------------------- helpers

    def _batches(self, rng, *, chunked: bool, offset: int = 0, seed: int = 0):
        start, count = rng
        if chunked:
            return list(self.loader.chunked_batches(
                start, count, batch_size=self.pc.batch,
                chunk_len=self.pc.chunk_len, offset=offset, seed=seed))
        return list(self.loader.full_seq_batches(
            start, count, batch_size=max(2, self.pc.batch // 2),
            offset=offset))

    def val_batch(self):
        if self._val_batch is None:
            bs = self._batches(self.rng_val, chunked=False)
            self._val_batch = {k: jnp.asarray(v) for k, v in bs[0].items()}
        return self._val_batch

    def fer(self, cfg, params) -> float:
        model = build_model(cfg)
        vb = self.val_batch()
        h, _ = model.apply(params, vb["feats"])
        logits = model.unembed(params, h)
        return float(frame_error_rate(logits, vb["labels"], vb["mask"]))

    def _train_ce(self, cfg, params, batches_per_epoch, n_epochs, lr,
                  label=""):
        model = build_model(cfg)
        step = jax.jit(make_train_step(model, cfg, loss_kind="ce", lr=lr))
        opt = init_opt_state(params)
        losses = []
        for ep in range(n_epochs):
            for b in batches_per_epoch(ep):
                bj = {k: jnp.asarray(v) for k, v in b.items()}
                params, opt, m = step(params, opt, bj)
                losses.append(float(m["loss"]))
        return params, losses

    def _ckpt(self, stage) -> CheckpointStore:
        return CheckpointStore(os.path.join(self.out, f"ckpt_{stage}"))

    def _load_or_none(self, stage, cfg):
        store = self._ckpt(stage)
        model = build_model(cfg)
        like = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        like = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), like)
        try:
            params, _ = store.load(like)
            return params
        except FileNotFoundError:
            return None

    # -------------------------------------------------------------- stages

    def stage_baseline(self) -> Dict:
        pc = self.pc
        model = build_model(self.student_cfg)
        params = model.init(jax.random.key(pc.seed))
        params, losses = self._train_ce(
            self.student_cfg, params,
            lambda ep: self._batches(self.rng_labeled, chunked=True,
                                     offset=ep % 3, seed=ep),
            pc.epochs_baseline, pc.lr)
        # full-sequence fine-tune (paper: 2 epochs full-seq CE)
        params, losses2 = self._train_ce(
            self.student_cfg, params,
            lambda ep: self._batches(self.rng_labeled, chunked=False),
            1, pc.lr * 0.3)
        self._ckpt("baseline").save(0, params)
        fer = self.fer(self.student_cfg, params)
        return {"loss_first": losses[0], "loss_last": losses2[-1],
                "val_fer": fer}

    def stage_teacher(self) -> Dict:
        pc = self.pc
        model = build_model(self.teacher_cfg)
        params = model.init(jax.random.key(pc.seed + 1))
        params, losses = self._train_ce(
            self.teacher_cfg, params,
            lambda ep: self._batches(self.rng_labeled, chunked=True,
                                     offset=ep % 3, seed=100 + ep),
            pc.epochs_baseline, pc.lr)
        params, losses2 = self._train_ce(
            self.teacher_cfg, params,
            lambda ep: self._batches(self.rng_labeled, chunked=False),
            1, pc.lr * 0.3)
        # sMBR fine-tune of the teacher (paper's "with sMBR teacher" arm)
        graph = self._graph()
        smbr_loss = make_smbr_loss_fn(model, self.teacher_cfg, graph,
                                      kappa=pc.smbr_kappa)

        def smbr_step(params, opt, batch):
            (_, m), g = jax.value_and_grad(smbr_loss, has_aux=True)(
                params, batch)
            params, opt = momentum_update(params, g, opt, lr=pc.smbr_lr)
            return params, opt, m

        step = jax.jit(smbr_step)
        opt = init_opt_state(params)
        for b in self._batches(self.rng_labeled, chunked=False):
            bj = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, m = step(params, opt, bj)
        self._ckpt("teacher").save(0, params)
        return {"loss_last": losses2[-1],
                "val_fer": self.fer(self.teacher_cfg, params),
                "smbr_eacc": float(m["expected_frame_acc"])}

    def _graph(self):
        pairs = self.loader.featurized(*self.rng_labeled)
        return build_denominator_graph([l for _, l, _ in pairs],
                                       self.pc.n_senones)

    def stage_targets(self) -> Dict:
        pc = self.pc
        tparams = self._load_or_none("teacher", self.teacher_cfg)
        assert tparams is not None, "run stage teacher first"
        runner = TeacherRunner(self.teacher_cfg, tparams, k=pc.topk)
        store = LogitStore(os.path.join(self.out, "logit_store"),
                           k=pc.topk, vocab=pc.n_senones)
        batches = self._batches(self.rng_unlabeled, chunked=True, seed=7)
        paths = runner.generate_to_store(
            store, ({"feats": jnp.asarray(b["feats"]),
                     "mask": jnp.asarray(b["mask"])} for b in batches))
        meta = store.stats()
        full = meta.n_frames * pc.n_senones * 4
        packed = meta.n_frames * (pc.topk * 6)
        return {"n_shards": len(paths), "n_frames": meta.n_frames,
                "storage_compression_x": round(full / packed, 1)}

    def stage_student(self) -> Dict:
        """Scheduled learning on unlabeled top-k targets + labeled passes."""
        pc = self.pc
        baseline = self._load_or_none("baseline", self.student_cfg)
        assert baseline is not None, "run stage baseline first"
        store = LogitStore(os.path.join(self.out, "logit_store"),
                           k=pc.topk, vocab=pc.n_senones)
        unl_batches = self._batches(self.rng_unlabeled, chunked=True, seed=7)
        shards = store.shards()
        assert len(shards) == len(unl_batches), "regenerate targets"

        sched = scheduled.ScheduleConfig(
            n_sub_epochs=pc.n_sub_epochs, sub_epoch_hours=1.0,
            labeled_every=pc.labeled_every, chunked_until=pc.chunked_until,
            lr0=pc.lr, labeled_lr_boost=1.5)
        model = build_model(self.student_cfg)
        params = baseline
        per_sub = max(1, len(unl_batches) // pc.n_sub_epochs)

        if self.student_trainer == "bmuf":
            return self._student_bmuf(params, sched, unl_batches, store,
                                      per_sub)

        step_d = jax.jit(make_train_step(model, self.student_cfg,
                                         loss_kind="distill_topk",
                                         lr=pc.lr), static_argnames=())
        losses = []
        opt = init_opt_state(params)
        for phase in scheduled.schedule(sched):
            if phase.kind == "unlabeled":
                lo = (phase.sub_epoch - 1) * per_sub
                for bi in range(lo, min(lo + per_sub, len(unl_batches))):
                    b = unl_batches[bi]
                    vals, idx = store.read_shard(bi)
                    bj = {"feats": jnp.asarray(b["feats"]),
                          "mask": jnp.asarray(b["mask"]),
                          "topk_vals": vals, "topk_idx": idx}
                    params, opt, m = self._lr_step(step_d, params, opt, bj,
                                                   phase.lr)
                    losses.append(float(m["loss"]))
            else:
                step_l = jax.jit(make_train_step(
                    model, self.student_cfg, loss_kind="ce", lr=phase.lr))
                for b in self._batches(self.rng_labeled,
                                       chunked=phase.chunked,
                                       offset=max(phase.feature_offset, 0)):
                    bj = {k: jnp.asarray(v) for k, v in b.items()}
                    params, opt, m = step_l(params, opt, bj)
                    losses.append(float(m["loss"]))
        self._ckpt(f"student_{self.student_trainer}").save(0, params)
        return self._student_metrics(params, losses)

    def _lr_step(self, step, params, opt, batch, lr):
        # steps are jitted with a fixed lr; re-jitting per phase is fine at
        # this scale — production uses the lr-as-argument variant
        return step(params, opt, batch)

    def _student_bmuf(self, params, sched, unl_batches, store, per_sub):
        """BMUF student (paper's 64-GPU arm, W workers here)."""
        pc = self.pc
        model = build_model(self.student_cfg)
        bc = bmuf_lib.BMUFConfig(n_workers=pc.bmuf_workers,
                                 block_steps=pc.bmuf_block_steps)
        train_step = make_train_step(model, self.student_cfg,
                                     loss_kind="distill_topk", lr=pc.lr)
        block = jax.jit(bmuf_lib.make_bmuf_block_step(train_step, bc))
        state = bmuf_lib.bmuf_init(params, bc)
        opt1 = init_opt_state(params)
        opts = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (bc.n_workers,) + x.shape).copy(),
            opt1)
        losses = []
        need = bc.block_steps * bc.n_workers
        for phase in scheduled.schedule(sched):
            if phase.kind != "unlabeled":
                continue
            lo = (phase.sub_epoch - 1) * per_sub
            group = []
            for bi in range(lo, min(lo + per_sub, len(unl_batches))):
                b = unl_batches[bi]
                vals, idx = store.read_shard(bi)
                group.append({"feats": jnp.asarray(b["feats"]),
                              "mask": jnp.asarray(b["mask"]),
                              "topk_vals": vals, "topk_idx": idx})
                if len(group) == need:
                    batches = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs).reshape(
                            bc.block_steps, bc.n_workers, *xs[0].shape),
                        *group)
                    state, opts, ms = block(state, opts, batches)
                    losses.append(float(jnp.mean(ms["loss"])))
                    group = []
        params = state["theta_g"]
        self._ckpt("student_bmuf").save(0, params)
        return self._student_metrics(params, losses)

    def _student_metrics(self, params, losses):
        fer = self.fer(self.student_cfg, params)
        base = self._load_or_none("baseline", self.student_cfg)
        base_fer = self.fer(self.student_cfg, base)
        return {"n_steps": len(losses),
                "loss_first": losses[0] if losses else None,
                "loss_last": losses[-1] if losses else None,
                "val_fer": fer, "baseline_fer": base_fer,
                "rel_fer_reduction_pct":
                    round(100 * (base_fer - fer) / max(base_fer, 1e-9), 2)}

    def stage_smbr(self) -> Dict:
        """Sequence training of the SSL student on labeled data only."""
        pc = self.pc
        stage = f"student_{self.student_trainer}"
        params = self._load_or_none(stage, self.student_cfg)
        if params is None:
            params = self._load_or_none("baseline", self.student_cfg)
        model = build_model(self.student_cfg)
        graph = self._graph()
        loss_fn = make_smbr_loss_fn(model, self.student_cfg, graph,
                                    kappa=pc.smbr_kappa)
        gc = gtc_lib.GTCConfig(tau=pc.gtc_tau, n_workers=1)
        gtc_state = gtc_lib.gtc_init(params)
        opt = init_opt_state(params)

        def step(params, opt, gtc_state, batch):
            (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            send, res = gtc_lib.compress_tree(g, gtc_state["residual"],
                                              pc.gtc_tau)
            params, opt = momentum_update(params, send, opt, lr=pc.smbr_lr)
            return params, opt, {"residual": res}, m

        jstep = jax.jit(step)
        eaccs = []
        for _ in range(pc.smbr_epochs):
            for b in self._batches(self.rng_labeled, chunked=False):
                bj = {k: jnp.asarray(v) for k, v in b.items()}
                params, opt, gtc_state, m = jstep(params, opt, gtc_state, bj)
                eaccs.append(float(m["expected_frame_acc"]))
        self._ckpt("smbr").save(0, params)
        fer = self.fer(self.student_cfg, params)
        base = self._load_or_none("baseline", self.student_cfg)
        base_fer = self.fer(self.student_cfg, base)
        return {"eacc_first": eaccs[0], "eacc_last": eaccs[-1],
                "val_fer": fer, "baseline_fer": base_fer,
                "rel_fer_reduction_pct":
                    round(100 * (base_fer - fer) / max(base_fer, 1e-9), 2)}

    # ----------------------------------------------------------------- run

    def run(self, stage: str = "all") -> Dict:
        if stage != "all":
            return getattr(self, f"stage_{stage}")()
        out = {}
        for s in ("baseline", "teacher", "targets", "student", "smbr"):
            out[s] = getattr(self, f"stage_{s}")()
            print(f"[pipeline] {s}: {out[s]}")
        return out
